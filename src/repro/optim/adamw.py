"""AdamW with global-norm clipping, decoupled weight decay and the paper's
frozen-exponent projection hook (optax is not available offline; this is the
framework's native optimizer).

Moments are stored in fp32 with the same sharding specs as the parameters
(ZeRO-3 style full sharding under the production mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def _decay_mask(path) -> bool:
    """Decay 2-D+ matrices only (no norms/biases/decay vectors)."""
    return True


def adamw_update(grads, opt_state, params, lr, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        update = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (update + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def make_lr_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, s / jnp.maximum(warmup, 1))
        t = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, warm, jnp.maximum(cos, base_lr * 0.1))
    return lr
