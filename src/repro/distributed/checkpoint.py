"""Checkpointing: atomic, manifest-based, async, resharding-safe.

Layout (one directory per step)::

    <dir>/step_00000123/
        manifest.json     # leaf paths, shapes, dtypes, framework metadata
        arrays.npz        # one entry per leaf, keyed by escaped path
    <dir>/LATEST          # atomically-updated pointer file

Design notes for fleet scale (documented; single-process here):
  * arrays are stored in *logical* (unsharded) layout keyed by pytree path, so
    restore works onto any mesh — elastic resharding is a ``device_put`` with
    the new shardings, no format change;
  * on a multi-host fleet each host writes only the shards it owns
    (``arrays.<process_index>.npz``) and the manifest records the index map —
    the same atomic-rename protocol applies per host, with host 0 committing
    the step directory after a barrier;
  * saves are ASYNC: the train loop hands off host copies to a writer thread
    and keeps stepping (checkpoint time hides behind compute).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np

_SEP = "//"


def _flatten(tree) -> Tuple[dict, Any]:
    paths_leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)
    flat = {}
    for path, leaf in paths_leaves[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat, paths_leaves[1]


def save(state, step: int, directory: str) -> str:
    """Synchronous atomic save. Returns the committed step directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, _ = _flatten(state)
    arrays, manifest = {}, {"step": step, "leaves": {}, "time": time.time()}
    for key, leaf in flat.items():
        if leaf is None:
            manifest["leaves"][key] = {"none": True}
            continue
        arr = np.asarray(leaf)
        arrays[key] = arr
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic commit
    _write_latest(directory, step)
    return final


def _write_latest(directory: str, step: int) -> None:
    tmp = os.path.join(directory, "LATEST.tmp")
    with open(tmp, "w") as f:
        f.write(str(step))
    os.replace(tmp, os.path.join(directory, "LATEST"))


def latest_step(directory: str) -> Optional[int]:
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            return int(f.read().strip())
    except (FileNotFoundError, ValueError):
        return None


def restore(target, directory: str, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``target`` (abstract or concrete pytree).

    ``shardings``: optional matching pytree of NamedShardings — arrays are
    ``device_put`` onto it (this is where elastic resharding happens)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    stepdir = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(stepdir, "arrays.npz"))

    flat_t, treedef = _flatten(target)
    out = []
    for key, leaf in flat_t.items():
        if leaf is None:
            out.append(None)
        else:
            arr = data[key]
            out.append(arr)
    restored = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        restored = jax.tree_util.tree_map(
            lambda a, s: a if a is None else jax.device_put(a, s),
            restored, shardings, is_leaf=lambda x: x is None)
    return restored, step


class AsyncCheckpointer:
    """Background writer thread; ``save_async`` returns immediately."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._exc: Optional[BaseException] = None
        self._worker.start()

    def save_async(self, state, step: int) -> None:
        if self._exc:
            raise self._exc
        host_state = jax.tree_util.tree_map(
            lambda x: None if x is None else np.asarray(x), state,
            is_leaf=lambda x: x is None)
        self._q.put((host_state, step))

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                state, step = item
                save(state, step, self.directory)
                self._gc()
            except BaseException as e:   # surfaced on next save_async/wait
                self._exc = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        """Block until all queued saves are committed."""
        self._q.join()
        if self._exc:
            raise self._exc

    def close(self):
        self._q.put(None)
        self._worker.join(timeout=30)
        if self._exc:
            raise self._exc
