"""Elastic scaling + straggler mitigation.

On a real fleet the coordinator runs on host 0: workers heartbeat over the
control plane; a missed deadline marks the host failed, the run drains, the
mesh is rebuilt over the survivors and the last checkpoint is restored with
the new shardings (checkpoints are stored in logical layout — resharding is a
``device_put``, see ``distributed/checkpoint.py``). In this container the
control plane is simulated (tests drive ``heartbeat``/``check`` directly),
but the decision logic — the part that must be correct — is real.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax


@dataclasses.dataclass
class HostState:
    last_beat: float
    healthy: bool = True


class ElasticCoordinator:
    """Tracks host liveness and proposes mesh reconfigurations."""

    def __init__(self, hosts: List[str], model_axis: int,
                 heartbeat_timeout: float = 60.0, clock=time.monotonic):
        self.clock = clock
        self.timeout = heartbeat_timeout
        self.model_axis = model_axis
        self.hosts: Dict[str, HostState] = {
            h: HostState(last_beat=self.clock()) for h in hosts}
        self.generation = 0

    def heartbeat(self, host: str) -> None:
        if host in self.hosts:
            self.hosts[host].last_beat = self.clock()

    def check(self) -> List[str]:
        """Mark hosts that missed the deadline; returns newly-failed hosts."""
        now = self.clock()
        failed = []
        for name, st in self.hosts.items():
            if st.healthy and now - st.last_beat > self.timeout:
                st.healthy = False
                failed.append(name)
        return failed

    @property
    def healthy_hosts(self) -> List[str]:
        return [h for h, st in self.hosts.items() if st.healthy]

    def propose_data_axis(self, devices_per_host: int) -> int:
        """Largest power-of-two data-parallel extent the survivors support.

        The model axis is fixed (TP degree is architectural); the data axis
        shrinks to the largest power of two that the remaining devices can
        fill — a 1000-node fleet losing 3 hosts drops at most half its DP
        width, and usually nothing (spares fill in first on real fleets)."""
        devices = len(self.healthy_hosts) * devices_per_host
        usable = devices // self.model_axis
        dp = 1
        while dp * 2 <= usable:
            dp *= 2
        return dp

    def reconfigure(self, devices_per_host: int):
        """-> (new generation id, new data axis extent)."""
        self.generation += 1
        return self.generation, self.propose_data_axis(devices_per_host)


@dataclasses.dataclass
class StragglerWatchdog:
    """EWMA step-time watchdog (straggler mitigation trigger).

    A step slower than ``factor`` x the EWMA flags a straggler; the train
    loop reports it to the elastic coordinator (on fleets this evicts or
    deprioritizes the slow host — the same drain/reshard path as a failure).
    """

    factor: float = 3.0
    alpha: float = 0.1
    ewma: Optional[float] = None
    flagged: int = 0

    def observe(self, step_time: float) -> bool:
        if self.ewma is None:
            self.ewma = step_time
            return False
        is_straggler = step_time > self.factor * self.ewma
        if is_straggler:
            self.flagged += 1
        else:  # stragglers don't poison the baseline estimate
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return is_straggler
