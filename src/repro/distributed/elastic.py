"""Elastic scaling + straggler mitigation.

On a real fleet the coordinator runs on host 0: workers heartbeat over the
control plane; a missed deadline marks the host failed, the run drains, the
mesh is rebuilt over the survivors and the last checkpoint is restored with
the new shardings (checkpoints are stored in logical layout — resharding is a
``device_put``, see ``distributed/checkpoint.py``). In this container the
control plane is simulated (tests drive ``heartbeat``/``check`` directly),
but the decision logic — the part that must be correct — is real.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax


@dataclasses.dataclass
class HostState:
    last_beat: float
    healthy: bool = True


class ElasticCoordinator:
    """Tracks host liveness and proposes mesh reconfigurations.

    A fresh heartbeat from a host previously marked failed RE-ADMITS it (the
    fleet router's drain/re-admit cycle): ``heartbeat`` flips it back to
    healthy and records it for ``drain_recovered`` so the router can resume
    admission. ``mark_failed`` forces the failure decision without waiting
    out the timeout (deterministic drains in tests and simulated outages).
    """

    def __init__(self, hosts: List[str], model_axis: int,
                 heartbeat_timeout: float = 60.0, clock=time.monotonic):
        assert model_axis >= 1, f"model_axis must be >= 1, got {model_axis}"
        self.clock = clock
        self.timeout = heartbeat_timeout
        self.model_axis = model_axis
        self.hosts: Dict[str, HostState] = {
            h: HostState(last_beat=self.clock()) for h in hosts}
        self.generation = 0
        self._recovered: List[str] = []

    def heartbeat(self, host: str) -> None:
        if host not in self.hosts:
            return
        st = self.hosts[host]
        st.last_beat = self.clock()
        if not st.healthy:          # back from the dead: re-admit
            st.healthy = True
            self._recovered.append(host)

    def check(self) -> List[str]:
        """Mark hosts that missed the deadline; returns newly-failed hosts."""
        now = self.clock()
        failed = []
        for name, st in self.hosts.items():
            if st.healthy and now - st.last_beat > self.timeout:
                st.healthy = False
                failed.append(name)
        return failed

    def mark_failed(self, host: str) -> bool:
        """Force-fail a host (simulated outage / operator drain). Returns
        True if the host was healthy before."""
        st = self.hosts.get(host)
        if st is None or not st.healthy:
            return False
        st.healthy = False
        return True

    def drain_recovered(self) -> List[str]:
        """Hosts that heartbeat back to life since the last call."""
        out, self._recovered = self._recovered, []
        return out

    @property
    def healthy_hosts(self) -> List[str]:
        return [h for h, st in self.hosts.items() if st.healthy]

    def propose_data_axis(self, devices_per_host: int) -> int:
        """Largest power-of-two data-parallel extent the survivors support.

        The model axis is fixed (TP degree is architectural); the data axis
        shrinks to the largest power of two that the remaining devices can
        fill — a 1000-node fleet losing 3 hosts drops at most half its DP
        width, and usually nothing (spares fill in first on real fleets).
        Returns 0 when the survivors cannot fill even one model group (no
        survivors, or model_axis exceeds the surviving device count) — the
        run cannot continue and the caller must hold for re-admission."""
        assert devices_per_host >= 1, devices_per_host
        devices = len(self.healthy_hosts) * devices_per_host
        usable = devices // self.model_axis
        if usable < 1:
            return 0
        dp = 1
        while dp * 2 <= usable:
            dp *= 2
        return dp

    def reconfigure(self, devices_per_host: int):
        """-> (new generation id, new data axis extent). A data axis of 0
        means no viable mesh exists over the survivors."""
        self.generation += 1
        return self.generation, self.propose_data_axis(devices_per_host)


@dataclasses.dataclass
class StragglerWatchdog:
    """EWMA step-time watchdog (straggler mitigation trigger).

    A step slower than ``factor`` x the EWMA flags a straggler; the train
    loop reports it to the elastic coordinator (on fleets this evicts or
    deprioritizes the slow host — the same drain/reshard path as a failure).
    """

    factor: float = 3.0
    alpha: float = 0.1
    ewma: Optional[float] = None
    flagged: int = 0

    def observe(self, step_time: float) -> bool:
        if self.ewma is None:
            self.ewma = step_time
            return False
        is_straggler = step_time > self.factor * self.ewma
        if is_straggler:
            self.flagged += 1
        else:  # stragglers don't poison the baseline estimate
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return is_straggler
