"""Int8 error-feedback gradient compression (cross-pod DCN traffic reducer).

Per-tensor symmetric int8 quantization with an error-feedback accumulator
(EF-SGD): the quantization residual is added back into the next step's
gradient, preserving convergence. On a real fleet the int8 payload is what
crosses the pod-to-pod DCN all-reduce (4x fewer bytes than fp32); here the
quantize->dequantize pair is applied in-graph so the numerics (and the tests)
are identical to the deployed path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, ef_error):
    """grads+EF -> int8 roundtrip -> (decompressed grads, new EF residual)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), x - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef_error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, new_e
