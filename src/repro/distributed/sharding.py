"""Sharding rules: logical axes -> mesh axes (DP / FSDP / TP / SP / EP).

The framework uses GSPMD via ``jax.jit`` + ``with_sharding_constraint``; this
module is the single place where logical tensor axes are mapped onto the
production mesh ``("pod", "data", "model")`` (multi-pod) / ``("data","model")``
(single-pod):

* ``batch``   -> ("pod", "data")   — data parallelism (pod = outer DP axis)
* ``seq``     -> "model"           — sequence parallelism for the residual
                                     stream between layers (activations of the
                                     scanned layer stack are sharded both ways)
* ``heads`` / ``ff`` / ``vocab`` / ``experts`` -> "model"  — tensor/expert par.
* ``fsdp``    -> "data"            — parameters, Adam moments and master
                                     weights are fully sharded (ZeRO-3 style)

A module-level "current mesh" keeps model code mesh-agnostic: with no mesh set
(CPU unit tests) every constraint is the identity.
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT_MESH: Optional[Mesh] = None
_SEQ_SHARD: bool = True   # sequence parallelism on the residual stream


def set_mesh(mesh: Optional[Mesh], seq_shard: bool = True) -> None:
    global _CURRENT_MESH, _SEQ_SHARD
    _CURRENT_MESH = mesh
    _SEQ_SHARD = seq_shard


def get_mesh() -> Optional[Mesh]:
    return _CURRENT_MESH


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], seq_shard: bool = True):
    prev, prev_sp = _CURRENT_MESH, _SEQ_SHARD
    set_mesh(mesh, seq_shard)
    try:
        yield
    finally:
        set_mesh(prev, prev_sp)


def _axes() -> Tuple[str, ...]:
    return tuple(_CURRENT_MESH.axis_names) if _CURRENT_MESH is not None else ()


def batch_axes():
    ax = _axes()
    got = tuple(a for a in ("pod", "data") if a in ax)
    return got if got else None


def model_axis():
    return "model" if "model" in _axes() else None


def seq_axis():
    return "model" if (_SEQ_SHARD and "model" in _axes()) else None


def logical(*names) -> P:
    """Build a PartitionSpec from logical axis names (None passes through)."""
    table = {
        "batch": batch_axes(),
        "seq": seq_axis(),
        "heads": model_axis(),
        "kv_heads": model_axis(),
        "kv_seq": model_axis(),   # flash-decoding: cache sharded over sequence
        "ff": model_axis(),
        "vocab": model_axis(),
        "experts": model_axis(),
        "fsdp": "data" if "data" in _axes() else None,
        None: None,
    }
    return P(*[table[n] for n in names])


def axis_size(name: str) -> int:
    if _CURRENT_MESH is None or name not in _axes():
        return 1
    return _CURRENT_MESH.shape[name]


def sanitize_spec(mesh, spec: P, shape) -> P:
    """Drop spec axes whose dimension is not divisible by the mesh extent.

    jit in_shardings (unlike constraints) require exact divisibility — e.g. a
    GQA cache with kv=8 cannot be head-sharded on a 16-way model axis, and
    batch=1 (long_500k) cannot be data-sharded.
    """
    out = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            out.append(None)
            continue
        axes = ax if isinstance(ax, (tuple, list)) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if shape[i] % size == 0 else None)
    return P(*out)


def shard(x, *names):
    """Apply a logical sharding constraint (identity when no mesh is set).

    Outside a trace, ``with_sharding_constraint`` degenerates to a
    ``device_put``, which (unlike in-jit constraints) demands exact
    divisibility — so eager calls drop spec axes the concrete shape cannot
    split (e.g. a batch of 1 on an 8-way "data" axis in an eager serve)."""
    if _CURRENT_MESH is None:
        return x
    spec = logical(*names)
    if not isinstance(x, jax.core.Tracer):
        spec = sanitize_spec(_CURRENT_MESH, spec, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CURRENT_MESH, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules (name-based).
#
# Leaf path names follow the model zoo's conventions. ``tail`` is the spec of
# the trailing dims; leading dims (e.g. the scan-stacked layer axis) get None.
# ---------------------------------------------------------------------------

_PARAM_RULES = [
    # embeddings (order matters: "embed$" would also match "unembed")
    (r"unembed$",          ("fsdp", "vocab")),
    (r"(^|/)embed$",       ("vocab", "fsdp")),
    # attention (merged-head 2-D layouts [D, H*hd] / [H*hd, D])
    (r"(wq|wk|wv|wkv)$",   ("fsdp", "heads")),
    (r"wo$",               ("heads", "fsdp")),
    # dense mlp
    (r"(w_gate|w_in|w_up)$", ("fsdp", "ff")),
    (r"w_out$",            ("ff", "fsdp")),
    # MoE: experts on "model" (EP); router replicated over model
    (r"moe_win$",          ("experts", "fsdp", None)),
    (r"moe_wgate$",        ("experts", "fsdp", None)),
    (r"moe_wout$",         ("experts", None, "fsdp")),
    (r"router$",           ("fsdp", None)),
    # rwkv6 / rg-lru projections
    (r"(w_r|w_k|w_v|w_g|w_x|w_gate_br)$", ("fsdp", "heads")),
    (r"(w_o|w_down)$",     ("heads", "fsdp")),
    # small lora/mix/decay/norm/bias params: replicated (negligible bytes)
]


def param_spec(path: str, ndim: int) -> P:
    for pattern, tail in _PARAM_RULES:
        if re.search(pattern, path):
            tail_spec = logical(*tail)
            if len(tail_spec) > ndim:   # e.g. 2-D rule on 1-D leaf
                break
            return P(*((None,) * (ndim - len(tail_spec)) + tuple(tail_spec)))
    return P(*((None,) * ndim))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params) -> object:
    """Pytree of PartitionSpecs matching ``params`` (by leaf path rules)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(_path_str(path), leaf.ndim), params)


def param_shardings(mesh: Mesh, params):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_specs(params),
        is_leaf=lambda x: isinstance(x, P))
