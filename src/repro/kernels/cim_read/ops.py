"""jit'd public wrappers for the fused decode-on-read matmul.

``cim_linear_store`` is the serving-path integration point: it consumes a
packed :class:`repro.core.cim.CIMStore` directly (mantissa plane + packed
codeword / exponent / sign words), pads every operand to tile boundaries, and
launches the fused Pallas kernel — decoded fp16 weight matrices never
materialize in HBM. Inputs that the kernel cannot tile (``per_weight``
protection, non-fp16 formats) fall back to the reference path; callers can
assert the kernel route actually ran via ``with_info=True``.

``interpret`` defaults to True off-TPU (this container validates the kernel
body on CPU); on a TPU runtime pass ``interpret=False`` for the Mosaic path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core import cim as cim_lib
from repro.core import faultmodels as fm_lib
from repro.kernels.cim_read.kernel import (SCALAR_M_LEN, SCALAR_M_THR,
                                           SCALAR_THR_MAN, SCALAR_THR_META,
                                           cim_read_matmul_one4n,
                                           cim_read_matmul_raw)
from repro.kernels.cim_read.ref import cim_read_ref  # noqa: F401


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return math.ceil(x / m) * m


def _pad2(a, r, c):
    return jnp.pad(a, ((0, r - a.shape[0]), (0, c - a.shape[1])))


def make_scalars(seeds=None, thr_man=0, thr_meta=0, off_k=0, off_j=0,
                 model=None) -> jnp.ndarray:
    """SMEM scalar vector for the fused kernel (see kernel.SCALAR_*).

    ``seeds`` is a :func:`repro.core.cim.plane_seeds` dict; zero thresholds
    mean static serving (no in-kernel flips are drawn on that field).
    ``off_k``/``off_j`` place a mesh shard's plane block at its global store
    coordinates (:func:`cim_linear_store_sharded` sets them per shard); zero
    offsets are the single-device image. ``model`` (a
    :class:`~repro.core.faultmodels.FaultProcess`) fills the fault-model
    parameter slots — its static kind/axis travel separately (the ``model=``
    argument of the kernel wrappers), so sweeping a rate or run length never
    recompiles.
    """
    z = jnp.uint32(0)
    seeds = seeds or {}
    m_thr, m_len = fm_lib.model_scalars(model)
    return jnp.stack([
        jnp.asarray(thr_man, jnp.uint32),
        jnp.asarray(thr_meta, jnp.uint32),
        jnp.asarray(seeds.get("man", z), jnp.uint32),
        jnp.asarray(seeds.get("meta", z), jnp.uint32),
        jnp.asarray(seeds.get("cw", z), jnp.uint32),
        jnp.asarray(off_k, jnp.uint32),
        jnp.asarray(off_j, jnp.uint32),
        jnp.asarray(m_thr, jnp.uint32),
        jnp.asarray(m_len, jnp.uint32),
    ])


@functools.partial(jax.jit, static_argnames=(
    "codec", "n_group", "man_bits", "exp_bits", "bias", "store_g", "store_j",
    "block_m", "block_n", "block_k", "dynamic", "hoist", "interpret",
    "model_kind", "model_axis"))
def _one4n_call(x, man, cw, scalars, *, codec, n_group, man_bits, exp_bits,
                bias, store_g, store_j, block_m, block_n, block_k, dynamic,
                hoist, interpret, model_kind="iid", model_axis="row"):
    return cim_read_matmul_one4n(
        x, man, cw, scalars, codec=codec, n_group=n_group, man_bits=man_bits,
        exp_bits=exp_bits, bias=bias, store_g=store_g, store_j=store_j,
        block_m=block_m, block_n=block_n, block_k=block_k, dynamic=dynamic,
        hoist=hoist, interpret=interpret, model_kind=model_kind,
        model_axis=model_axis)


@functools.partial(jax.jit, static_argnames=(
    "n_group", "man_bits", "exp_bits", "bias", "store_k", "store_j",
    "block_m", "block_n", "block_k", "dynamic", "hoist", "interpret",
    "model_kind", "model_axis"))
def _raw_call(x, man, exp, signw, scalars, *, n_group, man_bits, exp_bits,
              bias, store_k, store_j, block_m, block_n, block_k, dynamic,
              hoist, interpret, model_kind="iid", model_axis="row"):
    return cim_read_matmul_raw(
        x, man, exp, signw, scalars, n_group=n_group, man_bits=man_bits,
        exp_bits=exp_bits, bias=bias, store_k=store_k, store_j=store_j,
        block_m=block_m, block_n=block_n, block_k=block_k, dynamic=dynamic,
        hoist=hoist, interpret=interpret, model_kind=model_kind,
        model_axis=model_axis)


# Default per-call VMEM budget for tile selection: real TPU cores have
# ~16 MiB of VMEM; half of it is left for the pipelined plane windows, the
# activation tile and the accumulator.
DEFAULT_VMEM_BUDGET = 8 * 2 ** 20


def resolve_tiles(store, m: int, *, block_m=None, block_n=None, block_k=None,
                  hoist=None, vmem_budget: int = DEFAULT_VMEM_BUDGET):
    """Grid selection for one store shape -> ``(bm, bn, bk, hoist)``.

    ``None`` block sizes are **autotuned** per store shape; explicit values
    reproduce the legacy fixed-tile behaviour (snapped to the layout quanta:
    ``bn`` covers whole row_weights groups, ``bk`` whole exponent blocks and
    sign words). The autotune policy, validated by ``kernel_bench``:

    * ``bk`` prefers **full K** (one decode pass per plane tile — the
      K-revisit refold the decode hoist exists to kill simply never happens —
      and a single-K-step grid keeps the accumulation order of a plain
      ``x @ w`` matmul, which the bit-identity test matrix relies on),
      shrinking in layout quanta only when the decoded [bk, bn] strip would
      blow the VMEM budget;
    * ``bn`` covers the whole padded J when small (fewer grid columns, one
      decoded strip per call), capped near 1024 lanes;
    * ``bm`` covers M up to 128 rows;
    * ``hoist`` turns on exactly when some output row-block revisits the
      decoded strip (more than one M block) and the strip fits the budget.
    """
    cfg = store.cfg
    k_pad, j_pad = store.man.shape
    n, rw = cfg.n_group, cfg.row_weights
    lcm_k = n if cfg.protect == "one4n" else (n * 32 // math.gcd(n, 32))
    bn0 = rw * (128 // math.gcd(rw, 128))         # lcm(rw, 128)
    if block_n is None:
        bn = bn0 * min(math.ceil(j_pad / bn0), max(1, 1024 // bn0))
    else:
        bn = min(bn0 * max(1, block_n // bn0), bn0 * math.ceil(j_pad / bn0))
    if block_k is None:
        bk = _round_up(k_pad, lcm_k)
        while bk > lcm_k and bk * bn * 4 > vmem_budget:
            bk = max(lcm_k, (bk // 2 // lcm_k) * lcm_k)
    else:
        bk = max(lcm_k, (min(block_k, k_pad) // lcm_k) * lcm_k)
    bm = min(_round_up(block_m if block_m is not None else 128, 8),
             _round_up(max(m, 1), 8))
    if hoist is None:
        k_t = _round_up(k_pad, bk)
        m_t = _round_up(max(m, 1), bm)
        hoist = (m_t // bm) > 1 and k_t * bn * 4 <= vmem_budget
    return bm, bn, bk, bool(hoist)


def autotuned_tile_shapes(store, ms=(2, 8, 128, 512)):
    """The deduped ``(bm, bn, bk, hoist)`` combos :func:`resolve_tiles` picks
    for a store across representative batch sizes — the tile matrix the
    parity/stream-identity tests and the ``kernel_bench`` sweep cover."""
    seen, out = set(), []
    for m in ms:
        t = resolve_tiles(store, m)
        if t not in seen:
            seen.add(t)
            out.append(t)
    return out


def cim_linear_store(x, store, *, scalars=None, model=None,
                     block_m: int | None = None,
                     block_n: int | None = None, block_k: int | None = None,
                     hoist: bool | None = None,
                     interpret: bool | None = None, use_kernel: bool = True,
                     with_info: bool = False, global_dims=None):
    """Fused linear layer on a packed CIM store: ``x [..., K] -> [..., J]``.

    Static serving: ``scalars=None`` (or zero thresholds). Per-read dynamic
    injection: pass ``make_scalars(cim.plane_seeds(key), thr, thr)`` — the
    kernel then draws the exact :func:`repro.core.cim.inject` flip streams
    in-VMEM before decoding, so every read sees fresh faults without a stored
    image update.

    Block sizes default to :func:`resolve_tiles` autotuning (full-K tiles,
    whole-J columns when they fit, decode hoist when M revisits the strip);
    pass explicit ``block_m``/``block_n``/``block_k`` to pin a grid.

    Operands are zero-padded to tile boundaries (padded activations are zero,
    so padding never changes the result); outputs are sliced back. Returns
    the output array, or ``(out, info)`` with ``info['used_kernel']`` when
    ``with_info=True``.

    ``global_dims=(k_pad_global, j_pad_global)`` tells the kernel the store
    is one shard of a larger image: dynamic elem indices are computed against
    the GLOBAL padded dims (offsets ride in via the scalars vector), so the
    per-shard flip streams equal the single-device image's.

    ``model`` selects the :class:`~repro.core.faultmodels.FaultProcess` of a
    dynamic read: its kind/axis pick the compiled threshold path (static, like
    ``dynamic``), its parameters overwrite the SCALAR_M_* slots (traced), and
    a static drift tick pre-scales the field thresholds — streams bit-
    identical to ``cim.inject(..., model=model)`` at the same seeds.
    """
    if interpret is None:
        interpret = not _on_tpu()
    cfg = store.cfg
    k_log, j_log = store.shape
    b_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    assert x2.shape[-1] == k_log, (x2.shape, store.shape)
    dynamic = scalars is not None

    m_kind = model.kind if model is not None else "iid"
    m_axis = model.axis if model is not None else "row"
    if dynamic and model is not None:
        m_thr, m_len = fm_lib.model_scalars(model)
        scalars = scalars.at[SCALAR_M_THR].set(m_thr) \
                         .at[SCALAR_M_LEN].set(m_len)
        if m_kind == "drift":
            # element-independent: pre-scale the field thresholds once
            scalars = scalars.at[SCALAR_THR_MAN].set(
                fm_lib.compiled_threshold(model, scalars[SCALAR_THR_MAN]))
            scalars = scalars.at[SCALAR_THR_META].set(
                fm_lib.compiled_threshold(model, scalars[SCALAR_THR_META]))

    supported = use_kernel and cfg.protect in ("one4n", "none") \
        and cfg.fmt.name == "fp16"
    if not supported:
        assert global_dims is None, \
            "sharded (global_dims) calls require the kernel route"
        out = _fallback(x2, store, scalars, model)
        out = out.reshape(*b_shape, j_log)
        return (out, {"used_kernel": False}) if with_info else out

    n, rw = cfg.n_group, cfg.row_weights
    k_pad, j_pad = store.man.shape
    gk_pad, gj_pad = global_dims or (k_pad, j_pad)
    m = x2.shape[0]

    bm, bn, bk, hoist = resolve_tiles(store, m, block_m=block_m,
                                      block_n=block_n, block_k=block_k,
                                      hoist=hoist)
    j_t = _round_up(j_pad, bn)
    k_t = _round_up(k_pad, bk)
    m_t = _round_up(m, bm)

    xp = jnp.pad(x2, ((0, m_t - m), (0, k_t - k_log)))
    man = _pad2(store.man, k_t, j_t)
    if scalars is None:
        scalars = make_scalars()
    common = dict(man_bits=cfg.fmt.man_bits, exp_bits=cfg.fmt.exp_bits,
                  bias=cfg.fmt.bias, block_m=bm, block_n=bn, block_k=bk,
                  dynamic=dynamic, hoist=hoist, interpret=interpret,
                  model_kind=m_kind, model_axis=m_axis)
    if cfg.protect == "one4n":
        cw = store.codewords
        b_t, g_t = k_t // n, j_t // rw
        cw = jnp.pad(cw, ((0, b_t - cw.shape[0]), (0, g_t - cw.shape[1]),
                          (0, 0), (0, 0)))
        out = _one4n_call(xp, man, cw, scalars, codec=cfg.codec, n_group=n,
                          store_g=gj_pad // rw, store_j=gj_pad, **common)
    else:
        b_t = k_t // n
        exp = _pad2(store.exp, b_t, j_t)
        sw_t = k_t // 32
        signw = _pad2(store.sign, sw_t, j_t)
        out = _raw_call(xp, man, exp, signw, scalars, n_group=n,
                        store_k=gk_pad, store_j=gj_pad, **common)
    out = out[:m, :j_log].reshape(*b_shape, j_log)
    if with_info:
        return out, {"used_kernel": True, "tiles": (bm, bn, bk),
                     "hoist": hoist}
    return out


def cim_linear_store_sharded(x, store, *, scalars=None, model=None, mesh=None,
                             axis: str = "model", dim: str = "j",
                             block_m: int | None = None,
                             block_n: int | None = None,
                             block_k: int | None = None,
                             hoist: bool | None = None,
                             interpret: bool | None = None,
                             with_info: bool = False):
    """Mesh-sharded fused linear layer: each model-axis shard decodes and
    multiplies only ITS slab of the packed SRAM image (one shard ≈ one macro
    column group), under ``shard_map``.

    * ``dim='j'`` (default): planes column-sharded; every shard computes its
      ``[M, J/n]`` output slice — no collective on the contraction, the
      output stays J-sharded (``P(batch, axis)``).
    * ``dim='k'``: planes word-line-sharded; each shard contracts its K slab
      and the partial products are combined with a ``psum`` over ``axis``.

    Dynamic per-read injection stays bit-identical to the single-device
    image: each shard's kernel gets its global (row, col) offset via the
    SMEM scalars, so the counter-PRNG elem indices are global store
    coordinates. Falls back to the plain (GSPMD) :func:`cim_linear_store`
    when there is no mesh / no model axis, when the store does not split
    evenly, or for stores the kernel cannot tile (``per_weight``, non-fp16) —
    a 1-device mesh degrades to a single-shard ``shard_map``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.kernels.cim_read.kernel import SCALAR_OFF_J, SCALAR_OFF_K

    if mesh is None:
        from repro.distributed import sharding as shlib
        mesh = shlib.get_mesh()
    cfg = store.cfg
    n_sh = int(mesh.shape[axis]) if mesh is not None \
        and axis in mesh.axis_names else 0
    k_log, j_log = store.shape
    k_pad, j_pad = store.man.shape
    supported = n_sh > 0 and cfg.protect in ("one4n", "none") \
        and cfg.fmt.name == "fp16" \
        and cim_lib.can_shard_store(store, n_sh, dim) \
        and (dim == "j" or k_log == k_pad)   # K shards must tile whole slabs
    if not supported:
        out = cim_linear_store(x, store, scalars=scalars, model=model,
                               block_m=block_m, block_n=block_n,
                               block_k=block_k, hoist=hoist,
                               interpret=interpret, with_info=with_info)
        if with_info:
            out, info = out
            return out, dict(info, sharded=False)
        return out

    dynamic = scalars is not None
    sc = scalars if dynamic else make_scalars()
    b_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    m = x2.shape[0]
    planes = cim_lib._plane_dict(store)
    pspecs = cim_lib.store_plane_specs(store, axis, dim)
    data_ax = "data" if "data" in mesh.axis_names \
        and m % int(mesh.shape["data"]) == 0 else None
    j_loc, k_loc = j_pad // n_sh, k_pad // n_sh

    def body(x_loc, planes_loc, sc_loc):
        i = jax.lax.axis_index(axis)
        if dim == "j":
            sc_i = sc_loc.at[SCALAR_OFF_J].set(jnp.uint32(i * j_loc))
            shape = (k_log, j_loc)
        else:
            sc_i = sc_loc.at[SCALAR_OFF_K].set(jnp.uint32(i * k_loc))
            shape = (k_loc, j_log)
        loc = cim_lib.CIMStore(
            man=planes_loc["man"], sign=planes_loc.get("sign"),
            exp=planes_loc.get("exp"), codewords=planes_loc.get("cw"),
            shape=shape, cfg=cfg)
        out = cim_linear_store(x_loc, loc, scalars=sc_i if dynamic else None,
                               model=model, block_m=block_m, block_n=block_n,
                               block_k=block_k, hoist=hoist,
                               interpret=interpret,
                               global_dims=(k_pad, j_pad))
        if dim == "k":
            out = jax.lax.psum(out, axis)
        return out

    x_spec = P(data_ax, None) if dim == "j" else P(data_ax, axis)
    out_spec = P(data_ax, axis) if dim == "j" else P(data_ax, None)
    out = shard_map(body, mesh=mesh,
                    in_specs=(x_spec, pspecs, P(None)),
                    out_specs=out_spec, check_rep=False)(x2, planes, sc)
    out = out[:, :j_log].reshape(*b_shape, j_log)
    if with_info:
        return out, {"used_kernel": True, "sharded": True}
    return out


def _fallback(x2, store, scalars, model=None):
    """Reference path: packed jnp decode fused by XLA into the matmul (still
    no persistent fp16 copy; used for per_weight / non-fp16 formats). Dynamic
    scalars draw the same flip streams as the fused kernel; the fault model's
    drift tick was already folded into the threshold slots by the caller, so
    it is zeroed here to avoid double time-scaling."""
    if scalars is not None:
        import dataclasses as _dc
        if model is not None and model.kind == "drift" and model.tick:
            model = _dc.replace(model, tick=0)
        seeds = {"man": scalars[2], "meta": scalars[3], "cw": scalars[4]}
        store = cim_lib.inject_with_seeds(store, seeds, scalars[0], scalars[1],
                                          model=model)
    w, _ = cim_lib.read(store)
    return x2 @ w
