"""Pure-jnp oracle for the fused decode-on-read matmul.

The reference decodes the packed store with :func:`repro.core.cim.read` (the
bit-exact packed ECC path) and runs a plain fp32 matmul — i.e. exactly what
the fused kernel computes, but with the decoded weight matrix materialized.
With ``seeds``/thresholds it first applies :func:`cim.inject_with_seeds`,
which draws the identical counter-PRNG streams the kernel draws in-VMEM, so
static and dynamic kernel outputs can both be checked against it.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cim as cim_lib


def cim_read_ref(x, store, *, seeds=None, thr_man=0, thr_meta=0):
    """x [M, K] @ decode(store [K, J]) -> [M, J] f32 (+ decode stats)."""
    if seeds is not None:
        store = cim_lib.inject_with_seeds(store, seeds, thr_man, thr_meta)
    w, stats = cim_lib.read(store)
    return x.astype(jnp.float32) @ w, stats
