"""Pallas TPU kernel: fused SECDED decode + FP16 reconstruction + matmul.

The serving-path realization of the packed CIM store (DESIGN: decode-on-read).
Weights stream HBM->VMEM **in the macro's packed SRAM layout** — a uint16
mantissa plane plus either word-packed One4N codewords (``protect='one4n'``)
or a raw exponent plane + K-packed sign words (``protect='none'``). Each
weight tile is ECC-decoded and reconstructed to fp32 *in VMEM* and fed
straight to the MXU; decoded fp16 weight matrices never exist in HBM:

    SECDED syndrome/correction  -> XOR-parity folds on uint32 words
                                   (`ecc.syndrome_packed` +
                                   `ecc.correct_extract_packed`, shared code)
    exponent summation array    -> shared-exponent pow2 scale (exact)
    sign processing unit (XOR)  -> sign factor in the reconstruction
    mantissa multiplication     -> MXU dot on the reconstructed tile

The decode follows the hybrid-domain split of arXiv:2502.07212: the
exponent/SECDED path (``_meta_decode_*`` — all the per-word column-mask
parity folds, the correction and the sign/exponent expansion) is separated
from the cheap mantissa path (``_reconstruct_f32``), and both depend only on
the ``(j, kk)`` plane tile — never on the output-row index ``i``.

Optional **per-read dynamic injection**: with ``dynamic=True`` the kernel
draws counter-PRNG flip masks over the packed words before decoding —
bit-identical streams to :func:`repro.core.cim.inject` (same murmur3 hash,
same per-plane seeds, element index computed in *store* coordinates so
tile-level padding never shifts the streams). Thresholds and seeds are SMEM
scalars: sweeping BER or read index does not recompile. The flip masks are
functions of the ``(j, kk)`` tile coordinates only, so dynamic injection
hoists exactly like the clean decode.

Grid: (N/bn, M/bm, K/bk) — **j outermost, i middle, kk innermost** with
output revisiting; the [bm, bn] fp32 accumulator stays in VMEM across the K
loop, and plane tiles stream through ``pallas_call``'s pipelined
(double-buffered) BlockSpec windows across the K loop. With ``hoist=True``
the decoded [K, bn] strip of the current j-column lives in VMEM scratch:
each plane tile is decoded once at ``i == 0`` (syndrome folds + correction +
reconstruction) and the following M-row revisits re-use the decoded strip —
the i dimension is marked "arbitrary" so the revisits stay sequential on a
core. ``bn`` must cover whole ``row_weights`` groups and ``bk`` whole
exponent blocks (plus whole sign words for the raw path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bitpack
from repro.core.ecc import One4NRowCodec
from repro.core.faultmodels import scale_elem_thresholds
from repro.kernels.fault_inject.kernel import hash_u32

# jax renamed TPUCompilerParams -> CompilerParams across releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# SMEM scalar layout (uint32[9]); thresholds of 0 mean "no flips".
SCALAR_THR_MAN = 0     # mantissa-field Bernoulli threshold
SCALAR_THR_META = 1    # exponent_sign-field Bernoulli threshold
SCALAR_SEED_MAN = 2    # mantissa-plane seed
SCALAR_SEED_META = 3   # raw-exponent-plane seed   (protect='none')
SCALAR_SEED_CW = 4     # codeword-plane seed (protected) / sign-plane seed
SCALAR_OFF_K = 5       # global K-row offset of this shard's plane block
SCALAR_OFF_J = 6       # global J-column offset of this shard's plane block
SCALAR_M_THR = 7       # fault-model parameter: burst hit threshold /
                       # correlated strength (Q16)
SCALAR_M_LEN = 8       # fault-model parameter: burst run length /
                       # correlated period
# The offsets put the dynamic flip streams in GLOBAL store coordinates when
# the planes are mesh-sharded (ops.cim_linear_store_sharded): each shard's
# kernel sees only its local block, but elem indices — and therefore the
# counter-PRNG draws — match the single-device image bit for bit. They are
# traced SMEM values, so every shard runs the same compiled program. The
# fault-model *parameters* are traced the same way (sweeping a rate or run
# length never recompiles), while the model's KIND/AXIS are static kernel
# arguments picking the threshold-compilation code path — exactly like
# `dynamic` itself. Per-element thresholds come from
# ``faultmodels.scale_elem_thresholds`` on the same GLOBAL element indices,
# so kernel streams stay bit-identical to the jnp inject paths per process.


def _flip_mask(elem: jnp.ndarray, seed, threshold, positions) -> jnp.ndarray:
    """Counter-PRNG flip mask over ``positions`` for word elements ``elem``
    (same streams as ``cim.counter_flip_words`` / the fault_inject kernel)."""
    seed = seed * jnp.uint32(0x9E3779B9)
    mask = jnp.zeros(elem.shape, jnp.uint32)
    for p in positions:
        z = (elem * jnp.uint32(32) + jnp.uint32(p)) ^ seed
        flip = (hash_u32(z) < threshold).astype(jnp.uint32)
        mask = mask | (flip << p)
    return mask


def _reconstruct_f32(sign_bit, e_full, man, *, man_bits: int, exp_bits: int,
                     bias: int) -> jnp.ndarray:
    """IEEE-faithful fp16-grid reconstruction (incl. subnormal/inf/nan, so a
    corrupted exponent behaves exactly like the bitcast `read` path). This is
    the cheap mantissa half of the hybrid-domain split — elementwise only, no
    parity folds."""
    man_f = (man.astype(jnp.uint32) & ((1 << man_bits) - 1)).astype(jnp.float32)
    e = e_full.astype(jnp.int32)
    frac = man_f * (2.0 ** -man_bits)
    # 2^(e-bias) built by exponent-field bitcast: jnp.exp2 is a polynomial on
    # some backends and lands a few ulp off exact powers of two for large
    # (corrupted) exponents, which broke bit-identity with the bitcast `read`
    # path. e-bias+127 stays inside the normal f32 exponent range for every
    # 5-bit e, and (1+frac) * 2^s is exact, so normals match fp16 bit for bit.
    scale = jax.lax.bitcast_convert_type(
        jnp.left_shift(e - bias + 127, 23).astype(jnp.int32), jnp.float32)
    normal = (1.0 + frac) * scale
    sub = frac * (2.0 ** (1 - bias))
    emax = (1 << exp_bits) - 1
    special = jnp.where(man_f == 0.0, jnp.float32(jnp.inf), jnp.float32(jnp.nan))
    mag = jnp.where(e == 0, sub, jnp.where(e == emax, special, normal))
    sgn = jnp.where(sign_bit.astype(jnp.uint32) & 1 == 1, -1.0, 1.0)
    return sgn.astype(jnp.float32) * mag


def _expand_exp(e_block, n_group: int, bk: int, bn: int):
    """[bkb, bn] per-block exponents -> [bk, bn] per-row."""
    bkb = bk // n_group
    e = jnp.broadcast_to(e_block[:, None, :], (bkb, n_group, bn))
    return e.reshape(bk, bn)


def _meta_decode_one4n(cw, *, codec: One4NRowCodec, n_group: int,
                       block_k: int, block_n: int):
    """Exponent/SECDED half of the hybrid-domain split for one4n tiles.

    Runs the per-word column-mask syndrome folds + correction once for the
    codeword tile (``ecc.SecdedCode.syndrome_packed`` /
    ``correct_extract_packed`` via the codec) and expands the payload to a
    per-row exponent [bk, bn] and sign-bit plane [bk, bn].
    """
    bkb, bng = cw.shape[0], cw.shape[1]
    rw = codec.row_weights
    exp_rows, sign_words, _ = codec.decode_packed(cw)    # [bkb,bng,rw],[...,Sw]
    e_block = exp_rows.reshape(bkb, bng * rw)            # [bkb, bn]
    e_full = _expand_exp(e_block, n_group, block_k, block_n)
    # sign bit of weight (block b, i_n, group g, t) = payload sign bit
    # i_n*rw + t of that block's sign words
    per_in = []
    sw_list = [sign_words[..., v] for v in range(sign_words.shape[-1])]
    for i_n in range(n_group):
        sv = bitpack.extract_window(sw_list, i_n * rw, rw)[0]   # [bkb, bng]
        per_in.append(sv)
    sv_all = jnp.stack(per_in, axis=1)                   # [bkb, n, bng]
    t_iota = jax.lax.broadcasted_iota(jnp.uint32,
                                      sv_all.shape + (rw,), 3)
    bits = (sv_all[..., None] >> t_iota) & 1
    sign_full = bits.reshape(block_k, block_n)           # (b, i_n, g, t) order
    return e_full, sign_full


def _decode_tile_one4n(scalars_ref, man, cw, j, kk, *, codec: One4NRowCodec,
                       n_group: int, man_bits: int, exp_bits: int, bias: int,
                       store_g: int, store_j: int, block_n: int, block_k: int,
                       dynamic: bool, model_kind: str = "iid",
                       model_axis: str = "row"):
    """Decode one (kk, j) plane tile -> reconstructed fp32 [bk, bn].

    Depends only on the (j, kk) tile coordinates (plus SMEM scalars), never
    on the output-row index — the invariant the decode hoist relies on.
    """
    bkb, bng = cw.shape[0], cw.shape[1]
    rw = codec.row_weights

    if dynamic:
        thr_man = scalars_ref[SCALAR_THR_MAN]
        thr_meta = scalars_ref[SCALAR_THR_META]
        seed_man = scalars_ref[SCALAR_SEED_MAN]
        seed_cw = scalars_ref[SCALAR_SEED_CW]
        off_k = scalars_ref[SCALAR_OFF_K]
        off_j = scalars_ref[SCALAR_OFF_J]
        m_thr = scalars_ref[SCALAR_M_THR]
        m_len = scalars_ref[SCALAR_M_LEN]
        rows = jax.lax.broadcasted_iota(jnp.uint32, (block_k, block_n), 0) \
            + jnp.uint32(kk * block_k) + off_k
        cols = jax.lax.broadcasted_iota(jnp.uint32, (block_k, block_n), 1) \
            + jnp.uint32(j * block_n) + off_j
        elem = rows * jnp.uint32(store_j) + cols     # GLOBAL store coordinates
        t_man = scale_elem_thresholds(
            elem, thr_man, seed_man, kind=model_kind, axis=model_axis,
            m_thr=m_thr, m_len=m_len, width=store_j)
        man = man ^ _flip_mask(elem, seed_man, t_man,
                               tuple(range(man_bits))).astype(man.dtype)
        b_idx = jax.lax.broadcasted_iota(jnp.uint32, (bkb, bng), 0) \
            + jnp.uint32(kk * bkb) + off_k // jnp.uint32(n_group)
        g_idx = jax.lax.broadcasted_iota(jnp.uint32, (bkb, bng), 1) \
            + jnp.uint32(j * bng) + off_j // jnp.uint32(rw)
        s_, w_ = codec.n_segments, codec.codeword_words
        masks = codec.code.code_word_masks
        base = (b_idx * jnp.uint32(store_g) + g_idx) * jnp.uint32(s_ * w_)
        planes = []
        for s in range(s_):
            words = []
            for w in range(w_):
                positions = tuple(p for p in range(32)
                                  if (int(masks[w]) >> p) & 1)
                celem = base + jnp.uint32(s * w_ + w)
                t_cw = scale_elem_thresholds(
                    celem, thr_meta, seed_cw, kind=model_kind,
                    axis=model_axis, m_thr=m_thr, m_len=m_len,
                    width=store_g * s_ * w_, col_div=s_ * w_)
                m = _flip_mask(celem, seed_cw, t_cw, positions)
                words.append(cw[:, :, s, w] ^ m)
            planes.append(jnp.stack(words, axis=-1))
        cw = jnp.stack(planes, axis=-2)              # [bkb, bng, S, W]

    e_full, sign_full = _meta_decode_one4n(cw, codec=codec, n_group=n_group,
                                           block_k=block_k, block_n=block_n)
    return _reconstruct_f32(sign_full, e_full, man, man_bits=man_bits,
                            exp_bits=exp_bits, bias=bias)


def _cim_read_kernel_one4n(scalars_ref, x_ref, man_ref, cw_ref, o_ref,
                           *scratch, codec: One4NRowCodec, n_group: int,
                           man_bits: int, exp_bits: int, bias: int,
                           store_g: int, store_j: int, block_m: int,
                           block_n: int, block_k: int, dynamic: bool,
                           hoist: bool, model_kind: str = "iid",
                           model_axis: str = "row"):
    j = pl.program_id(0)
    i = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    decode = functools.partial(
        _decode_tile_one4n, codec=codec, n_group=n_group, man_bits=man_bits,
        exp_bits=exp_bits, bias=bias, store_g=store_g, store_j=store_j,
        block_n=block_n, block_k=block_k, dynamic=dynamic,
        model_kind=model_kind, model_axis=model_axis)

    if hoist:
        w_strip = scratch[0]                         # VMEM [n_k*bk, bn] f32

        @pl.when(i == 0)
        def _decode_once():
            w_strip[pl.ds(kk * block_k, block_k), :] = decode(
                scalars_ref, man_ref[...], cw_ref[...].astype(jnp.uint32),
                j, kk)

        w_tile = w_strip[pl.ds(kk * block_k, block_k), :]
    else:
        w_tile = decode(scalars_ref, man_ref[...],
                        cw_ref[...].astype(jnp.uint32), j, kk)

    o_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w_tile,
                          preferred_element_type=jnp.float32)


def _meta_decode_raw(e_block, signw, *, n_group: int, block_k: int,
                     block_n: int):
    """Exponent/sign half for unprotected tiles: expand the shared exponent
    blocks and unpack the K-packed sign words to a per-row bit plane."""
    bkw = signw.shape[0]
    e_full = _expand_exp(e_block, n_group, block_k, block_n)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (bkw, 32, block_n), 1)
    bits = (signw[:, None, :] >> lane) & 1
    sign_full = bits.reshape(bkw * 32, block_n)[:block_k]
    return e_full, sign_full


def _decode_tile_raw(scalars_ref, man, e_block, signw, j, kk, *, n_group: int,
                     man_bits: int, exp_bits: int, bias: int, store_k: int,
                     store_j: int, block_n: int, block_k: int, dynamic: bool,
                     model_kind: str = "iid", model_axis: str = "row"):
    """protect='none' twin of :func:`_decode_tile_one4n` (same (j, kk)-only
    dependence)."""
    bkw = signw.shape[0]

    if dynamic:
        thr_man = scalars_ref[SCALAR_THR_MAN]
        thr_meta = scalars_ref[SCALAR_THR_META]
        seed_man = scalars_ref[SCALAR_SEED_MAN]
        seed_meta = scalars_ref[SCALAR_SEED_META]
        seed_sign = scalars_ref[SCALAR_SEED_CW]
        off_k = scalars_ref[SCALAR_OFF_K]
        off_j = scalars_ref[SCALAR_OFF_J]
        m_thr = scalars_ref[SCALAR_M_THR]
        m_len = scalars_ref[SCALAR_M_LEN]

        def scale(elem_, thr_, seed_):
            return scale_elem_thresholds(
                elem_, thr_, seed_, kind=model_kind, axis=model_axis,
                m_thr=m_thr, m_len=m_len, width=store_j)

        rows = jax.lax.broadcasted_iota(jnp.uint32, (block_k, block_n), 0) \
            + jnp.uint32(kk * block_k) + off_k
        cols = jax.lax.broadcasted_iota(jnp.uint32, (block_k, block_n), 1) \
            + jnp.uint32(j * block_n) + off_j
        elem = rows * jnp.uint32(store_j) + cols
        man = man ^ _flip_mask(elem, seed_man, scale(elem, thr_man, seed_man),
                               tuple(range(man_bits))).astype(man.dtype)
        bkb = block_k // n_group
        b_rows = jax.lax.broadcasted_iota(jnp.uint32, (bkb, block_n), 0) \
            + jnp.uint32(kk * bkb) + off_k // jnp.uint32(n_group)
        b_cols = jax.lax.broadcasted_iota(jnp.uint32, (bkb, block_n), 1) \
            + jnp.uint32(j * block_n) + off_j
        e_elem = b_rows * jnp.uint32(store_j) + b_cols
        e_block = e_block ^ _flip_mask(e_elem, seed_meta,
                                       scale(e_elem, thr_meta, seed_meta),
                                       tuple(range(exp_bits))).astype(e_block.dtype)
        w_rows = jax.lax.broadcasted_iota(jnp.uint32, (bkw, block_n), 0) \
            + jnp.uint32(kk * bkw) + off_k // jnp.uint32(32)
        w_cols = jax.lax.broadcasted_iota(jnp.uint32, (bkw, block_n), 1) \
            + jnp.uint32(j * block_n) + off_j
        s_elem = w_rows * jnp.uint32(store_j) + w_cols
        smask = _flip_mask(s_elem, seed_sign,
                           scale(s_elem, thr_meta, seed_sign),
                           tuple(range(32)))
        # lanes beyond the store's K rows are not cells: mask them off
        lane = jax.lax.broadcasted_iota(jnp.uint32, (bkw, block_n, 32), 2)
        lane_k = w_rows[:, :, None] * jnp.uint32(32) + lane
        lane_valid = (lane_k < jnp.uint32(store_k)).astype(jnp.uint32)
        valid = jnp.sum(lane_valid << lane, axis=-1)
        signw = signw ^ (smask & valid)

    e_full, sign_full = _meta_decode_raw(e_block, signw, n_group=n_group,
                                         block_k=block_k, block_n=block_n)
    return _reconstruct_f32(sign_full, e_full, man, man_bits=man_bits,
                            exp_bits=exp_bits, bias=bias)


def _cim_read_kernel_raw(scalars_ref, x_ref, man_ref, exp_ref, signw_ref,
                         o_ref, *scratch, n_group: int, man_bits: int,
                         exp_bits: int, bias: int, store_k: int, store_j: int,
                         block_m: int, block_n: int, block_k: int,
                         dynamic: bool, hoist: bool, model_kind: str = "iid",
                         model_axis: str = "row"):
    """protect='none': raw exponent plane + K-packed sign words."""
    j = pl.program_id(0)
    i = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    decode = functools.partial(
        _decode_tile_raw, n_group=n_group, man_bits=man_bits,
        exp_bits=exp_bits, bias=bias, store_k=store_k, store_j=store_j,
        block_n=block_n, block_k=block_k, dynamic=dynamic,
        model_kind=model_kind, model_axis=model_axis)

    if hoist:
        w_strip = scratch[0]                         # VMEM [n_k*bk, bn] f32

        @pl.when(i == 0)
        def _decode_once():
            w_strip[pl.ds(kk * block_k, block_k), :] = decode(
                scalars_ref, man_ref[...], exp_ref[...],
                signw_ref[...].astype(jnp.uint32), j, kk)

        w_tile = w_strip[pl.ds(kk * block_k, block_k), :]
    else:
        w_tile = decode(scalars_ref, man_ref[...], exp_ref[...],
                        signw_ref[...].astype(jnp.uint32), j, kk)

    o_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w_tile,
                          preferred_element_type=jnp.float32)


def _grid_and_scratch(m, n, k, block_m, block_n, block_k, hoist):
    """(N/bn, M/bm, K/bk) grid — j outermost so each j-column's decoded strip
    is built once and revisited by every i — plus the hoist scratch shape."""
    grid = (n // block_n, m // block_m, k // block_k)
    scratch = [pltpu.VMEM((k, block_n), jnp.float32)] if hoist else []
    # i ("arbitrary") keeps the M-revisits of one j-column sequential on a
    # core, so the strip decoded at i == 0 is still live for i > 0.
    semantics = ("parallel", "arbitrary", "arbitrary")
    return grid, scratch, semantics


def cim_read_matmul_one4n(x, man, cw, scalars, *, codec: One4NRowCodec,
                          n_group: int, man_bits: int, exp_bits: int,
                          bias: int, store_g: int, store_j: int,
                          block_m: int, block_n: int, block_k: int,
                          dynamic: bool, hoist: bool = False,
                          interpret: bool = True, model_kind: str = "iid",
                          model_axis: str = "row"):
    """x [M, K] float; man uint16 [K, N]; cw uint32 [K//n, N//rw, S, W];
    scalars uint32 [9] (see SCALAR_*) -> [M, N] f32, decode fused into the
    matmul. ``hoist=True`` decodes each (j, kk) plane tile once into VMEM
    scratch and reuses the strip across the M-row revisits. ``model_kind`` /
    ``model_axis`` statically select the fault-model threshold compilation
    (its traced parameters ride in SCALAR_M_THR/SCALAR_M_LEN)."""
    m, k = x.shape
    k2, n = man.shape
    rw = codec.row_weights
    assert k == k2 and cw.shape[:2] == (k // n_group, n // rw)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    assert block_k % n_group == 0 and block_n % rw == 0

    s_, w_ = codec.n_segments, codec.codeword_words
    grid, scratch, semantics = _grid_and_scratch(m, n, k, block_m, block_n,
                                                 block_k, hoist)
    kernel = functools.partial(
        _cim_read_kernel_one4n, codec=codec, n_group=n_group,
        man_bits=man_bits, exp_bits=exp_bits, bias=bias, store_g=store_g,
        store_j=store_j, block_m=block_m, block_n=block_n, block_k=block_k,
        dynamic=dynamic, hoist=hoist, model_kind=model_kind,
        model_axis=model_axis)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_k), lambda j, i, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda j, i, kk: (kk, j)),
            pl.BlockSpec((block_k // n_group, block_n // rw, s_, w_),
                         lambda j, i, kk: (kk, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda j, i, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(scalars, x, man, cw)


def cim_read_matmul_raw(x, man, exp, signw, scalars, *, n_group: int,
                        man_bits: int, exp_bits: int, bias: int, store_k: int,
                        store_j: int, block_m: int, block_n: int,
                        block_k: int, dynamic: bool, hoist: bool = False,
                        interpret: bool = True, model_kind: str = "iid",
                        model_axis: str = "row"):
    """protect='none' variant: exp uint8 [K//n, N], signw uint32 [K//32, N];
    scalars uint32 [9] (see SCALAR_*)."""
    m, k = x.shape
    k2, n = man.shape
    assert k == k2 and exp.shape == (k // n_group, n)
    assert signw.shape == (k // 32, n)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    assert block_k % n_group == 0 and block_k % 32 == 0

    grid, scratch, semantics = _grid_and_scratch(m, n, k, block_m, block_n,
                                                 block_k, hoist)
    kernel = functools.partial(
        _cim_read_kernel_raw, n_group=n_group, man_bits=man_bits,
        exp_bits=exp_bits, bias=bias, store_k=store_k, store_j=store_j,
        block_m=block_m, block_n=block_n, block_k=block_k, dynamic=dynamic,
        hoist=hoist, model_kind=model_kind, model_axis=model_axis)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_k), lambda j, i, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda j, i, kk: (kk, j)),
            pl.BlockSpec((block_k // n_group, block_n), lambda j, i, kk: (kk, j)),
            pl.BlockSpec((block_k // 32, block_n), lambda j, i, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda j, i, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(scalars, x, man, exp, signw)
