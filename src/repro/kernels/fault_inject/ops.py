"""jit'd public wrapper for the fault-injection kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bitops import FP16, FloatFormat
from repro.kernels.fault_inject.kernel import (fault_inject_batched_pallas,
                                               fault_inject_pallas)
from repro.kernels.fault_inject.ref import fault_inject_ref  # noqa: F401


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ber_to_threshold(ber) -> jnp.ndarray:
    """Traced BER -> uint32 Bernoulli threshold (flip iff hash < threshold).

    Matches the static kernel's ``round(ber * 2^32)`` up to float32 rounding;
    saturates to 0xFFFFFFFF (flip always) near ber=1 because float32 cannot
    represent 2^32 - 1."""
    t = jnp.round(jnp.asarray(ber, jnp.float32) * jnp.float32(2.0 ** 32))
    return jnp.where(t >= jnp.float32(4294967040.0), jnp.uint32(0xFFFFFFFF),
                     t.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnames=("seed", "ber", "positions",
                                             "interpret"))
def fault_inject_bits(bits, *, seed: int, ber: float, positions,
                      interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return fault_inject_pallas(bits, seed=seed, ber=ber,
                               positions=tuple(positions), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("positions", "interpret",
                                             "model", "col_div"))
def fault_inject_bits_batched(bits, seeds, threshold, *, positions,
                              interpret: bool | None = None, model=None,
                              col_div: int = 1):
    """Trial-batched injection: bits [R, C] -> [T, R, C], one compile total.

    ``seeds`` (uint32 [T]) and ``threshold`` (uint32 scalar, see
    :func:`ber_to_threshold`) are traced — sweeping BER or trial seeds does
    NOT retrigger compilation, which is what lets the sweep engine evaluate a
    whole (BER x trial) plane per arm.

    ``model`` is an optional :class:`repro.core.faultmodels.FaultProcess`
    (hashable, static): burst/correlated compile to per-element thresholds
    inside the kernel (parameters ride in SMEM, so sweeping rate/length does
    not recompile either); drift pre-scales ``threshold`` by its tick.
    ``model=None`` / i.i.d. is bit-identical to the legacy stream."""
    if interpret is None:
        interpret = not _on_tpu()
    from repro.core import faultmodels as fm
    threshold = fm.compiled_threshold(model, threshold)
    m_thr, m_len = fm.model_scalars(model)
    kind = model.kind if model is not None else "iid"
    axis = model.axis if model is not None else "row"
    return fault_inject_batched_pallas(bits, seeds, threshold,
                                       positions=tuple(positions),
                                       interpret=interpret,
                                       m_thr=m_thr, m_len=m_len,
                                       model_kind=kind, model_axis=axis,
                                       col_div=col_div)


def fault_inject_fp16(w, *, seed: int, ber: float, field: str = "full",
                      fmt: FloatFormat = FP16, interpret: bool | None = None):
    """Field-targeted injection on an fp16-grid float tensor (kernel path)."""
    from repro.core import bitops
    shape = w.shape
    bits = bitops.to_bits(w.reshape(-1, shape[-1]), fmt)
    positions = tuple(int(p) for p in fmt.field_bit_positions(field))
    out = fault_inject_bits(bits, seed=seed, ber=ber, positions=positions,
                            interpret=interpret)
    return jnp.asarray(bitops.from_bits(out, fmt), w.dtype).reshape(shape)
