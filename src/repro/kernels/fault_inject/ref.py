"""Pure-jnp oracle for the fault-injection kernel (same counter-based PRNG)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.fault_inject.kernel import hash_u32


def fault_inject_ref(bits: jnp.ndarray, *, seed: int, ber: float,
                     positions: Sequence[int]) -> jnp.ndarray:
    r, c = bits.shape
    threshold = min(int(round(ber * 2 ** 32)), 2 ** 32 - 1)
    rows = jnp.arange(r, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(c, dtype=jnp.uint32)[None, :]
    elem = rows * jnp.uint32(c) + cols
    mask = jnp.zeros((r, c), jnp.uint32)
    for p in positions:
        z = elem * jnp.uint32(16) + jnp.uint32(p)
        z = z ^ (jnp.uint32(seed) * jnp.uint32(0x9E3779B9))
        flip = (hash_u32(z) < jnp.uint32(threshold)).astype(jnp.uint32)
        mask = mask | (flip << p)
    return bits ^ mask.astype(bits.dtype)
