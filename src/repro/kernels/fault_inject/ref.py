"""Pure-jnp oracle for the fault-injection kernel (same counter-based PRNG)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels.fault_inject.kernel import hash_u32


def fault_inject_ref(bits: jnp.ndarray, *, seed: int, ber: float,
                     positions: Sequence[int]) -> jnp.ndarray:
    r, c = bits.shape
    threshold = min(int(round(ber * 2 ** 32)), 2 ** 32 - 1)
    rows = jnp.arange(r, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(c, dtype=jnp.uint32)[None, :]
    elem = rows * jnp.uint32(c) + cols
    mask = jnp.zeros((r, c), jnp.uint32)
    for p in positions:
        z = elem * jnp.uint32(32) + jnp.uint32(p)
        z = z ^ (jnp.uint32(seed) * jnp.uint32(0x9E3779B9))
        flip = (hash_u32(z) < jnp.uint32(threshold)).astype(jnp.uint32)
        mask = mask | (flip << p)
    return bits ^ mask.astype(bits.dtype)


def fault_inject_batched_ref(bits: jnp.ndarray, seeds: jnp.ndarray,
                             threshold, *,
                             positions: Sequence[int]) -> jnp.ndarray:
    """Oracle for the trial-batched kernel: [R, C] x seeds [T] -> [T, R, C].

    Same counter-based streams — trial t equals ``fault_inject_ref`` at
    ``seed=seeds[t]`` for a matching threshold."""
    r, c = bits.shape
    threshold = jnp.asarray(threshold, jnp.uint32)
    rows = jnp.arange(r, dtype=jnp.uint32)[:, None]
    cols = jnp.arange(c, dtype=jnp.uint32)[None, :]
    elem = (rows * jnp.uint32(c) + cols)[None]            # [1, R, C]
    seeds = seeds.astype(jnp.uint32)[:, None, None]        # [T, 1, 1]
    mask = jnp.zeros((seeds.shape[0], r, c), jnp.uint32)
    for p in positions:
        z = elem * jnp.uint32(32) + jnp.uint32(p)
        z = z ^ (seeds * jnp.uint32(0x9E3779B9))
        flip = (hash_u32(z) < threshold).astype(jnp.uint32)
        mask = mask | (flip << p)
    return bits[None] ^ mask.astype(bits.dtype)
