"""Pallas TPU kernel: tiled bit-flip fault injection into a stored-bit plane.

Emulates soft errors in the CIM macro's SRAM cells (paper Fig. 1a) directly on
the packed uint16 weight representation. Randomness is a counter-based hash
PRNG (murmur3 finalizer) keyed by (seed, absolute element index, bit
position) — pure integer ops, so the kernel (a) lowers on TPU without the
Mosaic PRNG primitives, (b) runs bit-exactly in interpret mode on CPU, and
(c) produces tiling-independent faults (the same (seed, element, bit) always
flips the same way regardless of block shape).

Per bit position p in the target field: flip iff hash(...) < ber * 2^32,
i.e. i.i.d. Bernoulli(ber) per stored bit, matching `repro.core.fault`.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def hash_u32(z: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer (wrapping uint32 arithmetic)."""
    z = z.astype(jnp.uint32)
    z = z ^ (z >> 16)
    z = z * jnp.uint32(0x85EBCA6B)
    z = z ^ (z >> 13)
    z = z * jnp.uint32(0xC2B2AE35)
    z = z ^ (z >> 16)
    return z


def _fault_kernel(bits_ref, o_ref, *, seed: int, threshold: int,
                  positions: Tuple[int, ...], n_cols: int,
                  block_r: int, block_c: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    rows = jax.lax.broadcasted_iota(jnp.uint32, (block_r, block_c), 0) \
        + jnp.uint32(i * block_r)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (block_r, block_c), 1) \
        + jnp.uint32(j * block_c)
    elem = rows * jnp.uint32(n_cols) + cols

    mask = jnp.zeros((block_r, block_c), jnp.uint32)
    for p in positions:
        # distinct stream per (seed, element, bit position)
        z = elem * jnp.uint32(16) + jnp.uint32(p)
        z = z ^ (jnp.uint32(seed) * jnp.uint32(0x9E3779B9))
        r = hash_u32(z)
        flip = (r < jnp.uint32(threshold)).astype(jnp.uint32)
        mask = mask | (flip << p)

    o_ref[...] = bits_ref[...] ^ mask.astype(bits_ref.dtype)


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` not exceeding ``preferred``."""
    for d in range(min(preferred, dim), 0, -1):
        if dim % d == 0:
            return d
    return dim


def fault_inject_pallas(bits: jnp.ndarray, *, seed: int, ber: float,
                        positions: Sequence[int], block_r: int = 256,
                        block_c: int = 256, interpret: bool = True):
    """bits uint16 [R, C] -> bits with field positions flipped at rate ber."""
    r, c = bits.shape
    block_r = _pick_block(r, block_r)
    block_c = _pick_block(c, block_c)
    assert r % block_r == 0 and c % block_c == 0
    threshold = min(int(round(ber * 2 ** 32)), 2 ** 32 - 1)
    grid = (r // block_r, c // block_c)
    return pl.pallas_call(
        functools.partial(_fault_kernel, seed=seed, threshold=threshold,
                          positions=tuple(positions), n_cols=c,
                          block_r=block_r, block_c=block_c),
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, block_c), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(bits.shape, bits.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(bits)
