"""Pallas TPU kernel: tiled bit-flip fault injection into a stored-bit plane.

Emulates soft errors in the CIM macro's SRAM cells (paper Fig. 1a) directly on
the packed uint16 weight representation. Randomness is a counter-based hash
PRNG (murmur3 finalizer) keyed by (seed, absolute element index, bit
position) — pure integer ops, so the kernel (a) lowers on TPU without the
Mosaic PRNG primitives, (b) runs bit-exactly in interpret mode on CPU, and
(c) produces tiling-independent faults (the same (seed, element, bit) always
flips the same way regardless of block shape). Counter streams are strided
by 32 bits per element so positions 0..31 are independent across elements
(covers every format up to fp32).

Per bit position p in the target field: flip iff hash(...) < ber * 2^32,
i.e. i.i.d. Bernoulli(ber) per stored bit, matching `repro.core.fault`.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def hash_u32(z: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer (wrapping uint32 arithmetic)."""
    z = z.astype(jnp.uint32)
    z = z ^ (z >> 16)
    z = z * jnp.uint32(0x85EBCA6B)
    z = z ^ (z >> 13)
    z = z * jnp.uint32(0xC2B2AE35)
    z = z ^ (z >> 16)
    return z


def _fault_kernel(bits_ref, o_ref, *, seed: int, threshold: int,
                  positions: Tuple[int, ...], n_cols: int,
                  block_r: int, block_c: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    rows = jax.lax.broadcasted_iota(jnp.uint32, (block_r, block_c), 0) \
        + jnp.uint32(i * block_r)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (block_r, block_c), 1) \
        + jnp.uint32(j * block_c)
    elem = rows * jnp.uint32(n_cols) + cols

    mask = jnp.zeros((block_r, block_c), jnp.uint32)
    for p in positions:
        # distinct stream per (seed, element, bit position)
        z = elem * jnp.uint32(32) + jnp.uint32(p)
        z = z ^ (jnp.uint32(seed) * jnp.uint32(0x9E3779B9))
        r = hash_u32(z)
        flip = (r < jnp.uint32(threshold)).astype(jnp.uint32)
        mask = mask | (flip << p)

    o_ref[...] = bits_ref[...] ^ mask.astype(bits_ref.dtype)


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` not exceeding ``preferred``."""
    for d in range(min(preferred, dim), 0, -1):
        if dim % d == 0:
            return d
    return dim


# The counter is a uint32 striding 32 per element, so streams repeat after
# 2^27 elements; beyond that, element pairs 2^27 apart would receive
# identical (correlated) faults. Refuse instead of silently biasing stats.
MAX_COUNTER_ELEMENTS = 2 ** 27


def _check_counter_space(r: int, c: int) -> None:
    if r * c > MAX_COUNTER_ELEMENTS:
        raise ValueError(
            f"fault_inject counter space exhausted: {r}x{c} = {r * c} elements "
            f"> 2^27; split the leaf into chunks of <= {MAX_COUNTER_ELEMENTS} "
            f"elements (each with a distinct seed) to keep faults i.i.d.")


def fault_inject_pallas(bits: jnp.ndarray, *, seed: int, ber: float,
                        positions: Sequence[int], block_r: int = 256,
                        block_c: int = 256, interpret: bool = True):
    """bits uint16 [R, C] -> bits with field positions flipped at rate ber."""
    r, c = bits.shape
    _check_counter_space(r, c)
    block_r = _pick_block(r, block_r)
    block_c = _pick_block(c, block_c)
    assert r % block_r == 0 and c % block_c == 0
    threshold = min(int(round(ber * 2 ** 32)), 2 ** 32 - 1)
    grid = (r // block_r, c // block_c)
    return pl.pallas_call(
        functools.partial(_fault_kernel, seed=seed, threshold=threshold,
                          positions=tuple(positions), n_cols=c,
                          block_r=block_r, block_c=block_c),
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, block_c), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(bits.shape, bits.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(bits)


# ---------------------------------------------------------------------------
# Trial-batched variant with *traced* seeds/threshold (the sweep-engine path).
#
# The static kernel above bakes (seed, ber) into the compiled artifact — one
# compile per sweep cell. Here both live in an SMEM scalar block instead:
# scalars[0] is the uint32 Bernoulli threshold (round(ber * 2^32)),
# scalars[1:3] are the fault-model parameters (m_thr, m_len — zeros for
# i.i.d.) and scalars[3 + t] is trial t's seed, so a whole (trial × element
# × bit) fault plane evaluates under ONE compilation, with BER, model
# parameters and trial count swept as ordinary device values. The model
# *kind*/*axis* are static (they pick the compiled threshold code path, like
# ``dynamic`` in the cim_read kernel). The grid grows a leading trial
# dimension; every (seed, element, bit) stream is identical to the static
# kernel's, so trial t of the batched call is bit-exact with a static call
# at seed = seeds[t] (for the default i.i.d. model), and a non-i.i.d. model
# only ever *lowers* the per-element threshold (subset-of-iid contract of
# ``repro.core.faultmodels``).
# ---------------------------------------------------------------------------

SCALAR_B_THR = 0      # uint32 Bernoulli threshold round(ber * 2^32)
SCALAR_B_M_THR = 1    # fault model: burst hit threshold / correlated Q16
SCALAR_B_M_LEN = 2    # fault model: burst run length / correlated period
SCALAR_B_SEEDS = 3    # trial seeds start here


def _fault_kernel_batched(scalars_ref, bits_ref, o_ref, *,
                          positions: Tuple[int, ...], n_cols: int,
                          block_r: int, block_c: int,
                          model_kind: str = "iid", model_axis: str = "row",
                          col_div: int = 1):
    t = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    threshold = scalars_ref[SCALAR_B_THR]
    seed = scalars_ref[SCALAR_B_SEEDS + t]
    rows = jax.lax.broadcasted_iota(jnp.uint32, (block_r, block_c), 0) \
        + jnp.uint32(i * block_r)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (block_r, block_c), 1) \
        + jnp.uint32(j * block_c)
    elem = rows * jnp.uint32(n_cols) + cols

    if model_kind not in ("iid", "drift"):
        # lazy import: repro.core.faultmodels imports hash_u32 from here
        from repro.core.faultmodels import scale_elem_thresholds
        threshold = scale_elem_thresholds(
            elem, threshold, seed, kind=model_kind, axis=model_axis,
            m_thr=scalars_ref[SCALAR_B_M_THR],
            m_len=scalars_ref[SCALAR_B_M_LEN],
            width=n_cols, col_div=col_div)

    mask = jnp.zeros((block_r, block_c), jnp.uint32)
    for p in positions:
        z = elem * jnp.uint32(32) + jnp.uint32(p)
        z = z ^ (seed * jnp.uint32(0x9E3779B9))
        r = hash_u32(z)
        flip = (r < threshold).astype(jnp.uint32)
        mask = mask | (flip << p)

    o_ref[0] = bits_ref[...] ^ mask.astype(bits_ref.dtype)


def fault_inject_batched_pallas(bits: jnp.ndarray, seeds: jnp.ndarray,
                                threshold: jnp.ndarray, *,
                                positions: Sequence[int], block_r: int = 256,
                                block_c: int = 256, interpret: bool = True,
                                m_thr=0, m_len=0, model_kind: str = "iid",
                                model_axis: str = "row", col_div: int = 1):
    """bits uint [R, C], seeds uint32 [T] -> [T, R, C] faulted copies.

    ``seeds``, ``threshold`` and the fault-model parameters ``m_thr``/
    ``m_len`` are traced operands (SMEM scalars): one compile covers every
    (BER, model parameter, trial) the caller sweeps over. ``model_kind``/
    ``model_axis`` are static; ``col_div`` gives the macro-column unit width
    of the plane (words per column group for packed codeword planes).
    """
    r, c = bits.shape
    t = seeds.shape[0]
    _check_counter_space(r, c)
    block_r = _pick_block(r, block_r)
    block_c = _pick_block(c, block_c)
    scalars = jnp.concatenate([
        jnp.asarray(threshold, jnp.uint32).reshape(1),
        jnp.asarray(m_thr, jnp.uint32).reshape(1),
        jnp.asarray(m_len, jnp.uint32).reshape(1),
        seeds.astype(jnp.uint32)])
    grid = (t, r // block_r, c // block_c)
    return pl.pallas_call(
        functools.partial(_fault_kernel_batched, positions=tuple(positions),
                          n_cols=c, block_r=block_r, block_c=block_c,
                          model_kind=model_kind, model_axis=model_axis,
                          col_div=col_div),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((block_r, block_c), lambda t, i, j: (i, j))],
        out_specs=pl.BlockSpec((1, block_r, block_c), lambda t, i, j: (t, i, j)),
        out_shape=jax.ShapeDtypeStruct((t, r, c), bits.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(scalars, bits)
