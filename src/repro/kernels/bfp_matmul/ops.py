"""jit'd public wrapper for the BFP matmul kernel.

``interpret`` defaults to True off-TPU (this container validates the kernel
body on CPU); on a TPU runtime pass ``interpret=False`` for the Mosaic path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.bfp_matmul.kernel import bfp_matmul_pallas
from repro.kernels.bfp_matmul.ref import bfp_matmul_ref, dequant_ref, pack_bfp  # noqa: F401


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("n_group", "block_m", "block_n",
                                             "block_k", "interpret"))
def bfp_matmul(x, man, exp, *, n_group: int = 8, block_m: int = 128,
               block_n: int = 128, block_k: int = 512,
               interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return bfp_matmul_pallas(x, man, exp, n_group=n_group, block_m=block_m,
                             block_n=block_n, block_k=block_k,
                             interpret=interpret)


def cim_linear(x, man, exp, *, n_group: int = 8, use_kernel: bool = True):
    """Linear layer consuming the CIM SRAM image directly (no fp16
    rematerialization in HBM) — the serving-path integration point."""
    if use_kernel:
        b_shape = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        m = x2.shape[0]
        bm = 128 if m % 128 == 0 else (m if m <= 128 else None)
        if bm is not None and man.shape[0] % 512 == 0 and man.shape[1] % 128 == 0:
            out = bfp_matmul(x2, man, exp, n_group=n_group, block_m=bm)
            return out.reshape(*b_shape, man.shape[1])
    return x @ dequant_ref(man, exp, n_group)
