"""jit'd public wrapper for the BFP matmul kernel.

``interpret`` defaults to True off-TPU (this container validates the kernel
body on CPU); on a TPU runtime pass ``interpret=False`` for the Mosaic path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.bfp_matmul.kernel import bfp_matmul_pallas
from repro.kernels.bfp_matmul.ref import bfp_matmul_ref, dequant_ref, pack_bfp  # noqa: F401


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return math.ceil(x / m) * m


@functools.partial(jax.jit, static_argnames=("n_group", "block_m", "block_n",
                                             "block_k", "interpret"))
def bfp_matmul(x, man, exp, *, n_group: int = 8, block_m: int = 128,
               block_n: int = 128, block_k: int = 512,
               interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return bfp_matmul_pallas(x, man, exp, n_group=n_group, block_m=block_m,
                             block_n=block_n, block_k=block_k,
                             interpret=interpret)


def cim_linear(x, man, exp, *, n_group: int = 8, use_kernel: bool = True,
               with_info: bool = False):
    """Linear layer consuming the BFP weight planes directly (no fp16
    rematerialization in HBM) — the serving-path integration point.

    Arbitrary M/K/N are zero-padded up to tile boundaries (padded activations
    are zero, so the result is unchanged) instead of silently falling back to
    the dequantized reference; the kernel therefore runs whenever
    ``use_kernel`` is set. ``with_info=True`` additionally returns
    ``{'used_kernel': bool}`` so callers/tests can assert the kernel path.
    """
    b_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    n_out = man.shape[1]
    if use_kernel:
        m, k = x2.shape
        bm = min(128, _round_up(m, 8))
        bk = max(n_group, (min(512, k) // n_group) * n_group)
        bn = 128
        m_t, k_t, n_t = _round_up(m, bm), _round_up(k, bk), _round_up(n_out, bn)
        xp = jnp.pad(x2, ((0, m_t - m), (0, k_t - k)))
        manp = jnp.pad(man, ((0, k_t - k), (0, n_t - n_out)))
        expp = jnp.pad(exp, ((0, k_t // n_group - exp.shape[0]),
                             (0, n_t - n_out)))
        out = bfp_matmul(xp, manp, expp, n_group=n_group, block_m=bm,
                         block_n=bn, block_k=bk)
        out = out[:m, :n_out].reshape(*b_shape, n_out)
        return (out, {"used_kernel": True}) if with_info else out
    out = (x2 @ dequant_ref(man, exp, n_group)).reshape(*b_shape, n_out)
    return (out, {"used_kernel": False}) if with_info else out
