"""Pure-jnp oracle for the BFP (One4N) matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitops


def pack_bfp(w_aligned: jnp.ndarray, n_group: int = 8):
    """Exponent-aligned fp16-grid weights [K, N] -> (man uint16, exp uint8).

    man packs sign (bit 15) and the 10-bit mantissa; exp holds the shared
    biased exponent per [n_group, :] block (block max — exact for aligned w).
    """
    k, n = w_aligned.shape
    assert k % n_group == 0
    s, e, m = bitops.split_fields(w_aligned, bitops.FP16)
    man = ((s.astype(jnp.uint32) << 15) | m.astype(jnp.uint32)).astype(jnp.uint16)
    exp = jnp.max(e.reshape(k // n_group, n_group, n), axis=1).astype(jnp.uint8)
    return man, exp


def dequant_ref(man: jnp.ndarray, exp: jnp.ndarray, n_group: int = 8):
    """Inverse of pack_bfp (normal numbers; alignment never emits exp=0)."""
    k, n = man.shape
    sign = jnp.where((man >> 15) == 1, -1.0, 1.0).astype(jnp.float32)
    frac = 1.0 + (man & 0x3FF).astype(jnp.float32) / 1024.0
    scale = jnp.exp2(exp.astype(jnp.float32) - 15.0)
    scale_full = jnp.repeat(scale, n_group, axis=0)
    return sign * frac * scale_full


def bfp_matmul_ref(x: jnp.ndarray, man: jnp.ndarray, exp: jnp.ndarray,
                   n_group: int = 8) -> jnp.ndarray:
    w = dequant_ref(man, exp, n_group)
    return x.astype(jnp.float32) @ w
