"""Pallas TPU kernel: block-shared-exponent (One4N / BFP) matmul.

TPU-native realization of the Unicorn-CIM macro (DESIGN.md §2): weights live
in SRAM-image form — a sign+mantissa plane (uint16: bit15 = sign, bits 0..9 =
fp16 mantissa) plus ONE shared biased exponent per ``n_group`` rows (the
input-channel direction, exactly the paper's Fig. 3 ① grouping). The kernel
streams HBM->VMEM tiles, dequantizes in VMEM (exponent applied as an exact
power-of-two scale) and feeds the MXU with fp32 accumulation:

    mantissa multiplication array  -> MXU dot on the dequantized tile
    exponent summation/alignment   -> folded into the pow2 scale (exact)
    sign processing unit (XOR)     -> sign factor in the dequant

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary") with output revisiting —
the [bm, bn] fp32 accumulator stays in VMEM across the K loop.

Block constraints: bm/bn multiples of 128 (MXU-aligned), bk a multiple of
``n_group`` so each K tile covers whole exponent groups.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _dequant_tile(man, exp, n_group: int):
    """man uint16 [bk, bn] (sign|mantissa), exp uint8 [bk//n_group, bn] -> f32."""
    sign = jnp.where((man >> 15) == 1, -1.0, 1.0).astype(jnp.float32)
    frac = 1.0 + (man & 0x3FF).astype(jnp.float32) * (1.0 / 1024.0)
    scale = jnp.exp2(exp.astype(jnp.float32) - 15.0)     # [bk/n, bn]
    bk, bn = man.shape
    scale_full = jnp.broadcast_to(scale[:, None, :], (bk // n_group, n_group, bn))
    scale_full = scale_full.reshape(bk, bn)
    return sign * frac * scale_full


def _bfp_matmul_kernel(x_ref, man_ref, exp_ref, o_ref, *, n_group: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = _dequant_tile(man_ref[...], exp_ref[...], n_group)
    o_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                          preferred_element_type=jnp.float32)


def bfp_matmul_pallas(x, man, exp, *, n_group: int = 8,
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 512, interpret: bool = True):
    """x [M, K] float; man uint16 [K, N]; exp uint8 [K//n_group, N] -> [M, N] f32."""
    m, k = x.shape
    k2, n = man.shape
    assert k == k2 and exp.shape == (k // n_group, n)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    assert block_k % n_group == 0

    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_bfp_matmul_kernel, n_group=n_group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k // n_group, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, man, exp)
