"""Deterministic synthetic data pipelines.

* ``MarkovLM`` — a fixed-seed first-order Markov chain over the vocabulary with
  sparse transitions: genuinely learnable (a trained model beats the unigram
  floor by a wide margin), so fault-injection accuracy degradation is a real
  signal, not noise. Used by examples/benchmarks.
* ``batches_for`` — shape-correct random batches for any (arch x shape) cell,
  including the modality stubs (vision patch / audio frame embeddings).
* ``GaussianBlobs`` — tiny image-classification task for the paper-family CNN
  benchmark (stands in for ImageNet-scale tasks, see DESIGN.md §1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.losses import IGNORE


@dataclasses.dataclass
class MarkovLM:
    vocab_size: int
    seq_len: int
    batch_size: int
    branching: int = 4        # successors per token
    seed: int = 1234

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.successors = rng.integers(
            0, self.vocab_size, (self.vocab_size, self.branching))

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng(hash((self.seed, step)) % 2 ** 32)
        toks = np.empty((self.batch_size, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, self.batch_size)
        choices = rng.integers(0, self.branching,
                               (self.batch_size, self.seq_len))
        for t in range(self.seq_len):
            toks[:, t + 1] = self.successors[toks[:, t], choices[:, t]]
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass
class CheckpointableLoader:
    """Stateful, restartable data iterator (production data pipeline).

    Wraps any ``batch(step)``-style source; its cursor is a pytree leaf that
    rides inside the training checkpoint, so a restart (or an elastic
    reshard) resumes at the exact batch the failed run would have consumed
    next — no repeated or skipped data. Deterministic: batch(step) is a pure
    function of (seed, step), so replaying a cursor always yields identical
    batches on any host count.
    """

    source: object
    cursor: int = 0

    def __next__(self):
        b = self.source.batch(self.cursor)
        self.cursor += 1
        return b

    def __iter__(self):
        return self

    def state_dict(self) -> Dict[str, int]:
        return {"cursor": self.cursor}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        self.cursor = int(state["cursor"])


def batches_for(cfg: ModelConfig, shape: ShapeConfig, batch_override: int = 0,
                seq_override: int = 0, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """One random batch with the exact input structure of the arch."""
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.modality == "vision_stub":
        p = cfg.n_prefix_embeds
        toks = jax.random.randint(k1, (b, s - p), 0, cfg.vocab_size, jnp.int32)
        vis = jax.random.normal(k2, (b, p, cfg.d_model), jnp.float32) * 0.02
        labels = jnp.concatenate(
            [jnp.full((b, p), IGNORE, jnp.int32),
             jax.random.randint(k3, (b, s - p), 0, cfg.vocab_size, jnp.int32)], 1)
        return {"tokens": toks, "vision_embeds": vis, "labels": labels}
    if cfg.modality == "audio_stub":
        emb = jax.random.normal(k2, (b, s, cfg.d_model), jnp.float32) * 0.02
        labels = jax.random.randint(k3, (b, s), 0, cfg.vocab_size, jnp.int32)
        return {"embeds": emb, "labels": labels}
    toks = jax.random.randint(k1, (b, s), 0, cfg.vocab_size, jnp.int32)
    labels = jax.random.randint(k3, (b, s), 0, cfg.vocab_size, jnp.int32)
    return {"tokens": toks, "labels": labels}


@dataclasses.dataclass
class GaussianBlobs:
    """K-class Gaussian blobs rendered as small images (CNN benchmark task).

    noise/center scales are set so a trained CNN sits at ~85-95% accuracy —
    headroom for the Table I alignment grid to discriminate (a saturated task
    reports ratio 1.0 for every N x index cell)."""
    n_classes: int = 16
    image_size: int = 16
    channels: int = 3
    noise: float = 2.5
    seed: int = 7

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.centers = rng.standard_normal(
            (self.n_classes, self.image_size, self.image_size, self.channels))

    def batch(self, batch_size: int, step: int):
        rng = np.random.default_rng(hash((self.seed, step)) % 2 ** 32)
        y = rng.integers(0, self.n_classes, batch_size)
        x = self.centers[y] + rng.standard_normal(
            (batch_size, self.image_size, self.image_size, self.channels)) * self.noise
        return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)
