"""MusicGen-large: decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] — 48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192
vocab=2048. The EnCodec frontend is a STUB: input_specs provide precomputed
frame embeddings; decode embeds generated audio tokens.
"""
from repro.configs.base import ModelConfig, register


@register("musicgen-large")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="musicgen-large", family="dense",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=2048,
        mlp_type="gelu", norm_type="layernorm",
        modality="audio_stub",
        tag="[arXiv:2306.05284; hf]",
    )
