"""Command-R 35B: GQA, no-bias dense transformer.

[hf:CohereForAI/c4ai-command-r-v01; unverified] — 40L d_model=8192 64H
(GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.configs.base import ModelConfig, register


@register("command-r-35b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="command-r-35b", family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22528, vocab_size=256000,
        mlp_type="swiglu", norm_type="layernorm",
        rope_theta=8e6,
        tag="[hf:CohereForAI/c4ai-command-r-v01; unverified]",
    )
