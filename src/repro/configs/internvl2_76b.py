"""InternVL2-76B backbone: InternLM2-76B decoder (+ InternViT patch stub).

[arXiv:2404.16821; unverified] — 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256. The vision frontend is a STUB per the assignment: input_specs
provide 256 precomputed patch embeddings prepended to the text tokens.
"""
from repro.configs.base import ModelConfig, register


@register("internvl2-76b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="internvl2-76b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab_size=128256,
        mlp_type="swiglu", norm_type="rmsnorm",
        modality="vision_stub", n_prefix_embeds=256,
        rope_theta=1e6,
        tag="[arXiv:2404.16821; unverified]",
    )
