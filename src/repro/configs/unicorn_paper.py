"""The paper's own benchmark family, reduced to container scale.

The paper evaluates ResNet18 / YOLOv5 / nnUNet / TinyViT; `tinyvit-paper` is
a small ViT-style transformer and the CNN lives in repro.models.cnn (used by
the Fig. 2/6/7 + Table I benchmarks). See DESIGN.md §1 fidelity notes.
"""
from repro.configs.base import ModelConfig, register


@register("tinyvit-paper")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="tinyvit-paper", family="dense",
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=512,
        mlp_type="gelu", norm_type="layernorm",
        tag="[paper benchmark family; reduced]",
    )
