"""CodeQwen1.5-7B: qwen1.5 architecture.

[hf:Qwen/CodeQwen1.5-7B; hf] — 32L d_model=4096 32H (GQA kv=32... listed MHA)
d_ff=13440 vocab=92416.
"""
from repro.configs.base import ModelConfig, register


@register("codeqwen1.5-7b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="codeqwen1.5-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=13440, vocab_size=92416,
        mlp_type="swiglu", norm_type="rmsnorm",
        rope_theta=1e6,
        tag="[hf:Qwen/CodeQwen1.5-7B; hf]",
    )
