"""RecurrentGemma-9B (Griffin): RG-LRU recurrent blocks + local attention, 1:2.

[arXiv:2402.19427; unverified] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, window 2048. Pattern (rec, rec, local) cycled; sub-quadratic ->
runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, register


@register("recurrentgemma-9b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab_size=256000,
        mlp_type="gelu", norm_type="rmsnorm",
        block_pattern=("rec", "rec", "local"),
        d_rnn=4096, local_window=2048,
        sub_quadratic=True,
        tag="[arXiv:2402.19427; unverified]",
    )
