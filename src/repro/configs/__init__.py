# Architecture registry: importing this package registers every assigned arch.
from repro.configs import (  # noqa: F401
    codeqwen15_7b,
    command_r_35b,
    dbrx_132b,
    granite_3_8b,
    internvl2_76b,
    musicgen_large,
    olmo_1b,
    qwen3_moe_235b_a22b,
    recurrentgemma_9b,
    rwkv6_1_6b,
    unicorn_paper,
)
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    get_config,
    list_archs,
)
