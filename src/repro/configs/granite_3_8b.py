"""Granite-3 8B: GQA dense transformer.

[hf:ibm-granite/granite-3.0-2b-base; hf] — 40L d_model=4096 32H (GQA kv=8)
d_ff=12800 vocab=49155.
"""
from repro.configs.base import ModelConfig, register


@register("granite-3-8b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-3-8b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12800, vocab_size=49155,
        mlp_type="swiglu", norm_type="rmsnorm",
        tag="[hf:ibm-granite/granite-3.0-2b-base; hf]",
    )
