"""Qwen3-MoE 235B-A22B: 128 experts, top-8, GQA kv=4.

[hf:Qwen/Qwen3-30B-A3B; hf] — 94L d_model=4096 64H (GQA kv=4)
d_ff_expert=1536 vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ModelConfig, register


@register("qwen3-moe-235b-a22b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab_size=151936,
        n_experts=128, top_k=8, d_ff_expert=1536,
        mlp_type="swiglu", norm_type="rmsnorm",
        block_pattern=("moe",),
        rope_theta=1e6,
        tag="[hf:Qwen/Qwen3-30B-A3B; hf]",
    )
