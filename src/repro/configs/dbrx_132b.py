"""DBRX 132B: fine-grained MoE, 16 experts top-4, GQA kv=8.

[hf:databricks/dbrx-base; unverified] — 40L d_model=6144 48H (GQA kv=8)
d_ff_expert=10752 vocab=100352, MoE 16e top-4.
"""
from repro.configs.base import ModelConfig, register


@register("dbrx-132b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab_size=100352,
        n_experts=16, top_k=4, d_ff_expert=10752,
        mlp_type="swiglu", norm_type="layernorm",
        block_pattern=("moe",),
        rope_theta=5e5,
        tag="[hf:databricks/dbrx-base; unverified]",
    )
