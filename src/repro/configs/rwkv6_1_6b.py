"""RWKV6 "Finch" 1.6B: attention-free, data-dependent decay.

[arXiv:2404.05892; unverified] — 24L d_model=2048 d_ff=7168 vocab=65536.
Head size 64 -> 32 rwkv heads. Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, register


@register("rwkv6-1.6b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="rwkv6-1.6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=7168, vocab_size=65536,
        mlp_type="rwkv_cmix", norm_type="layernorm",
        block_pattern=("rwkv",),
        sub_quadratic=True,
        tag="[arXiv:2404.05892; unverified]",
    )
