"""Config system: architecture + shape + reliability + run configs.

Every assigned architecture registers a :class:`ModelConfig` under its id
(``--arch <id>`` in the launchers). ``reduced()`` derives the same-family
smoke-test config (small widths/layers/experts) used by the per-arch CPU
tests; the full config is exercised only via the dry-run (ShapeDtypeStruct,
no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core.api import ReliabilityConfig

# ---------------------------------------------------------------- shapes

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------- model

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # blocks
    mlp_type: str = "swiglu"         # swiglu | gelu | rwkv_cmix
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm | nonparametric_ln
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_dispatch: str = "a2a"        # a2a: shard_map all-to-all EP (falls back
                                     #   to "sort" without a mesh/model axis)
                                     # sort | cumsum: GSPMD dense dispatch
    attn_impl: str = "cp"            # cp: q stays seq-sharded, gather K/V only
                                     # tp: heads on "model" (full-seq gather; baseline)
    mlp_impl: str = "fsdp"           # fsdp: weights gathered, tokens stay sharded
                                     # tp: Megatron (ff on "model"; baseline)
    kv_cache_dtype: str = "compute"  # compute | int8 (per-token-head scales)
    # hybrid / recurrent
    block_pattern: Tuple[str, ...] = ("attn",)   # cycled over layers
    d_rnn: int = 0
    local_window: int = 0            # 0 -> full attention
    conv_width: int = 4
    # modality stub
    modality: str = "text"           # text | vision_stub | audio_stub
    n_prefix_embeds: int = 0         # vision_stub: # of patch embeddings
    # numerics
    rope_theta: float = 1e4
    compute_dtype: str = "float32"   # smoke default; launcher overrides bf16
    param_dtype: str = "float32"
    # attention chunking threshold (q-chunked attention above this seq len)
    attn_chunk_q: int = 1024
    attn_chunk_threshold: int = 8192
    # which shapes this arch supports (long_500k only for sub-quadratic)
    sub_quadratic: bool = False
    tag: str = ""                    # provenance note [source; tier]

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def supports_shape(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False
        return True

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def reduced(self) -> "ModelConfig":
        """Same-family tiny config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 if len(self.block_pattern) < 2
                         else len(self.block_pattern)),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, max(1, min(self.n_heads, 4) // 2))
            if self.n_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256,
            d_ff_expert=64 if self.n_experts else 0,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            vocab_size=256,
            d_rnn=128 if self.d_rnn else 0,
            local_window=min(self.local_window, 64) if self.local_window else 0,
            n_prefix_embeds=min(self.n_prefix_embeds, 4),
            attn_chunk_threshold=10 ** 9,
        )


# ---------------------------------------------------------------- registry

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    from repro import configs as _  # noqa: F401  (triggers registration)
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs():
    from repro import configs as _  # noqa: F401
    return sorted(_REGISTRY)


# ---------------------------------------------------------------- run config

@dataclasses.dataclass(frozen=True)
class RunConfig:
    """One training run.

    Reliability is **policy-native**: hand a
    :class:`repro.core.deployment.ReliabilityPolicy` to ``policy`` (with
    ``ber``/``inject`` for the dynamic fault schedule). The legacy
    ``reliability=ReliabilityConfig(...)`` field still works — it is compiled
    into a single-rule policy bit-compatibly — but is deprecated;
    ``run_training`` warns on it. Setting both is an error.

    ``exp_reg_coef`` turns on the exponent-compression regularizer (co-design
    fine-tuning stage 1, see :mod:`repro.training.codesign`);
    ``freeze_exponents=False`` disables exponent alignment + the frozen
    (exponent, sign) projection even when the policy/config is enabled, so the
    regularizer can reshape the exponent distribution before alignment.
    """

    arch: str = "olmo-1b"
    shape: str = "train_4k"
    steps: int = 100
    learning_rate: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    multi_pod: bool = False
    seq_shard: bool = True
    remat: bool = True
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    reliability: Optional[ReliabilityConfig] = None   # DEPRECATED: use policy
    grad_compression: bool = False
    straggler_factor: float = 3.0
    # policy-native reliability surface
    policy: Optional[object] = None   # ReliabilityPolicy
    ber: float = 0.0                  # deployment BER for the fault schedule
    inject: str = "dynamic"           # static | dynamic
    # co-design fine-tuning knobs
    exp_reg_coef: float = 0.0         # exponent-compression regularizer weight
    exp_reg_margin: float = 1.0       # allowed per-block exponent spread (lg)
    freeze_exponents: bool = True     # align + project when reliability is on

    def __post_init__(self):
        if self.policy is not None:
            if self.reliability is not None:
                raise ValueError(
                    "RunConfig: pass either policy= (the policy-native "
                    "surface) or the deprecated reliability=, not both")
            from repro.core import deployment as dep_lib
            if not isinstance(self.policy, dep_lib.ReliabilityPolicy):
                raise TypeError(f"RunConfig: policy must be a "
                                f"ReliabilityPolicy, got "
                                f"{type(self.policy).__name__}")
        if self.ber < 0:
            raise ValueError(f"RunConfig: ber must be >= 0, got {self.ber}")
        if self.inject not in ("static", "dynamic"):
            raise ValueError(f"RunConfig: inject must be 'static' or "
                             f"'dynamic', got {self.inject!r}")

    @property
    def rel(self) -> ReliabilityConfig:
        """The resolved reliability config of this run: the policy compiled
        via :meth:`ReliabilityConfig.from_policy` when ``policy`` is set, the
        legacy ``reliability`` when given, else the inert default."""
        if self.policy is not None:
            return ReliabilityConfig.from_policy(self.policy, ber=self.ber,
                                                 inject=self.inject)
        if self.reliability is not None:
            return self.reliability
        return ReliabilityConfig()
