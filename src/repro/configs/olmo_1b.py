"""OLMo-1B: dense, non-parametric LayerNorm (no learnable scale/bias).

[arXiv:2402.00838; hf] — 16L d_model=2048 16H (MHA) d_ff=8192 vocab=50304.
"""
from repro.configs.base import ModelConfig, register


@register("olmo-1b")
def config() -> ModelConfig:
    return ModelConfig(
        arch_id="olmo-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab_size=50304,
        mlp_type="swiglu", norm_type="nonparametric_ln",
        tag="[arXiv:2402.00838; hf]",
    )
