"""ShapeDtypeStruct input specs + sharding specs for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns stand-ins for every model input (the
shannon/kernels pattern: weak-type-correct, shardable, zero allocation).
Training/prefill cells feed token batches; decode cells feed (caches, token).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shlib
from repro.models import lm


def batch_struct(cfg: ModelConfig, shape: ShapeConfig, with_labels: bool) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    out = {}
    if cfg.modality == "vision_stub":
        p = cfg.n_prefix_embeds
        out["tokens"] = sds((b, s - p), jnp.int32)
        out["vision_embeds"] = sds((b, p, cfg.d_model), jnp.bfloat16)
    elif cfg.modality == "audio_stub":
        out["embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = sds((b, s), jnp.int32)
    if with_labels:
        out["labels"] = sds((b, s), jnp.int32)
    return out


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(0), cfg))


def abstract_caches(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: lm.init_slot_states(cfg, shape.global_batch, shape.seq_len,
                                    prefilled=shape.seq_len - 1))


# ---------------------------------------------------------------- shardings

def _ns(mesh, spec: P, shape) -> NamedSharding:
    return NamedSharding(mesh, shlib.sanitize_spec(mesh, spec, shape))


def batch_shardings(mesh: Mesh, tree) -> object:
    def spec(path, leaf):
        s = shlib.logical(*(("batch",) + (None,) * (leaf.ndim - 1)))
        return _ns(mesh, s, leaf.shape)
    return jax.tree_util.tree_map_with_path(spec, tree)


def _cache_rules(cfg):
    from repro.models.attention import cache_spec
    kv = cache_spec(cfg)
    return {
        "k": kv,
        "v": kv,
        "k_scale": kv,
        "v_scale": kv,
        "s": ("batch", "heads", None, None),
        "x_tmix": ("batch", None),
        "x_cmix": ("batch", None),
        "h": ("batch", "heads"),
        "conv": ("batch", None, "heads"),
    }


def cache_shardings(mesh: Mesh, cfg, caches) -> object:
    rules = _cache_rules(cfg)

    def spec(path, leaf):
        name = None
        for part in reversed(path):
            if hasattr(part, "key"):
                name = str(part.key)
                break
        tail = rules.get(name)
        if name == "pos" and leaf.ndim >= 2:       # local-attn slot positions
            tail = ("batch", None)
        if tail is None or leaf.ndim < len(tail):
            return NamedSharding(mesh, P())
        lead = (None,) * (leaf.ndim - len(tail))
        return _ns(mesh, shlib.logical(*(lead + tail)), leaf.shape)
    return jax.tree_util.tree_map_with_path(spec, caches)


def param_shardings_sane(mesh: Mesh, tree, serve_replicated: bool = False):
    """serve_replicated: inference layout — weights replicated over "data"
    (no per-step FSDP gathers; there is no optimizer state to amortize them
    against) and TP-sharded over "model" only. Fits when
    params x 2B / model_axis <= HBM (granite-3-8b: 1.0 GB/device)."""
    def one(path, leaf):
        spec = shlib.param_spec(shlib._path_str(path), leaf.ndim)
        if serve_replicated:
            spec = P(*[None if ax == "data" else ax for ax in spec])
        return _ns(mesh, spec, leaf.shape)
    return jax.tree_util.tree_map_with_path(one, tree)


def state_shardings(mesh: Mesh, abstract_state):
    """Shardings for a TrainState: params/m/v/signs by param rules, exps by the
    same rules (block-exponent planes inherit their weight's layout)."""
    from repro.training.steps import TrainState
    pspec = param_shardings_sane(mesh, abstract_state.params)
    opt = {"m": param_shardings_sane(mesh, abstract_state.opt["m"]),
           "v": param_shardings_sane(mesh, abstract_state.opt["v"]),
           "step": NamedSharding(mesh, P())}

    def aux_spec(tree):
        def one(path, leaf):
            if leaf is None:
                return None
            spec = shlib.param_spec(shlib._path_str(path), leaf.ndim)
            return _ns(mesh, spec, leaf.shape)
        return jax.tree_util.tree_map_with_path(one, tree,
                                                is_leaf=lambda x: x is None)

    return TrainState(params=pspec, opt=opt,
                      exps=aux_spec(abstract_state.exps),
                      signs=aux_spec(abstract_state.signs),
                      ef_error=None if abstract_state.ef_error is None
                      else param_shardings_sane(mesh, abstract_state.ef_error))
