import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " \
    + os.environ.get("XLA_FLAGS", "")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh (16x16 single-pod / 2x16x16 multi-pod) with zero allocation:
inputs are ShapeDtypeStructs, parameters come from ``jax.eval_shape``.
Outputs (memory analysis, cost analysis, collective bytes, compile time) are
written to ``artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json`` and feed
EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, RunConfig, get_config, list_archs
from repro.core.api import ReliabilityConfig
from repro.distributed import sharding as shlib
from repro.launch import hlo_analysis, specs
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.training import steps


def _mem_analysis(compiled):
    try:
        m = compiled.memory_analysis()
        if m is None:
            return {"note": "memory_analysis unavailable on this backend"}
        keys = ("temp_size_in_bytes", "argument_size_in_bytes",
                "output_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes", "peak_memory_in_bytes")
        out = {k: int(getattr(m, k)) for k in keys if hasattr(m, k)}
        return out or {"repr": str(m)}
    except Exception as e:  # CPU backend may not implement it
        return {"error": f"{type(e).__name__}: {e}"}


def _param_bytes_per_device(tree, shardings, n_devices):
    total = 0
    flat = jax.tree_util.tree_leaves(tree)
    for leaf in flat:
        total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return total, total / n_devices  # upper bound: fully sharded


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rel_mode: str = "align", seq_shard: bool = True,
               extra_cfg: dict | None = None, unroll: bool = False,
               serve_replicated: bool = False):
    """Build + lower one cell; returns (lowered, meta)."""
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, compute_dtype="bfloat16",
                              **(extra_cfg or {}))
    shape = SHAPES[shape_name]
    if not cfg.supports_shape(shape):
        return None, {"skipped": "full attention is quadratic at 500k; "
                                 "run only for sub-quadratic archs (DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    shlib.set_mesh(mesh, seq_shard=seq_shard)

    if shape.kind == "train":
        run = RunConfig(arch=arch, shape=shape_name,
                        reliability=ReliabilityConfig(mode=rel_mode))
        abstract_state = jax.eval_shape(
            functools.partial(steps.init_train_state, cfg=cfg, run=run),
            jax.random.PRNGKey(0))
        st_sh = specs.state_shardings(mesh, abstract_state)
        bt = specs.batch_struct(cfg, shape, with_labels=True)
        bt_sh = specs.batch_shardings(mesh, bt)
        step_fn = steps.make_train_step(cfg, run, unroll=unroll)
        jitted = jax.jit(step_fn, in_shardings=(st_sh, bt_sh),
                         out_shardings=(st_sh, None), donate_argnums=(0,))
        lowered = jitted.lower(abstract_state, bt)
        n_params = lm.param_count(abstract_state.params)
    elif shape.kind == "prefill":
        params = specs.abstract_params(cfg)
        p_sh = specs.param_shardings_sane(mesh, params)
        bt = specs.batch_struct(cfg, shape, with_labels=False)
        bt_sh = specs.batch_shardings(mesh, bt)
        jitted = jax.jit(steps.make_prefill_step(cfg, unroll=unroll),
                         in_shardings=(p_sh, bt_sh))
        lowered = jitted.lower(params, bt)
        n_params = lm.param_count(params)
    else:  # decode
        params = specs.abstract_params(cfg)
        p_sh = specs.param_shardings_sane(mesh, params, serve_replicated)
        caches = specs.abstract_caches(cfg, shape)
        c_sh = specs.cache_shardings(mesh, cfg, caches)
        toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        t_sh = specs._ns(mesh, shlib.logical("batch", None), toks.shape)
        jitted = jax.jit(steps.make_serve_step(cfg, unroll=unroll),
                         in_shardings=(p_sh, c_sh, t_sh), donate_argnums=(1,))
        lowered = jitted.lower(params, caches, toks)
        n_params = lm.param_count(params)

    meta = {"n_params": int(n_params), "mesh": list(mesh.devices.shape),
            "axes": list(mesh.axis_names)}
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             rel_mode: str = "align", seq_shard: bool = True,
             overwrite: bool = False, tag: str = ""):
    mesh_name = "multi" if multi_pod else "single"
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    if os.path.exists(path) and not overwrite:
        print(f"[skip-cached] {path}")
        return json.load(open(path))
    os.makedirs(out_dir, exist_ok=True)

    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "rel_mode": rel_mode, "seq_shard": seq_shard, "tag": tag}
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod, rel_mode,
                                   seq_shard)
        if lowered is None:
            record.update(meta)
            json.dump(record, open(path, "w"), indent=1)
            print(f"[skipped ] {arch} x {shape_name} x {mesh_name}: {meta['skipped']}")
            return record
        record.update(meta)
        record["lower_s"] = round(time.time() - t0, 1)

        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

        mem = _mem_analysis(compiled)
        record["memory_analysis"] = mem
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        flops = float(cost.get("flops", 0.0))
        bts = float(cost.get("bytes accessed", 0.0))
        record["cost_analysis"] = {"flops": flops, "bytes_accessed": bts}

        coll = hlo_analysis.collective_bytes(compiled.as_text())
        record["collectives"] = coll
        chips = 512 if multi_pod else 256
        coll_total = sum(v for k, v in coll.items() if k != "count")
        record["roofline"] = hlo_analysis.roofline_terms(flops, bts, coll_total,
                                                         chips)
        shape = SHAPES[shape_name]
        tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else 1)
        if shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
        record["model_flops"] = hlo_analysis.model_flops(
            record["n_params"], tokens, shape.kind)
        print(f"[ok      ] {arch} x {shape_name} x {mesh_name}: "
              f"compile {record['compile_s']}s flops/dev {flops:.3e} "
              f"bytes/dev {bts:.3e} coll/dev {coll_total:.3e} "
              f"dominant {record['roofline']['dominant']}")
        print(f"           memory_analysis: {mem}")
    except Exception as e:
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        print(f"[FAILED  ] {arch} x {shape_name} x {mesh_name}: {record['error']}")
    json.dump(record, open(path, "w"), indent=1)
    return record


def _measure(lowered, multi_pod):
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": {k: v for k, v in coll.items()}}


def run_roofline_cell(arch: str, shape_name: str, out_dir: str,
                      rel_mode: str = "align", seq_shard: bool = True,
                      overwrite: bool = False, tag: str = "",
                      extra_cfg: dict | None = None,
                      serve_replicated: bool = False):
    """Exact roofline terms via 1-group/2-group UNROLLED lowerings.

    XLA cost analysis counts a scan body once, so the full-depth scan compile
    undercounts per-layer flops/bytes/collectives. Layers are identical across
    groups, so:  per_group = m(2g) - m(1g);  outer = m(1g) - per_group;
    total = outer + G_full * per_group (+ tail scaled by its layer fraction).
    Single-pod mesh only (the roofline table is single-pod by assignment).
    """
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}__roofline{suffix}.json")
    if os.path.exists(path) and not overwrite:
        print(f"[skip-cached] {path}")
        return json.load(open(path))
    os.makedirs(out_dir, exist_ok=True)
    record = {"arch": arch, "shape": shape_name, "mesh": "single",
              "rel_mode": rel_mode, "seq_shard": seq_shard, "tag": tag,
              "method": "unrolled 1g/2g extrapolation"}
    try:
        cfg_full = get_config(arch)
        pat_len = len(cfg_full.block_pattern)
        n_groups_full = cfg_full.n_layers // pat_len
        n_tail = cfg_full.n_layers % pat_len
        shape = SHAPES[shape_name]
        if not cfg_full.supports_shape(shape):
            record["skipped"] = "sub-quadratic only (DESIGN.md §4)"
            json.dump(record, open(path, "w"), indent=1)
            return record

        t0 = time.time()
        measures = {}
        # decode: extrapolate from (0, 1) groups — G>=2 unrolled decode makes
        # GSPMD replicate sliced cache shards (~36 GB/layer of spurious bytes
        # the real scan path never moves); train/prefill use (1, 2).
        g_lo, g_hi = (0, 1) if shape.kind == "decode" else (1, 2)
        for k_groups in (g_lo, g_hi):
            lowered, meta = lower_cell(
                arch, shape_name, multi_pod=False, rel_mode=rel_mode,
                seq_shard=seq_shard, unroll=True,
                extra_cfg=dict(extra_cfg or {}, n_layers=pat_len * k_groups),
                serve_replicated=serve_replicated)
            measures[k_groups] = _measure(lowered, multi_pod=False)
        record["compile_s"] = round(time.time() - t0, 1)
        record["extrapolation_groups"] = [g_lo, g_hi]

        def extrapolate(f1, f2):
            per_group = f2 - f1
            outer = f1 - g_lo * per_group
            total = outer + n_groups_full * per_group
            if n_tail:
                total += per_group * (n_tail / pat_len)
            return total, per_group, outer

        flops, flops_g, flops_o = extrapolate(measures[g_lo]["flops"],
                                              measures[g_hi]["flops"])
        byts, bytes_g, bytes_o = extrapolate(measures[g_lo]["bytes"],
                                             measures[g_hi]["bytes"])
        coll_kinds = {}
        for kind in hlo_analysis.COLLECTIVES:
            tot, _, _ = extrapolate(float(measures[g_lo]["coll"][kind]),
                                    float(measures[g_hi]["coll"][kind]))
            coll_kinds[kind] = max(tot, 0.0)
        coll_total = sum(coll_kinds.values())

        # full-model params for MODEL_FLOPS (active params for MoE)
        cfg = dataclasses.replace(cfg_full, compute_dtype="bfloat16")
        n_params = lm.param_count(specs.abstract_params(cfg))
        n_active = n_params
        if cfg.n_experts:
            per_layer_expert = 3 * cfg.d_model * cfg.d_ff_expert
            n_active = n_params - cfg.n_layers * cfg.n_experts * per_layer_expert \
                + cfg.n_layers * cfg.top_k * per_layer_expert
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind in ("train", "prefill") else 1)
        record.update({
            "n_params": int(n_params), "n_active_params": int(n_active),
            "per_device": {"flops": flops, "bytes": byts,
                           "coll_bytes": coll_total,
                           "flops_per_group": flops_g, "flops_outer": flops_o,
                           "bytes_per_group": bytes_g},
            "collectives": coll_kinds,
            "roofline": hlo_analysis.roofline_terms(flops, byts, coll_total, 256),
            "model_flops": hlo_analysis.model_flops(n_params, tokens, shape.kind,
                                                    n_active),
        })
        r = record["roofline"]
        print(f"[roofline] {arch} x {shape_name}: compute {r['compute_s']:.4f}s "
              f"memory {r['memory_s']:.4f}s coll {r['collective_s']:.4f}s "
              f"dominant {r['dominant']} "
              f"(model_flops/HLO = {record['model_flops'] / max(flops * 256, 1):.3f})")
    except Exception as e:
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        print(f"[FAILED  ] roofline {arch} x {shape_name}: {record['error']}")
    json.dump(record, open(path, "w"), indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rel-mode", default="align", choices=["off", "align"])
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--overwrite", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--roofline", action="store_true",
                    help="also produce unrolled-extrapolation roofline artifacts")
    ap.add_argument("--roofline-only", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    assigned = [a for a in list_archs() if a != "tinyvit-paper"]
    archs = [args.arch] if args.arch else assigned
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            if not args.roofline_only:
                for mp in meshes:
                    rec = run_cell(arch, shape, mp, args.out, args.rel_mode,
                                   not args.no_seq_shard, args.overwrite, args.tag)
                    failures += 1 if "error" in rec else 0
            if args.roofline or args.roofline_only:
                rec = run_roofline_cell(arch, shape, args.out, args.rel_mode,
                                        not args.no_seq_shard, args.overwrite,
                                        args.tag)
                failures += 1 if "error" in rec else 0
    print(f"dry-run complete; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
