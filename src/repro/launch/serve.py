"""Serving launcher: batched prefill + decode with CIM-deployed weights.

The weight path mirrors deployment on a Unicorn-CIM macro: weights are
exponent-aligned and packed into the word-packed SRAM image (mantissa plane +
SECDED codeword words, or raw exponent rows + packed sign words).

Two serve paths (``--serve-path``):

* ``fused`` (default) — the model's CIM-deployed matrices stay **packed** for
  the whole run: the unembed projection runs through the fused decode-on-read
  Pallas kernel (``kernels/cim_read``: SECDED decode + FP16 reconstruction +
  matmul in VMEM) and the embedding table is decoded row-by-row at gather
  time. Decoded fp16 weight matrices never materialize in HBM. Supports
  static injection (``--inject static``: flip the image once, serve many) and
  per-read dynamic injection (``--inject dynamic``: every prefill/decode step
  draws fresh counter-PRNG faults in-kernel, keyed by the decode position).
* ``hbm`` — the legacy path: inject + ECC-decode once, rematerialize fp16
  weights, serve those (the baseline ``benchmarks/cim_store_bench.py``
  compares against).

  python -m repro.launch.serve --arch olmo-1b --reduced --batch 4 \\
      --prompt-len 64 --gen 32 --cim --ber 1e-4 --protect one4n \\
      --serve-path fused --inject dynamic
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import cim as cim_lib
from repro.core.api import ReliabilityConfig
from repro.data.synthetic import MarkovLM
from repro.models import lm
from repro.training import steps as steps_lib


def deploy(params, *, ber: float, protect: str, n_group: int, index: int,
           key):
    """HBM path: align -> pack -> (inject) -> read. Returns the decoded fp16
    weights the macro would serve, plus ECC statistics."""
    cfg = cim_lib.CIMConfig(n_group=n_group, index=index, protect=protect)

    def eligible(path, leaf):
        return hasattr(leaf, "ndim") and leaf.ndim == 2 and \
            jnp.issubdtype(leaf.dtype, jnp.floating)

    stores, aligned = cim_lib.deploy_pytree(params, cfg, predicate=eligible)
    if ber > 0:
        stores = cim_lib.inject_pytree(key, stores, ber)
    return cim_lib.read_pytree(stores)


def _fused_eligible(path, leaf):
    """The fused serve path CIM-deploys the big embedding/unembedding
    matrices (block weights are scan-stacked >2-D and were never deployable)."""
    names = {getattr(p, "key", None) for p in path}
    return hasattr(leaf, "ndim") and leaf.ndim == 2 and \
        jnp.issubdtype(leaf.dtype, jnp.floating) and \
        bool({"embed", "unembed"} & names)


def deploy_fused(params, *, ber: float, protect: str, n_group: int,
                 index: int, key, inject_mode: str, field: str):
    """Fused path: align -> pack; weights STAY packed. Static faults are
    injected into the image; dynamic faults ride in via the ``_cim`` runtime
    (per-read seeds + thresholds consumed by the model's read hooks)."""
    cfg = cim_lib.CIMConfig(n_group=n_group, index=index, protect=protect)
    stores, _ = cim_lib.deploy_pytree(params, cfg, predicate=_fused_eligible)
    if ber > 0 and inject_mode == "static":
        stores = cim_lib.inject_pytree(key, stores, ber, field)
    if ber > 0 and inject_mode == "dynamic":
        from repro.kernels.fault_inject.ops import ber_to_threshold
        thr = ber_to_threshold(ber)
        zero = jnp.uint32(0)
        stores["_cim"] = {
            "seeds": cim_lib.plane_seeds(jax.random.fold_in(key, 99)),
            "thr_man": thr if field in ("full", "mantissa") else zero,
            "thr_meta": thr if field in ("full", "exponent_sign") else zero,
        }
    return stores


def _fused_report(stores):
    n_stores, packed_bytes, fp16_bytes = 0, 0, 0
    corrected = uncorrectable = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            stores, is_leaf=cim_lib._is_store)[0]:
        if cim_lib._is_store(leaf):
            n_stores += 1
            packed_bytes += leaf.stored_bytes
            fp16_bytes += 2 * leaf.shape[0] * leaf.shape[1]
            st = cim_lib.store_stats(leaf)
            corrected += int(st["corrected"])
            uncorrectable += int(st["uncorrectable"])
    print(f"CIM fused serve: {n_stores} weight matrices stay packed "
          f"({packed_bytes / 1e6:.2f} MB image vs {fp16_bytes / 1e6:.2f} MB "
          f"decoded fp16 — never materialized); "
          f"corrected={corrected} uncorrectable={uncorrectable}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cim", action="store_true", help="serve via CIM image")
    ap.add_argument("--ber", type=float, default=0.0)
    ap.add_argument("--protect", default="one4n",
                    choices=["one4n", "per_weight", "none"])
    ap.add_argument("--n-group", type=int, default=8)
    ap.add_argument("--index", type=int, default=2)
    ap.add_argument("--serve-path", default=None, choices=["fused", "hbm"],
                    help="fused: decode-on-read kernels off the packed image; "
                         "hbm: decode once to fp16 copies "
                         "(default: ReliabilityConfig.serve_path)")
    ap.add_argument("--inject", default="static",
                    choices=["static", "dynamic"],
                    help="static: flip the image once; dynamic: fresh "
                         "in-kernel faults on every weight read (fused only)")
    ap.add_argument("--field", default="full",
                    choices=["full", "mantissa", "exponent_sign"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert cfg.modality == "text", "serving demo uses text archs"
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_lm(key, cfg)

    serve_path = args.serve_path or ReliabilityConfig().serve_path
    stats = None
    if args.cim or args.ber > 0:
        if serve_path == "fused":
            params = deploy_fused(
                params, ber=args.ber, protect=args.protect,
                n_group=args.n_group, index=args.index,
                key=jax.random.fold_in(key, 1), inject_mode=args.inject,
                field=args.field)
            _fused_report(params)
        else:
            params, stats = deploy(params, ber=args.ber, protect=args.protect,
                                   n_group=args.n_group, index=args.index,
                                   key=jax.random.fold_in(key, 1))
            print(f"CIM deploy (hbm): protect={args.protect} "
                  f"ber={args.ber:.1e} corrected={int(stats['corrected'])} "
                  f"uncorrectable={int(stats['uncorrectable'])}")

    data = MarkovLM(cfg.vocab_size, args.prompt_len, args.batch, seed=args.seed)
    prompts = data.batch(0)["tokens"]

    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    serve = jax.jit(steps_lib.make_serve_step(cfg))

    t0 = time.time()
    logits, caches = prefill(params, {"tokens": prompts})
    # grow attention caches to hold the generated tokens
    total = args.prompt_len + args.gen

    def grow(a):
        if a.ndim >= 4 and a.shape[-3] == args.prompt_len:
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, args.gen)
            return jnp.pad(a, pad)
        return a
    caches = jax.tree_util.tree_map(grow, caches)
    prefill_s = time.time() - t0

    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    t1 = time.time()
    for _ in range(args.gen - 1):
        logits, caches = serve(params, caches, toks)
        toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(toks)
    decode_s = time.time() - t1

    gen = jnp.concatenate(out, axis=1)
    tok_per_s = args.batch * (args.gen - 1) / max(decode_s, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {prefill_s*1e3:.0f} ms; "
          f"decode: {tok_per_s:.1f} tok/s; sample: {gen[0, :16].tolist()}")
    return gen, stats


if __name__ == "__main__":
    main()
