"""Serving launcher: batched prefill + decode with CIM-deployed weights.

The weight path mirrors deployment on a Unicorn-CIM macro: weights are
exponent-aligned and packed into the word-packed SRAM image (mantissa plane +
SECDED codeword words, or raw exponent rows + packed sign words).

Two serve paths (``--serve-path``):

* ``fused`` (default) — the model's CIM-deployed matrices stay **packed** for
  the whole run: the unembed projection runs through the fused decode-on-read
  Pallas kernel (``kernels/cim_read``: SECDED decode + FP16 reconstruction +
  matmul in VMEM) and the embedding table is decoded row-by-row at gather
  time. Decoded fp16 weight matrices never materialize in HBM. Supports
  static injection (``--inject static``: flip the image once, serve many) and
  per-read dynamic injection (``--inject dynamic``: every prefill/decode step
  draws fresh counter-PRNG faults in-kernel, keyed by the decode position).
* ``hbm`` — the legacy path: inject + ECC-decode once, rematerialize fp16
  weights, serve those (the baseline ``benchmarks/cim_store_bench.py``
  compares against).

Multi-device serving (``--mesh DATAxMODEL``, e.g. ``--mesh 2x4``): requests
are data-parallel (each "data" row of the mesh serves its own batch shard)
while every CIM store's packed planes are column-sharded over "model" — one
shard ≈ one macro column group, served through the ``shard_map``'d fused
kernel (``kernels/cim_read.ops.cim_linear_store_sharded``). tok/s is
reported per device and aggregate. ``--rounds`` turns the single batch into
a serving loop over successive request batches.

  python -m repro.launch.serve --arch olmo-1b --reduced --batch 4 \\
      --prompt-len 64 --gen 32 --cim --ber 1e-4 --protect one4n \\
      --serve-path fused --inject dynamic --mesh 2x4 --rounds 2

``--engine`` swaps the lock-step batch loop for the continuous-batching
engine (``repro.launch.engine``): a synthetic open-loop Poisson load of
``--requests`` requests at ``--rate`` req/s with ragged prompt/generation
lengths is scheduled through ``--slots`` decode slots (chunked prefill,
per-request counter-PRNG fault streams, per-request ECC + TTFT accounting;
``--engine-json`` writes the per-request artifact). This file stays a thin
frontend — the scheduler lives in ``repro.launch.engine``.

  python -m repro.launch.serve --arch olmo-1b --reduced --engine \\
      --cim --ber 1e-3 --inject dynamic --slots 4 --chunk 8 \\
      --requests 32 --rate 64 --prompt-range 4,24 --gen-range 4,12 \\
      --engine-json artifacts/engine.json
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core import cim as cim_lib
from repro.core import deployment as dep_lib
from repro.core.api import ReliabilityConfig
from repro.data.synthetic import MarkovLM
from repro.distributed import sharding as shlib
from repro.models import lm
from repro.training import steps as steps_lib


def serving_policy(*, protect: str, n_group: int, index: int,
                   field: str = "full", serve_path: str = "fused"
                   ) -> dep_lib.ReliabilityPolicy:
    """The serving launcher's deployment policy.

    ``fused``: only the big embedding/unembedding matrices deploy (block
    weights are scan-stacked >2-D and were never deployable) and stay
    packed. ``hbm``: every 2-D float matrix deploys, to be decoded once.

    Row-cache economics: the **unembed** projection needs the full decoded
    matrix on every decode step, so static serving warms its decoded-row
    cache once per fault image (a fault refresh only re-decodes this one
    leaf). The **embed** table opts out — each step gathers a handful of
    rows, decoded on read straight off the packed image, so a full decode
    (the thing the HBM path pays on every refresh) never happens for it.
    """
    rule = dep_lib.PolicyRule(pattern="*", protect=protect, n_group=n_group,
                              index=index, field=field, serve_path=serve_path)
    if serve_path == "hbm":
        return dep_lib.ReliabilityPolicy(rules=(), default=rule)
    return dep_lib.ReliabilityPolicy(
        rules=(dataclasses.replace(rule, pattern="embed", row_cache=False),
               dataclasses.replace(rule, pattern="unembed", row_cache=True)),
        default=dep_lib.PolicyRule(deploy=False))


def expert_serving_policy(*, protect: str, n_group: int, index: int,
                          field: str = "full", ber_scales: dict = None
                          ) -> dep_lib.ReliabilityPolicy:
    """Per-expert MoE deployment policy (``--expert-cim``).

    Every expert store (paths like ``groups/blk0/moe_win/g0/expert3``) gets
    the launcher's protection settings; ``ber_scales`` maps expert index ->
    BER scale for experts on weaker macros (``{3: 4.0}`` ages expert 3 of
    every MoE matrix 4x harder).
    """
    base = dep_lib.PolicyRule(pattern="*", protect=protect, n_group=n_group,
                              index=index, field=field, serve_path="hbm")
    rules = tuple(
        dataclasses.replace(base, pattern=f"*/expert{e}", ber_scale=s)
        for e, s in sorted((ber_scales or {}).items()))
    return dep_lib.ReliabilityPolicy(rules=rules, default=base)


def deploy(params, *, ber: float, protect: str, n_group: int, index: int,
           key, fault_model: str = ""):
    """HBM path through :class:`CIMDeployment`: align -> pack -> (inject) ->
    read. Returns the decoded fp16 weights the macro would serve, plus ECC
    statistics."""
    policy = serving_policy(protect=protect, n_group=n_group, index=index,
                            serve_path="hbm")
    dep = dep_lib.CIMDeployment.deploy(params, policy)
    if ber > 0:
        dep = dep.inject(key, ber, field="full", model=fault_model or None)
    return dep.read()


def deploy_fused(params, *, ber: float, protect: str, n_group: int,
                 index: int, key, inject_mode: str, field: str,
                 fault_model: str = ""):
    """Fused path through :class:`CIMDeployment`: align -> pack; weights STAY
    packed. Static faults are injected into the image; dynamic faults ride in
    via the ``_cim`` runtime (per-read seeds + thresholds consumed by the
    model's read hooks). Returns the serving params pytree; the deployment
    object itself comes from :func:`make_deployment`."""
    dep = make_deployment(params, ber=ber, protect=protect, n_group=n_group,
                          index=index, key=key, inject_mode=inject_mode,
                          field=field, fault_model=fault_model)
    return dep.serving_params(**serving_kw(
        ber=ber, key=key, inject_mode=inject_mode, field=field,
        fault_model=fault_model))


def make_deployment(params, *, ber: float, protect: str, n_group: int,
                    index: int, key, inject_mode: str, field: str,
                    fault_model: str = "") -> dep_lib.CIMDeployment:
    policy = serving_policy(protect=protect, n_group=n_group, index=index,
                            field=field, serve_path="fused")
    dep = dep_lib.CIMDeployment.deploy(params, policy)
    if ber > 0 and inject_mode == "static":
        dep = dep.inject(key, ber, field=field, model=fault_model or None)
    return dep


def serving_kw(*, ber, key, inject_mode, field, fault_model: str = ""):
    """The ``serving_params`` kwargs for this launch — shared with the scrub
    controller so a post-scrub params rebuild serves identically."""
    dynamic = ber > 0 and inject_mode == "dynamic"
    return dict(
        dynamic_key=jax.random.fold_in(key, 99) if dynamic else None,
        ber=ber if dynamic else 0.0, field=field,
        model=(fault_model or None) if dynamic else None)


def _serving_params(dep, *, ber, key, inject_mode, field, fault_model=""):
    return dep.serving_params(**serving_kw(
        ber=ber, key=key, inject_mode=inject_mode, field=field,
        fault_model=fault_model))


def make_serve_mesh(spec: str) -> Mesh:
    """``"DxM"`` -> a ``("data", "model")`` mesh over the first D*M devices."""
    d_ax, m_ax = (int(v) for v in spec.lower().split("x"))
    devs = jax.devices()
    assert d_ax * m_ax <= len(devs), \
        f"mesh {spec} needs {d_ax * m_ax} devices, have {len(devs)}"
    return Mesh(np.asarray(devs[:d_ax * m_ax]).reshape(d_ax, m_ax),
                ("data", "model"))


def place_on_mesh(params, mesh: Mesh):
    """Serving placement: CIM stores column-sharded over "model" (one shard
    per macro column group); every other leaf — block weights, norms, the
    ``_cim`` dynamic runtime — replicated. One rule, shared with
    ``CIMDeployment.shard`` (:func:`repro.core.deployment.place_stores`)."""
    return dep_lib.place_stores(params, mesh, axis="model", dim="j")


def _fused_report(stores):
    n_stores, n_cached, packed_bytes, fp16_bytes, cache_bytes = 0, 0, 0, 0, 0
    corrected = uncorrectable = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            stores, is_leaf=cim_lib._is_store)[0]:
        if cim_lib._is_store(leaf):
            n_stores += 1
            packed_bytes += leaf.stored_bytes
            fp16_bytes += 2 * leaf.shape[0] * leaf.shape[1]
            if leaf.cache is not None:
                n_cached += 1
                cache_bytes += int(leaf.cache.size) * leaf.cache.dtype.itemsize
            st = cim_lib.store_stats(leaf)
            corrected += int(st["corrected"])
            uncorrectable += int(st["uncorrectable"])
    print(f"CIM fused serve: {n_stores} weight matrices stay packed "
          f"({packed_bytes / 1e6:.2f} MB image vs {fp16_bytes / 1e6:.2f} MB "
          f"decoded fp16); {n_cached} hot leaves carry a decoded-row cache "
          f"({cache_bytes / 1e6:.2f} MB, rebuilt per fault image), the rest "
          f"decode on read; corrected={corrected} "
          f"uncorrectable={uncorrectable}")


def _parse_range(spec: str) -> tuple:
    lo, hi = (int(v) for v in spec.split(","))
    assert 1 <= lo <= hi, f"bad length range {spec!r}"
    return lo, hi


def _serve_engine(args, cfg, params, mesh, dep=None, scrub_kw=None,
                  expert_dep=None):
    """Thin frontend onto :class:`repro.launch.engine.Engine`: synthetic
    Poisson load -> scheduler -> per-request ECC/latency artifact.

    ``--scrub`` attaches a :class:`repro.launch.scrub.ScrubController` as the
    engine's step hook (``dep`` + ``scrub_kw`` come from the fused deploy);
    ``--age-ber`` adds a drift-aging wear process under it. ``--probe RID``
    re-serves one request through a fresh solo engine and asserts its tokens
    and ECC stream match the co-batched run bitwise (skipped-with-a-note
    when MoE capacity coupling voids the guarantee at these shapes)."""
    from repro.launch import engine as engine_lib

    load = engine_lib.LoadGen(
        n_requests=args.requests,
        rate=args.rate if args.rate > 0 else float("inf"),
        prompt_lens=_parse_range(args.prompt_range),
        gen_lens=_parse_range(args.gen_range),
        vocab_size=cfg.vocab_size, seed=args.seed)
    max_len = args.max_len or load.max_len()
    eng = engine_lib.Engine(cfg, params, n_slots=args.slots,
                            max_len=max_len, chunk=args.chunk,
                            ecc_accounting=not args.no_ecc_accounting)
    scrubber = None
    if args.scrub:
        from repro.launch import scrub as scrub_lib
        assert dep is not None, \
            "--scrub needs the fused CIM serve path (--cim --serve-path fused)"
        assert not args.no_ecc_accounting, \
            "--scrub thresholds on per-store ECC accounting"
        aging = None
        if args.age_ber > 0:
            aging = scrub_lib.DriftAging(
                key=jax.random.fold_in(jax.random.PRNGKey(args.seed), 7),
                ber=args.age_ber, model=args.fault_model or "drift",
                every=args.age_every)
        scrubber = scrub_lib.ScrubController(
            dep, scrub_lib.ScrubPolicy(threshold=args.scrub_threshold,
                                       interval=args.scrub_interval),
            aging=aging, serving_kw=scrub_kw or {})
    requests = load.requests()
    results, agg = eng.run(requests, open_loop=args.rate > 0,
                           on_step=scrubber)

    incomplete = [r.rid for r in requests if r.rid not in results]
    assert not incomplete, f"engine dropped requests: {incomplete}"
    print(f"engine: {agg['n_requests']} requests over {args.slots} slots "
          f"(chunk {args.chunk}, max_len {max_len}); "
          f"{agg['total_tokens']} tokens in {agg['decode_steps']} decode "
          f"steps, occupancy {agg['slot_occupancy']:.2f}")
    msg = (f"decode: {agg['decode_tok_s']:.1f} tok/s aggregate; "
           f"TTFT mean {agg['ttft_s_mean']*1e3:.0f} ms "
           f"p95 {agg['ttft_s_p95']*1e3:.0f} ms; "
           f"ECC reads={agg['ecc']['reads']} "
           f"corrected={agg['ecc']['corrected']} "
           f"uncorrectable={agg['ecc']['uncorrectable']}")
    if mesh is not None:
        msg += (f" (mesh {mesh.shape['data']}x{mesh.shape['model']} "
                f"data x model, {mesh.size} devices)")
    print(msg)
    if scrubber is not None:
        sc = agg["scrub"]
        print(f"scrub: {sc['events']} events, {sc['rows_reencoded']} rows "
              f"re-encoded, corrected cleared {sc['corrected_cleared']}, "
              f"uncorrectable cleared {sc['uncorrectable_cleared']} "
              f"({sc['wall_s']*1e3:.0f} ms scrub wall)")
    if expert_dep is not None:
        est = expert_dep.stats_by_expert()
        print(f"expert CIM: {len(est)} expert stores, "
              f"corrected={sum(v['corrected'] for v in est.values())} "
              f"uncorrectable="
              f"{sum(v['uncorrectable'] for v in est.values())}")

    probe = None
    if args.probe >= 0:
        assert not args.scrub, \
            "--probe replays against the launch image; --scrub mutates it"
        preq = [r for r in requests if r.rid == args.probe]
        assert preq, f"--probe {args.probe}: no such rid in the load"
        solo_eng = engine_lib.Engine(
            cfg, params, n_slots=args.slots, max_len=max_len,
            chunk=args.chunk, ecc_accounting=not args.no_ecc_accounting)
        pres, _ = solo_eng.run(preq)
        routed, solo = results[args.probe], pres[args.probe]
        ok = (routed.tokens == solo.tokens and routed.ecc == solo.ecc)
        probe = {"rid": args.probe,
                 "tokens_equal": routed.tokens == solo.tokens,
                 "ecc_equal": routed.ecc == solo.ecc, "ok": ok,
                 "capacity_coupled": eng.capacity_coupled}
        print(f"probe rid={args.probe}: solo replay "
              f"{'MATCHES' if ok else 'DIVERGES'} "
              f"(tokens {probe['tokens_equal']}, ecc {probe['ecc_equal']})")
        if eng.capacity_coupled:
            print("probe: MoE capacity coupling active at these shapes — "
                  "bitwise match not guaranteed (moe.drop_free)")
        else:
            assert ok, f"solo-vs-cobatched probe failed: {probe}"

    if args.engine_json:
        import json
        import os
        os.makedirs(os.path.dirname(args.engine_json) or ".", exist_ok=True)
        payload = {
            "config": {"arch": args.arch, "reduced": args.reduced,
                       "slots": args.slots, "chunk": args.chunk,
                       "max_len": max_len, "requests": args.requests,
                       "rate": args.rate, "ber": args.ber,
                       "protect": args.protect, "inject": args.inject,
                       "serve_path": args.serve_path or "fused",
                       "mesh": args.mesh, "seed": args.seed,
                       "fault_model": args.fault_model,
                       "scrub": bool(args.scrub),
                       "age_ber": args.age_ber,
                       "expert_cim": bool(args.expert_cim)},
            "aggregate": agg,
            "probe": probe,
            "expert_ecc": (expert_dep.stats_by_expert()
                           if expert_dep is not None else None),
            "requests": [results[r.rid].to_json() for r in requests],
        }
        with open(args.engine_json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.engine_json}")
    return results, agg


def _serve_fleet(args, cfg, params):
    """Frontend onto :class:`repro.launch.fleet.Fleet`: one deployed image,
    ``--fleet N`` data-parallel engine replicas behind the SLO router.

    ``params`` must be UNSHARDED — the fleet spools it once and places it on
    each replica's own mesh (``--mesh DxM`` is the per-replica shape over
    disjoint device blocks). ``--probe RID`` re-serves one request through a
    fresh single-replica fleet off the same spool and asserts its tokens and
    ECC stream match the routed run bitwise (the live replica-invariance
    probe).
    """
    from repro.launch import engine as engine_lib
    from repro.launch import fleet as fleet_lib

    load = engine_lib.LoadGen(
        n_requests=args.requests,
        rate=args.rate if args.rate > 0 else float("inf"),
        prompt_lens=_parse_range(args.prompt_range),
        gen_lens=_parse_range(args.gen_range),
        vocab_size=cfg.vocab_size, seed=args.seed,
        prefix_len=args.shared_prefix)
    max_len = args.max_len or load.max_len()
    meshes = fleet_lib.make_fleet_meshes(args.mesh, args.fleet) \
        if args.mesh else None
    fl = fleet_lib.Fleet.from_serving_params(
        cfg, params, n_replicas=args.fleet, meshes=meshes,
        prefix_cache=not args.no_prefix_cache, n_slots=args.slots,
        max_len=max_len, chunk=args.chunk,
        ecc_accounting=not args.no_ecc_accounting)
    requests = load.requests()
    results, agg = fl.run(requests, open_loop=args.rate > 0)

    incomplete = [r.rid for r in requests if r.rid not in results]
    assert not incomplete, f"fleet dropped requests: {incomplete}"
    by_rep = " ".join(f"{k}={v}" for k, v in
                      sorted(agg["requests_by_replica"].items()))
    print(f"fleet: {agg['n_requests']} requests over "
          f"{agg['n_replicas']} replicas x {args.slots} slots "
          f"(chunk {args.chunk}, max_len {max_len}); routed {by_rep}")
    print(f"fleet: {agg['tok_s']:.1f} tok/s wall, "
          f"{agg['tok_s_virtual']:.1f} tok/s virtual "
          f"(busy wall {agg['busy_wall_s']:.2f}s of {agg['wall_s']:.2f}s); "
          f"TTFT mean {agg['ttft_s_mean']*1e3:.0f} ms "
          f"p95 {agg['ttft_s_p95']*1e3:.0f} ms; "
          f"prefix hits {agg['prefix_hits']} "
          f"({agg['prefix_tokens']} tokens reused)")

    probe = None
    if args.probe >= 0:
        preq = [r for r in requests if r.rid == args.probe]
        assert preq, f"--probe {args.probe}: no such rid in the load"
        pf = fleet_lib.Fleet.from_serving_params(
            cfg, params, n_replicas=1,
            meshes=meshes[:1] if meshes else None,
            spool_dir=fl.spool_dir, prefix_cache=not args.no_prefix_cache,
            n_slots=args.slots, max_len=max_len, chunk=args.chunk,
            ecc_accounting=not args.no_ecc_accounting)
        pres, _ = pf.run(preq)
        routed, solo = results[args.probe], pres[args.probe]
        ok = (routed.tokens == solo.tokens and routed.ecc == solo.ecc)
        probe = {"rid": args.probe, "replica_routed": routed.replica,
                 "tokens_equal": routed.tokens == solo.tokens,
                 "ecc_equal": routed.ecc == solo.ecc, "ok": ok}
        print(f"probe rid={args.probe}: routed via {routed.replica!r}, "
              f"solo replay {'MATCHES' if ok else 'DIVERGES'} "
              f"(tokens {probe['tokens_equal']}, ecc {probe['ecc_equal']})")
        assert ok, f"replica-invariance probe failed: {probe}"

    if args.engine_json:
        import json
        import os
        os.makedirs(os.path.dirname(args.engine_json) or ".", exist_ok=True)
        payload = {
            "config": {"arch": args.arch, "reduced": args.reduced,
                       "fleet": args.fleet, "slots": args.slots,
                       "chunk": args.chunk, "max_len": max_len,
                       "requests": args.requests, "rate": args.rate,
                       "ber": args.ber, "protect": args.protect,
                       "inject": args.inject,
                       "serve_path": args.serve_path or "fused",
                       "mesh": args.mesh, "seed": args.seed,
                       "shared_prefix": args.shared_prefix,
                       "prefix_cache": not args.no_prefix_cache},
            "aggregate": agg,
            "probe": probe,
            "requests": [results[r.rid].to_json() for r in requests],
        }
        with open(args.engine_json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.engine_json}")
    return results, agg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cim", action="store_true", help="serve via CIM image")
    ap.add_argument("--ber", type=float, default=0.0)
    ap.add_argument("--protect", default="one4n",
                    choices=["one4n", "per_weight", "none"])
    ap.add_argument("--n-group", type=int, default=8)
    ap.add_argument("--index", type=int, default=2)
    ap.add_argument("--serve-path", default=None, choices=["fused", "hbm"],
                    help="fused: decode-on-read kernels off the packed image; "
                         "hbm: decode once to fp16 copies "
                         "(default: ReliabilityConfig.serve_path)")
    ap.add_argument("--inject", default="static",
                    choices=["static", "dynamic"],
                    help="static: flip the image once; dynamic: fresh "
                         "in-kernel faults on every weight read (fused only)")
    ap.add_argument("--field", default="full",
                    choices=["full", "mantissa", "exponent_sign"])
    ap.add_argument("--expert-cim", action="store_true",
                    help="MoE archs: deploy every expert's matrices as its "
                         "own per-expert CIM store (static faults, decode-"
                         "once restack; per-expert ECC in the artifact)")
    ap.add_argument("--fault-model", default="", metavar="SPEC",
                    help="error process for injection "
                         "(repro.core.faultmodels grammar, e.g. "
                         "'burst:rate=0.3,length=8,axis=col' or "
                         "'drift:drift_rate=0.05'; default: i.i.d.)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve on a (data, model) device mesh, e.g. 2x4: "
                         "request batches shard over 'data', CIM stores "
                         "column-shard over 'model'")
    ap.add_argument("--rounds", type=int, default=1,
                    help="number of successive request batches to serve")
    # continuous-batching engine mode (repro.launch.engine)
    ap.add_argument("--engine", action="store_true",
                    help="serve a synthetic open-loop request stream through "
                         "the continuous-batching engine instead of one "
                         "lock-step batch")
    ap.add_argument("--slots", type=int, default=4,
                    help="engine decode slots (the fixed co-batch width)")
    ap.add_argument("--chunk", type=int, default=16,
                    help="engine prefill chunk length (ragged prompts)")
    ap.add_argument("--max-len", type=int, default=0,
                    help="engine per-slot KV ceiling (0: fit the load)")
    ap.add_argument("--requests", type=int, default=16,
                    help="engine load: number of requests")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="engine load: Poisson arrival rate in req/s "
                         "(0: closed burst, all arrive at t=0)")
    ap.add_argument("--prompt-range", default="8,32", metavar="LO,HI",
                    help="engine load: uniform prompt-length range")
    ap.add_argument("--gen-range", default="4,16", metavar="LO,HI",
                    help="engine load: uniform generation-length range")
    ap.add_argument("--engine-json", default=None, metavar="PATH",
                    help="write the engine's per-request ECC/latency JSON")
    ap.add_argument("--no-ecc-accounting", action="store_true",
                    help="skip per-read ECC accounting (dynamic accounting "
                         "re-decodes the codeword planes per read — "
                         "disable when measuring throughput)")
    # online ECC scrubbing (repro.launch.scrub, engine mode only)
    ap.add_argument("--scrub", action="store_true",
                    help="engine: background ECC scrubbing — when a store's "
                         "cumulative ECC events cross --scrub-threshold, "
                         "re-encode its image and hot-swap the params "
                         "(fused CIM path only)")
    ap.add_argument("--scrub-threshold", type=int, default=16,
                    help="scrub: per-store cumulative ECC events before a "
                         "re-encode fires")
    ap.add_argument("--scrub-interval", type=int, default=1,
                    help="scrub: check cadence in engine steps")
    ap.add_argument("--age-ber", type=float, default=0.0,
                    help="scrub soak: per-step static wear injection at this "
                         "BER under --fault-model (default drift), keyed per "
                         "engine step — damage accumulates until scrubbed")
    ap.add_argument("--age-every", type=int, default=1,
                    help="scrub soak: apply wear every N engine steps")
    # fleet mode (repro.launch.fleet)
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve the engine load through N data-parallel "
                         "replicas behind the SLO router (one deployed "
                         "image, spooled + restored per replica); with "
                         "--fleet, --mesh DxM is the PER-REPLICA mesh over "
                         "disjoint device blocks")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="fleet: disable per-replica prefix/KV-chunk reuse")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="L",
                    help="fleet load: prepend one shared L-token prefix to "
                         "every prompt (the system-prompt workload the "
                         "prefix cache accelerates)")
    ap.add_argument("--probe", type=int, default=-1, metavar="RID",
                    help="after the run, re-serve request RID solo (engine "
                         "mode: a fresh solo engine; fleet mode: a fresh "
                         "single-replica fleet off the same spool) and "
                         "assert tokens+ECC match the co-batched/routed run "
                         "bitwise")
    args = ap.parse_args(argv)
    assert args.rounds >= 1, "--rounds must be >= 1"

    if args.fleet > 0:
        # per-replica meshes are built (and entered) inside the fleet; the
        # image must deploy unsharded so every replica places its own copy
        return _serve(args, None)
    mesh = make_serve_mesh(args.mesh) if args.mesh else None
    if mesh is None:
        return _serve(args, None)
    with shlib.use_mesh(mesh):   # restores the global mesh on any exit
        return _serve(args, mesh)


def _serve(args, mesh):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert cfg.modality == "text", "serving demo uses text archs"
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_lm(key, cfg)

    edep = None
    if args.expert_cim:
        # expert-parallel MoE deployment: per-expert stores, static faults,
        # decode-once restack — runs BEFORE the embed/unembed deploy so the
        # fused/hbm paths see the expert weights the macros would serve
        epolicy = expert_serving_policy(
            protect=args.protect, n_group=args.n_group, index=args.index,
            field=args.field)
        edep = dep_lib.ExpertDeployment.deploy(params, epolicy)
        if args.ber > 0:
            edep = edep.inject(jax.random.fold_in(key, 2), args.ber,
                               model=args.fault_model or None)
        params = edep.serving_params(params)
        est = edep.stats_by_expert()
        print(f"expert CIM deploy: {len(est)} per-expert stores "
              f"(protect={args.protect} ber={args.ber:.1e}), "
              f"corrected={sum(v['corrected'] for v in est.values())} "
              f"uncorrectable="
              f"{sum(v['uncorrectable'] for v in est.values())}")

    serve_path = args.serve_path or ReliabilityConfig().serve_path
    stats = None
    dep = scrub_kw = None
    if args.cim or args.ber > 0:
        dkey = jax.random.fold_in(key, 1)
        if serve_path == "fused":
            dep = make_deployment(
                params, ber=args.ber, protect=args.protect,
                n_group=args.n_group, index=args.index, key=dkey,
                inject_mode=args.inject, field=args.field,
                fault_model=args.fault_model)
            if mesh is not None:
                dep = dep.shard(mesh, axis="model", dim="j")
            scrub_kw = serving_kw(ber=args.ber, key=dkey,
                                  inject_mode=args.inject, field=args.field,
                                  fault_model=args.fault_model)
            params = dep.serving_params(**scrub_kw)
            _fused_report(params)
        else:
            params, stats = deploy(params, ber=args.ber, protect=args.protect,
                                   n_group=args.n_group, index=args.index,
                                   key=dkey, fault_model=args.fault_model)
            print(f"CIM deploy (hbm): protect={args.protect} "
                  f"ber={args.ber:.1e} corrected={int(stats['corrected'])} "
                  f"uncorrectable={int(stats['uncorrectable'])}")
            if mesh is not None:
                params = place_on_mesh(params, mesh)
    elif mesh is not None:
        params = place_on_mesh(params, mesh)

    if args.fleet > 0:
        return _serve_fleet(args, cfg, params)

    if args.engine:
        return _serve_engine(args, cfg, params, mesh, dep=dep,
                             scrub_kw=scrub_kw, expert_dep=edep)

    data = MarkovLM(cfg.vocab_size, args.prompt_len, args.batch, seed=args.seed)

    def place_batch(tokens):
        if mesh is None:
            return tokens
        # per-device request shards: each "data" row serves its own slice
        spec = P("data", None) if args.batch % mesh.shape["data"] == 0 else P()
        return jax.device_put(tokens, NamedSharding(mesh, spec))

    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    serve = jax.jit(steps_lib.make_serve_step(cfg))

    def grow(a):
        # grow attention caches to hold the generated tokens
        if a.ndim >= 4 and a.shape[-3] == args.prompt_len:
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, args.gen)
            return jnp.pad(a, pad)
        return a

    gen = None
    prefill_s = decode_s = 0.0
    for r in range(args.rounds):
        prompts = place_batch(data.batch(r)["tokens"])
        t0 = time.time()
        logits, caches = prefill(params, {"tokens": prompts})
        caches = jax.tree_util.tree_map(grow, caches)
        jax.block_until_ready(logits)
        prefill_s += time.time() - t0

        toks = jnp.argmax(logits, -1)[:, None]
        out = [toks]
        t1 = time.time()
        for _ in range(args.gen - 1):
            logits, caches = serve(params, caches, toks)
            toks = jnp.argmax(logits, -1)[:, None]
            out.append(toks)
        jax.block_until_ready(toks)
        decode_s += time.time() - t1
        gen = jnp.concatenate(out, axis=1)

    n_tok = args.rounds * args.batch * (args.gen - 1)
    tok_per_s = n_tok / max(decode_s, 1e-9)
    msg = (f"prefill: {args.rounds}x{args.batch}x{args.prompt_len} in "
           f"{prefill_s*1e3:.0f} ms; decode: {tok_per_s:.1f} tok/s")
    if mesh is not None:
        msg += (f" aggregate / {tok_per_s / mesh.size:.1f} tok/s/device "
                f"(mesh {mesh.shape['data']}x{mesh.shape['model']} "
                f"data x model, {mesh.size} devices)")
    print(msg + f"; sample: {gen[0, :16].tolist()}")
    return gen, stats


if __name__ == "__main__":
    main()
