"""Serving launcher: batched prefill + decode with CIM-deployed weights.

The weight path mirrors deployment on a Unicorn-CIM macro: weights are
exponent-aligned, packed into the SRAM image (mantissa plane + shared
exponent rows + sign bits + SECDED check bits), statically injected with soft
errors at ``--ber`` and ECC-decoded on read (``--protect one4n``) or not
(``--protect none``) before serving.

  python -m repro.launch.serve --arch olmo-1b --reduced --batch 4 \\
      --prompt-len 64 --gen 32 --ber 1e-4 --protect one4n
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import cim as cim_lib
from repro.data.synthetic import MarkovLM
from repro.models import lm
from repro.training import steps as steps_lib


def deploy(params, *, ber: float, protect: str, n_group: int, index: int,
           key):
    """Align -> pack -> (inject) -> read: returns the weights the macro would
    actually serve, plus ECC statistics."""
    cfg = cim_lib.CIMConfig(n_group=n_group, index=index, protect=protect)

    def eligible(path, leaf):
        return hasattr(leaf, "ndim") and leaf.ndim == 2 and \
            jnp.issubdtype(leaf.dtype, jnp.floating)

    stores, aligned = cim_lib.deploy_pytree(params, cfg, predicate=eligible)
    if ber > 0:
        stores = cim_lib.inject_pytree(key, stores, ber)
    return cim_lib.read_pytree(stores)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cim", action="store_true", help="serve via CIM image")
    ap.add_argument("--ber", type=float, default=0.0)
    ap.add_argument("--protect", default="one4n", choices=["one4n", "none"])
    ap.add_argument("--n-group", type=int, default=8)
    ap.add_argument("--index", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert cfg.modality == "text", "serving demo uses text archs"
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_lm(key, cfg)

    stats = None
    if args.cim or args.ber > 0:
        params, stats = deploy(params, ber=args.ber, protect=args.protect,
                               n_group=args.n_group, index=args.index,
                               key=jax.random.fold_in(key, 1))
        print(f"CIM deploy: protect={args.protect} ber={args.ber:.1e} "
              f"corrected={int(stats['corrected'])} "
              f"uncorrectable={int(stats['uncorrectable'])}")

    data = MarkovLM(cfg.vocab_size, args.prompt_len, args.batch, seed=args.seed)
    prompts = data.batch(0)["tokens"]

    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    serve = jax.jit(steps_lib.make_serve_step(cfg))

    t0 = time.time()
    logits, caches = prefill(params, {"tokens": prompts})
    # grow attention caches to hold the generated tokens
    total = args.prompt_len + args.gen

    def grow(a):
        if a.ndim >= 4 and a.shape[-3] == args.prompt_len:
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, args.gen)
            return jnp.pad(a, pad)
        return a
    caches = jax.tree_util.tree_map(grow, caches)
    prefill_s = time.time() - t0

    toks = jnp.argmax(logits, -1)[:, None]
    out = [toks]
    t1 = time.time()
    for _ in range(args.gen - 1):
        logits, caches = serve(params, caches, toks)
        toks = jnp.argmax(logits, -1)[:, None]
        out.append(toks)
    jax.block_until_ready(toks)
    decode_s = time.time() - t1

    gen = jnp.concatenate(out, axis=1)
    tok_per_s = args.batch * (args.gen - 1) / max(decode_s, 1e-9)
    print(f"prefill: {args.batch}x{args.prompt_len} in {prefill_s*1e3:.0f} ms; "
          f"decode: {tok_per_s:.1f} tok/s; sample: {gen[0, :16].tolist()}")
    return gen, stats


if __name__ == "__main__":
    main()
