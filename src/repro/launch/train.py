"""Training launcher.

Runs any registered architecture (``--arch``) at any scale on the local
devices, with the paper's reliability feature as first-class flags:

  python -m repro.launch.train --arch olmo-1b --reduced --steps 200 \\
      --rel-mode align --n-group 8 --index 2
  python -m repro.launch.train --arch rwkv6-1.6b --reduced --steps 100 \\
      --rel-mode cim --ber 1e-6 --protect one4n --inject dynamic

Production meshes are exercised through ``repro.launch.dryrun`` (this
container has one device); on a real fleet this same entrypoint runs under
``jax.distributed.initialize`` with the production mesh — the loop, the
checkpointing and the elastic hooks are identical.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax

from repro.configs import SHAPES, RunConfig, get_config
from repro.core.deployment import PolicyRule, ReliabilityPolicy
from repro.data.synthetic import MarkovLM, batches_for
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.training.loop import run_training


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0, help="override width")
    ap.add_argument("--n-layers", type=int, default=0, help="override depth")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-jsonl", default="")
    # reliability (the paper's feature surface)
    ap.add_argument("--rel-mode", default="off", choices=["off", "align", "cim"])
    ap.add_argument("--n-group", type=int, default=8)
    ap.add_argument("--index", type=int, default=2)
    ap.add_argument("--ber", type=float, default=0.0)
    ap.add_argument("--protect", default="one4n",
                    choices=["one4n", "per_weight", "none"])
    ap.add_argument("--inject", default="dynamic", choices=["static", "dynamic"])
    ap.add_argument("--grad-compression", action="store_true")
    return ap


def main(argv=None):
    args = build_argparser().parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
    if args.n_layers:
        overrides["n_layers"] = args.n_layers
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    # the policy-native surface: flags build a uniform single-rule
    # ReliabilityPolicy (validated at construction — typos fail here with the
    # allowed vocabulary); --rel-mode align trains aligned but fault-free
    # (ber 0), cim adds the dynamic fault schedule
    rel_kw = {}
    if args.rel_mode != "off":
        rel_kw = dict(
            policy=ReliabilityPolicy(default=PolicyRule(
                protect=args.protect, n_group=args.n_group,
                index=args.index)),
            ber=args.ber if args.rel_mode == "cim" else 0.0,
            inject=args.inject)
    run = RunConfig(arch=args.arch, steps=args.steps, learning_rate=args.lr,
                    seed=args.seed, checkpoint_dir=args.checkpoint_dir,
                    checkpoint_every=args.checkpoint_every,
                    grad_compression=args.grad_compression, remat=False,
                    **rel_kw)

    if cfg.modality == "text":
        data = MarkovLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
        batches = iter(data)
    else:
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=args.seq,
                                    global_batch=args.batch)
        batches = iter(lambda s=[0]: None, None)  # placeholder; below

        def gen():
            step = 0
            while True:
                yield batches_for(cfg, shape, seed=args.seed + step)
                step += 1
        batches = gen()

    logf = open(args.log_jsonl, "a") if args.log_jsonl else None

    def log(step, metrics):
        line = {k: v for k, v in metrics.items()}
        if step % 10 == 0 or step == run.steps - 1:
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"acc {metrics['accuracy']:.3f} "
                  f"gnorm {metrics['grad_norm']:.2f} "
                  f"{metrics['step_time']*1e3:.0f} ms")
        if logf:
            logf.write(json.dumps(line) + "\n")

    res = run_training(cfg, run, batches, log_fn=log)
    n = lm.param_count(res.state.params)
    print(f"done: {len(res.history)} steps, {n/1e6:.2f}M params, "
          f"resumed_from={res.info['resumed_from']}, "
          f"stragglers={res.info['stragglers_flagged']}")
    if args.rel_mode == "cim":
        stats = res.ecc_stats
        print(f"deployment: {stats['stored_bits']} stored bits "
              f"({stats['overhead']:+.1%} vs raw fp16)")
    if logf:
        logf.close()
    return res


if __name__ == "__main__":
    main()
