"""Fleet-scale serving: data-parallel Engine replicas, SLO-aware routing.

One deployed CIM image serves N :class:`~repro.launch.engine.Engine`
replicas. The image is deployed ONCE (fault injection, ECC state, row
caches), spooled to the logical-layout checkpoint format
(``distributed/checkpoint.py``), and restored per replica — resharding onto
each replica's own ``("data", "model")`` mesh is a ``device_put``, never a
re-deployment. Replicas are therefore bit-identical by construction: same
packed planes, same ECC metadata, same dynamic-injection seed table.

**Router.** Arrived requests go to the admitting replica with the lowest
SLO score ``(depth + 1) * max(EWMA TTFT, floor)`` — queue depth is the
instantaneous load signal, the per-replica TTFT EWMA folds in how fast that
replica has actually been serving (a straggler replica organically sheds
load). Ties break on replica name, so routing is a pure function of the
observable state.

**Replica invariance.** A request's tokens, logits, fault streams and ECC
counts do not depend on which replica serves it, whether its prefix came
from the trie, or whether it was drained and re-admitted elsewhere:

1. every replica restores the SAME deployed image from one spool;
2. every replica runs the same jitted programs (the engine step cache is
   keyed by (``ModelConfig``, mesh) — shared outright across replicas of a
   single-device fleet, and structurally identical on per-replica meshes,
   which differ only in device ids);
3. fault streams key on (leaf salt, content/request salt, position) — no
   slot index, replica name, engine step or attempt count in the chain;
4. decode math is row-independent across slots for every slot-state kind:
   attention rows are per-slot, recurrent folds (rwkv/rec) advance per-slot
   state and are frozen while a slot is inactive (``lm.decode_slots``), and
   drop-free MoE dispatch computes each token from its own capacity row.
   Capacity-coupled MoE shapes (``lm.engine_capacity_coupled``) are the one
   documented exception — the engine warns at construction.

``tests/test_fleet.py`` asserts this bitwise, and ``serve.py --probe`` does
the same as a live fleet probe.

**Drain / re-admit.** The router heartbeats every live replica into an
:class:`~repro.distributed.elastic.ElasticCoordinator`; a replica that
misses the deadline (or is force-failed) is drained — its queued AND
in-flight requests return to the router queue in arrival order and re-route
to survivors. A recovered heartbeat re-admits the replica
(``drain_recovered``). Re-served requests reproduce their uninterrupted
results exactly (ingredient 3 above).

**Throughput accounting.** ``aggregate()`` reports real wall tok/s AND
``tok_s_virtual`` = total tokens / max per-replica busy-wall. On a real
fleet the replicas run on disjoint devices concurrently, so the busiest
replica's wall IS the fleet wall; in this container the router steps
replicas sequentially on shared host cores, so real wall adds replicas up
instead of overlapping them. ``tok_s_virtual`` is the disjoint-device
projection the scaling gate tracks (deterministic in the schedule, not in
host-core contention).
"""
from __future__ import annotations

import contextlib
import dataclasses
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.core import deployment as dep_lib
from repro.distributed import checkpoint as ckpt_lib
from repro.distributed import sharding as shlib
from repro.distributed.elastic import ElasticCoordinator
from repro.launch.engine import Engine, Request, RequestResult


class FleetError(RuntimeError):
    """No admitting replica for arrived work, or inconsistent router state."""


def make_fleet_meshes(spec: str, n_replicas: int) -> List[Mesh]:
    """``"DxM"`` per-replica meshes over DISJOINT device blocks.

    Replica i owns devices ``[i*D*M, (i+1)*D*M)`` reshaped to
    ``("data", "model")`` — the fleet is data-parallel across blocks, each
    block is model-parallel inside (the 2x(1x4) CI split).
    """
    d_ax, m_ax = (int(v) for v in spec.lower().split("x"))
    per = d_ax * m_ax
    devs = jax.devices()
    assert per * n_replicas <= len(devs), \
        f"fleet of {n_replicas} x mesh {spec} needs {per * n_replicas} " \
        f"devices, have {len(devs)}"
    return [Mesh(np.asarray(devs[i * per:(i + 1) * per]).reshape(d_ax, m_ax),
                 ("data", "model")) for i in range(n_replicas)]


@dataclasses.dataclass
class Replica:
    """One engine + its mesh + the router's view of its service rate."""

    name: str
    engine: Engine
    mesh: Optional[Mesh] = None
    ewma_ttft: float = 0.0
    served: int = 0
    busy_s: float = 0.0               # wall seconds inside this engine

    def observe_ttft(self, ttft: float, alpha: float) -> None:
        self.ewma_ttft = ttft if self.served == 0 else \
            (1 - alpha) * self.ewma_ttft + alpha * ttft
        self.served += 1

    def score(self) -> float:
        """Lower = more attractive: queue depth x demonstrated TTFT."""
        return (self.engine.depth + 1) * max(self.ewma_ttft, 1e-3)

    def _mesh_ctx(self):
        return shlib.use_mesh(self.mesh) if self.mesh is not None \
            else contextlib.nullcontext()


class Fleet:
    """N data-parallel engine replicas behind the SLO-aware router."""

    def __init__(self, cfg: ModelConfig, replicas: List[Replica], *,
                 heartbeat_timeout: float = 60.0, ewma_alpha: float = 0.25,
                 max_depth: Optional[int] = None,
                 spool_dir: Optional[str] = None):
        assert replicas, "a fleet needs at least one replica"
        self.cfg = cfg
        self.replicas: Dict[str, Replica] = {r.name: r for r in replicas}
        assert len(self.replicas) == len(replicas), "duplicate replica names"
        self.coordinator = ElasticCoordinator(
            [r.name for r in replicas], model_axis=1,
            heartbeat_timeout=heartbeat_timeout)
        self.ewma_alpha = ewma_alpha
        self.max_depth = max_depth
        self.spool_dir = spool_dir
        self._admitting = {r.name for r in replicas}
        self._suppressed: set = set()     # force-failed: no heartbeats
        self._queue: List[Tuple[Request, float]] = []   # (req, submit_t)
        self.results: Dict[int, RequestResult] = {}
        self.routed: Dict[int, str] = {}  # rid -> replica that FINISHED it
        self.drains = 0
        self.requeued = 0
        self._open_loop = False

    # ------------------------------------------------------------ build

    @classmethod
    def from_serving_params(cls, cfg: ModelConfig, sparams, *,
                            n_replicas: int, meshes: Optional[List[Mesh]] = None,
                            spool_dir: Optional[str] = None,
                            prefix_cache: bool = True,
                            heartbeat_timeout: float = 60.0,
                            ewma_alpha: float = 0.25,
                            max_depth: Optional[int] = None,
                            **engine_kw) -> "Fleet":
        """Spool ``sparams`` once, restore+place per replica, build engines.

        ``meshes`` (from :func:`make_fleet_meshes`) gives each replica its
        own device block; ``None`` replicates on the default device (the
        single-device soak). ``engine_kw`` passes through to every
        :class:`Engine` (``n_slots``, ``max_len``, ``chunk``, ...).
        """
        assert n_replicas >= 1, n_replicas
        if meshes is not None:
            assert len(meshes) == n_replicas, (len(meshes), n_replicas)
        spool = spool_dir or tempfile.mkdtemp(prefix="fleet_spool_")
        ckpt_lib.save(sparams, 0, spool)
        replicas = []
        for i in range(n_replicas):
            name = f"replica{i}"
            mesh = meshes[i] if meshes is not None else None
            restored, _ = ckpt_lib.restore(sparams, spool)
            if mesh is not None:
                # construct under the replica's mesh: the engine's jitted
                # steps are cached per (cfg, mesh), and replicas on disjoint
                # device blocks must each trace their own constraints
                with shlib.use_mesh(mesh):
                    placed = dep_lib.place_stores(restored, mesh,
                                                  axis="model", dim="j")
                    eng = Engine(cfg, placed, replica=name,
                                 prefix_cache=True if prefix_cache else None,
                                 **engine_kw)
            else:
                placed = jax.device_put(restored)
                eng = Engine(cfg, placed, replica=name,
                             prefix_cache=True if prefix_cache else None,
                             **engine_kw)
            replicas.append(Replica(name=name, engine=eng, mesh=mesh))
        return cls(cfg, replicas, heartbeat_timeout=heartbeat_timeout,
                   ewma_alpha=ewma_alpha, max_depth=max_depth,
                   spool_dir=spool)

    # ------------------------------------------------------------ elasticity

    def _drain(self, name: str) -> None:
        """Pull a replica's queued + in-flight work back into the router."""
        self._admitting.discard(name)
        rep = self.replicas[name]
        with rep._mesh_ctx():
            back = rep.engine.drain()
        self.drains += 1
        self.requeued += len(back)
        for req in back:
            self._queue.append((req, req.arrival if self._open_loop else 0.0))
        self._queue.sort(key=lambda e: (e[1], e[0].arrival, e[0].rid))

    def fail(self, name: str) -> None:
        """Simulated outage: stop heartbeats, force-fail, drain now."""
        assert name in self.replicas, name
        self._suppressed.add(name)
        self.coordinator.mark_failed(name)
        self._drain(name)

    def recover(self, name: str) -> None:
        """End a simulated outage; the next tick's heartbeat re-admits."""
        self._suppressed.discard(name)

    # ------------------------------------------------------------ routing

    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    def _route(self, now: float) -> List[int]:
        routed = []
        while self._queue:
            req, submit_t = self._queue[0]
            if submit_t > now:
                break
            cands = [r for r in self.replicas.values()
                     if r.name in self._admitting
                     and (self.max_depth is None
                          or r.engine.depth < self.max_depth)]
            if not cands:
                if not self._admitting:
                    raise FleetError(
                        f"request {req.rid} arrived with no admitting "
                        f"replica (all drained, none recovered)")
                break                      # backpressure: retry next tick
            best = min(cands, key=lambda r: (r.score(), r.name))
            self._queue.pop(0)
            best.engine.submit(req, now=submit_t)
            routed.append(req.rid)
        return routed

    def tick(self, now: Optional[float] = None) -> dict:
        """One router cycle: heartbeat, drain failures, re-admit recoveries,
        route arrivals, step every busy replica one decode."""
        if now is None:
            now = self._clock()
        for name in self.replicas:
            if name not in self._suppressed:
                self.coordinator.heartbeat(name)
        for name in self.coordinator.check():
            self._drain(name)
        for name in self.coordinator.drain_recovered():
            self._admitting.add(name)
        routed = self._route(now)
        stepped, finished = [], []
        for rep in self.replicas.values():
            if not rep.engine.busy:
                continue
            t0 = time.perf_counter()
            with rep._mesh_ctx():
                ev = rep.engine.step(now=now)
            rep.busy_s += time.perf_counter() - t0
            stepped.append(rep.name)
            for rid in ev["evicted"]:
                res = rep.engine.results[rid]
                self.results[rid] = res
                self.routed[rid] = rep.name
                rep.observe_ttft(res.ttft_s, self.ewma_alpha)
                finished.append(rid)
        return {"routed": routed, "stepped": stepped, "finished": finished}

    def run(self, requests, *, open_loop: bool = False
            ) -> Tuple[Dict[int, RequestResult], dict]:
        """Serve ``requests`` to completion -> (results by rid, aggregate)."""
        self._open_loop = open_loop
        self._t0 = time.perf_counter()
        for rep in self.replicas.values():
            rep.engine.start(self._t0)    # one time base fleet-wide
        for req in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            self._queue.append((req, req.arrival if open_loop else 0.0))
        while self._queue or any(r.engine.busy for r in self.replicas.values()):
            ev = self.tick()
            if not ev["stepped"] and self._queue:
                # open loop: next arrival is in the future — sleep to it
                wait = self._queue[0][1] - self._clock()
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        return self.results, self.aggregate()

    # ------------------------------------------------------------ reporting

    def aggregate(self) -> dict:
        res = list(self.results.values())
        ttfts = np.asarray([r.ttft_s for r in res]) if res else np.zeros(1)
        total_tok = sum(len(r.tokens) for r in res)
        wall = self._clock() if hasattr(self, "_t0") else 0.0
        per = {name: rep.engine.aggregate()
               for name, rep in self.replicas.items()}
        busy_wall = max((rep.busy_s for rep in self.replicas.values()),
                        default=0.0)
        by_rep = {name: sum(1 for r in res if r.replica == name)
                  for name in self.replicas}
        return {
            "n_replicas": len(self.replicas),
            "n_requests": len(res),
            "total_tokens": total_tok,
            "wall_s": wall,
            "busy_wall_s": busy_wall,
            "tok_s": total_tok / wall if wall > 0 else 0.0,
            # disjoint-device projection: the busiest replica's wall is the
            # fleet wall when replicas run concurrently (see module doc)
            "tok_s_virtual": total_tok / busy_wall if busy_wall > 0 else 0.0,
            "ttft_s_mean": float(ttfts.mean()),
            "ttft_s_p95": float(np.percentile(ttfts, 95)),
            "ttft_s_p99": float(np.percentile(ttfts, 99)),
            "requests_by_replica": by_rep,
            "drains": self.drains,
            "requeued": self.requeued,
            "prefix_hits": sum(p["prefix_hits"] for p in per.values()),
            "prefix_tokens": sum(p["prefix_tokens"] for p in per.values()),
            "scrub": {
                key: sum(p.get("scrub", {}).get(key, 0) for p in per.values())
                for key in ("events", "rows_reencoded", "corrected_cleared",
                            "uncorrectable_cleared", "wall_s")
            },
            "replicas": per,
        }
