"""Continuous-batching CIM serving engine with per-request fault streams.

The paper's threat model is soft errors striking the FP CIM macro *during
inference*; this engine is where that is demonstrated under realistic load.
It serves a stream of requests through a fixed decode batch of ``n_slots``
slots over the :class:`~repro.core.deployment.CIMDeployment` dispatch path:

* **admit** — a queued request (arrived, open-loop) takes a free slot; its
  prompt is chunk-prefilled (``chunk`` tokens per jitted call, ragged tail
  padded) into the slot's row of the batched slot states. Position-addressed
  kinds hide padding behind the causal mask until later writes overwrite it;
  fold kinds (rwkv/rec) mask padding out of the state fold itself. The final
  chunk's logits give the first token (TTFT is measured here).
* **decode** — one jitted :func:`repro.models.lm.decode_slots` step advances
  every active slot at its own position.
* **evict** — a slot that hits its request's ``max_new`` (or the cache
  ceiling ``max_len``) frees; the next queued request reuses it, lowest slot
  index first.

The engine is architecture-agnostic: it speaks only the slot-state protocol
(:class:`repro.models.lm.SlotStateSpec` and the ``init_slot_states`` /
``prefill_chunk`` / ``decode_slots`` / ``extract_state_chunk`` /
``inject_state_chunk`` operations), so KV-cache transformers, windowed
local attention, RWKV6, RecurrentGemma and expert-parallel MoE all serve
through the same admit/decode/evict loop. The only per-kind concessions are
shape clamps derived from the specs: ``chunk`` is clamped to the local
window when any block is ``window_bound`` (a ring buffer cannot absorb a
chunk larger than itself).

**Batch-invariance contract.** Every CIM read folds its dynamic-injection
seeds per (leaf salt, request salt, request-local position) — never per slot
index or engine step (:func:`repro.core.deployment.request_read_seeds`).
Prompt-prefill reads salt by prompt *content*
(:func:`repro.core.deployment.prefix_salt` of the tokens up through the
chunk); decode reads salt by request id
(:func:`repro.core.deployment.request_salt`). Decode math is row-independent
across slots for every kind (recurrent folds advance per-slot state and are
frozen while a slot is inactive), so a request's decoded tokens, logits and
injected-fault streams are bit-identical whether it is served alone or
continuously co-batched (``tests/test_engine.py`` asserts this for all five
kinds). The one contract boundary is capacity-coupled MoE dispatch: when
``moe.drop_free`` does not hold at the engine's shapes, co-batched tokens
can evict each other from expert capacity and the bitwise guarantee is
voided (fault-stream keying stays per-request). Drop-free configurations —
including every engine with ``max(n_slots, chunk) <= 8``, via the capacity
floor — retain the full guarantee; the engine warns only when actually
coupled (:func:`repro.models.lm.engine_capacity_coupled`).

**Prefix/state-cache reuse.** With a :class:`PrefixCache` attached,
admission walks the prompt's full leading chunks through a hash-consed
token-chunk trie: a hit injects the cached state chunk into the slot
(:func:`repro.models.lm.inject_state_chunk`) instead of re-running
``prefill_chunk``, and replays the chunk's ECC accounting from the same
(leaf, content-salt, position) counter-PRNG chain cold prefill would have
drawn — tokens, logits, fault streams and ECC counts stay bitwise identical
to a cold prefill, only TTFT drops. Cached units follow each block's spec:
KV rows for position-addressed kinds, the post-chunk state *snapshot* for
fold/window kinds — exact because those states are pure left folds over the
salted token prefix, and the engine always prefills at fixed ``chunk``
boundaries, so a cold recompute of the same prefix runs the same chunk
shapes and reproduces the snapshot bitwise. The final chunk always runs
cold (its logits emit the first token). Any image or runtime change must go
through :meth:`Engine.refresh_params`, which invalidates the trie (the
invalidation-on-inject contract: cached state embeds the faults of the
image it was prefilled against).

**Fleet hooks.** ``repro.launch.fleet`` runs N engines as data-parallel
replicas behind an SLO-aware router: :meth:`Engine.drain` hands back queued
and in-flight requests for re-admission elsewhere (re-serving from scratch
reproduces the same tokens — streams key on content/request/position, never
on the attempt), :attr:`Engine.depth` feeds the router's queue-depth
scoring, and :meth:`Engine.start` aligns the engine clock to the fleet's so
latency accounting shares one origin.

**Accounting.** Per request: queue wait, TTFT, decode seconds, tok/s, and
ECC activity — every CIM read is charged the macro's corrected/uncorrectable
codeword counts for the image that read observed (the static image's counts
per read, or the per-(request, position) dynamically-faulted image when a
``_cim`` runtime rides in params). Aggregate: tok/s over the decode loop and
per-slot occupancy.

``LoadGen`` drives the engine open-loop: Poisson arrivals at ``rate`` req/s
(arrivals are wall-clock gated, independent of service) with uniform prompt
and generation length ranges.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cim as cim_lib
from repro.core import deployment as dep_lib
from repro.distributed import sharding as shlib
from repro.models import lm
from repro.training import steps as steps_lib


class EngineError(RuntimeError):
    """Non-finite logits or an inconsistent scheduler state."""


# one jitted (prefill_chunk, decode_slots, extract_state, inject_state) set per
# (ModelConfig, ambient mesh): every Engine instance over the same arch AND
# mesh shares the jit cache, so a fresh engine (e.g. a solo-request
# invariance replay, or every replica of a single-device fleet) costs zero
# recompiles at matched shapes. The mesh is part of the key because
# ``sharding.shard`` bakes the CONCRETE mesh (device ids included) into the
# trace — replicas on disjoint device blocks must not share executables
_STEP_CACHE: Dict[tuple, tuple] = {}


def _jitted_steps(cfg: ModelConfig) -> tuple:
    key = (cfg, shlib.get_mesh())
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = (
            jax.jit(steps_lib.make_prefill_chunk_step(cfg)),
            jax.jit(steps_lib.make_decode_slots_step(cfg)),
            jax.jit(steps_lib.make_extract_state_step(cfg), static_argnums=3),
            jax.jit(steps_lib.make_inject_state_step(cfg)))
    return _STEP_CACHE[key]


@dataclasses.dataclass
class Request:
    """One serving request: a prompt and a generation budget."""

    rid: int
    tokens: np.ndarray                 # [L] prompt token ids
    max_new: int = 16
    arrival: float = 0.0               # open-loop arrival time (s from start)

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        assert self.tokens.size >= 1, f"request {self.rid}: empty prompt"
        assert self.max_new >= 1, f"request {self.rid}: max_new must be >= 1"


@dataclasses.dataclass
class RequestResult:
    """Per-request serving record (the engine's JSON artifact rows)."""

    rid: int
    prompt_len: int
    tokens: List[int]                  # generated ids (greedy)
    finish: str                        # 'length' | 'max_len'
    queue_s: float                     # submit/arrival -> slot admission
    ttft_s: float                      # submit/arrival -> first token
    decode_s: float                    # wall time inside decode steps
    slot: int
    ecc: Dict[str, int]                # reads / corrected / uncorrectable
    finite: bool = True                # every served logit vector was finite
    logits: Optional[np.ndarray] = None   # [n_tokens, V] when collected
    replica: str = ""                  # fleet: name of the serving replica
    prefix_tokens: int = 0             # prompt tokens reused from the trie
    salt: int = 0                      # uint32 request salt (decode streams)
    ecc_window: List[Dict[str, int]] = dataclasses.field(
        default_factory=list)          # per decode-chunk ECC time series
    scrubs: int = 0                    # scrub events while this req was live

    def to_json(self) -> dict:
        tok_s = len(self.tokens) / self.decode_s if self.decode_s > 0 else 0.0
        return {"rid": self.rid, "prompt_len": self.prompt_len,
                "n_tokens": len(self.tokens), "finish": self.finish,
                "queue_s": self.queue_s, "ttft_s": self.ttft_s,
                "decode_s": self.decode_s, "tok_s": tok_s, "slot": self.slot,
                "ecc": {k: int(v) for k, v in self.ecc.items()},
                "ecc_window": [{k: int(v) for k, v in w.items()}
                               for w in self.ecc_window],
                "scrubs": self.scrubs,
                "finite": self.finite, "replica": self.replica,
                "prefix_hit": self.prefix_tokens > 0,
                "prefix_tokens": self.prefix_tokens, "salt": self.salt}


@dataclasses.dataclass
class _PrefixNode:
    """One full prefill chunk in the trie: (parent, chunk tokens) -> state."""

    nid: int
    key: tuple                         # (parent nid, chunk tokens bytes)
    salt: int                          # content salt its fault streams used
    state: object                      # state chunk (lm.extract_state_chunk)
    tokens: int                        # chunk length


class PrefixCache:
    """Hash-consed token-chunk trie of prefilled state chunks (per replica).

    A node is one FULL prefill chunk keyed by ``(parent node id, chunk token
    bytes)`` — the path from the root spells a prompt prefix in chunk steps,
    and identical chunks under the same parent share one node (hash-consing:
    inserting an existing chunk returns the existing node). Admission walks
    the trie over the prompt's full leading chunks; each hit injects the
    node's state chunk instead of recomputing it. Per-block cached units
    follow the :class:`repro.models.lm.SlotStateSpec`: KV rows for
    position-addressed kinds, post-chunk state snapshots for fold/window
    kinds (injection overwrites the slot's state, so the deepest hit wins).

    Reuse is exact: a node's state was prefilled under the content salt of
    its token prefix (``deployment.prefix_salt``), which is what a cold
    prefill of the same tokens would use — bitwise, including per-read
    dynamic injection; snapshot units are additionally exact because the
    engine prefills at fixed chunk boundaries, so the fold that produced a
    snapshot is re-run with identical chunk shapes on a cold recompute. The
    cache is therefore ONLY valid for the image/runtime it was filled
    against; :meth:`Engine.refresh_params` calls :meth:`invalidate` on any
    change (the invalidation-on-inject contract).

    Capacity is bounded at ``max_chunks`` nodes with least-recently-used
    eviction restricted to LEAF chunks — a parent is always at least as
    reachable as its children, so evicting interior nodes would orphan state
    a hot descendant still spells a path through.
    """

    def __init__(self, max_chunks: int = 256):
        assert max_chunks >= 1, max_chunks
        self.max_chunks = max_chunks
        self._nodes: Dict[tuple, _PrefixNode] = {}
        self._children: Dict[int, set] = {}
        self._lru: "OrderedDict[tuple, None]" = OrderedDict()
        self._next_id = 1
        self.hits = self.misses = self.inserts = self.evictions = 0
        self.invalidations = 0

    @staticmethod
    def _key(parent: Optional[_PrefixNode], tokens) -> tuple:
        pid = 0 if parent is None else parent.nid
        return (pid, np.asarray(tokens, np.int32).tobytes())

    def lookup(self, parent: Optional[_PrefixNode], tokens):
        node = self._nodes.get(self._key(parent, tokens))
        if node is None:
            self.misses += 1
            return None
        self.hits += 1
        self._lru.move_to_end(node.key)
        return node

    def insert(self, parent: Optional[_PrefixNode], tokens, state,
               salt) -> _PrefixNode:
        key = self._key(parent, tokens)
        node = self._nodes.get(key)
        if node is not None:            # hash-consed: one copy per chunk
            self._lru.move_to_end(key)
            return node
        node = _PrefixNode(nid=self._next_id, key=key, salt=int(salt),
                           state=state, tokens=int(np.asarray(tokens).size))
        self._next_id += 1
        self._nodes[key] = node
        self._children.setdefault(key[0], set()).add(key)
        self._lru[key] = None
        self.inserts += 1
        while len(self._nodes) > self.max_chunks and self._evict_leaf():
            pass
        return node

    def _evict_leaf(self) -> bool:
        for key in self._lru:           # oldest first
            if not self._children.get(self._nodes[key].nid):
                node = self._nodes.pop(key)
                self._children.get(key[0], set()).discard(key)
                self._children.pop(node.nid, None)
                del self._lru[key]
                self.evictions += 1
                return True
        return False

    def invalidate(self) -> None:
        """Drop every cached chunk (stale against a new image/runtime)."""
        self._nodes.clear()
        self._children.clear()
        self._lru.clear()
        self.invalidations += 1

    def __len__(self) -> int:
        return len(self._nodes)

    def stats(self) -> dict:
        return {"chunks": len(self._nodes),
                "tokens": sum(n.tokens for n in self._nodes.values()),
                "hits": self.hits, "misses": self.misses,
                "inserts": self.inserts, "evictions": self.evictions,
                "invalidations": self.invalidations}


@dataclasses.dataclass
class _Slot:
    rid: int
    prompt_len: int
    max_new: int
    submit_t: float
    admit_t: float
    req: Optional[Request] = None      # original request (fleet requeue)
    ttft_s: float = 0.0
    decode_s: float = 0.0
    finite: bool = True
    prefix_tokens: int = 0
    salt: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)
    logits: List[np.ndarray] = dataclasses.field(default_factory=list)
    ecc: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"reads": 0, "corrected": 0,
                                 "uncorrectable": 0})
    ecc_window: List[Dict[str, int]] = dataclasses.field(default_factory=list)
    scrubs: int = 0


@dataclasses.dataclass
class LoadGen:
    """Synthetic open-loop load: Poisson arrivals, uniform length ranges.

    ``rate=float('inf')`` (the default) drops every arrival at t=0 — the
    closed "all at once" burst the tests and benches use; a finite rate
    draws exponential inter-arrival gaps (open loop: arrivals never wait for
    service).

    ``prefix_len > 0`` prepends one shared token prefix (drawn once from the
    same seed) to every prompt — the system-prompt workload that exercises
    the prefix cache. The schedule is a pure function of the config: the same
    ``LoadGen`` yields bit-identical requests whether they are then fed to
    one engine or fanned out across a fleet.
    """

    n_requests: int = 32
    rate: float = float("inf")         # requests / second
    prompt_lens: Tuple[int, int] = (8, 32)
    gen_lens: Tuple[int, int] = (4, 16)
    vocab_size: int = 256
    seed: int = 0
    prefix_len: int = 0                # shared leading tokens (0 = none)

    def requests(self) -> List[Request]:
        rng = np.random.default_rng(self.seed)
        if np.isinf(self.rate):
            arrivals = np.zeros(self.n_requests)
        else:
            arrivals = np.cumsum(rng.exponential(1.0 / self.rate,
                                                 self.n_requests))
        # drawn before the per-request loop so prefix_len=0 reproduces the
        # historical schedules exactly (no extra rng consumption)
        prefix = (rng.integers(0, self.vocab_size, self.prefix_len)
                  if self.prefix_len > 0 else None)
        out = []
        for i in range(self.n_requests):
            plen = int(rng.integers(self.prompt_lens[0],
                                    self.prompt_lens[1] + 1))
            gen = int(rng.integers(self.gen_lens[0], self.gen_lens[1] + 1))
            toks = rng.integers(0, self.vocab_size, plen)
            if prefix is not None:
                toks = np.concatenate([prefix, toks])
            out.append(Request(rid=i, tokens=toks, max_new=gen,
                               arrival=float(arrivals[i])))
        return out

    def max_len(self) -> int:
        return self.prefix_len + self.prompt_lens[1] + self.gen_lens[1] + 1


class Engine:
    """Slot-based continuous-batching serving over a params pytree.

    ``params`` is whatever :meth:`CIMDeployment.serving_params` produced —
    packed stores (fused), decoded fp16 (hbm), or plain weights, plus the
    optional ``_cim`` dynamic-injection runtime. Four jitted programs total:
    one full-chunk prefill, one ragged-chunk prefill per distinct tail
    length, one slot decode, and the state extract/inject pair the prefix
    cache rides on.

    ``prefix_cache`` attaches a :class:`PrefixCache` (pass your own, or
    ``True`` for a default-sized one). ``replica`` names this engine in
    fleet artifacts (``RequestResult.replica``).
    """

    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 max_len: int = 64, chunk: int = 16,
                 collect_logits: bool = False, ecc_accounting: bool = True,
                 check_finite: bool = True, prefix_cache=None,
                 replica: str = ""):
        specs = lm.check_engine_kinds(cfg)
        assert n_slots >= 1 and chunk >= 1 and max_len >= 2, \
            (n_slots, chunk, max_len)
        self.cfg = cfg
        self.params = params
        self.replica = replica
        # a chunk never writes past the cache ceiling (an overflowing padded
        # dynamic_update_slice would clamp backwards over real prompt rows);
        # window-bound kinds additionally cap the chunk at the ring size (a
        # W-slot ring cannot absorb more than W new tokens in one write)
        chunk = min(chunk, max_len)
        if any(s.window_bound for s in specs):
            chunk = min(chunk, cfg.local_window)
        self.n_slots, self.max_len, self.chunk = n_slots, max_len, chunk
        # capacity-coupled MoE dispatch at these shapes voids the bitwise
        # solo-vs-cobatched guarantee (moe.drop_free documents the boundary)
        self.capacity_coupled = lm.engine_capacity_coupled(
            cfg, max(n_slots, self.chunk))
        if self.capacity_coupled:
            warnings.warn(
                "engine: MoE dispatch is capacity-coupled at these shapes "
                f"(n_slots={n_slots}, chunk={self.chunk}): co-batched tokens "
                "may contend for expert capacity, voiding the bitwise "
                "solo-vs-cobatched guarantee (fault streams stay "
                "per-request). Raise capacity_factor or shrink the batch "
                "until moe.drop_free holds to restore it.")
        self.collect_logits = collect_logits
        self.check_finite = check_finite
        self._prefill, self._decode, self._extract, self._inject = \
            _jitted_steps(cfg)
        self.prefix_cache: Optional[PrefixCache] = \
            PrefixCache() if prefix_cache is True else prefix_cache
        self.caches = lm.init_slot_states(cfg, n_slots, max_len)
        self.caches["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.slots: List[Optional[_Slot]] = [None] * n_slots
        self.queue: deque[Tuple[Request, float]] = deque()
        self._tokens = np.zeros((n_slots, 1), np.int32)
        self._salts = np.zeros(n_slots, np.uint32)
        self.results: Dict[int, RequestResult] = {}
        self.steps = 0
        self.idle_steps = 0
        self.requeues = 0
        self._decode_wall = 0.0
        self._decoded_tokens = 0
        self._ecc_accounting = ecc_accounting
        self._runtime = params.get("_cim") if isinstance(params, dict) \
            else None
        # per-store cumulative ECC charges (path -> counters): the signal a
        # ScrubPolicy thresholds on. Survives refresh_params — scrubbing
        # resets it per store via launch.scrub, not here.
        self.store_ecc: Dict[str, Dict[str, int]] = {}
        self.scrub_events: List[dict] = []
        self._ecc_fns = self._build_ecc_fns() if ecc_accounting else []

    # ------------------------------------------------------------ ECC

    def _build_ecc_fns(self):
        """One per-read ECC accountant per deployed store leaf.

        Static image: the macro's corrected/uncorrectable counts are a
        constant of the image — computed once, charged per read. Dynamic
        runtime: a jitted fn re-derives the (request, position) flip streams
        (the exact chain the model's reads use) and counts the ECC events of
        that read's faulted image. That re-derivation decodes the FULL
        codeword planes per active slot per step (the serving read itself
        never surfaces ECC status), so dynamic accounting costs the same
        order as the decode it observes — fine for reduced-arch soaks, and
        exactly what ``ecc_accounting=False`` (``--no-ecc-accounting``)
        switches off for throughput measurement (``engine_bench.py`` does).
        """
        fns = []
        flat = jax.tree_util.tree_flatten_with_path(
            self.params, is_leaf=cim_lib._is_store)[0]
        rt = self._runtime
        model = rt.get("model") if rt is not None else None
        if model is not None and model.kind == "drift":
            # reads absorb drift's time scaling into the thresholds (keyed on
            # the request-local pos); the model handed downstream is tick-0
            model0 = dataclasses.replace(model, tick=0)
        else:
            model0 = model
        for path, leafv in flat:
            if not cim_lib._is_store(leafv):
                continue
            pstr = dep_lib.path_str(path)
            salt = dep_lib.leaf_salt(pstr)
            self.store_ecc.setdefault(
                pstr, {"reads": 0, "corrected": 0, "uncorrectable": 0})
            if rt is None:
                st = cim_lib.store_stats(leafv)
                const = (int(st["corrected"]), int(st["uncorrectable"]))
                fns.append((pstr, lambda req_salt, pos, c=const: c))
            else:
                from repro.core import faultmodels as fm_lib

                def dyn(req_salt, pos, store=leafv, leaf_salt=salt):
                    seeds = dep_lib.request_read_seeds(
                        rt["seeds"], leaf_salt, req_salt, pos)
                    tm = fm_lib.compiled_threshold(model, rt["thr_man"],
                                                   tick=pos)
                    tt = fm_lib.compiled_threshold(model, rt["thr_meta"],
                                                   tick=pos)
                    faulted = cim_lib.inject_with_seeds(store, seeds, tm, tt,
                                                        model=model0)
                    st = cim_lib.store_stats(faulted)
                    return jnp.stack([st["corrected"], st["uncorrectable"]])
                jfn = jax.jit(dyn)
                fns.append((pstr, lambda req_salt, pos, f=jfn:
                            tuple(int(v)
                                  for v in np.asarray(f(req_salt, pos)))))
        return fns

    def _charge_reads(self, slot: _Slot, salt, pos: int) -> None:
        """Charge one CIM read (all deployed macros) at read index ``pos``.

        Besides the request's cumulative counters, every charge lands in the
        request's ``ecc_window`` time series (one row per decode chunk, the
        scrub-decision observable) and the engine's per-store ``store_ecc``
        totals (the ScrubPolicy threshold signal)."""
        if not self._ecc_fns:
            return
        slot.ecc["reads"] += 1
        corr = unc = 0
        for pstr, fn in self._ecc_fns:
            c, u = fn(jnp.uint32(salt), jnp.int32(pos))
            corr += c
            unc += u
            store = self.store_ecc[pstr]
            store["reads"] += 1
            store["corrected"] += c
            store["uncorrectable"] += u
        slot.ecc["corrected"] += corr
        slot.ecc["uncorrectable"] += unc
        slot.ecc_window.append({"pos": int(pos), "reads": 1,
                                "corrected": corr, "uncorrectable": unc})

    # ------------------------------------------------------------ scheduling

    def submit(self, req: Request, now: Optional[float] = None) -> None:
        self.queue.append((req, now if now is not None else req.arrival))

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def active(self) -> np.ndarray:
        return np.asarray([s is not None for s in self.slots])

    def _admit(self, req: Request, slot_idx: int, submit_t: float) -> None:
        """Chunk-prefill the request's prompt into ``slot_idx`` and emit its
        first token, reusing trie-cached state chunks where they match.

        Prefill fault streams key on prompt *content*
        (:func:`repro.core.deployment.prefix_salt` of the tokens up through
        the chunk), so a cached chunk's state — and its replayed ECC charges
        — are bitwise what a cold prefill of the same tokens would produce.
        The final chunk always runs cold: its logits emit the first token.
        """
        plen = req.tokens.size
        if plen + req.max_new > self.max_len:
            raise EngineError(
                f"request {req.rid}: prompt {plen} + max_new {req.max_new} "
                f"exceeds the engine's max_len {self.max_len}")
        rsalt = np.uint32(dep_lib.request_salt(req.rid))
        # admit_t comes from the wall clock, never the admission gate `now`
        # (a closed-loop run gates with now=inf — that must not leak into
        # queue_s or the JSON artifact)
        slot = _Slot(rid=req.rid, prompt_len=plen, max_new=req.max_new,
                     submit_t=submit_t, admit_t=self._clock(), req=req,
                     salt=int(rsalt))
        # walk the trie over the prompt's full LEADING chunks (never the
        # final one — its logits are the first token, so it must run);
        # `prefill_chunk` masks off the explicit pos argument and the
        # always-cold final chunk leaves caches['pos'][slot] = plen, so
        # injection only has to land the state chunk (KV rows, or the
        # post-chunk snapshot for fold/window kinds — deepest hit wins)
        starts = list(range(0, plen, self.chunk))
        node = None
        pos = 0
        if self.prefix_cache is not None:
            for c0 in starts[:-1]:
                seg = req.tokens[c0:c0 + self.chunk]
                hit = self.prefix_cache.lookup(node, seg)
                if hit is None:
                    break
                self.caches = self._inject(
                    self.caches, jnp.int32(slot_idx), jnp.int32(c0),
                    hit.state)
                # replay the ECC accounting of the read this chunk's cold
                # prefill would have issued — same salt, same read index
                self._charge_reads(slot, np.uint32(hit.salt), c0)
                node = hit
                pos = c0 + self.chunk
        slot.prefix_tokens = pos
        logits = None
        for c0 in range(pos, plen, self.chunk):
            seg = req.tokens[c0:c0 + self.chunk]
            length = seg.size
            csalt = np.uint32(dep_lib.prefix_salt(req.tokens[:c0 + length]))
            # the ragged tail pads only to what still fits under max_len
            # (padding row writes must not clamp back over prompt rows);
            # pad length never enters the fault-stream chain
            pad_to = min(self.chunk, self.max_len - c0)
            padded = np.pad(seg, (0, pad_to - length))
            logits, self.caches = self._prefill(
                self.params, self.caches, jnp.asarray(padded),
                jnp.int32(slot_idx), jnp.int32(c0), jnp.int32(length),
                jnp.uint32(csalt))
            self._charge_reads(slot, csalt, c0)
            if self.prefix_cache is not None and length == self.chunk:
                state = self._extract(self.caches, jnp.int32(slot_idx),
                                      jnp.int32(c0), self.chunk)
                node = self.prefix_cache.insert(node, seg, state, csalt)
        logits = np.asarray(logits)
        self._check(logits, slot)
        tok = int(np.argmax(logits))
        slot.tokens.append(tok)
        if self.collect_logits:
            slot.logits.append(logits)
        slot.ttft_s = self._clock() - submit_t
        self.slots[slot_idx] = slot
        self._tokens[slot_idx, 0] = tok
        self._salts[slot_idx] = rsalt

    def _evict(self, slot_idx: int, finish: str) -> None:
        slot = self.slots[slot_idx]
        res = RequestResult(
            rid=slot.rid, prompt_len=slot.prompt_len, tokens=slot.tokens,
            finish=finish, queue_s=slot.admit_t - slot.submit_t,
            ttft_s=slot.ttft_s, decode_s=slot.decode_s, slot=slot_idx,
            ecc=slot.ecc, finite=slot.finite,
            logits=np.stack(slot.logits) if slot.logits else None,
            replica=self.replica, prefix_tokens=slot.prefix_tokens,
            salt=slot.salt, ecc_window=slot.ecc_window, scrubs=slot.scrubs)
        self.results[slot.rid] = res
        self.slots[slot_idx] = None
        # reset the slot's position so the next admission prefills from 0;
        # stale KV/ring rows stay causally masked until overwritten, and
        # prefill_chunk zeroes fold states (rwkv/rec) at pos == 0
        self.caches["pos"] = self.caches["pos"].at[slot_idx].set(0)

    def _check(self, logits: np.ndarray, slot: _Slot) -> None:
        """Record the slot's actual finiteness verdict (the JSON artifact
        reports it) and, when ``check_finite``, fail fast on violation."""
        if not np.isfinite(logits).all():
            slot.finite = False
            if self.check_finite:
                raise EngineError(
                    f"non-finite logits serving request {slot.rid}")

    def _clock(self) -> float:
        return time.perf_counter() - self._t0

    # ------------------------------------------------------------ fleet hooks

    @property
    def depth(self) -> int:
        """Queued + in-flight request count (the router's load signal)."""
        return len(self.queue) + int(self.active.sum())

    @property
    def busy(self) -> bool:
        return bool(self.queue) or bool(self.active.any())

    def start(self, t0: Optional[float] = None) -> None:
        """Pin the engine clock origin (fleet replicas share the router's
        ``t0`` so queue/TTFT accounting has one time base)."""
        self._t0 = time.perf_counter() if t0 is None else t0

    def drain(self) -> List[Request]:
        """Abandon all work and hand the requests back, arrival order.

        In-flight requests are dropped mid-generation and returned whole —
        re-serving one from scratch reproduces the exact tokens, logits and
        fault streams of an uninterrupted run, because every stream keys on
        content/request/position, never on the attempt or the slot. Queued
        requests ride along. Slots and cache positions reset; the prefix
        trie survives (its state is a pure function of the image, not of
        which requests ran).
        """
        back: List[Request] = []
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            assert slot.req is not None, slot.rid
            back.append(slot.req)
            self.slots[i] = None
            self.caches["pos"] = self.caches["pos"].at[i].set(0)
        back.extend(req for req, _ in self.queue)
        self.queue.clear()
        self.requeues += len(back)
        back.sort(key=lambda r: (r.arrival, r.rid))
        return back

    def refresh_params(self, params, *, force: bool = False) -> None:
        """Swap in a new deployed image/runtime (engine must be idle).

        The invalidation-on-inject contract: cached prefix state embeds the
        faults of the image it was prefilled against, so ANY params change
        drops the trie before the next admission can hit it.

        ``force=True`` swaps while requests are in flight — the online
        scrubbing/aging path. In-flight slot state stays (it embeds the
        faults of the image it was computed against — exactly the physics:
        old reads saw the old cells); subsequent reads see the new image.
        """
        if self.busy and not force:
            raise EngineError("refresh_params on a busy engine: drain first")
        self.params = params
        self._runtime = params.get("_cim") if isinstance(params, dict) \
            else None
        self._ecc_fns = self._build_ecc_fns() if self._ecc_accounting else []
        if self.prefix_cache is not None:
            self.prefix_cache.invalidate()

    def record_scrub(self, event: dict) -> None:
        """Log one scrub event (``launch.scrub`` calls this) and mark every
        in-flight request as having lived through it; per-store cumulative
        counters of the scrubbed stores reset (damage cleared)."""
        self.scrub_events.append(dict(event))
        for s in self.slots:
            if s is not None:
                s.scrubs += 1
        for pstr in event.get("paths", ()):
            if pstr in self.store_ecc:
                self.store_ecc[pstr] = {"reads": 0, "corrected": 0,
                                        "uncorrectable": 0}

    # ------------------------------------------------------------ stepping

    def step(self, now: Optional[float] = None) -> dict:
        """Admit arrived requests into free slots, then advance every active
        slot by one token. Returns an event dict (admitted/decoded/evicted
        rids, ``idle`` when there was nothing to do)."""
        if not hasattr(self, "_t0"):
            self._t0 = time.perf_counter()
        if now is None:
            now = self._clock()
        admitted, evicted = [], []
        while self.queue and self.free_slots():
            req, submit_t = self.queue[0]
            if submit_t > now:
                break
            self.queue.popleft()
            idx = self.free_slots()[0]
            self._admit(req, idx, submit_t)
            admitted.append(req.rid)
            # a 1-token request is done at TTFT
            if len(self.slots[idx].tokens) >= req.max_new:
                self._evict(idx, "length")
                evicted.append(req.rid)

        active = self.active
        if not active.any():
            self.idle_steps += 1
            return {"idle": True, "admitted": admitted, "evicted": evicted,
                    "decoded": []}

        t0 = time.perf_counter()
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self._tokens),
            jnp.asarray(active), jnp.asarray(self._salts))
        logits = np.asarray(logits)
        dt = time.perf_counter() - t0
        self.steps += 1
        decoded = []
        n_active = int(active.sum())
        for i in np.flatnonzero(active):
            slot = self.slots[i]
            self._check(logits[i], slot)
            tok = int(np.argmax(logits[i]))
            slot.tokens.append(tok)
            if self.collect_logits:
                slot.logits.append(logits[i])
            slot.decode_s += dt / n_active
            # the read index this decode step consumed: the slot's pre-step
            # position (prefill left it at prompt_len; each decode adds 1)
            self._charge_reads(slot, self._salts[i],
                               slot.prompt_len + len(slot.tokens) - 2)
            self._tokens[i, 0] = tok
            decoded.append(slot.rid)
            self._decoded_tokens += 1
        self._decode_wall += dt
        for i in np.flatnonzero(active):
            slot = self.slots[i]
            done = len(slot.tokens) >= slot.max_new
            full = slot.prompt_len + len(slot.tokens) >= self.max_len
            if done or full:
                self._evict(int(i), "length" if done else "max_len")
                evicted.append(slot.rid)
        return {"idle": False, "admitted": admitted, "decoded": decoded,
                "evicted": evicted}

    def run(self, requests, *, open_loop: bool = False, on_step=None
            ) -> Tuple[Dict[int, RequestResult], dict]:
        """Serve ``requests`` to completion -> (results by rid, aggregate).

        ``open_loop=True`` gates admissions on each request's wall-clock
        ``arrival`` offset (the Poisson load); otherwise everything is
        admissible immediately and ``arrival`` only sets the queue order.

        ``on_step(engine, event)`` runs after every engine step — the hook
        the online scrub controller (``launch.scrub.ScrubController``) and
        aging schedules interleave with request slots.
        """
        self._t0 = time.perf_counter()
        for req in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            self.submit(req, now=req.arrival if open_loop else 0.0)
        while self.queue or self.active.any():
            ev = self.step(now=None if open_loop else float("inf"))
            if on_step is not None:
                on_step(self, ev)
            if ev["idle"] and self.queue:
                # open loop: nothing active and the next arrival is in the
                # future — sleep to it instead of spinning
                nxt = self.queue[0][1]
                wait = nxt - self._clock()
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        return self.results, self.aggregate()

    # ------------------------------------------------------------ reporting

    def aggregate(self) -> dict:
        res = list(self.results.values())
        ttfts = np.asarray([r.ttft_s for r in res]) if res else np.zeros(1)
        total_tok = sum(len(r.tokens) for r in res)
        wall = self._clock() if hasattr(self, "_t0") else 0.0
        return {
            "replica": self.replica,
            "n_requests": len(res),
            "n_slots": self.n_slots,
            "total_tokens": total_tok,
            "decode_steps": self.steps,
            "idle_steps": self.idle_steps,
            "wall_s": wall,
            "decode_wall_s": self._decode_wall,
            "decode_tok_s": (self._decoded_tokens / self._decode_wall
                             if self._decode_wall > 0 else 0.0),
            "tok_s": total_tok / wall if wall > 0 else 0.0,
            "ttft_s_mean": float(ttfts.mean()),
            "ttft_s_p95": float(np.percentile(ttfts, 95)),
            "slot_occupancy": (self._decoded_tokens
                               / max(self.steps * self.n_slots, 1)),
            "requeues": self.requeues,
            "prefix_hits": sum(1 for r in res if r.prefix_tokens > 0),
            "prefix_tokens": sum(r.prefix_tokens for r in res),
            "prefix_cache": (self.prefix_cache.stats()
                             if self.prefix_cache is not None else None),
            "ecc": {k: int(sum(r.ecc[k] for r in res))
                    for k in ("reads", "corrected", "uncorrectable")},
            "store_ecc": {p: dict(v) for p, v in self.store_ecc.items()},
            "scrub": self._scrub_summary(),
        }

    def _scrub_summary(self) -> dict:
        ev = self.scrub_events
        return {
            "events": len(ev),
            "rows_reencoded": int(sum(e.get("rows", 0) for e in ev)),
            "corrected_cleared": int(sum(e.get("corrected_cleared", 0)
                                         for e in ev)),
            "uncorrectable_cleared": int(sum(e.get("uncorrectable_cleared", 0)
                                             for e in ev)),
            "wall_s": float(sum(e.get("wall_s", 0.0) for e in ev)),
        }
