"""Online ECC scrubbing: a self-healing loop over the serving engine.

SRAM soft errors accumulate between deployments — under a drift process
(:mod:`repro.core.faultmodels`) the per-read BER grows with time, and every
uncorrected double-bit row is permanent until the image is rewritten. Memory
scrubbing is the classical answer: periodically read every word through the
ECC decoder and write the corrected value back, converting correctable
errors into clean cells before a second hit makes them uncorrectable.

This module interleaves that loop with the engine's request slots:

* :class:`ScrubPolicy` — when to scrub: a per-store cumulative ECC-event
  threshold over ``engine.store_ecc`` (charged by the engine's per-read
  accountants) plus a check interval in engine steps.
* :class:`DriftAging` — the wear process for soaks: every ``every`` steps
  the deployment takes a fresh static injection at the aging tick's
  drift-scaled BER, keyed on ``fold_in(key, tick)`` so a scrub-on and a
  scrub-off run draw bit-identical damage streams.
* :class:`ScrubController` — the ``engine.run(on_step=...)`` hook tying
  them together. A scrub re-encodes the affected stores exactly the way
  deployment did (``cim.read`` through the decoder, ``cim.pack`` back into
  a fresh image), swaps the engine's params via
  ``refresh_params(force=True)`` — which drops the prefix cache, honouring
  the PR-6 invalidation contract (decoded-row caches are rebuilt from the
  clean image by ``serving_params``) — and logs per-scrub accounting
  through ``engine.record_scrub`` (which also resets the scrubbed stores'
  ``store_ecc`` counters and stamps in-flight requests).

The controller mutates its ``dep`` attribute (aging and scrubbing both
produce derived deployments); read ``controller.dep`` after a run for the
final image, and ``engine.aggregate()['scrub']`` for the rollup.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim as cim_lib
from repro.core import faultmodels as fm_lib


@dataclasses.dataclass(frozen=True)
class ScrubPolicy:
    """When the controller rewrites a store's SRAM image.

    ``threshold``: cumulative ECC events (corrected + uncorrectable) charged
    to one store in ``engine.store_ecc`` since its last scrub. ``interval``:
    check cadence in engine steps. ``max_scrubs``: hard cap on scrub events
    per run (0 = unbounded) — a safety valve for runaway thresholds.
    """
    threshold: int = 16
    interval: int = 1
    max_scrubs: int = 0

    def __post_init__(self):
        if self.threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {self.threshold}")
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")

    def due(self, store_ecc: dict) -> List[str]:
        """Store paths whose cumulative charges crossed the threshold."""
        return [p for p, c in store_ecc.items()
                if c["corrected"] + c["uncorrectable"] >= self.threshold]


@dataclasses.dataclass
class DriftAging:
    """Cumulative wear: fresh static faults into the deployment per tick.

    Each application injects at ``ber`` scaled by the drift curve at
    ``tick`` (``model.tick`` is rewritten per call), keyed on
    ``fold_in(key, tick)``. Damage accumulates because each injection lands
    on the *current* (already-faulted) image — only a scrub's re-encode
    clears it. The same (key, ber, model) sequence is bit-reproducible, so
    scrub-on vs scrub-off soaks see identical incident errors.
    """
    key: jax.Array
    ber: float
    model: fm_lib.FaultProcess = dataclasses.field(
        default_factory=fm_lib.FaultProcess.drift)
    every: int = 1

    def __post_init__(self):
        if self.every < 1:
            raise ValueError(f"every must be >= 1, got {self.every}")
        self.model = fm_lib.parse_fault_model(self.model)

    def age(self, dep, tick: int):
        """One wear step at ``tick`` -> derived deployment."""
        model = self.model
        if model is not None and model.kind == "drift":
            model = dataclasses.replace(model, tick=int(tick))
        return dep.inject(jax.random.fold_in(self.key, tick), self.ber,
                          model=model)


class ScrubController:
    """``engine.run(on_step=controller)`` — age, threshold, re-encode, swap.

    Parameters
    ----------
    dep: the live :class:`~repro.core.deployment.CIMDeployment` behind the
        engine's params (the controller owns it from here; aging and scrubs
        replace it).
    policy: :class:`ScrubPolicy` (default thresholds if omitted).
    aging: optional :class:`DriftAging` wear process driven off engine steps.
    serving_kw: kwargs for ``dep.serving_params`` when rebuilding the
        engine's params after aging or a scrub (``dynamic_key``/``ber``/
        ``model``/``row_cache``...). Must match how the engine's original
        params were built or the swap changes serving semantics.
    """

    def __init__(self, dep, policy: Optional[ScrubPolicy] = None, *,
                 aging: Optional[DriftAging] = None, serving_kw=None):
        self.dep = dep
        self.policy = policy or ScrubPolicy()
        self.aging = aging
        self.serving_kw = dict(serving_kw or {})
        self.events: List[dict] = []
        self.tick = 0

    # ------------------------------------------------------------ hook

    def __call__(self, engine, ev=None) -> None:
        self.on_step(engine, ev)

    def on_step(self, engine, ev=None) -> None:
        self.tick += 1
        dirty = False
        if self.aging is not None and self.tick % self.aging.every == 0:
            self.dep = self.aging.age(self.dep, self.tick)
            dirty = True
        if self.tick % self.policy.interval == 0:
            due = self.policy.due(engine.store_ecc)
            if due and not (self.policy.max_scrubs
                            and len(self.events) >= self.policy.max_scrubs):
                event = self.scrub(due)
                event["step"] = int(getattr(engine, "steps", self.tick))
                engine.record_scrub(event)
                dirty = True
        if dirty:
            engine.refresh_params(self.dep.serving_params(**self.serving_kw),
                                  force=True)

    # ------------------------------------------------------------ scrub

    def scrub(self, paths) -> dict:
        """Re-encode the stores at ``paths`` -> accounting event dict.

        Each store is read through its ECC decoder (clearing every
        correctable error; uncorrectable rows are rewritten as their decoded
        — wrong but now stable — values) and packed back into a fresh image,
        exactly the deploy-time encode. Unprotected stores are skipped: with
        no decoder a rewrite would only bake the faults in.
        """
        t0 = time.perf_counter()
        paths = [str(p) for p in paths]
        flat, treedef = self.dep._flat()
        rows = words = corrected = uncorrectable = 0
        scrubbed = []
        for i, (pstr, leaf) in enumerate(zip(self.dep.paths, flat)):
            if pstr not in paths or not cim_lib._is_store(leaf):
                continue
            if leaf.codewords is None:      # unprotected: nothing to heal
                continue
            st = cim_lib.store_stats(leaf)
            w, _ = cim_lib.read(leaf)
            fresh = cim_lib.pack(w, leaf.cfg)
            rows += int(leaf.man.shape[0])  # whole image rewritten
            old_pd = cim_lib._plane_dict(leaf)
            new_pd = cim_lib._plane_dict(fresh)
            words += sum(int((np.asarray(old_pd[n]) !=
                              np.asarray(new_pd[n])).sum()) for n in old_pd)
            corrected += int(st["corrected"])
            uncorrectable += int(st["uncorrectable"])
            flat[i] = fresh
            scrubbed.append(pstr)
        self.dep = self.dep._replace_stores(
            jax.tree_util.tree_unflatten(treedef, flat))
        event = {
            "paths": scrubbed,
            "rows": rows,
            "words_healed": words,
            "corrected_cleared": corrected,
            # uncorrectable events this image would keep charging on every
            # future read until rewritten — the scrub's averted estimate
            "uncorrectable_cleared": uncorrectable,
            "wall_s": time.perf_counter() - t0,
            "tick": self.tick,
        }
        self.events.append(event)
        return event
