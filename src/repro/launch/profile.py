import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " \
    + os.environ.get("XLA_FLAGS", "")
# ^ MUST precede every other import (jax locks device count on first init).

"""HLO profiler: the dry-run-based "profile" used by the §Perf loop.

Lowers one (arch x shape) cell (optionally unrolled to G groups) and prints:
  * the largest collectives with their op_name provenance,
  * result-shape bytes aggregated by op kind,
  * the biggest individual tensors,
  * cost-analysis totals + roofline terms.

This is how every §Perf hypothesis in EXPERIMENTS.md was localized — e.g.
the 13 GB fp32 logits all-gather (unembed grad), the kv x group involuntary
rematerialization, and the Megatron-TP sequence gathers.

Usage:
  python -m repro.launch.profile --arch command-r-35b --shape train_4k \\
      [--groups 1] [--multi-pod] [--top 15] [--attn-impl tp] ...
"""
import argparse
import collections
import re

from repro.launch import hlo_analysis
from repro.launch.dryrun import lower_cell


def profile_text(text: str, top: int = 15) -> str:
    lines_out = []
    colls = []
    by_kind = collections.Counter()
    tensors = []
    for line in text.splitlines():
        m = re.match(r"\s*%?\S+ = \(?([a-z0-9]+)\[([0-9,]*)\][^ ]* (\S+?)\(", line)
        if m:
            b = hlo_analysis._shape_bytes(m.group(1), m.group(2))
            kind = m.group(3).split(".")[0]
            by_kind[kind] += b
            if b > 1e8:
                op = re.search(r'op_name="([^"]*)"', line)
                tensors.append((b, f"{m.group(1)}[{m.group(2)}]",
                                (op.group(1) if op else "")[:80]))
        for kind in hlo_analysis.COLLECTIVES:
            if f" {kind}(" in line or f" {kind}-start(" in line:
                rhs = line.split("=", 1)[1] if "=" in line else line
                shapes = hlo_analysis._SHAPE_RE.findall(rhs.split("(")[0])
                b = sum(hlo_analysis._shape_bytes(d, s) for d, s in shapes)
                op = re.search(r'op_name="([^"]*)"', line)
                colls.append((b, kind, (op.group(1) if op else "")[:90]))
                break

    colls.sort(reverse=True)
    lines_out.append(f"== collectives: total {sum(c[0] for c in colls)/1e9:.3f} "
                     f"GB across {len(colls)} ops ==")
    for b, kind, op in colls[:top]:
        lines_out.append(f"  {b/1e9:9.3f} GB  {kind:18s} {op}")
    lines_out.append("\n== result-shape bytes by op kind ==")
    for k, v in by_kind.most_common(top):
        lines_out.append(f"  {k:28s} {v/1e9:9.3f} GB")
    tensors.sort(reverse=True)
    lines_out.append("\n== biggest tensors ==")
    for b, shape, op in tensors[:top]:
        lines_out.append(f"  {b/1e9:9.3f} GB  {shape:36s} {op}")
    return "\n".join(lines_out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--groups", type=int, default=1,
                    help="unrolled layer groups to lower (0 = embed/loss only)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--rel-mode", default="align")
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--mlp-impl", default=None)
    ap.add_argument("--moe-dispatch", default=None)
    args = ap.parse_args()

    pat_len = 1
    from repro.configs import get_config
    pat_len = len(get_config(args.arch).block_pattern)
    extra = {"n_layers": pat_len * args.groups}
    for k, v in (("attn_impl", args.attn_impl), ("mlp_impl", args.mlp_impl),
                 ("moe_dispatch", args.moe_dispatch)):
        if v:
            extra[k] = v
    lowered, meta = lower_cell(args.arch, args.shape, args.multi_pod,
                               rel_mode=args.rel_mode, unroll=True,
                               extra_cfg=extra)
    if lowered is None:
        print(f"cell skipped: {meta}")
        return
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    print(f"{args.arch} x {args.shape} ({args.groups} unrolled groups, "
          f"{'multi' if args.multi_pod else 'single'}-pod)")
    print(f"per-device flops {float(cost.get('flops', 0)):.3e}  "
          f"bytes {float(cost.get('bytes accessed', 0)):.3e}\n")
    print(profile_text(compiled.as_text(), args.top))


if __name__ == "__main__":
    main()
