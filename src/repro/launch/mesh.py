"""Production mesh builders.

Defined as FUNCTIONS (not module constants) so importing never touches jax
device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else in the repo sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def make_trial_mesh(n_devices: int = 0):
    """1-D mesh over the Monte-Carlo trial axis (characterization sweeps).

    Fault-injection trials are embarrassingly parallel, so the sweep engine
    shards its trial batch across every available device; a single-device
    mesh degenerates to fully-replicated execution at zero cost.
    """
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), ("trial",))


def make_sweep_mesh(model_axis: int = 1, n_devices: int = 0):
    """2-D ``("trial", "model")`` mesh: Monte-Carlo trials x macro columns.

    One Fig. 6 arm then spans the whole mesh — the sweep engine splits its
    trial batch over "trial" while each CIM deployment's packed planes are
    column-sharded over "model" (``cim.shard_store``), i.e. every trial's
    inject+decode runs across ``model_axis`` emulated macro column groups.
    """
    n = n_devices or len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("trial", "model"))
