"""Roofline-term extraction from compiled XLA artifacts.

Sources (per the assignment):
  * ``compiled.cost_analysis()``  -> HLO FLOPs and HLO bytes accessed
    (per-partition program; multiplied by chip count to report global terms)
  * ``compiled.as_text()``        -> optimized post-SPMD HLO; collective bytes
    are summed from the *result shapes* of every all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute op (per-device program,
    scaled to global).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the HLO module."""
    out = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        for kind in COLLECTIVES:
            tag = f" {kind}("
            alt = f" {kind}-start("
            idx = line.find(tag)
            if idx < 0:
                idx = line.find(alt)
            if idx < 0:
                continue
            lhs = line[:idx]
            if "=" in lhs:
                lhs = lhs.split("=", 1)[1]
            total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
            out[kind] += total
            out["count"] += 1
            break
    return out


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float, chips: int) -> Dict[str, float]:
    """Three roofline terms in seconds (global work / global resource)."""
    compute = flops_per_device * chips / (chips * PEAK_FLOPS)
    memory = bytes_per_device * chips / (chips * HBM_BW)
    collective = coll_bytes_per_device * chips / (chips * ICI_BW)
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    return {"compute_s": compute, "memory_s": memory, "collective_s": collective,
            "dominant": dominant}


def model_flops(n_params: int, tokens: int, kind: str,
                n_active_params: int = 0) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D per decoded/prefilled token."""
    n = n_active_params or n_params
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
