"""repro — Unicorn-CIM reliability framework for JAX (multi-pod).

Stable top-level namespace. Everything in ``__all__`` is the public API
surface — ``tests/test_public_api.py`` snapshots it, so additions and
removals are deliberate, reviewed events rather than accidental drift.

The one entry point for putting a model on the emulated macro is the
deployment API::

    import repro

    policy = repro.ReliabilityPolicy(
        rules=(repro.PolicyRule("unembed", protect="one4n"),
               repro.PolicyRule("*mlp*", protect="none")),
        default=repro.PolicyRule(deploy=False))
    dep = repro.CIMDeployment.deploy(params, policy)
"""
__version__ = "0.1.0"

# deployment API (the public entry point)
from repro.core.deployment import (CIMDeployment, PolicyRule,  # noqa: F401
                                   ReliabilityPolicy, dispatch_linear,
                                   dispatch_read_rows)
# configuration surface
from repro.core.api import ReliabilityConfig  # noqa: F401
from repro.core.align import AlignmentConfig  # noqa: F401
from repro.core.cim import CIMConfig, CIMStore  # noqa: F401
from repro.core.fault import FaultModel  # noqa: F401
# fault-model zoo (error processes on the counter-PRNG flip contract)
from repro.core.faultmodels import (FaultProcess,  # noqa: F401
                                    parse_fault_model)
# characterization engine (paper Fig. 2 / Fig. 6 grids)
from repro.core.resilience import (characterize_fields,  # noqa: F401
                                   characterize_policies,
                                   characterize_protection,
                                   search_policies)
from repro.core.sweep import SweepEngine, SweepPlan, SweepResult  # noqa: F401
# co-design loop (resilience-aware fine-tuning + automatic policy search)
from repro.training.codesign import (AccuracySLO, Finetuner,  # noqa: F401
                                     PolicySearch, SearchSpace)
from repro.training.loop import TrainResult, run_training  # noqa: F401
# kernel ops (fused decode-on-read serving + trial-batched fault injection)
from repro.kernels.cim_read.ops import (cim_linear_store,  # noqa: F401
                                        cim_linear_store_sharded)
from repro.kernels.fault_inject.ops import (ber_to_threshold,  # noqa: F401
                                            fault_inject_bits)
# expert-parallel MoE deployment (each expert its own macro)
from repro.core.deployment import ExpertDeployment  # noqa: F401
# serving model/state protocol (the engine <-> architecture boundary)
from repro.models.lm import (SlotStateSpec,  # noqa: F401
                             extract_state_chunk, init_slot_states,
                             inject_state_chunk, slot_state_spec)
# serving engine (continuous batching over a deployment, per-request streams)
from repro.launch.engine import (Engine, LoadGen,  # noqa: F401
                                 PrefixCache, Request)
# fleet serving (data-parallel replicas behind the SLO-aware router)
from repro.launch.fleet import Fleet  # noqa: F401
# online ECC scrubbing (self-healing serving loop)
from repro.launch.scrub import (DriftAging, ScrubController,  # noqa: F401
                                ScrubPolicy)

__all__ = [
    "__version__",
    # deployment
    "CIMDeployment",
    "PolicyRule",
    "ReliabilityPolicy",
    "dispatch_linear",
    "dispatch_read_rows",
    # configuration
    "AlignmentConfig",
    "CIMConfig",
    "CIMStore",
    "FaultModel",
    "ReliabilityConfig",
    # fault-model zoo
    "FaultProcess",
    "parse_fault_model",
    # characterization
    "SweepEngine",
    "SweepPlan",
    "SweepResult",
    "characterize_fields",
    "characterize_policies",
    "characterize_protection",
    # co-design loop (fine-tune through the deployment + policy search)
    "AccuracySLO",
    "Finetuner",
    "PolicySearch",
    "SearchSpace",
    "TrainResult",
    "run_training",
    "search_policies",
    # kernel ops
    "ber_to_threshold",
    "cim_linear_store",
    "cim_linear_store_sharded",
    "fault_inject_bits",
    # expert-parallel MoE deployment
    "ExpertDeployment",
    # slot-state protocol (engine <-> architecture boundary)
    "SlotStateSpec",
    "extract_state_chunk",
    "init_slot_states",
    "inject_state_chunk",
    "slot_state_spec",
    # serving engine
    "Engine",
    "LoadGen",
    "PrefixCache",
    "Request",
    # fleet serving
    "Fleet",
    # online ECC scrubbing
    "DriftAging",
    "ScrubController",
    "ScrubPolicy",
]
