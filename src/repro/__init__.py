"""repro — Unicorn-CIM reliability framework for JAX (multi-pod)."""
__version__ = "0.1.0"
