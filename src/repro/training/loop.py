"""The training loop: checkpointing, auto-resume, straggler watchdog, dynamic
fault injection — the part of the framework that has to survive a fleet.

``run_training`` is used by ``launch/train.py``, the examples and the
fault-tolerance tests. Reliability modes:

  * ``off`` / ``align`` — plain or frozen-exponent training (align projection
    lives inside ``train_step``);
  * ``cim`` + ``inject: dynamic`` — fresh soft errors hit the stored weights
    every step *before* the forward pass (paper Fig. 7). With
    ``protect=one4n`` the exponent/sign field sees the post-ECC residual rate
    (closed form, ``residual_ber_after_secded``); with ``protect=none`` it
    sees the raw BER. Mantissa bits are always unprotected (the paper's
    design decision).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed import checkpoint as ckpt_lib
from repro.distributed.elastic import StragglerWatchdog
from repro.training import steps as steps_lib


def make_fault_schedule(run: RunConfig):
    """Per-step weight corruption for dynamic injection (or None).

    Delegates to :func:`repro.core.deployment.training_fault_schedule`: with
    the (uniform) policy of ``run.reliability`` every leaf sees the post-ECC
    residual rate on exponent/sign and the raw BER on mantissas — the legacy
    schedule, stream-for-stream; a multi-rule policy gives each layer ITS
    rule's residual rate and BER scale."""
    from repro.core import deployment as dep_lib
    return dep_lib.training_fault_schedule(run.reliability)


def run_training(cfg: ModelConfig, run: RunConfig, batches: Iterable[Dict],
                 log_fn: Optional[Callable[[int, Dict], None]] = None,
                 state: Optional[steps_lib.TrainState] = None,
                 sleep_injector: Optional[Callable[[int], float]] = None):
    """Train for ``run.steps`` steps with checkpoint/resume + watchdog.

    Returns (final state, history list, info dict)."""
    corrupt = make_fault_schedule(run)
    rel = run.reliability

    def wrapped_step(state, batch, key):
        if corrupt is not None:
            faulty = corrupt(state.params, key)
            state = steps_lib.TrainState(faulty, state.opt, state.exps,
                                         state.signs, state.ef_error)
        return base_step(state, batch)

    base_step = steps_lib.make_train_step(cfg, run)
    step_fn = jax.jit(wrapped_step) if corrupt is not None else \
        jax.jit(lambda s, b, k: base_step(s, b))

    start_step = 0
    ckpt_dir = run.checkpoint_dir
    checkpointer = None
    if ckpt_dir:
        os.makedirs(ckpt_dir, exist_ok=True)
        if state is None:
            latest = ckpt_lib.latest_step(ckpt_dir)
            if latest is not None:
                abstract = jax.eval_shape(
                    lambda: steps_lib.init_train_state(
                        jax.random.PRNGKey(run.seed), cfg, run))
                state, start_step = ckpt_lib.restore(abstract, ckpt_dir)
                state = jax.tree_util.tree_map(
                    lambda x: None if x is None else jnp.asarray(x), state,
                    is_leaf=lambda x: x is None)
        checkpointer = ckpt_lib.AsyncCheckpointer(ckpt_dir)
    if state is None:
        state = steps_lib.init_train_state(jax.random.PRNGKey(run.seed), cfg, run)

    watchdog = StragglerWatchdog(factor=run.straggler_factor)
    history, stragglers = [], 0
    it = iter(batches)
    for step in range(start_step, run.steps):
        batch = next(it)
        t0 = time.time()
        if sleep_injector is not None:   # simulated host slowness (tests)
            time.sleep(sleep_injector(step))
        key = jax.random.fold_in(jax.random.PRNGKey(run.seed + 17), step)
        state, metrics = step_fn(state, batch, key)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.time() - t0
        # first step includes jit compile — never feed it to the watchdog
        if step > start_step and watchdog.observe(dt):
            stragglers += 1
        metrics.update(step=step, step_time=dt)
        history.append(metrics)
        if log_fn:
            log_fn(step, metrics)
        if checkpointer and (step + 1) % run.checkpoint_every == 0:
            checkpointer.save_async(state, step + 1)

    if checkpointer:
        checkpointer.save_async(state, run.steps)
        checkpointer.wait()
        checkpointer.close()
    info = {"stragglers_flagged": stragglers, "resumed_from": start_step,
            "ewma_step_time": watchdog.ewma}
    return state, history, info
