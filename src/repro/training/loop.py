"""The training loop: checkpointing, auto-resume, straggler watchdog, dynamic
fault injection — the part of the framework that has to survive a fleet.

``run_training`` is used by ``launch/train.py``, the examples, the co-design
fine-tuner (:mod:`repro.training.codesign`) and the fault-tolerance tests.
Reliability is **policy-native**: pass ``RunConfig(policy=..., ber=...)``; the
legacy ``RunConfig(reliability=ReliabilityConfig(...))`` path still works but
raises a ``DeprecationWarning`` (it compiles into a single-rule policy
bit-compatibly — training streams unchanged). Modes:

  * ``off`` / ``align`` — plain or frozen-exponent training (align projection
    lives inside ``train_step``);
  * ``cim`` + ``inject: dynamic`` — fresh soft errors hit the stored weights
    every step *before* the forward pass (paper Fig. 7). With
    ``protect=one4n`` the exponent/sign field sees the post-ECC residual rate
    (closed form, ``residual_ber_after_secded``); with ``protect=none`` it
    sees the raw BER. Mantissa bits are always unprotected (the paper's
    design decision). Multi-rule policies give each leaf ITS rule's residual
    rate, field restriction and BER scale.

``run_training`` returns a structured :class:`TrainResult`; legacy callers
that unpack ``state, history, info = run_training(...)`` keep working (the
result iterates as that triple).

Counter-PRNG contract: the per-step fault key is
``fold_in(PRNGKey(seed+17), step)`` and splits across flat leaves — a pure
function of (seed, step, policy, pytree structure), independent of device
count or mesh shape. Training fault streams are bit-identical on 1 device and
a forced-8-device ("data","model") mesh (tests/test_codesign.py).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
import warnings
from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed import checkpoint as ckpt_lib
from repro.distributed.elastic import StragglerWatchdog
from repro.training import steps as steps_lib


def make_fault_schedule(run: RunConfig):
    """Per-step weight corruption for dynamic injection (or None).

    Delegates to :func:`repro.core.deployment.training_fault_schedule`: with
    a uniform policy every leaf sees the post-ECC residual rate on
    exponent/sign and the raw BER on mantissas — the legacy schedule,
    stream-for-stream; a multi-rule policy gives each layer ITS rule's
    residual rate and BER scale."""
    from repro.core import deployment as dep_lib
    return dep_lib.training_fault_schedule(run.rel)


@dataclasses.dataclass
class TrainResult:
    """Structured result of :func:`run_training`.

    Iterates as the legacy ``(state, history, info)`` triple, so existing
    tuple-unpacking call sites keep working. ``deployment`` lazily packs the
    final weights onto the emulated macro under the run's policy (None when
    the run was not in ``cim`` mode); ``ecc_stats`` combines the deployment's
    stored-bit cost accounting with its ECC counters.
    """

    state: steps_lib.TrainState
    history: List[Dict]
    info: Dict
    cfg: ModelConfig
    run: RunConfig

    def __iter__(self):
        # legacy compat: `state, history, info = run_training(...)`
        return iter((self.state, self.history, self.info))

    @functools.cached_property
    def deployment(self):
        """The final weights deployed under the run's policy (lazy; None
        unless the resolved reliability mode is 'cim')."""
        rel = self.run.rel
        if rel.mode != "cim":
            return None
        from repro.core import deployment as dep_lib
        return dep_lib.CIMDeployment.deploy(self.state.params, rel.policy)

    @property
    def ecc_stats(self) -> Dict:
        """Stored-bit/overhead accounting + cumulative ECC counters of the
        final deployment ({} when not deployed)."""
        dep = self.deployment
        if dep is None:
            return {}
        out = dict(dep.bit_cost())
        out.update({k: int(v) for k, v in dep.ecc_stats.items()})
        return out

    @property
    def final_loss(self) -> float:
        return float(self.history[-1]["loss"]) if self.history else float("nan")


def _shard_batch(batch, mesh):
    """Data-parallel batch placement: leading-axis leaves split over "data"
    when divisible, everything else replicated (bitwise-neutral — sharding
    never changes the computed streams, only their placement)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = int(mesh.shape.get("data", 1))

    def place(x):
        x = jnp.asarray(x)
        spec = P("data") if (x.ndim >= 1 and n > 1 and x.shape[0] % n == 0) \
            else P()
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(place, batch)


def run_training(cfg: ModelConfig, run: RunConfig, batches: Iterable[Dict],
                 log_fn: Optional[Callable[[int, Dict], None]] = None,
                 state: Optional[steps_lib.TrainState] = None,
                 sleep_injector: Optional[Callable[[int], float]] = None,
                 mesh=None) -> TrainResult:
    """Train for ``run.steps`` steps with checkpoint/resume + watchdog.

    ``mesh`` (optional, a ("data","model") mesh from
    :func:`repro.launch.mesh.make_host_mesh`) turns on data-parallel batch
    sharding; state stays replicated and GSPMD partitions the step. Returns a
    :class:`TrainResult` (unpacks as the legacy ``(state, history, info)``).
    """
    if run.reliability is not None:
        warnings.warn(
            "RunConfig(reliability=ReliabilityConfig(...)) is deprecated; "
            "pass RunConfig(policy=<ReliabilityPolicy>, ber=..., inject=...) "
            "instead (ReliabilityConfig.from_policy compiles it "
            "bit-compatibly).", DeprecationWarning, stacklevel=2)
    corrupt = make_fault_schedule(run)

    def wrapped_step(state, batch, key):
        if corrupt is not None:
            faulty = corrupt(state.params, key)
            state = steps_lib.TrainState(faulty, state.opt, state.exps,
                                         state.signs, state.ef_error)
        return base_step(state, batch)

    base_step = steps_lib.make_train_step(cfg, run)
    step_fn = jax.jit(wrapped_step) if corrupt is not None else \
        jax.jit(lambda s, b, k: base_step(s, b))

    start_step = 0
    ckpt_dir = run.checkpoint_dir
    checkpointer = None
    if ckpt_dir:
        os.makedirs(ckpt_dir, exist_ok=True)
        if state is None:
            latest = ckpt_lib.latest_step(ckpt_dir)
            if latest is not None:
                abstract = jax.eval_shape(
                    lambda: steps_lib.init_train_state(
                        jax.random.PRNGKey(run.seed), cfg, run))
                state, start_step = ckpt_lib.restore(abstract, ckpt_dir)
                state = jax.tree_util.tree_map(
                    lambda x: None if x is None else jnp.asarray(x), state,
                    is_leaf=lambda x: x is None)
        checkpointer = ckpt_lib.AsyncCheckpointer(ckpt_dir)
    if state is None:
        state = steps_lib.init_train_state(jax.random.PRNGKey(run.seed), cfg, run)

    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        rep = NamedSharding(mesh, P())
        state = jax.tree_util.tree_map(
            lambda x: None if x is None else jax.device_put(jnp.asarray(x), rep),
            state, is_leaf=lambda x: x is None)

    watchdog = StragglerWatchdog(factor=run.straggler_factor)
    history, stragglers = [], 0
    it = iter(batches)
    for step in range(start_step, run.steps):
        batch = next(it)
        if mesh is not None:
            batch = _shard_batch(batch, mesh)
        t0 = time.time()
        if sleep_injector is not None:   # simulated host slowness (tests)
            time.sleep(sleep_injector(step))
        key = jax.random.fold_in(jax.random.PRNGKey(run.seed + 17), step)
        state, metrics = step_fn(state, batch, key)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.time() - t0
        # first step includes jit compile — never feed it to the watchdog
        if step > start_step and watchdog.observe(dt):
            stragglers += 1
        metrics.update(step=step, step_time=dt)
        history.append(metrics)
        if log_fn:
            log_fn(step, metrics)
        if checkpointer and (step + 1) % run.checkpoint_every == 0:
            checkpointer.save_async(state, step + 1)

    if checkpointer:
        checkpointer.save_async(state, run.steps)
        checkpointer.wait()
        checkpointer.close()
    info = {"stragglers_flagged": stragglers, "resumed_from": start_step,
            "ewma_step_time": watchdog.ewma}
    return TrainResult(state=state, history=history, info=info, cfg=cfg,
                       run=run)
