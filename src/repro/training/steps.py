"""jit-able train / prefill / serve steps with the reliability feature wired in.

``train_step`` implements: forward (+MoE aux) -> grad -> global-norm clip ->
(optional int8 error-feedback compression of the cross-pod gradient) -> AdamW
-> frozen-exponent projection (paper §III-C fine-tuning: mantissa-only
updates). ``serve_step`` is one decode token; ``prefill_step`` returns
last-token logits + caches.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.core import align as align_lib
from repro.core.api import ReliabilityConfig
from repro.models import lm
from repro.models.losses import lm_loss
from repro.optim import adamw


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: object
    opt: object
    exps: object          # frozen block exponents (None leaves when mode=off)
    signs: object         # frozen signs
    ef_error: object      # error-feedback accumulator (grad compression) or None

    def tree_flatten(self):
        return (self.params, self.opt, self.exps, self.signs, self.ef_error), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_train_state(key, cfg: ModelConfig, run: RunConfig,
                     params=None) -> TrainState:
    """Fresh TrainState (new optimizer): init weights, or align+freeze the
    given ``params`` (the co-design fine-tuning entry — stage 2 re-aligns a
    reshaped model instead of re-initializing it)."""
    if params is None:
        params = lm.init_lm(key, cfg)
    rel = run.rel
    exps = signs = jax.tree_util.tree_map(lambda _: None, params)
    if rel.enabled() and run.freeze_exponents:
        if rel.policy.uniform:
            # the legacy uniform path, stream/bit-compatible with every
            # pre-policy checkpoint (tests pin the frozen exponents)
            params, exps = align_lib.align_pytree(params, rel.align_cfg)
        else:
            params, exps = align_lib.align_pytree_policy(params, rel.policy)
        signs = jax.tree_util.tree_map(
            lambda w, e: jnp.sign(w).astype(jnp.int8) if e is not None else None,
            params, exps, is_leaf=lambda x: x is None)
    ef = None
    if run.grad_compression:
        ef = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, opt=adamw.init_opt_state(params),
                      exps=exps, signs=signs, ef_error=ef)


def make_train_step(cfg: ModelConfig, run: RunConfig,
                    unroll: bool = False) -> Callable:
    rel = run.rel
    project = rel.enabled() and run.freeze_exponents
    reg_policy = rel.policy if run.exp_reg_coef > 0 else None
    opt_cfg = adamw.AdamWConfig(weight_decay=run.weight_decay,
                                grad_clip=run.grad_clip)
    lr_fn = adamw.make_lr_schedule(run.learning_rate, run.warmup_steps, run.steps)

    cdt = cfg.cdtype()

    def _cast(p):
        # Cast weights to the compute dtype ONCE at the step top, while still
        # sharded: every downstream FSDP all-gather then moves bf16, not fp32
        # (XLA does not hoist the convert above the gather by itself —
        # §Perf command-r iteration 3). Grads return in fp32 at this boundary.
        if p.ndim >= 2 and jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(cdt)
        return p

    def loss_fn(params, batch):
        params_c = jax.tree_util.tree_map(_cast, params)
        logits, aux, _ = lm.forward(params_c, cfg, batch, remat=run.remat,
                                    unroll=unroll)
        loss, metrics = lm_loss(logits, batch["labels"])
        if reg_policy is not None:
            from repro.models.losses import exponent_compression_penalty
            pen = exponent_compression_penalty(params, reg_policy,
                                               margin=run.exp_reg_margin)
            loss = loss + run.exp_reg_coef * pen
            metrics = dict(metrics, exp_penalty=pen)
        return loss + aux, (metrics, aux)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        (loss, (metrics, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch)
        grads, gnorm = adamw.clip_by_global_norm(grads, opt_cfg.grad_clip)

        ef = state.ef_error
        if ef is not None:
            from repro.distributed.compression import compress_decompress
            grads, ef = compress_decompress(grads, ef)

        lr = lr_fn(state.opt["step"])
        params, opt = adamw.adamw_update(grads, state.opt, state.params, lr, opt_cfg)
        if project:
            if rel.policy.uniform:
                params = align_lib.project_pytree(params, state.exps,
                                                  state.signs, rel.align_cfg)
            else:
                params = align_lib.project_pytree_policy(
                    params, state.exps, state.signs, rel.policy)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr, aux_loss=aux)
        return TrainState(params, opt, state.exps, state.signs, ef), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, unroll: bool = False) -> Callable:
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, unroll=unroll)
    return prefill_step


def make_serve_step(cfg: ModelConfig, unroll: bool = False) -> Callable:
    def serve_step(params, caches, tokens):
        return lm.decode(params, cfg, caches, tokens, unroll=unroll)
    return serve_step


def make_prefill_chunk_step(cfg: ModelConfig) -> Callable:
    """Continuous-batching engine: one prompt chunk of one slot appended to
    the batched caches. ``slot``/``pos``/``length`` are traced — one compile
    per chunk *shape*, reused across slots, offsets and ragged tails."""
    def prefill_chunk_step(params, caches, tokens, slot, pos, length,
                          req_salt):
        return lm.prefill_chunk(params, cfg, caches, tokens, slot, pos,
                                length=length, req_salt=req_salt)
    return prefill_chunk_step


def make_decode_slots_step(cfg: ModelConfig) -> Callable:
    """Continuous-batching engine: one decode token across the slot batch
    with per-slot positions and per-request fault-stream salts."""
    def decode_slots_step(params, caches, tokens, active, req_salts):
        return lm.decode_slots(params, cfg, caches, tokens, active,
                               req_salts=req_salts)
    return decode_slots_step


def make_extract_state_step(cfg: ModelConfig) -> Callable:
    """Prefix cache: extract one slot's per-block state chunk after a
    prefill — KV rows for position-addressable kinds, the final state
    snapshot for recurrent folds. Jit with ``length`` static (one trace per
    chunk shape)."""
    def extract_state_step(caches, slot, pos, length):
        return lm.extract_state_chunk(cfg, caches, slot, pos, length)
    return extract_state_step


def make_inject_state_step(cfg: ModelConfig) -> Callable:
    """Prefix cache: write a cached state chunk into a slot (the
    prefill-from-cache entry)."""
    def inject_state_step(caches, slot, pos, chunk):
        return lm.inject_state_chunk(cfg, caches, slot, pos, chunk)
    return inject_state_step


# deprecated factory aliases (the lm.* shims under them warn per call)
make_extract_kv_step = make_extract_state_step
make_inject_kv_step = make_inject_state_step
