"""The co-design loop (paper §III-C + beyond): resilience-aware fine-tuning
and automatic reliability-policy search.

The paper's headline result is a two-sided trade: **fine-tune** the model so
its exponent distribution compresses into shared-block exponents, then
**protect** the (now small) sensitive field with lightweight ECC at ~9%
stored-bit overhead. This module closes that loop end to end:

* :class:`Finetuner` — two-stage resilience-aware fine-tuning *through* the
  deployment stack, on a ("data","model") host mesh:

    1. **reshape** — train with the exponent-compression regularizer
       (:func:`repro.models.losses.exponent_compression_penalty`, weighted per
       the policy's rule groups) and *unfrozen* exponents, shrinking each
       N-block's log-magnitude spread so the subsequent alignment loses less;
    2. **aligned** — re-align the reshaped weights per rule
       (:func:`repro.core.align.align_pytree_policy`), freeze (exponent,
       sign), and train mantissas under the policy's dynamic fault schedule
       (:func:`repro.core.deployment.training_fault_schedule` inside the
       jitted step) — the model learns *under* the soft errors it will serve
       with.

  Fault streams follow the counter-PRNG contract: per-step keys derive from
  (seed, step) and split across flat leaves, so streams are bit-identical on
  1 device and any forced multi-device mesh.

* :class:`PolicySearch` — finds the cheapest per-layer protection meeting an
  accuracy-vs-BER SLO. The search space is per-group (pattern) choices of
  ``protect x field x n_group`` (:class:`SearchSpace`); the evaluator is
  ``SweepEngine.run_policies`` (one compiled (BER x trial) plane per
  candidate arm); the cost axis is deployed ``stored_bits``
  (:meth:`repro.core.deployment.CIMDeployment.bit_cost`). Greedy cost-ascent:
  start every group at its cheapest candidate, batch-evaluate single-step
  upgrades, accept the best accuracy-per-bit move until the SLO holds, then a
  prune pass walks groups back down while the SLO still holds.

``python -m repro.training.codesign --quick --json out.json`` runs the CI
smoke: a short fine-tune plus a 2-candidate policy selection, asserting
finite losses and reporting the SLO verdict (see ``codesign-smoke`` in CI).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.core import cim as cim_lib
from repro.core import sweep as sweep_lib
from repro.core.deployment import (PolicyRule, ReliabilityPolicy, path_str,
                                   VALID_PROTECTS, VALID_FIELDS, check_enum)
from repro.training import steps as steps_lib
from repro.training.loop import TrainResult, run_training


# ---------------------------------------------------------------- fine-tune


@dataclasses.dataclass
class Finetuner:
    """Two-stage resilience-aware fine-tuning under a reliability policy.

    ``run(batches, params=...)`` fine-tunes ``params`` (or trains from
    scratch when None) and returns the stage-2 :class:`TrainResult`, whose
    ``deployment`` is the final weights packed under ``policy`` and whose
    ``info['reshape']`` carries the stage-1 curve. ``batches`` is an iterator
    (consumed across both stages) or a zero-arg callable returning one per
    stage. ``mesh='auto'`` builds the ("data","model") host mesh over all
    local devices; pass None to stay unplaced or a prebuilt mesh to control
    the shape.
    """

    cfg: ModelConfig
    policy: ReliabilityPolicy
    ber: float = 0.0
    reshape_steps: int = 40
    aligned_steps: int = 40
    learning_rate: float = 1e-3
    exp_reg_coef: float = 5e-2
    exp_reg_margin: float = 1.0
    weight_decay: float = 0.0
    seed: int = 0
    mesh: object = "auto"

    def _mesh(self):
        if isinstance(self.mesh, str):
            if self.mesh != "auto":
                raise ValueError(f"Finetuner: mesh must be 'auto', None or a "
                                 f"Mesh, got {self.mesh!r}")
            from repro.launch.mesh import make_host_mesh
            return make_host_mesh(model_axis=1)
        return self.mesh

    def _run_cfg(self, **kw) -> RunConfig:
        base = dict(arch=self.cfg.arch_id, policy=self.policy,
                    learning_rate=self.learning_rate,
                    weight_decay=self.weight_decay, seed=self.seed,
                    checkpoint_dir="", remat=False, warmup_steps=0)
        base.update(kw)
        return RunConfig(**base)

    def _batches(self, batches):
        if callable(batches):
            return iter(batches())
        return iter(batches)

    def run(self, batches, params=None,
            log_fn: Optional[Callable] = None) -> TrainResult:
        mesh = self._mesh()
        key = jax.random.PRNGKey(self.seed)
        reshape_hist: List[Dict] = []
        if self.reshape_steps > 0:
            run1 = self._run_cfg(steps=self.reshape_steps, ber=0.0,
                                 exp_reg_coef=self.exp_reg_coef,
                                 exp_reg_margin=self.exp_reg_margin,
                                 freeze_exponents=False)
            state1 = steps_lib.init_train_state(key, self.cfg, run1,
                                                params=params)
            res1 = run_training(self.cfg, run1, self._batches(batches),
                                log_fn=log_fn, state=state1, mesh=mesh)
            params = res1.state.params
            reshape_hist = res1.history

        run2 = self._run_cfg(steps=self.aligned_steps, ber=self.ber,
                             inject="dynamic", freeze_exponents=True)
        state2 = steps_lib.init_train_state(jax.random.fold_in(key, 1),
                                            self.cfg, run2, params=params)
        res2 = run_training(self.cfg, run2, self._batches(batches),
                            log_fn=log_fn, state=state2, mesh=mesh)
        res2.info["reshape"] = {"steps": self.reshape_steps,
                                "history": reshape_hist}
        return res2


# ------------------------------------------------------------ search space


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Per-layer protection search grammar.

    ``groups`` is an ordered tuple of ``(name, pattern)`` rule groups —
    pattern syntax is :class:`PolicyRule`'s (glob / ``re:`` regex, first
    match wins, so order specific groups before catch-alls). Every group
    independently picks one candidate from the ``protects x fields x
    n_groups`` grid; leaves no group matches fall to ``default`` (fixed, not
    searched).
    """

    groups: Tuple[Tuple[str, str], ...]
    protects: Tuple[str, ...] = ("none", "one4n", "per_weight")
    fields: Tuple[str, ...] = ("full",)
    n_groups: Tuple[int, ...] = (8,)
    default: PolicyRule = PolicyRule()

    def __post_init__(self):
        object.__setattr__(self, "groups", tuple(
            (str(n), str(p)) for n, p in self.groups))
        object.__setattr__(self, "protects", tuple(self.protects))
        object.__setattr__(self, "fields", tuple(self.fields))
        object.__setattr__(self, "n_groups", tuple(int(n)
                                                   for n in self.n_groups))
        if not self.groups:
            raise ValueError("SearchSpace: need at least one (name, pattern) "
                             "group")
        names = [n for n, _ in self.groups]
        if len(set(names)) != len(names):
            raise ValueError(f"SearchSpace: duplicate group names in {names}")
        for p in self.protects:
            check_enum("protects", p, VALID_PROTECTS, "SearchSpace")
        for f in self.fields:
            check_enum("fields", f, VALID_FIELDS, "SearchSpace")
        if not self.protects or not self.fields or not self.n_groups:
            raise ValueError("SearchSpace: protects/fields/n_groups must be "
                             "non-empty")

    def candidates(self) -> Tuple[dict, ...]:
        """The per-group candidate grid as PolicyRule kwargs."""
        return tuple(dict(protect=p, field=f, n_group=n)
                     for p, f, n in itertools.product(
                         self.protects, self.fields, self.n_groups))


@dataclasses.dataclass(frozen=True)
class AccuracySLO:
    """Accuracy floor at a BER: ``accuracy(ber) >= clean - max_drop`` (and
    ``>= min_accuracy`` when given). ``floor`` resolves the effective bound
    against the measured clean accuracy."""

    ber: float
    max_drop: float = 0.02
    min_accuracy: Optional[float] = None

    def __post_init__(self):
        if self.ber < 0:
            raise ValueError(f"AccuracySLO: ber must be >= 0, got {self.ber}")
        if self.max_drop < 0:
            raise ValueError(f"AccuracySLO: max_drop must be >= 0, got "
                             f"{self.max_drop}")

    def floor(self, clean_accuracy: float) -> float:
        f = clean_accuracy - self.max_drop
        if self.min_accuracy is not None:
            f = max(f, self.min_accuracy)
        return f


@dataclasses.dataclass
class SearchResult:
    """Outcome of a policy search/selection."""

    policy: ReliabilityPolicy
    name: str
    accuracy: float            # mean accuracy at slo.ber under the policy
    clean_accuracy: float
    floor: float               # resolved SLO floor
    slo_met: bool
    stored_bits: int
    raw_bits: int
    overhead: float            # stored_bits / raw_bits - 1
    evals: int                 # total candidate-arm evaluations spent
    trace: List[Dict]          # per-move search log

    @property
    def assignment(self) -> Dict[str, dict]:
        """Group name -> chosen rule settings (search results only)."""
        return {r.pattern: dict(protect=r.protect, field=r.field,
                                n_group=r.n_group)
                for r in self.policy.rules}


class PolicySearch:
    """Cheapest per-layer protection meeting an accuracy-vs-BER SLO.

    ``eval_fn(params) -> scalar accuracy`` must be jit-compatible (same
    contract as the characterization engine). Evaluation goes through
    ``SweepEngine.run_policies`` — one compiled (BER x trial) plane per arm,
    trials batched and mesh-sharded; cost comes from the arm's actual
    deployed ``stored_bits``.
    """

    def __init__(self, params, eval_fn: Callable, slo: AccuracySLO,
                 space: Optional[SearchSpace] = None, *, n_trials: int = 3,
                 key=None, engine: Optional[sweep_lib.SweepEngine] = None):
        self.params = params
        self.eval_fn = eval_fn
        self.slo = slo
        self.space = space
        self.key = key if key is not None else jax.random.PRNGKey(0)
        if engine is None:
            plan = sweep_lib.SweepPlan(bers=(slo.ber,), n_trials=n_trials)
            engine = sweep_lib.SweepEngine(plan)
        elif engine.plan.bers != (float(slo.ber),):
            raise ValueError(f"engine.plan.bers={engine.plan.bers} must be "
                             f"exactly (slo.ber,)=({slo.ber},)")
        self.engine = engine
        self.evals = 0
        self.trace: List[Dict] = []
        self._clean: Optional[float] = None
        self._bits_cache: Dict[tuple, int] = {}

    # ------------------------------------------------------------- plumbing

    def clean_accuracy(self) -> float:
        if self._clean is None:
            self._clean = float(jax.device_get(self.eval_fn(self.params)))
        return self._clean

    def _leaf_bits(self, shape, rule: PolicyRule) -> int:
        """Stored bits of one K x J leaf under ``rule`` — shape-only, so a
        zeros probe pack is cached per (shape, packing config)."""
        ck = (tuple(shape), rule.protect, rule.n_group, rule.index,
              rule.row_weights, rule.fmt_name)
        if ck not in self._bits_cache:
            probe = cim_lib.pack(jnp.zeros(shape, jnp.float32), rule.cim_cfg)
            self._bits_cache[ck] = int(probe.stored_bits)
        return self._bits_cache[ck]

    def _group_map(self) -> Dict[Optional[str], List[tuple]]:
        """Group name -> [(path, shape)] of the deployable leaves it owns
        (first matching group wins, mirroring rule order); key None holds the
        default rule's leaves."""
        from repro.core.cim import _deployable
        probes = {name: PolicyRule(pattern)
                  for name, pattern in self.space.groups}
        out: Dict[Optional[str], List[tuple]] = {None: []}
        out.update({name: [] for name, _ in self.space.groups})
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.params)[0]:
            if not _deployable(path, leaf):
                continue
            p = path_str(path)
            for name, _ in self.space.groups:
                if probes[name].matches(p):
                    out[name].append((p, tuple(leaf.shape)))
                    break
            else:
                out[None].append((p, tuple(leaf.shape)))
        return out

    def _policy_of(self, assignment: Dict[str, dict]) -> ReliabilityPolicy:
        rules = tuple(PolicyRule(pattern, **assignment[name])
                      for name, pattern in self.space.groups)
        return ReliabilityPolicy(rules=rules, default=self.space.default)

    def _evaluate(self, named_policies) -> Dict[str, tuple]:
        """One batched engine call -> {name: (mean accuracy, stored_bits)}."""
        if isinstance(named_policies, dict):
            named_policies = list(named_policies.items())
        self.key, sub = jax.random.split(self.key)
        results = self.engine.run_policies(sub, self.params, self.eval_fn,
                                           named_policies)
        self.evals += len(named_policies)
        return {r.protect: (r.mean, r.stored_bits) for r in results}

    # --------------------------------------------------------------- search

    def search(self, max_rounds: Optional[int] = None) -> SearchResult:
        """Greedy cost-ascent + prune over the :class:`SearchSpace`."""
        if self.space is None:
            raise ValueError("PolicySearch.search needs a SearchSpace (or "
                             "use .select(named_policies))")
        clean = self.clean_accuracy()
        floor = self.slo.floor(clean)
        cands = self.space.candidates()
        gmap = self._group_map()
        for name, _ in self.space.groups:
            if not gmap[name]:
                self.trace.append({"action": "warn-empty-group",
                                   "group": name})

        def group_bits(name: str, ci: int) -> int:
            rule = PolicyRule("*", **cands[ci])
            return sum(self._leaf_bits(shape, rule)
                       for _, shape in gmap[name])

        # per-group candidate order, cheapest stored-bits first
        order = {name: sorted(range(len(cands)),
                              key=lambda ci: (group_bits(name, ci), ci))
                 for name, _ in self.space.groups}
        pos = {name: 0 for name, _ in self.space.groups}

        def assignment():
            return {name: cands[order[name][pos[name]]]
                    for name, _ in self.space.groups}

        acc, bits = self._evaluate([("start", self._policy_of(assignment()))])[
            "start"]
        self.trace.append({"action": "start", "accuracy": acc,
                           "stored_bits": bits, "floor": floor})

        budget = max_rounds if max_rounds is not None else \
            len(order) * len(cands)
        rounds = 0
        while acc < floor and rounds < budget:
            rounds += 1
            proposals = {}
            for name, _ in self.space.groups:
                if pos[name] + 1 < len(order[name]):
                    a = assignment()
                    a[name] = cands[order[name][pos[name] + 1]]
                    proposals[name] = self._policy_of(a)
            if not proposals:
                break
            res = self._evaluate([(n, p) for n, p in proposals.items()])
            # a proposal that already meets the SLO wins on cheapness;
            # otherwise climb the best accuracy-gain-per-added-bit slope
            meeting = [(res[n][1], n) for n in proposals if res[n][0] >= floor]
            if meeting:
                _, pick = min(meeting)
            else:
                def slope(n):
                    da = res[n][0] - acc
                    db = max(res[n][1] - bits, 1)
                    return da / db
                pick = max(proposals, key=slope)
            pos[pick] += 1
            acc, bits = res[pick]
            self.trace.append({"action": "upgrade", "group": pick,
                               "candidate": cands[order[pick][pos[pick]]],
                               "accuracy": acc, "stored_bits": bits})

        # prune: walk groups back down while the SLO still holds
        while acc >= floor:
            downs = {}
            for name, _ in self.space.groups:
                if pos[name] > 0:
                    a = assignment()
                    a[name] = cands[order[name][pos[name] - 1]]
                    downs[name] = self._policy_of(a)
            if not downs:
                break
            res = self._evaluate([(n, p) for n, p in downs.items()])
            ok = [(res[n][1], n) for n in downs if res[n][0] >= floor]
            if not ok:
                break
            _, pick = min(ok)   # biggest saving = smallest resulting bits
            pos[pick] -= 1
            acc, bits = res[pick]
            self.trace.append({"action": "prune", "group": pick,
                               "candidate": cands[order[pick][pos[pick]]],
                               "accuracy": acc, "stored_bits": bits})

        policy = self._policy_of(assignment())
        return self._result(policy, "searched", acc, clean, floor, bits)

    def select(self, named_policies) -> SearchResult:
        """Cheapest SLO-meeting policy from an explicit candidate list (the
        2-candidate CI smoke path); falls back to the most accurate candidate
        when none meets the floor (``slo_met=False``)."""
        if isinstance(named_policies, dict):
            named_policies = list(named_policies.items())
        if not named_policies:
            raise ValueError("select: empty candidate list")
        clean = self.clean_accuracy()
        floor = self.slo.floor(clean)
        res = self._evaluate(named_policies)
        by_name = dict(named_policies)
        meeting = [(res[n][1], n) for n, _ in named_policies
                   if res[n][0] >= floor]
        if meeting:
            _, name = min(meeting)
        else:
            name = max(res, key=lambda n: res[n][0])
        acc, bits = res[name]
        self.trace.append({"action": "select", "name": name,
                           "accuracy": acc, "stored_bits": bits,
                           "floor": floor,
                           "arms": {n: {"accuracy": res[n][0],
                                        "stored_bits": res[n][1]}
                                    for n in res}})
        return self._result(by_name[name], name, acc, clean, floor, bits)

    def _result(self, policy, name, acc, clean, floor, bits) -> SearchResult:
        from repro.core.deployment import CIMDeployment
        cost = CIMDeployment.deploy(self.params, policy).bit_cost()
        return SearchResult(policy=policy, name=name, accuracy=acc,
                            clean_accuracy=clean, floor=floor,
                            slo_met=acc >= floor,
                            stored_bits=cost["stored_bits"],
                            raw_bits=cost["raw_bits"],
                            overhead=cost["overhead"], evals=self.evals,
                            trace=list(self.trace))


# ------------------------------------------------------------- CI smoke CLI


def _smoke(args) -> dict:
    """Quick fine-tune + 2-candidate policy selection (codesign-smoke CI)."""
    import time
    from repro.configs import get_config
    from repro.data.synthetic import MarkovLM
    from repro.models import lm
    from repro.models.losses import lm_loss

    t0 = time.time()
    cfg = get_config("olmo-1b").reduced()
    data = MarkovLM(cfg.vocab_size, 64, 8, seed=0)
    policy = ReliabilityPolicy()      # uniform one4n
    ft = Finetuner(cfg, policy, ber=args.ber,
                   reshape_steps=args.reshape_steps,
                   aligned_steps=args.aligned_steps, seed=0)
    res = ft.run(iter(data))
    losses = np.asarray(
        [h["loss"] for h in res.info["reshape"]["history"]] +
        [h["loss"] for h in res.history])
    eval_batches = [data.batch(9000 + i) for i in range(2)]

    def eval_fn(params):
        accs = []
        for batch in eval_batches:
            logits, _, _ = lm.forward(params, cfg, batch, remat=False)
            accs.append(lm_loss(logits, batch["labels"])[1]["accuracy"])
        return jnp.mean(jnp.stack(accs))

    search = PolicySearch(res.state.params, eval_fn,
                          AccuracySLO(ber=args.ber, max_drop=args.max_drop),
                          n_trials=2)
    sel = search.select({
        "uniform_one4n": ReliabilityPolicy(),
        "embeds_only": ReliabilityPolicy(
            rules=(PolicyRule("embed", protect="one4n"),
                   PolicyRule("unembed", protect="one4n"),
                   PolicyRule("*", protect="none"))),
    })
    return {
        "quick": True,
        "wall_s": time.time() - t0,
        "finetune": {"steps": int(len(losses)),
                     "final_loss": float(losses[-1]),
                     "losses_finite": bool(np.isfinite(losses).all()),
                     "ecc_stats": res.ecc_stats},
        "search": {"selected": sel.name, "slo_met": bool(sel.slo_met),
                   "accuracy": sel.accuracy,
                   "clean_accuracy": sel.clean_accuracy,
                   "floor": sel.floor, "stored_bits": sel.stored_bits,
                   "overhead": sel.overhead, "evals": sel.evals},
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(
        description="co-design smoke: quick fine-tune + policy selection")
    ap.add_argument("--quick", action="store_true",
                    help="shrink steps further (CI)")
    ap.add_argument("--ber", type=float, default=1e-3)
    ap.add_argument("--max-drop", type=float, default=0.05)
    ap.add_argument("--reshape-steps", type=int, default=20)
    ap.add_argument("--aligned-steps", type=int, default=20)
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args(argv)
    if args.quick:
        args.reshape_steps = min(args.reshape_steps, 10)
        args.aligned_steps = min(args.aligned_steps, 10)

    out = _smoke(args)
    print(json.dumps(out, indent=2))
    if args.json:
        import os
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
    if not out["finetune"]["losses_finite"]:
        print("codesign smoke: NON-FINITE training losses")
        return 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
