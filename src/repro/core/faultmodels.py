"""Fault-model zoo: structured error processes over the counter-PRNG streams.

Every injection path in the repo draws i.i.d. Bernoulli flips from the
counter PRNG: bit ``p`` of the word at C-order flat index ``e`` flips iff
``murmur3(e*32 + p XOR seed*GOLD) < threshold``. The CIM-reliability
literature the paper builds on (Wan et al., arXiv:2008.02400; Yan et al.,
arXiv:2205.13018) models richer processes — spatially-correlated failures,
row/column/bank bursts, and time-dependent drift. This module defines that
vocabulary as :class:`FaultProcess` and **compiles every process to a
per-element uint32 threshold** derived from the GLOBAL C-order element index
of the packed plane:

    ==========  ===========================================================
    kind        compiled threshold at element ``e``
    ==========  ===========================================================
    iid         ``thr`` unchanged — bit-for-bit today's streams (the
                default; the zoo costs nothing when unused)
    burst       ``thr`` where the element's row/column/bank *unit* draws a
                Bernoulli hit at ``rate`` (one draw per aligned run of
                ``length`` units), else 0 — whole word lines / macro column
                groups fail together
    correlated  ``thr`` scaled per macro-column group by a hash-derived
                factor in ``[1-strength, 1]`` (Q16 fixed point, exact
                uint32 arithmetic) — per-column retention-margin spread
    drift       ``thr * (1+drift_rate)**tick`` — a BER-vs-time schedule
                keyed on a logical tick (element-independent; the serving
                engine keys ``tick`` on the request-local read position)
    ==========  ===========================================================

Because the compiled threshold is a pure function of (plane seed, model,
global element index), every consumer — the jnp ``inject`` path, the
``shard_map`` local blocks of ``inject_sharded``, ``read_rows`` gathers, and
the ``fault_inject``/``cim_read`` Pallas kernels — derives bit-identical
masks, so the PR-2/PR-3 reproducibility contract extends to the whole zoo:
same key + model ⇒ identical streams solo vs co-batched vs sharded vs
cached-prefix.

Scaled thresholds never exceed the i.i.d. threshold (burst zeroes, the
correlated factor is ≤ 1), so a process's flip set is a *subset* of the
i.i.d. flip set at the same (seed, threshold) — the property the model-zoo
tests pin.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fault_inject.kernel import hash_u32

VALID_KINDS = ("iid", "burst", "correlated", "drift")
VALID_AXES = ("row", "col", "bank")

_GOLD = 0x9E3779B9
# Salt folding a plane seed into the burst/correlated *unit* stream, so unit
# hit decisions never alias the per-bit flip stream of the same seed. The
# fold mirrors cim.fold_seed(seed, MODEL_SEED_SALT) exactly (inlined here —
# cim imports this module, not the reverse).
MODEL_SEED_SALT = 0x0DD5EED5
# threshold saturation (mirrors fault_inject.ops.ber_to_threshold): values at
# or above this map to the all-ones threshold
_THR_SAT = 4294967040.0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FaultProcess:
    """One error process of the zoo. Hashable and static under ``jit``.

    Registered as a *leafless* pytree (all fields ride in the aux data), so a
    process can sit inside the serving runtime dict (``params['_cim']``) and
    pass through ``jax.jit`` as compile-time structure — exactly like the
    PR-3 shard offsets, the model's *parameters* are traced (SMEM scalars)
    while its *kind* picks the compiled code path.

    Fields (unused ones ignored per kind):

    * ``rate`` — burst: fraction of units hit (Bernoulli per aligned run).
    * ``length`` — burst: units per aligned run (a hit knocks out ``length``
      consecutive rows/columns; for ``axis='bank'`` a ``length x length``
      tile).
    * ``axis`` — burst alignment: ``row`` (word lines / exponent block
      rows), ``col`` (macro column groups), ``bank`` (2-D tiles).
    * ``strength`` — correlated: per-column scaling spread in ``[0, 1]``
      (0 ⇒ exactly i.i.d.).
    * ``period`` — correlated: macro column groups per probability draw.
    * ``drift_rate`` — drift: per-tick multiplicative BER growth.
    * ``tick`` — drift: logical time of a *static* injection (serving paths
      override it per read position and keep the stored tick at 0).
    """

    kind: str = "iid"
    rate: float = 0.25
    length: int = 4
    axis: str = "row"
    strength: float = 0.5
    period: int = 1
    drift_rate: float = 0.02
    tick: int = 0

    def __post_init__(self):
        if self.kind not in VALID_KINDS:
            raise ValueError(f"FaultProcess: kind={self.kind!r} is not valid; "
                             f"expected one of {', '.join(VALID_KINDS)}")
        if self.axis not in VALID_AXES:
            raise ValueError(f"FaultProcess: axis={self.axis!r} is not valid; "
                             f"expected one of {', '.join(VALID_AXES)}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"FaultProcess: rate must be in [0, 1], "
                             f"got {self.rate}")
        if not 0.0 <= self.strength <= 1.0:
            raise ValueError(f"FaultProcess: strength must be in [0, 1], "
                             f"got {self.strength}")
        if self.length < 1 or self.period < 1:
            raise ValueError("FaultProcess: length and period must be >= 1")
        if self.drift_rate < 0 or self.tick < 0:
            raise ValueError("FaultProcess: drift_rate and tick must be >= 0")

    # -------------------------------------------------------- constructors

    @classmethod
    def iid(cls) -> "FaultProcess":
        return cls()

    @classmethod
    def burst(cls, rate: float = 0.25, length: int = 4,
              axis: str = "row") -> "FaultProcess":
        return cls(kind="burst", rate=rate, length=length, axis=axis)

    @classmethod
    def correlated(cls, strength: float = 0.5,
                   period: int = 1) -> "FaultProcess":
        return cls(kind="correlated", strength=strength, period=period)

    @classmethod
    def drift(cls, drift_rate: float = 0.02, tick: int = 0) -> "FaultProcess":
        return cls(kind="drift", drift_rate=drift_rate, tick=tick)

    # ------------------------------------------------------------- pytree

    def tree_flatten(self):
        return (), self

    @classmethod
    def tree_unflatten(cls, aux, children):
        return aux


def parse_fault_model(spec) -> Optional[FaultProcess]:
    """CLI/policy grammar -> :class:`FaultProcess` (``None``/'' -> ``None``).

    ``'burst'`` takes the kind's defaults; ``'burst:rate=0.3,length=8,
    axis=col'`` overrides fields (floats/ints coerced per field).
    """
    if spec is None or isinstance(spec, FaultProcess):
        return spec
    spec = str(spec).strip()
    if not spec:
        return None
    kind, _, rest = spec.partition(":")
    if kind not in VALID_KINDS:
        raise ValueError(f"unknown fault model {kind!r}; expected one of "
                         f"{', '.join(VALID_KINDS)}")
    kw = {"kind": kind}
    if rest:
        fields = {f.name: f.type for f in dataclasses.fields(FaultProcess)}
        for part in rest.split(","):
            name, _, val = part.partition("=")
            name = name.strip()
            if name not in fields or name == "kind":
                raise ValueError(f"fault model {kind!r}: unknown parameter "
                                 f"{name!r}")
            kw[name] = (val.strip() if fields[name] == "str"
                        else int(val) if fields[name] == "int"
                        else float(val))
    return FaultProcess(**kw)


# ---------------------------------------------------------------------------
# Compilation: process -> (SMEM scalar payload, per-element thresholds).
# ---------------------------------------------------------------------------


def model_scalars(model: Optional[FaultProcess]):
    """The traced uint32 SMEM payload ``(m_thr, m_len)`` of a process.

    ``burst``: (hit threshold of ``rate``, run ``length``); ``correlated``:
    (Q16 ``strength``, ``period``); ``iid``/``drift``: (0, 0) — their
    compiled thresholds need no per-element parameters.
    """
    if model is None or model.kind in ("iid", "drift"):
        return jnp.uint32(0), jnp.uint32(0)
    if model.kind == "burst":
        from repro.kernels.fault_inject.ops import ber_to_threshold
        return ber_to_threshold(model.rate), jnp.uint32(model.length)
    q16 = max(0, min(65536, int(round(model.strength * 65536.0))))
    return jnp.uint32(q16), jnp.uint32(model.period)


def plane_geometry(shape) -> tuple:
    """``(width, col_div)`` of a packed plane's C-order layout.

    ``width`` is the number of flat elements per logical row (word line /
    exponent block row / sign word row); ``col_div`` divides an intra-row
    offset down to its macro-column *unit*. 2-D planes ``[R, C]`` address
    columns directly; the 4-D One4N codeword plane ``[B, G, S, W]`` has
    ``G*S*W`` words per block row with ``S*W`` words per column group.
    """
    if len(shape) == 4:
        return (int(shape[1]) * int(shape[2]) * int(shape[3]),
                int(shape[2]) * int(shape[3]))
    return int(shape[-1]), 1


def unit_seed(plane_seed):
    """The burst/correlated unit-decision seed of a plane seed (one fold by
    ``MODEL_SEED_SALT``, the ``cim.fold_seed`` chain extended sideways)."""
    salt = jnp.uint32(MODEL_SEED_SALT) * jnp.uint32(0x85EBCA6B) \
        + jnp.uint32(0x9E3779B9)
    return hash_u32(jnp.asarray(plane_seed, jnp.uint32) ^ salt)


def scale_elem_thresholds(elem, threshold, plane_seed, *, kind: str,
                          axis: str, m_thr, m_len, width: int,
                          col_div: int = 1):
    """Per-element flip thresholds of a compiled burst/correlated process.

    ``elem`` holds GLOBAL C-order flat element indices (any shape), so the
    jnp inject path, shard_map local blocks, row gathers and the Pallas
    kernels all derive bit-identical thresholds. ``kind``/``axis`` are
    static (they pick the code path); ``m_thr``/``m_len`` are traced SMEM
    scalars. Pure jnp/uint32 — the ``cim_read`` kernel calls this function
    verbatim inside its tiles.
    """
    threshold = jnp.asarray(threshold, jnp.uint32)
    if kind in ("iid", "drift"):
        return threshold
    elem = jnp.asarray(elem, jnp.uint32)
    m_thr = jnp.asarray(m_thr, jnp.uint32)
    m_len = jnp.asarray(m_len, jnp.uint32)
    useed = unit_seed(plane_seed) * jnp.uint32(_GOLD)
    row = elem // jnp.uint32(width)
    col = (elem % jnp.uint32(width)) // jnp.uint32(col_div)
    if kind == "burst":
        if axis == "row":
            unit = row // m_len
        elif axis == "col":
            unit = col // m_len
        else:  # bank: length x length tiles, mixed into one unit index
            unit = (row // m_len) * jnp.uint32(0x10001) + col // m_len
        hit = hash_u32(unit ^ useed) < m_thr
        return jnp.where(hit, threshold, jnp.uint32(0))
    # correlated: scale by s/65536 with s = 65536 - strength_q16 * h16 / 65536
    # drawn per column group. Split multiply keeps every intermediate < 2^32
    # and makes strength=0 reproduce `threshold` EXACTLY (s = 65536).
    grp = col // m_len
    h16 = hash_u32(grp ^ useed) >> jnp.uint32(16)
    var = (m_thr * h16) >> jnp.uint32(16)              # [0, 65536)
    s = jnp.uint32(65536) - var                        # (0, 65536]
    hi = (threshold >> jnp.uint32(16)) * s
    lo = ((threshold & jnp.uint32(0xFFFF)) * s) >> jnp.uint32(16)
    return hi + lo


def drift_threshold(threshold, drift_rate, tick):
    """Drift time scaling: ``thr * (1+drift_rate)**tick``, saturating like
    ``ber_to_threshold``. ``tick`` may be traced (read position)."""
    thr_f = jnp.asarray(threshold, jnp.uint32).astype(jnp.float32)
    scale = jnp.power(jnp.float32(1.0) + jnp.float32(drift_rate),
                      jnp.asarray(tick, jnp.float32))
    scaled = thr_f * scale
    return jnp.where(scaled >= jnp.float32(_THR_SAT),
                     jnp.uint32(0xFFFFFFFF),
                     scaled.astype(jnp.uint32))


def compiled_threshold(model: Optional[FaultProcess], threshold, tick=None):
    """The element-independent part of a process: drift's time scaling
    (identity for every other kind). ``tick=None`` uses the model's static
    tick; serving paths pass the traced request-local read position."""
    if model is None or model.kind != "drift":
        return jnp.asarray(threshold, jnp.uint32)
    t = model.tick if tick is None else tick
    if isinstance(t, int) and t == 0:
        # static tick 0 is exactly identity — skip the f32 roundtrip so a
        # drift model at t=0 reproduces the i.i.d. streams bit for bit
        return jnp.asarray(threshold, jnp.uint32)
    return drift_threshold(threshold, model.drift_rate, t)


def plane_thresholds(model: Optional[FaultProcess], threshold, elem,
                     plane_seed, shape):
    """Full compile of ``model`` for one packed plane: drift time scaling
    plus the burst/correlated per-element mask at global indices ``elem``.
    ``model=None`` / ``iid`` return ``threshold`` untouched — the zero-cost
    legacy path."""
    if model is None or model.kind == "iid":
        return jnp.asarray(threshold, jnp.uint32)
    threshold = compiled_threshold(model, threshold)
    if model.kind == "drift":
        return threshold
    m_thr, m_len = model_scalars(model)
    width, col_div = plane_geometry(shape)
    return scale_elem_thresholds(elem, threshold, plane_seed,
                                 kind=model.kind, axis=model.axis,
                                 m_thr=m_thr, m_len=m_len, width=width,
                                 col_div=col_div)
