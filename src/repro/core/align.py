"""Exponent alignment — the algorithm half of the co-design (paper §III-C).

Every block of ``N`` weights along the *input channel* (contracting dimension)
is forced to share one biased exponent ``E_index``:

1. extract the biased exponents of all N weights, sort descending, take the
   ``index``-th largest (1-based; the paper sweeps index ∈ {1..4}, N ∈ {4,8,16}
   and finds N=8 with index 2–3 optimal);
2. the representable range for that exponent is ``(LL, UL) =
   (2^(E-bias)·M_min, 2^(E-bias)·M_max)`` (Fig. 5);
3. rescale positive and negative weights of the block *separately* into
   ``[LL, UL]`` / ``[-UL, -LL]`` via the min–max map of Eq. 4;
4. round to the FP16 grid — every weight in the block now has exponent E.

Fine-tuning then freezes exponent and sign and updates only mantissas; we
implement that as a projection (``project_to_block_exponent``) applied after
each optimizer step, which is mathematically the paper's "update mantissa only"
scheme (projected gradient descent onto the fixed-exponent manifold).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitops
from repro.core.bitops import FP16, FloatFormat


@dataclasses.dataclass(frozen=True)
class AlignmentConfig:
    n_group: int = 8        # N
    index: int = 2          # 1-based rank of the chosen exponent (paper: 2 or 3)
    fmt: FloatFormat = FP16
    group_axis: int = 0     # input-channel axis of 2-D [in, out] weights


def _block_view(w: jnp.ndarray, n: int, axis: int) -> jnp.ndarray:
    """[K, J] -> [K//n (blocks), n, J] (pad K up to a multiple of n).

    The paper groups along the input channel; remaining (<N) weights form an
    extra block (footnote 2) — we realize that by edge-padding with the last
    row so padding never changes a real block's exponent choice.
    """
    if axis != 0:
        w = jnp.moveaxis(w, axis, 0)
    k = w.shape[0]
    rem = (-k) % n
    if rem:
        w = jnp.concatenate([w, jnp.broadcast_to(w[-1:], (rem,) + w.shape[1:])], 0)
    return w.reshape(-1, n, *w.shape[1:]), k


def _block_exponent_moved(w: jnp.ndarray, cfg: AlignmentConfig) -> jnp.ndarray:
    """E_index per block in moved layout [B, ...other dims]."""
    blocks, _ = _block_view(w, cfg.n_group, cfg.group_axis)
    exps = bitops.biased_exponent(blocks, cfg.fmt)           # [B, n, ...]
    order = jnp.sort(exps.astype(jnp.int32), axis=1)         # ascending
    idx = jnp.clip(cfg.n_group - cfg.index, 0, cfg.n_group - 1)
    return order[:, idx]                                      # [B, ...]


def block_exponent(w: jnp.ndarray, cfg: AlignmentConfig) -> jnp.ndarray:
    """Select E_index per block; the block axis sits at ``cfg.group_axis``
    (i.e. exponents of a [*, K, J] weight are [*, K/N, J]) so exponent planes
    inherit their weight's sharding layout."""
    return jnp.moveaxis(_block_exponent_moved(w, cfg), 0, cfg.group_axis)


def _rescale_signed(mag: jnp.ndarray, mask: jnp.ndarray, ll: jnp.ndarray,
                    ul: jnp.ndarray) -> jnp.ndarray:
    """Eq. 4 min–max rescale of the magnitudes selected by ``mask`` into [LL,UL].

    Degenerate blocks (0 or 1 member of the sign class) map to the midpoint of
    the range, keeping the block on the shared-exponent grid.
    """
    big = jnp.where(mask, mag, -jnp.inf)
    small = jnp.where(mask, mag, jnp.inf)
    wmax = jnp.max(big, axis=1, keepdims=True)
    wmin = jnp.min(small, axis=1, keepdims=True)
    span = wmax - wmin
    ok = jnp.isfinite(span) & (span > 0)
    t = jnp.where(ok, (mag - wmin) / jnp.where(ok, span, 1.0), 0.5)
    return t * (ul - ll) + ll


def align_matrix(w: jnp.ndarray, cfg: AlignmentConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exponent-align one weight matrix.

    Returns (aligned weights, shared biased exponents [K/N-blocks, ...]).
    Aligned weights are on the fmt grid with |w| ∈ [LL, UL] per block.
    """
    orig_dtype = w.dtype
    blocks, k = _block_view(w, cfg.n_group, cfg.group_axis)   # [B, n, ...]
    e_moved = _block_exponent_moved(w, cfg)                   # [B, ...]
    ll, ul = bitops.exponent_range(e_moved, cfg.fmt)
    ll = ll[:, None]
    ul = ul[:, None]

    mag = jnp.abs(blocks.astype(jnp.float32))
    pos = blocks >= 0            # paper: zeros rescale with the positive class
    neg = ~pos
    y_pos = _rescale_signed(mag, pos, ll, ul)
    y_neg = _rescale_signed(mag, neg, ll, ul)
    y = jnp.where(pos, y_pos, -y_neg)
    # Round to the storage grid; values stay in [LL, UL] so the exponent holds.
    y = bitops.quantize_to_format(jnp.clip(jnp.abs(y), ll, ul), cfg.fmt) * jnp.sign(y)

    y = y.reshape(-1, *y.shape[2:])[:k]
    if cfg.group_axis != 0:
        y = jnp.moveaxis(y, 0, cfg.group_axis)
    return y.astype(orig_dtype), jnp.moveaxis(e_moved, 0, cfg.group_axis)


def project_to_block_exponent(w: jnp.ndarray, e_shared: jnp.ndarray,
                              sign0: Optional[jnp.ndarray], cfg: AlignmentConfig) -> jnp.ndarray:
    """Project weights back onto the frozen (exponent, sign) manifold.

    Applied after every optimizer update during fine-tuning: magnitude clamped
    into the block's [LL, UL]; sign frozen to ``sign0`` (the paper updates the
    mantissa only). ``sign0=None`` lets signs float (ablation).
    ``e_shared`` uses the block-at-group-axis layout of ``block_exponent``.
    """
    orig_dtype = w.dtype
    blocks, k = _block_view(w, cfg.n_group, cfg.group_axis)
    e_moved = jnp.moveaxis(e_shared, cfg.group_axis, 0)
    ll, ul = bitops.exponent_range(e_moved, cfg.fmt)
    mag = jnp.clip(jnp.abs(blocks.astype(jnp.float32)), ll[:, None], ul[:, None])
    if sign0 is not None:
        sblocks, _ = _block_view(sign0, cfg.n_group, cfg.group_axis)
        sgn = jnp.where(sblocks > 0, 1.0, -1.0)
    else:
        sgn = jnp.where(blocks >= 0, 1.0, -1.0)
    y = bitops.quantize_to_format(mag, cfg.fmt) * sgn
    y = y.reshape(-1, *y.shape[2:])[:k]
    if cfg.group_axis != 0:
        y = jnp.moveaxis(y, 0, cfg.group_axis)
    return y.astype(orig_dtype)


def is_alignable(path: tuple, leaf) -> bool:
    """Leaves the technique applies to: >=2-D float weights (DESIGN.md §4)."""
    return hasattr(leaf, "ndim") and leaf.ndim >= 2 and \
        jnp.issubdtype(leaf.dtype, jnp.floating)


def _leaf_group_axis(leaf: jnp.ndarray) -> int:
    """Input-channel axis convention: axis -2 for [in, out]-style matrices
    (stacked-layer params [L, in, out] included); conv kernels are reshaped by
    callers."""
    return leaf.ndim - 2


def align_pytree(params, cfg: AlignmentConfig, predicate=is_alignable):
    """Align every eligible leaf; returns (aligned params, exponents pytree)."""
    def _align(path, leaf):
        if not predicate(path, leaf):
            return leaf, None
        lcfg = dataclasses.replace(cfg, group_axis=_leaf_group_axis(leaf))
        return align_matrix(leaf, lcfg)

    paths_leaves = jax.tree_util.tree_flatten_with_path(params)
    flat, treedef = jax.tree_util.tree_flatten(params)
    out_w, out_e = [], []
    for (path, _), leaf in zip(paths_leaves[0], flat):
        w, e = _align(path, leaf)
        out_w.append(w)
        out_e.append(e)
    aligned = jax.tree_util.tree_unflatten(treedef, out_w)
    exps = jax.tree_util.tree_unflatten(treedef, out_e)
    return aligned, exps


def align_pytree_policy(params, policy, predicate=is_alignable):
    """Per-rule alignment: every leaf is aligned with ITS policy rule's
    (n_group, index, fmt) — or passed through when the rule says
    ``deploy=False``. Returns (aligned params, exponents pytree with None on
    passthrough leaves); mirrors ``CIMDeployment.deploy``'s per-leaf align so
    a fine-tuned model projects onto exactly the manifold it will be packed
    from."""
    from repro.core.deployment import path_str
    leaves_wp, treedef = jax.tree_util.tree_flatten_with_path(params)
    out_w, out_e = [], []
    for path, leaf in leaves_wp:
        rule = policy.rule_for(path_str(path))
        if rule.deploy and predicate(path, leaf):
            lcfg = dataclasses.replace(rule.align_cfg,
                                       group_axis=_leaf_group_axis(leaf))
            w, e = align_matrix(leaf, lcfg)
        else:
            w, e = leaf, None
        out_w.append(w)
        out_e.append(e)
    return (jax.tree_util.tree_unflatten(treedef, out_w),
            jax.tree_util.tree_unflatten(treedef, out_e))


def project_pytree_policy(params, exps, signs, policy, predicate=is_alignable):
    """Per-rule frozen-(exponent, sign) projection — the multi-rule
    counterpart of :func:`project_pytree`, applied after each optimizer step
    of a policy-native fine-tune."""
    from repro.core.deployment import path_str
    leaves_wp, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_e = jax.tree_util.tree_flatten(exps, is_leaf=lambda x: x is None)[0]
    flat_s = jax.tree_util.tree_flatten(signs, is_leaf=lambda x: x is None)[0]
    out = []
    for (path, w), e, s in zip(leaves_wp, flat_e, flat_s):
        if e is None or not predicate(path, w):
            out.append(w)
            continue
        rule = policy.rule_for(path_str(path))
        lcfg = dataclasses.replace(rule.align_cfg,
                                   group_axis=_leaf_group_axis(w))
        out.append(project_to_block_exponent(w, e, s, lcfg))
    return jax.tree_util.tree_unflatten(treedef, out)


def project_pytree(params, exps, signs, cfg: AlignmentConfig, predicate=is_alignable):
    """Post-update projection over a pytree (see project_to_block_exponent)."""
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(params)[0]]
    flat_w, treedef = jax.tree_util.tree_flatten(params)
    flat_e = jax.tree_util.tree_flatten(exps, is_leaf=lambda x: x is None)[0]
    flat_s = jax.tree_util.tree_flatten(signs, is_leaf=lambda x: x is None)[0]
    out = []
    for path, w, e, s in zip(paths, flat_w, flat_e, flat_s):
        if e is None or not predicate(path, w):
            out.append(w)
        else:
            lcfg = dataclasses.replace(cfg, group_axis=_leaf_group_axis(w))
            out.append(project_to_block_exponent(w, e, s, lcfg))
    return jax.tree_util.tree_unflatten(treedef, out)
