"""Bit-level views of IEEE floating point numbers.

The paper's fault model operates on the *stored binary representation* of FP
weights (sign / exponent / mantissa fields of FP16 in the SRAM CIM macro).
Everything here is a pure, jit-able bit manipulation on unsigned integer views.

Supported formats: fp16 (paper's), bf16, fp32, fp8_e4m3 / fp8_e5m2 (the paper's
stated future work).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """Static description of an IEEE-like binary float format."""

    name: str
    total_bits: int
    exp_bits: int
    man_bits: int
    float_dtype: object  # jnp dtype used for computation
    uint_dtype: object   # matching-width unsigned integer dtype

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def sign_shift(self) -> int:
        return self.total_bits - 1

    @property
    def exp_shift(self) -> int:
        return self.man_bits

    @property
    def exp_mask(self) -> int:
        return ((1 << self.exp_bits) - 1) << self.man_bits

    @property
    def man_mask(self) -> int:
        return (1 << self.man_bits) - 1

    @property
    def sign_mask(self) -> int:
        return 1 << self.sign_shift

    @property
    def max_mantissa_value(self) -> float:
        """M_max in the paper's Fig. 5: largest 1.M value, i.e. 2 - 2^-man_bits."""
        return 2.0 - 2.0 ** (-self.man_bits)

    def field_bit_positions(self, field: str) -> np.ndarray:
        """Bit indices (LSB=0) belonging to ``field``."""
        if field == "sign":
            return np.array([self.sign_shift], dtype=np.int32)
        if field == "exponent":
            return np.arange(self.man_bits, self.man_bits + self.exp_bits, dtype=np.int32)
        if field == "mantissa":
            return np.arange(0, self.man_bits, dtype=np.int32)
        if field == "full":
            return np.arange(0, self.total_bits, dtype=np.int32)
        if field == "exponent_sign":  # the One4N-protected payload
            return np.arange(self.man_bits, self.total_bits, dtype=np.int32)
        raise ValueError(f"unknown field {field!r}")


FP16 = FloatFormat("fp16", 16, 5, 10, jnp.float16, jnp.uint16)
BF16 = FloatFormat("bf16", 16, 8, 7, jnp.bfloat16, jnp.uint16)
FP32 = FloatFormat("fp32", 32, 8, 23, jnp.float32, jnp.uint32)
# fp8 formats (no native jnp dtype guaranteed on CPU -> emulate via fp32 rounding)
FP8_E4M3 = FloatFormat("fp8_e4m3", 8, 4, 3, jnp.float32, jnp.uint8)
FP8_E5M2 = FloatFormat("fp8_e5m2", 8, 5, 2, jnp.float32, jnp.uint8)

FORMATS = {f.name: f for f in (FP16, BF16, FP32, FP8_E4M3, FP8_E5M2)}


def to_bits(x: jnp.ndarray, fmt: FloatFormat = FP16) -> jnp.ndarray:
    """Bitcast float array -> unsigned integer array of the format's width.

    ``x`` may be stored at higher precision (e.g. fp32 holding exact fp16
    values); it is rounded to the format's dtype first, which is exact when the
    values already lie on the format grid. fp8 formats (the paper's stated
    future work) are packed via field extraction from the fp32 emulation.
    """
    if fmt.name.startswith("fp8"):
        return _pack_fp8(x, fmt)
    return jnp.asarray(x, fmt.float_dtype).view(fmt.uint_dtype)


def from_bits(bits: jnp.ndarray, fmt: FloatFormat = FP16) -> jnp.ndarray:
    """Bitcast unsigned integer array -> float array (in fmt's float dtype)."""
    if fmt.name.startswith("fp8"):
        return _unpack_fp8(bits, fmt)
    return jnp.asarray(bits, fmt.uint_dtype).view(fmt.float_dtype)


def _pack_fp8(x: jnp.ndarray, fmt: FloatFormat) -> jnp.ndarray:
    """fp32 values on the fp8 grid -> uint8 (sign|exp|mantissa). Subnormals
    flush to zero (matching `_round_to_fp8`); e4m3 uses the extended exponent."""
    x32 = jnp.asarray(_round_to_fp8(x, fmt), jnp.float32)
    b32 = x32.view(jnp.uint32)
    sign = (b32 >> 31) & 1
    exp32 = ((b32 >> 23) & 0xFF).astype(jnp.int32) - 127          # unbiased
    man32 = (b32 >> (23 - fmt.man_bits)) & ((1 << fmt.man_bits) - 1)
    exp8 = jnp.clip(exp32 + fmt.bias, 0, (1 << fmt.exp_bits) - 1)
    word = (sign << fmt.sign_shift) | (exp8.astype(jnp.uint32) << fmt.man_bits) \
        | man32
    return jnp.where(x32 == 0.0, sign << fmt.sign_shift, word).astype(jnp.uint8)


def _unpack_fp8(bits: jnp.ndarray, fmt: FloatFormat) -> jnp.ndarray:
    b = bits.astype(jnp.uint32)
    sign = jnp.where((b >> fmt.sign_shift) & 1 == 1, -1.0, 1.0)
    exp = ((b >> fmt.man_bits) & ((1 << fmt.exp_bits) - 1)).astype(jnp.float32)
    man = (b & ((1 << fmt.man_bits) - 1)).astype(jnp.float32)
    frac = 1.0 + man / (1 << fmt.man_bits)
    val = sign * jnp.exp2(exp - fmt.bias) * frac
    return jnp.where(exp == 0, 0.0, val).astype(jnp.float32)  # subnormals -> 0


def split_fields(x: jnp.ndarray, fmt: FloatFormat = FP16) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Return (sign, biased_exponent, mantissa) integer fields."""
    b = to_bits(x, fmt).astype(jnp.uint32)
    sign = (b >> fmt.sign_shift) & 1
    exp = (b >> fmt.exp_shift) & ((1 << fmt.exp_bits) - 1)
    man = b & fmt.man_mask
    return sign, exp, man


def combine_fields(sign: jnp.ndarray, exp: jnp.ndarray, man: jnp.ndarray,
                   fmt: FloatFormat = FP16) -> jnp.ndarray:
    """Assemble float values from integer (sign, biased_exponent, mantissa)."""
    b = ((sign.astype(jnp.uint32) & 1) << fmt.sign_shift) \
        | ((exp.astype(jnp.uint32) & ((1 << fmt.exp_bits) - 1)) << fmt.exp_shift) \
        | (man.astype(jnp.uint32) & fmt.man_mask)
    return from_bits(b.astype(fmt.uint_dtype), fmt)


def biased_exponent(x: jnp.ndarray, fmt: FloatFormat = FP16) -> jnp.ndarray:
    """Biased exponent field of each value (0 for zeros/subnormals)."""
    return split_fields(x, fmt)[1]


def exponent_range(biased_exp: jnp.ndarray, fmt: FloatFormat = FP16):
    """(LL, UL) representable with a fixed biased exponent (paper Fig. 5).

    LL = 2^(E-bias) * 1.0       (mantissa all zeros, M_min)
    UL = 2^(E-bias) * (2-2^-m)  (mantissa all ones,  M_max)
    """
    e = biased_exp.astype(jnp.float32) - fmt.bias
    scale = jnp.exp2(e)
    return scale, scale * fmt.max_mantissa_value


def quantize_to_format(x: jnp.ndarray, fmt: FloatFormat = FP16) -> jnp.ndarray:
    """Round values to the format grid, returned in float32."""
    if fmt.name.startswith("fp8"):
        # Emulated round-to-nearest-even for fp8: clamp exponent+mantissa width.
        return _round_to_fp8(x, fmt)
    return jnp.asarray(jnp.asarray(x, fmt.float_dtype), jnp.float32)


def _round_to_fp8(x: jnp.ndarray, fmt: FloatFormat) -> jnp.ndarray:
    x32 = jnp.asarray(x, jnp.float32)
    # Scale so mantissa width matches, round via float32->bf16-style trick:
    man_drop = 23 - fmt.man_bits
    b = x32.view(jnp.uint32)
    # round-to-nearest-even on the dropped mantissa bits
    round_bit = jnp.uint32(1) << (man_drop - 1)
    lsb = (b >> man_drop) & 1
    b = b + round_bit - 1 + lsb
    b = b & ~jnp.uint32((1 << man_drop) - 1)
    y = b.view(jnp.float32)
    # clamp exponent range; e4m3 reclaims the all-ones exponent (max = 448)
    max_e = (1 << fmt.exp_bits) - 2 - fmt.bias
    min_e = 1 - fmt.bias
    lim_hi = 448.0 if fmt.name == "fp8_e4m3" else 2.0 ** max_e * fmt.max_mantissa_value
    lim_lo = 2.0 ** min_e
    y = jnp.clip(y, -lim_hi, lim_hi)
    y = jnp.where(jnp.abs(y) < lim_lo, 0.0, y)
    return jnp.where(x32 == 0, 0.0, y)


def unpack_bits(words: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """uint array [...,] -> bit array [..., n_bits] (LSB first), uint8 in {0,1}."""
    words = words.astype(jnp.uint32)
    shifts = jnp.arange(n_bits, dtype=jnp.uint32)
    return ((words[..., None] >> shifts) & 1).astype(jnp.uint8)


def pack_bits(bits: jnp.ndarray, dtype=jnp.uint32) -> jnp.ndarray:
    """bit array [..., n_bits] (LSB first) -> uint array [...]."""
    n_bits = bits.shape[-1]
    shifts = jnp.arange(n_bits, dtype=jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) << shifts, axis=-1).astype(dtype)
