"""Error-correcting codes for the One4N scheme (paper §III-B, Fig. 4).

Two layers:

* :class:`SecdedCode` — a single-error-correct / double-error-detect extended
  Hamming code over ``d`` data bits, vectorized over leading axes.  The decode
  syndrome follows the paper's Fig. 4 ③ semantics exactly:

    - ``R == 0``                      → no error,
    - parity bit of R set (R[7])      → single-bit error at position R[6:0],
      corrected by flipping that bit,
    - R[7] == 0 but R[6:0] != 0       → ≥2-bit error, uncorrectable (detected).

* :class:`One4NRowCodec` — the paper's row-based payload layout: for each
  ``N×(16 weights)`` block, the protected payload is the shared-exponent row
  (16 × exp_bits) followed by the N×16 sign bits (Eq. 3:
  ``TB = exp_bits·16 + N·16``).  The payload is split into
  ``ceil(TB/104)`` rows ("divided into two rows for encoding" for N=8), each
  SECDED-encoded with an 8-bit redundancy (7 Hamming + 1 overall parity).

Everything is implemented as jit-able jnp bit arithmetic; generator/parity-check
structure is precomputed with numpy at trace time.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np

# Max data bits covered by one SECDED row with a 7-bit Hamming syndrome
# (2^7 = 128 >= 104 + 7 + 1). The paper's N=8 block (208 payload bits) splits
# into exactly two 104-bit rows with 8 redundant bits each.
MAX_SEGMENT_DATA_BITS = 104


def _hamming_r(d: int) -> int:
    r = 1
    while (1 << r) < d + r + 1:
        r += 1
    return r


@functools.lru_cache(maxsize=None)
def _secded_tables(d: int):
    """Precompute position layout + parity-check matrix for d data bits."""
    r = _hamming_r(d)
    n = d + r                      # codeword length before overall parity
    positions = np.arange(1, n + 1)
    is_parity = (positions & (positions - 1)) == 0  # powers of two
    data_pos = positions[~is_parity]                # length d
    parity_pos = positions[is_parity]               # length r
    # H[j, i] = bit j of position (i+1): syndrome bit j = XOR of bits whose
    # position has bit j set.
    H = ((positions[None, :] >> np.arange(r)[:, None]) & 1).astype(np.int32)
    # encode matrix: parity bit at position 2^j = XOR of *data* bits whose
    # position has bit j set (parity positions excluded from their own sum).
    enc = H[:, ~is_parity]                          # [r, d]
    # scatter indices: codeword[pos-1]
    return r, n, data_pos - 1, parity_pos - 1, H, enc


@dataclasses.dataclass(frozen=True)
class SecdedCode:
    """Extended Hamming SECDED over ``data_bits`` bits (vectorized)."""

    data_bits: int

    @property
    def r(self) -> int:
        return _secded_tables(self.data_bits)[0]

    @property
    def n(self) -> int:
        """Codeword length including the overall parity bit."""
        return _secded_tables(self.data_bits)[1] + 1

    @property
    def redundant_bits(self) -> int:
        return self.r + 1

    def encode(self, data: jnp.ndarray) -> jnp.ndarray:
        """data [..., d] bits in {0,1} -> codeword [..., n] (overall parity last)."""
        r, n, data_idx, parity_idx, _, enc = _secded_tables(self.data_bits)
        data = data.astype(jnp.uint8)
        parity = (data.astype(jnp.int32) @ jnp.asarray(enc.T)) & 1  # [..., r]
        code = jnp.zeros(data.shape[:-1] + (n,), jnp.uint8)
        code = code.at[..., jnp.asarray(data_idx)].set(data)
        code = code.at[..., jnp.asarray(parity_idx)].set(parity.astype(jnp.uint8))
        overall = jnp.sum(code, axis=-1, dtype=jnp.int32) & 1
        return jnp.concatenate([code, overall[..., None].astype(jnp.uint8)], axis=-1)

    def decode(self, code: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """codeword [..., n] -> (data [..., d], status [...]).

        status: 0 = clean, 1 = corrected single error, 2 = uncorrectable (>=2).
        """
        r, n, data_idx, _, H, _ = _secded_tables(self.data_bits)
        body = code[..., :n].astype(jnp.int32)
        overall_bit = code[..., n].astype(jnp.int32)
        syndrome_bits = (body @ jnp.asarray(H.T)) & 1            # [..., r]
        pos = jnp.sum(syndrome_bits << jnp.arange(r), axis=-1)   # R[6:0], 1-based
        parity = (jnp.sum(body, axis=-1) + overall_bit) & 1      # R[7]

        clean = (pos == 0) & (parity == 0)
        single = parity == 1          # odd number of flips -> assume 1, correctable
        double = (parity == 0) & (pos > 0)

        # Correct: flip bit at position ``pos`` (1-based). pos==0 with parity==1
        # means the overall parity bit itself flipped — body untouched.
        flip = (jnp.arange(1, n + 1) == pos[..., None]) & single[..., None]
        corrected = body ^ flip.astype(jnp.int32)
        data = corrected[..., jnp.asarray(data_idx)].astype(jnp.uint8)
        status = jnp.where(clean, 0, jnp.where(double, 2, 1)).astype(jnp.int32)
        return data, status


@dataclasses.dataclass(frozen=True)
class One4NRowCodec:
    """Row-based One4N payload codec for an ``N x (row_weights)`` weight block.

    Payload per block & 16-weight row group (paper Eq. 3):
      ``[exp_0 .. exp_15] (exp_bits each)  ||  sign bits (N x row_weights)``.
    """

    n_group: int = 8          # N — weights sharing one exponent (input channel)
    row_weights: int = 16     # FP16 weights per 256-bit SRAM row
    exp_bits: int = 5
    sign_bits_per_row: int = 16

    @property
    def payload_bits(self) -> int:
        # TB = exp_bits * row_weights + N * row_weights (Eq. 3 with 16 weights/row)
        return self.exp_bits * self.row_weights + self.n_group * self.sign_bits_per_row

    @property
    def n_segments(self) -> int:
        return math.ceil(self.payload_bits / MAX_SEGMENT_DATA_BITS)

    @property
    def segment_bits(self) -> int:
        return math.ceil(self.payload_bits / self.n_segments)

    @property
    def code(self) -> SecdedCode:
        return SecdedCode(self.segment_bits)

    @property
    def redundant_bits_per_block(self) -> int:
        return self.n_segments * self.code.redundant_bits

    @property
    def padded_bits(self) -> int:
        return self.n_segments * self.segment_bits

    def build_payload(self, exp_row: jnp.ndarray, signs: jnp.ndarray) -> jnp.ndarray:
        """exp_row [..., 16] ints, signs [..., N, 16] bits -> payload bits."""
        from repro.core.bitops import unpack_bits
        exp_bits = unpack_bits(exp_row, self.exp_bits)                  # [...,16,5]
        exp_flat = exp_bits.reshape(exp_bits.shape[:-2] + (-1,))
        sign_flat = signs.astype(jnp.uint8).reshape(signs.shape[:-2] + (-1,))
        payload = jnp.concatenate([exp_flat, sign_flat], axis=-1)
        pad = self.padded_bits - self.payload_bits
        if pad:
            payload = jnp.concatenate(
                [payload, jnp.zeros(payload.shape[:-1] + (pad,), jnp.uint8)], axis=-1)
        return payload

    def split_payload(self, payload: jnp.ndarray):
        """Inverse of build_payload -> (exp_row [...,16], signs [..., N, 16])."""
        from repro.core.bitops import pack_bits
        eb = self.exp_bits * self.row_weights
        exp_flat = payload[..., :eb].reshape(payload.shape[:-1] + (self.row_weights, self.exp_bits))
        exp_row = pack_bits(exp_flat, jnp.uint8)
        sb = self.n_group * self.sign_bits_per_row
        signs = payload[..., eb:eb + sb].reshape(
            payload.shape[:-1] + (self.n_group, self.sign_bits_per_row)).astype(jnp.uint8)
        return exp_row, signs

    def encode(self, exp_row: jnp.ndarray, signs: jnp.ndarray) -> jnp.ndarray:
        """-> codewords [..., n_segments, code.n] bits."""
        payload = self.build_payload(exp_row, signs)
        segs = payload.reshape(payload.shape[:-1] + (self.n_segments, self.segment_bits))
        return self.code.encode(segs)

    def decode(self, codewords: jnp.ndarray):
        """-> (exp_row [...,16], signs [...,N,16], status [..., n_segments])."""
        data, status = self.code.decode(codewords)
        payload = data.reshape(data.shape[:-2] + (self.padded_bits,))
        payload = payload[..., :self.payload_bits] if self.padded_bits != self.payload_bits \
            else payload
        exp_row, signs = self.split_payload(payload)
        return exp_row, signs, status


def residual_ber_after_secded(ber: float, codeword_bits: int = 112) -> float:
    """Post-ECC residual error rate per protected bit.

    SECDED corrects one flip per codeword; a bit stays wrong only when its
    codeword took >=2 flips. With n-bit codewords and i.i.d. flips at ``ber``:
        P(>=2 flips) = 1 - (1-p)^n - n p (1-p)^(n-1)
    and conditional on that, ~2 of n bits are wrong. Used for closed-form
    injection at scales where bit-plane emulation is impractical (launcher
    dynamic mode); the bit-accurate path is ``repro.core.cim``.
    """
    import math as _math
    n, p = codeword_bits, ber
    if p <= 0:
        return 0.0
    p_ge2 = 1.0 - (1.0 - p) ** n - n * p * (1.0 - p) ** (n - 1)
    return p_ge2 * 2.0 / n


def secded_redundant_bits(protected_bits: int) -> int:
    """SECDED redundancy (Hamming r + overall parity) for a payload.

    Matches every count in the paper: 6-bit sign+exponent -> 5 (§III-A2),
    10-bit mantissa -> 5, 96-bit unified row -> 8 (§III-B1), 104-bit One4N
    segment -> 8, 160-bit mantissa row -> 9 (Table III row-based full-num).
    """
    return _hamming_r(protected_bits) + 1
