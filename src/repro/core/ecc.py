"""Error-correcting codes for the One4N scheme (paper §III-B, Fig. 4).

Two layers:

* :class:`SecdedCode` — a single-error-correct / double-error-detect extended
  Hamming code over ``d`` data bits, vectorized over leading axes.  The decode
  syndrome follows the paper's Fig. 4 ③ semantics exactly:

    - ``R == 0``                      → no error,
    - parity bit of R set (R[7])      → single-bit error at position R[6:0],
      corrected by flipping that bit,
    - R[7] == 0 but R[6:0] != 0       → ≥2-bit error, uncorrectable (detected).

* :class:`One4NRowCodec` — the paper's row-based payload layout: for each
  ``N×(16 weights)`` block, the protected payload is the shared-exponent row
  (16 × exp_bits) followed by the N×16 sign bits (Eq. 3:
  ``TB = exp_bits·16 + N·16``).  The payload is split into
  ``ceil(TB/104)`` rows ("divided into two rows for encoding" for N=8), each
  SECDED-encoded with an 8-bit redundancy (7 Hamming + 1 overall parity).

Everything is implemented as jit-able jnp bit arithmetic; generator/parity-check
structure is precomputed with numpy at trace time.

Both codecs expose **two equivalent APIs**:

* the original per-bit API (``encode`` / ``decode`` on ``uint8`` bit arrays) —
  kept as the readable oracle the packed path is tested against;
* a word-packed API (``encode_packed`` / ``decode_packed`` on ``uint32`` word
  arrays, bit ``i`` in word ``i//32`` lane ``i%32``) — syndrome/parity bits
  are computed with precomputed per-word column masks + XOR-parity folds
  (:mod:`repro.core.bitpack`), and parity-bit placement/removal uses static
  single-bit funnel shifts. No ``int32`` bit-matrix matmuls, no ``.at[].set``
  scatters — this is the representation the packed :class:`~repro.core.cim`
  store and the fused ``cim_read`` kernel operate on.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import bitpack

# Max data bits covered by one SECDED row with a 7-bit Hamming syndrome
# (2^7 = 128 >= 104 + 7 + 1). The paper's N=8 block (208 payload bits) splits
# into exactly two 104-bit rows with 8 redundant bits each.
MAX_SEGMENT_DATA_BITS = 104


def _hamming_r(d: int) -> int:
    r = 1
    while (1 << r) < d + r + 1:
        r += 1
    return r


@functools.lru_cache(maxsize=None)
def _secded_tables(d: int):
    """Precompute position layout + parity-check matrix for d data bits."""
    r = _hamming_r(d)
    n = d + r                      # codeword length before overall parity
    positions = np.arange(1, n + 1)
    is_parity = (positions & (positions - 1)) == 0  # powers of two
    data_pos = positions[~is_parity]                # length d
    parity_pos = positions[is_parity]               # length r
    # H[j, i] = bit j of position (i+1): syndrome bit j = XOR of bits whose
    # position has bit j set.
    H = ((positions[None, :] >> np.arange(r)[:, None]) & 1).astype(np.int32)
    # encode matrix: parity bit at position 2^j = XOR of *data* bits whose
    # position has bit j set (parity positions excluded from their own sum).
    enc = H[:, ~is_parity]                          # [r, d]
    # scatter indices: codeword[pos-1]
    return r, n, data_pos - 1, parity_pos - 1, H, enc


@functools.lru_cache(maxsize=None)
def _secded_packed_tables(d: int):
    """Per-word column masks for the packed encode/decode of ``d`` data bits.

    Packed codeword layout: body bit ``i`` (0-based, position ``i+1``) at word
    ``i//32`` lane ``i%32``; the overall parity bit at bit index ``n``.
    """
    r, n, data_idx, _, _, _ = _secded_tables(d)
    Wd = bitpack.n_words(d)
    Wc = bitpack.n_words(n + 1)
    # syndrome bit j = parity of body bits whose 1-based position has bit j set
    hmask = np.zeros((r, Wc), np.uint32)
    for i in range(n):
        pos = i + 1
        for j in range(r):
            if (pos >> j) & 1:
                hmask[j, i // 32] |= np.uint32(1 << (i % 32))
    # encode: parity bit j = parity of DATA bits whose (data) position has bit j
    encmask = np.zeros((r, Wd), np.uint32)
    for q, i in enumerate(data_idx):          # i = 0-based codeword body index
        pos = i + 1
        for j in range(r):
            if (pos >> j) & 1:
                encmask[j, q // 32] |= np.uint32(1 << (q % 32))
    body_mask = bitpack.word_masks(n, Wc)          # body bits only
    code_mask = bitpack.word_masks(n + 1, Wc)      # body + overall parity
    data_mask = bitpack.word_masks(d, Wd)
    parity_pos0 = tuple((1 << j) - 1 for j in range(r))   # 0-based body indices
    return r, n, Wd, Wc, hmask, encmask, body_mask, code_mask, data_mask, \
        parity_pos0


@dataclasses.dataclass(frozen=True)
class SecdedCode:
    """Extended Hamming SECDED over ``data_bits`` bits (vectorized)."""

    data_bits: int

    @property
    def r(self) -> int:
        return _secded_tables(self.data_bits)[0]

    @property
    def n(self) -> int:
        """Codeword length including the overall parity bit."""
        return _secded_tables(self.data_bits)[1] + 1

    @property
    def redundant_bits(self) -> int:
        return self.r + 1

    def encode(self, data: jnp.ndarray) -> jnp.ndarray:
        """data [..., d] bits in {0,1} -> codeword [..., n] (overall parity last)."""
        r, n, data_idx, parity_idx, _, enc = _secded_tables(self.data_bits)
        data = data.astype(jnp.uint8)
        parity = (data.astype(jnp.int32) @ jnp.asarray(enc.T)) & 1  # [..., r]
        code = jnp.zeros(data.shape[:-1] + (n,), jnp.uint8)
        code = code.at[..., jnp.asarray(data_idx)].set(data)
        code = code.at[..., jnp.asarray(parity_idx)].set(parity.astype(jnp.uint8))
        overall = jnp.sum(code, axis=-1, dtype=jnp.int32) & 1
        return jnp.concatenate([code, overall[..., None].astype(jnp.uint8)], axis=-1)

    def decode(self, code: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """codeword [..., n] -> (data [..., d], status [...]).

        status: 0 = clean, 1 = corrected single error, 2 = uncorrectable (>=2).
        """
        r, n, data_idx, _, H, _ = _secded_tables(self.data_bits)
        body = code[..., :n].astype(jnp.int32)
        overall_bit = code[..., n].astype(jnp.int32)
        syndrome_bits = (body @ jnp.asarray(H.T)) & 1            # [..., r]
        pos = jnp.sum(syndrome_bits << jnp.arange(r), axis=-1)   # R[6:0], 1-based
        parity = (jnp.sum(body, axis=-1) + overall_bit) & 1      # R[7]

        clean = (pos == 0) & (parity == 0)
        single = parity == 1          # odd number of flips -> assume 1, correctable
        double = (parity == 0) & (pos > 0)

        # Correct: flip bit at position ``pos`` (1-based). pos==0 with parity==1
        # means the overall parity bit itself flipped — body untouched.
        flip = (jnp.arange(1, n + 1) == pos[..., None]) & single[..., None]
        corrected = body ^ flip.astype(jnp.int32)
        data = corrected[..., jnp.asarray(data_idx)].astype(jnp.uint8)
        status = jnp.where(clean, 0, jnp.where(double, 2, 1)).astype(jnp.int32)
        return data, status

    # ------------------------------------------------------- packed (uint32)

    @property
    def data_words(self) -> int:
        return bitpack.n_words(self.data_bits)

    @property
    def code_words(self) -> int:
        return bitpack.n_words(self.n)

    @property
    def code_word_masks(self) -> np.ndarray:
        """uint32 [code_words] validity mask of stored codeword bits."""
        return _secded_packed_tables(self.data_bits)[7]

    def encode_packed(self, data_words: jnp.ndarray) -> jnp.ndarray:
        """Packed encode: data [..., data_words] uint32 -> [..., code_words].

        Parity bits come from XOR-parity folds against precomputed column
        masks; their placement at the power-of-two positions is a sequence of
        static single-bit funnel shifts (no scatters).
        """
        r, n, Wd, Wc, _, encmask, _, _, data_mask, parity_pos0 = \
            _secded_packed_tables(self.data_bits)
        dw = [data_words[..., w].astype(jnp.uint32) & jnp.uint32(data_mask[w])
              for w in range(Wd)]
        parity = [bitpack.masked_parity(dw, encmask[j]) for j in range(r)]
        body = dw + [jnp.zeros_like(dw[0]) for _ in range(Wc - Wd)]
        for pp in parity_pos0:                    # ascending 0, 1, 3, 7, ...
            body = bitpack.insert_zero_bit(body, pp)
        for j, pp in enumerate(parity_pos0):
            wl, sh = divmod(pp, 32)
            body[wl] = body[wl] | (parity[j] << sh)
        overall = bitpack.masked_parity(body, bitpack.word_masks(n, Wc))
        wl, sh = divmod(n, 32)
        body[wl] = body[wl] | (overall << sh)
        return bitpack.from_words(body)

    def syndrome_packed(self, code_words: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Syndrome half of :meth:`decode_packed` — the expensive part.

        All ``r + 1`` XOR-parity folds against the precomputed per-word
        column masks happen here (the "per-word column-mask folds" the fused
        kernel hoists: one syndrome per codeword tile, reused across output
        revisits). Returns ``(pos, parity, status)``: the 1-based error
        position ``R[6:0]``, the overall-parity bit ``R[7]``, and the
        0/1/2 clean/corrected/uncorrectable status.
        """
        r, n, Wd, Wc, hmask, _, body_mask, _, _, _ = \
            _secded_packed_tables(self.data_bits)
        cw = [code_words[..., w].astype(jnp.uint32) for w in range(Wc)]
        body = [cw[w] & jnp.uint32(body_mask[w]) for w in range(Wc)]
        synd = [bitpack.masked_parity(body, hmask[j]) for j in range(r)]
        pos = synd[0]
        for j in range(1, r):
            pos = pos | (synd[j] << j)                       # 1-based, R[6:0]
        owl, osh = divmod(n, 32)
        overall_bit = (cw[owl] >> osh) & jnp.uint32(1)
        parity = bitpack.masked_parity(body, bitpack.word_masks(n, Wc)) \
            ^ overall_bit                                    # R[7]
        clean = (pos == 0) & (parity == 0)
        double = (parity == 0) & (pos > 0)
        status = jnp.where(clean, 0, jnp.where(double, 2, 1)).astype(jnp.int32)
        return pos, parity, status

    def correct_extract_packed(self, code_words: jnp.ndarray, pos: jnp.ndarray,
                               parity: jnp.ndarray) -> jnp.ndarray:
        """Correction half of :meth:`decode_packed` — the cheap part.

        Flips the single errored bit located by ``(pos, parity)`` (from
        :meth:`syndrome_packed`) and removes the parity-bit positions with
        static funnel shifts. Returns the packed data words.
        """
        r, n, Wd, Wc, _, _, body_mask, _, data_mask, parity_pos0 = \
            _secded_packed_tables(self.data_bits)
        cw = [code_words[..., w].astype(jnp.uint32) for w in range(Wc)]
        body = [cw[w] & jnp.uint32(body_mask[w]) for w in range(Wc)]
        single = parity == 1
        do_flip = single & (pos > 0)
        pos0 = jnp.where(pos > 0, pos - 1, 0)
        flip_word = pos0 // 32
        flip_bit = jnp.left_shift(jnp.uint32(1), pos0 % 32)
        for w in range(Wc):
            flipw = jnp.where(do_flip & (flip_word == w), flip_bit,
                              jnp.uint32(0)) & jnp.uint32(body_mask[w])
            body[w] = body[w] ^ flipw
        for pp in reversed(parity_pos0):          # descending 63, 31, ..., 0
            body = bitpack.delete_bit(body, pp)
        data = [body[w] & jnp.uint32(data_mask[w]) for w in range(Wd)]
        return bitpack.from_words(data)

    def decode_packed(self, code_words: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Packed decode: [..., code_words] uint32 -> (data words, status).

        Bit-exact with :meth:`decode` on the unpacked bits (same syndrome
        semantics, same status codes 0/1/2). Composition of
        :meth:`syndrome_packed` (column-mask folds) and
        :meth:`correct_extract_packed` (flip + funnel-shift extraction) —
        callers that reuse one syndrome across several passes over the same
        codeword tile call the halves directly.
        """
        pos, parity, status = self.syndrome_packed(code_words)
        return self.correct_extract_packed(code_words, pos, parity), status


@dataclasses.dataclass(frozen=True)
class One4NRowCodec:
    """Row-based One4N payload codec for an ``N x (row_weights)`` weight block.

    Payload per block & 16-weight row group (paper Eq. 3):
      ``[exp_0 .. exp_15] (exp_bits each)  ||  sign bits (N x row_weights)``.
    """

    n_group: int = 8          # N — weights sharing one exponent (input channel)
    row_weights: int = 16     # FP16 weights per 256-bit SRAM row
    exp_bits: int = 5
    sign_bits_per_row: int = 16

    @property
    def payload_bits(self) -> int:
        # TB = exp_bits * row_weights + N * row_weights (Eq. 3 with 16 weights/row)
        return self.exp_bits * self.row_weights + self.n_group * self.sign_bits_per_row

    @property
    def n_segments(self) -> int:
        return math.ceil(self.payload_bits / MAX_SEGMENT_DATA_BITS)

    @property
    def segment_bits(self) -> int:
        return math.ceil(self.payload_bits / self.n_segments)

    @property
    def code(self) -> SecdedCode:
        return SecdedCode(self.segment_bits)

    @property
    def redundant_bits_per_block(self) -> int:
        return self.n_segments * self.code.redundant_bits

    @property
    def padded_bits(self) -> int:
        return self.n_segments * self.segment_bits

    def build_payload(self, exp_row: jnp.ndarray, signs: jnp.ndarray) -> jnp.ndarray:
        """exp_row [..., 16] ints, signs [..., N, 16] bits -> payload bits."""
        from repro.core.bitops import unpack_bits
        exp_bits = unpack_bits(exp_row, self.exp_bits)                  # [...,16,5]
        exp_flat = exp_bits.reshape(exp_bits.shape[:-2] + (-1,))
        sign_flat = signs.astype(jnp.uint8).reshape(signs.shape[:-2] + (-1,))
        payload = jnp.concatenate([exp_flat, sign_flat], axis=-1)
        pad = self.padded_bits - self.payload_bits
        if pad:
            payload = jnp.concatenate(
                [payload, jnp.zeros(payload.shape[:-1] + (pad,), jnp.uint8)], axis=-1)
        return payload

    def split_payload(self, payload: jnp.ndarray):
        """Inverse of build_payload -> (exp_row [...,16], signs [..., N, 16])."""
        from repro.core.bitops import pack_bits
        eb = self.exp_bits * self.row_weights
        exp_flat = payload[..., :eb].reshape(payload.shape[:-1] + (self.row_weights, self.exp_bits))
        exp_row = pack_bits(exp_flat, jnp.uint8)
        sb = self.n_group * self.sign_bits_per_row
        signs = payload[..., eb:eb + sb].reshape(
            payload.shape[:-1] + (self.n_group, self.sign_bits_per_row)).astype(jnp.uint8)
        return exp_row, signs

    def encode(self, exp_row: jnp.ndarray, signs: jnp.ndarray) -> jnp.ndarray:
        """-> codewords [..., n_segments, code.n] bits."""
        payload = self.build_payload(exp_row, signs)
        segs = payload.reshape(payload.shape[:-1] + (self.n_segments, self.segment_bits))
        return self.code.encode(segs)

    def decode(self, codewords: jnp.ndarray):
        """-> (exp_row [...,16], signs [...,N,16], status [..., n_segments])."""
        data, status = self.code.decode(codewords)
        payload = data.reshape(data.shape[:-2] + (self.padded_bits,))
        payload = payload[..., :self.payload_bits] if self.padded_bits != self.payload_bits \
            else payload
        exp_row, signs = self.split_payload(payload)
        return exp_row, signs, status

    # ------------------------------------------------------- packed (uint32)

    @property
    def sign_bits(self) -> int:
        return self.n_group * self.sign_bits_per_row

    @property
    def sign_words(self) -> int:
        """uint32 words holding one block's sign bits (bit = i_n*row + t)."""
        return bitpack.n_words(self.sign_bits)

    @property
    def payload_words(self) -> int:
        return bitpack.n_words(self.padded_bits)

    @property
    def codeword_words(self) -> int:
        return self.code.code_words

    def pack_signs(self, signs: jnp.ndarray) -> jnp.ndarray:
        """signs [..., N, row_weights] bits -> packed [..., sign_words]."""
        flat = signs.reshape(signs.shape[:-2] + (self.sign_bits,))
        return bitpack.pack_bits_words(flat, self.sign_bits)

    def unpack_signs(self, sign_words: jnp.ndarray) -> jnp.ndarray:
        """Packed [..., sign_words] -> signs [..., N, row_weights] uint8 bits."""
        bits = bitpack.unpack_words(sign_words, self.sign_bits)
        return bits.reshape(bits.shape[:-1] +
                            (self.n_group, self.sign_bits_per_row))

    def build_payload_packed(self, exp_row: jnp.ndarray,
                             sign_words: jnp.ndarray):
        """exp_row [..., row_weights] ints + packed signs -> payload words list.

        Payload bit layout matches :meth:`build_payload`: ``row_weights``
        exponent fields of ``exp_bits`` each, then the ``N*row_weights`` sign
        bits, then zero padding up to ``padded_bits``.
        """
        eb, rw = self.exp_bits, self.row_weights
        pw = bitpack.zeros_like_words(exp_row[..., 0], self.payload_words)
        for t in range(rw):
            bitpack.or_window(pw, [exp_row[..., t].astype(jnp.uint32)],
                              t * eb, eb)
        off = rw * eb
        for v in range(self.sign_words):
            nb = min(32, self.sign_bits - 32 * v)
            bitpack.or_window(pw, [sign_words[..., v].astype(jnp.uint32)],
                              off + 32 * v, nb)
        return pw

    def split_payload_packed(self, pw):
        """Payload word list -> (exp_row [..., row_weights] uint8,
        sign_words [..., sign_words])."""
        eb, rw = self.exp_bits, self.row_weights
        exps = [bitpack.extract_window(pw, t * eb, eb)[0] for t in range(rw)]
        exp_row = jnp.stack(exps, axis=-1).astype(jnp.uint8)
        off = rw * eb
        svs = [bitpack.extract_window(pw, off + 32 * v,
                                      min(32, self.sign_bits - 32 * v))[0]
               for v in range(self.sign_words)]
        return exp_row, jnp.stack(svs, axis=-1)

    def encode_packed(self, exp_row: jnp.ndarray,
                      sign_words: jnp.ndarray) -> jnp.ndarray:
        """-> packed codewords [..., n_segments, codeword_words] uint32."""
        pw = self.build_payload_packed(exp_row, sign_words)
        segs = [bitpack.from_words(
            bitpack.extract_window(pw, s * self.segment_bits, self.segment_bits))
            for s in range(self.n_segments)]
        return self.code.encode_packed(jnp.stack(segs, axis=-2))

    def decode_packed(self, codewords: jnp.ndarray):
        """Packed codewords [..., n_segments, codeword_words] ->
        (exp_row [..., row_weights], sign_words [..., sign_words],
        status [..., n_segments])."""
        data, status = self.code.decode_packed(codewords)
        pw = bitpack.zeros_like_words(data[..., 0, 0], self.payload_words)
        for s in range(self.n_segments):
            bitpack.or_window(pw, [data[..., s, w] for w in range(data.shape[-1])],
                              s * self.segment_bits, self.segment_bits)
        exp_row, sign_words = self.split_payload_packed(pw)
        return exp_row, sign_words, status


def residual_ber_after_secded(ber: float, codeword_bits: Optional[int] = None,
                              codec: Optional[One4NRowCodec] = None) -> float:
    """Post-ECC residual error rate per protected bit.

    SECDED corrects one flip per codeword; a bit stays wrong only when its
    codeword took >=2 flips. With n-bit codewords and i.i.d. flips at ``ber``:
        P(>=2 flips) = 1 - (1-p)^n - n p (1-p)^(n-1)
    and conditional on that, ~2 of n bits are wrong. Used for closed-form
    injection at scales where bit-plane emulation is impractical (launcher
    dynamic mode); the bit-accurate path is ``repro.core.cim``.

    ``codeword_bits`` defaults to the stored codeword length of the active
    ``codec`` (or the paper's default :class:`One4NRowCodec`, 112 bits for
    N=8) so non-default ``n_group`` / ``row_weights`` configurations get a
    consistent closed form without callers hard-coding the length.
    """
    import math as _math
    if codeword_bits is None:
        codeword_bits = (codec or One4NRowCodec()).code.n
    n, p = codeword_bits, ber
    if p <= 0:
        return 0.0
    p_ge2 = 1.0 - (1.0 - p) ** n - n * p * (1.0 - p) ** (n - 1)
    return p_ge2 * 2.0 / n


def secded_redundant_bits(protected_bits: int) -> int:
    """SECDED redundancy (Hamming r + overall parity) for a payload.

    Matches every count in the paper: 6-bit sign+exponent -> 5 (§III-A2),
    10-bit mantissa -> 5, 96-bit unified row -> 8 (§III-B1), 104-bit One4N
    segment -> 8, 160-bit mantissa row -> 9 (Table III row-based full-num).
    """
    return _hamming_r(protected_bits) + 1
