"""Multiword (uint32) bit-plane arithmetic for the packed SRAM image.

Everything in the packed CIM path — SECDED codewords, One4N payloads, sign
planes — is a little-endian bit string stored across the **last axis** of a
``uint32`` array: bit ``i`` of the string lives in word ``i // 32`` at lane
``i % 32`` (LSB first). These helpers implement the handful of primitives the
packed codec needs as pure shift/mask/xor arithmetic:

* window extraction / insertion at *static* bit offsets (payload assembly,
  segment split/join),
* single-bit insert/delete "funnel shifts" (placing Hamming parity bits at
  the power-of-two codeword positions without scatters),
* word-parallel parity (syndrome bits via precomputed column masks instead of
  ``int32`` bit-matrix matmuls).

Inside the algorithms a multiword value is carried as a Python **list** of
``[...]``-shaped ``uint32`` arrays (one per word) so every per-word expression
is statically unrolled; ``to_words`` / ``from_words`` convert to/from the
stacked last-axis representation. All functions are jit-/vmap-/Pallas-safe
element-wise ops.
"""
from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp
import numpy as np

WORD = 32
_FULL = np.uint32(0xFFFFFFFF)


def n_words(nbits: int) -> int:
    """Number of uint32 words needed to hold ``nbits`` bits."""
    return (nbits + WORD - 1) // WORD


def word_masks(nbits: int, W: int | None = None) -> np.ndarray:
    """uint32 [W] validity mask: bit set iff that bit index is < ``nbits``."""
    W = n_words(nbits) if W is None else W
    out = np.zeros((W,), np.uint32)
    for w in range(W):
        lo = w * WORD
        valid = min(max(nbits - lo, 0), WORD)
        out[w] = _FULL if valid == WORD else np.uint32((1 << valid) - 1)
    return out


def to_words(arr: jnp.ndarray) -> List[jnp.ndarray]:
    """Stacked [..., W] uint32 array -> list of W per-word [...] arrays."""
    return [arr[..., w].astype(jnp.uint32) for w in range(arr.shape[-1])]


def from_words(words: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """List of per-word arrays -> stacked [..., W] uint32 array."""
    return jnp.stack([w.astype(jnp.uint32) for w in words], axis=-1)


def zeros_like_words(ref: jnp.ndarray, W: int) -> List[jnp.ndarray]:
    """W zero words shaped like ``ref`` (any array supplying shape/weak type)."""
    z = jnp.zeros_like(jnp.asarray(ref, jnp.uint32))
    return [z for _ in range(W)]


def parity32(x: jnp.ndarray) -> jnp.ndarray:
    """Bit parity of each uint32 element (0 or 1), via xor-folding."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x ^ (x >> 8)
    x = x ^ (x >> 4)
    x = x ^ (x >> 2)
    x = x ^ (x >> 1)
    return x & jnp.uint32(1)


def masked_parity(words: Sequence[jnp.ndarray], masks: np.ndarray) -> jnp.ndarray:
    """Parity of the bits selected by per-word ``masks`` (uint32 [W]).

    parity(a) ^ parity(b) == parity(a ^ b), so the word reduction is a plain
    XOR fold followed by one ``parity32``.
    """
    acc = words[0] & jnp.uint32(masks[0])
    for w in range(1, len(words)):
        if int(masks[w]) == 0:
            continue
        acc = acc ^ (words[w] & jnp.uint32(masks[w]))
    return parity32(acc)


def extract_window(words: Sequence[jnp.ndarray], start: int,
                   nbits: int) -> List[jnp.ndarray]:
    """Bits [start, start+nbits) as a fresh ``n_words(nbits)``-word value."""
    W = len(words)
    masks = word_masks(nbits)
    out = []
    for ow in range(n_words(nbits)):
        bitpos = start + ow * WORD
        wl, sh = divmod(bitpos, WORD)
        v = (words[wl] >> sh) if wl < W else jnp.uint32(0)
        if sh and wl + 1 < W:
            v = v | (words[wl + 1] << (WORD - sh))
        out.append(v & jnp.uint32(masks[ow]))
    return out


def or_window(dst: List[jnp.ndarray], src: Sequence[jnp.ndarray], start: int,
              nbits: int) -> None:
    """OR an ``nbits``-wide value into ``dst`` at bit offset ``start``.

    ``dst`` must be zero (or disjoint) in the target window; ``src`` is masked
    to ``nbits`` first. Mutates the ``dst`` list in place.
    """
    masks = word_masks(nbits)
    for sw in range(n_words(nbits)):
        s = src[sw] & jnp.uint32(masks[sw]) if sw < len(src) else None
        if s is None:
            break
        bitpos = start + sw * WORD
        wl, sh = divmod(bitpos, WORD)
        if wl < len(dst):
            dst[wl] = dst[wl] | ((s << sh) if sh else s)
        if sh and wl + 1 < len(dst):
            dst[wl + 1] = dst[wl + 1] | (s >> (WORD - sh))


def insert_zero_bit(words: Sequence[jnp.ndarray], pos: int) -> List[jnp.ndarray]:
    """Insert a zero bit at ``pos``, shifting higher bits up by one.

    The caller provides enough words to hold the grown value (the top bit of
    the last word is shifted out).
    """
    W = len(words)
    shifted = []
    for w in range(W):
        v = words[w] << 1
        if w > 0:
            v = v | (words[w - 1] >> (WORD - 1))
        shifted.append(v)
    wl, sh = divmod(pos, WORD)
    lo = jnp.uint32((1 << sh) - 1)
    # bits < pos keep, bit pos forced to 0, bits > pos come from the shift
    hi = jnp.uint32(((1 << (sh + 1)) - 1) & 0xFFFFFFFF)
    out = []
    for w in range(W):
        if w < wl:
            out.append(words[w])
        elif w == wl:
            out.append((words[w] & lo) | (shifted[w] & ~hi))
        else:
            out.append(shifted[w])
    return out


def delete_bit(words: Sequence[jnp.ndarray], pos: int) -> List[jnp.ndarray]:
    """Remove the bit at ``pos``, shifting higher bits down by one."""
    W = len(words)
    shifted = []
    for w in range(W):
        v = words[w] >> 1
        if w + 1 < W:
            v = v | (words[w + 1] << (WORD - 1))
        shifted.append(v)
    wl, sh = divmod(pos, WORD)
    lo = jnp.uint32((1 << sh) - 1)
    out = []
    for w in range(W):
        if w < wl:
            out.append(words[w])
        elif w == wl:
            out.append((words[w] & lo) | (shifted[w] & ~lo))
        else:
            out.append(shifted[w])
    return out


def pack_bits_words(bits: jnp.ndarray, nbits: int | None = None) -> jnp.ndarray:
    """Bit array [..., nbits] (LSB first, {0,1}) -> packed [..., W] uint32."""
    nbits = bits.shape[-1] if nbits is None else nbits
    W = n_words(nbits)
    pad = W * WORD - nbits
    b = bits.astype(jnp.uint32)
    if pad:
        b = jnp.concatenate(
            [b, jnp.zeros(b.shape[:-1] + (pad,), jnp.uint32)], axis=-1)
    b = b.reshape(b.shape[:-1] + (W, WORD))
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1).astype(jnp.uint32)


def unpack_words(words: jnp.ndarray, nbits: int) -> jnp.ndarray:
    """Packed [..., W] uint32 -> bit array [..., nbits] uint8 (LSB first)."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = ((words[..., None].astype(jnp.uint32) >> shifts) & 1).astype(jnp.uint8)
    bits = bits.reshape(bits.shape[:-2] + (words.shape[-1] * WORD,))
    return bits[..., :nbits]
