"""Bit-accurate emulation of the Unicorn-CIM weight memory (paper Fig. 3/4).

A :class:`CIMStore` holds one weight matrix the way the macro's SRAM does —
as **word-packed bit planes**, not as one byte per stored bit:

* a mantissa plane (``man_bits`` per weight) in native ``uint16`` words — the
  Mantissa Multiplication Array;
* ONE shared exponent per ``N x row_weights`` block — the reduced Exponent
  Summation Array (8x fewer exponent bit cells for N=8, Table III);
* for ``protect='one4n'``: each block row's exponent + sign payload lives
  ONLY inside SECDED codewords (:class:`~repro.core.ecc.One4NRowCodec`),
  packed 32 bits per ``uint32`` word — check bits are SRAM cells next to the
  payload, exactly as in Fig. 4 ①;
* for ``protect='per_weight'``: one SECDED(6) codeword per weight, packed in
  a single ``uint16`` word (11 stored bits);
* for ``protect='none'``: a raw exponent plane plus a K-packed ``uint32``
  sign plane (bit ``k % 32`` of word ``k // 32``).

``inject`` flips stored bits (including check bits — they are SRAM cells too)
at a given BER. Flip decisions come from the same counter-based PRNG as the
:mod:`repro.kernels.fault_inject` Pallas kernel: bit ``p`` of the word at
C-order flat index ``e`` flips iff ``murmur3(e*32 + p ^ seed*0x9E3779B9) <
round(ber * 2^32)`` — one draw **per stored bit**, never one tensor op per
bit. ``read`` runs the packed ECC decode path (Fig. 4 ②③) and reconstructs
FP16 weights; :func:`read_reference` is the per-bit oracle the packed path is
equivalence-tested against. Static injection = inject once then read many;
dynamic injection = fresh inject before every read (the fused
``kernels/cim_read`` path draws the identical streams in-kernel).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as align_lib
from repro.core import bitops, bitpack
from repro.core import faultmodels as fm
from repro.core.bitops import FP16, FloatFormat
from repro.core.ecc import One4NRowCodec, SecdedCode


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    n_group: int = 8            # N
    index: int = 2              # exponent rank used at alignment time
    protect: str = "one4n"      # 'one4n' | 'per_weight' | 'none'
                                # per_weight = Table III "traditional ECC for
                                # exponent & sign": SECDED(6) per weight,
                                # 5 redundant bits each (83.3% SRAM overhead)
    fmt: FloatFormat = FP16
    row_weights: int = 16       # weights per SRAM row (256-bit rows of FP16)

    @property
    def codec(self) -> One4NRowCodec:
        return One4NRowCodec(n_group=self.n_group, row_weights=self.row_weights,
                             exp_bits=self.fmt.exp_bits,
                             sign_bits_per_row=self.row_weights)

    @property
    def pw_code(self) -> SecdedCode:
        """The per-weight (Table III traditional) SECDED over sign+exponent."""
        return SecdedCode(self.fmt.exp_bits + 1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CIMStore:
    """Word-packed SRAM image of one [K, J] weight matrix.

    Exactly one of {``codewords``, (``sign``, ``exp``)} is populated: when the
    exponent/sign payload is ECC-protected it lives *only* inside the
    codeword words (so the overhead accounting counts each sign bit once).
    """

    man: jnp.ndarray                      # uint16 [K_pad, J_pad], mantissas
    sign: Optional[jnp.ndarray]           # uint32 [ceil(K_pad/32), J_pad] or None
    exp: Optional[jnp.ndarray]            # uint8  [B, J_pad] or None
    codewords: Optional[jnp.ndarray]      # one4n: uint32 [B, G, n_seg, W];
                                          # per_weight: uint16 [K_pad, J_pad]
    shape: Tuple[int, int]                # logical (K, J)
    cfg: CIMConfig
    cache: Optional[jnp.ndarray] = None   # fp32 [K, J] decoded-row cache
                                          # (== read(store)[0]); serving-only
                                          # materialization, NOT part of the
                                          # SRAM image or its bit accounting.

    def tree_flatten(self):
        children = (self.man, self.sign, self.exp, self.codewords, self.cache)
        return children, (self.shape, self.cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        man, sign, exp, codewords, cache = children
        shape, cfg = aux
        return cls(man, sign, exp, codewords, shape, cfg, cache)

    @property
    def stored_bits(self) -> int:
        """Total SRAM bits of this image (for the overhead accounting).

        Counts *logical* stored cells, not container bytes: codeword planes
        count ``code.n`` bits per codeword, and — because protected images
        keep no separate sign/exponent planes — each sign bit is counted
        exactly once (inside its codeword).
        """
        n = int(self.man.size) * self.cfg.fmt.man_bits
        if self.codewords is not None:
            if self.cfg.protect == "per_weight":
                n += int(self.codewords.size) * self.cfg.pw_code.n
            else:
                n_cw = int(np.prod(self.codewords.shape[:-1]))
                n += n_cw * self.cfg.codec.code.n
        else:
            n += int(self.exp.size) * self.cfg.fmt.exp_bits
            n += int(self.man.size)                      # one sign bit/weight
        return n

    @property
    def stored_bytes(self) -> int:
        """Actual container bytes of every plane (what HBM/SRAM emulation
        holds) — the quantity the packed refactor shrinks."""
        planes = [self.man, self.sign, self.exp, self.codewords]
        return sum(int(p.size) * p.dtype.itemsize
                   for p in planes if p is not None)


def _pad_to(x: jnp.ndarray, k: int, j: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, k - x.shape[0]), (0, j - x.shape[1])))


def pack_sign_plane(sign_bits: jnp.ndarray) -> jnp.ndarray:
    """Sign bit plane [K, J] {0,1} -> K-packed uint32 [ceil(K/32), J]."""
    k, j = sign_bits.shape
    sw = bitpack.n_words(k)
    padded = jnp.pad(sign_bits.astype(jnp.uint32), ((0, sw * 32 - k), (0, 0)))
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    return jnp.sum(padded.reshape(sw, 32, j) << shifts, axis=1).astype(jnp.uint32)


def unpack_sign_plane(sign_words: jnp.ndarray, k: int) -> jnp.ndarray:
    """K-packed uint32 [SW, J] -> sign bit plane [k, J] uint8."""
    sw, j = sign_words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :, None]
    bits = ((sign_words[:, None, :] >> shifts) & 1).astype(jnp.uint8)
    return bits.reshape(sw * 32, j)[:k]


def pack(w: jnp.ndarray, cfg: CIMConfig) -> CIMStore:
    """Pack an exponent-aligned [K, J] weight matrix into its SRAM image.

    Weights must already be aligned (``align_matrix``): every N-block along K
    shares a biased exponent. The shared exponent is taken as the block max —
    exact for aligned input.
    """
    assert w.ndim == 2, "pack() operates on 2-D [in, out] matrices"
    k, j = w.shape
    n, rw = cfg.n_group, cfg.row_weights
    k_pad = math.ceil(k / n) * n
    j_pad = math.ceil(j / rw) * rw
    b = k_pad // n
    g = j_pad // rw

    s, e, m = bitops.split_fields(w.astype(jnp.float32), cfg.fmt)
    s = _pad_to(s.astype(jnp.uint8), k_pad, j_pad)
    e = _pad_to(e.astype(jnp.uint8), k_pad, j_pad)
    m = _pad_to(m.astype(jnp.uint16), k_pad, j_pad)

    e_block = jnp.max(e.reshape(b, n, j_pad), axis=1)          # [B, J_pad]
    sign = exp = codewords = None
    if cfg.protect == "one4n":
        codec = cfg.codec
        exp_rows = e_block.reshape(b, g, rw)                    # [B, G, rw]
        signs = s.reshape(b, n, g, rw).transpose(0, 2, 1, 3)    # [B, G, N, rw]
        codewords = codec.encode_packed(exp_rows, codec.pack_signs(signs))
    elif cfg.protect == "per_weight":
        # traditional scheme: one SECDED word per weight over its (exp, sign)
        # bits (per-weight exponents — no alignment assumed); the 11 stored
        # bits fit one uint16 word per weight.
        eb = cfg.fmt.exp_bits
        data = (e.astype(jnp.uint32) | (s.astype(jnp.uint32) << eb))[..., None]
        cw = cfg.pw_code.encode_packed(data)                    # [K, J, 1]
        assert cfg.pw_code.n <= 16
        codewords = cw[..., 0].astype(jnp.uint16)
    else:
        sign = pack_sign_plane(s)
        exp = e_block
    return CIMStore(man=m, sign=sign, exp=exp, codewords=codewords,
                    shape=(k, j), cfg=cfg)


# ---------------------------------------------------------------------------
# Counter-PRNG fault injection on packed words.
#
# The contract (shared with kernels/fault_inject and kernels/cim_read): a
# plane is a word array; bit p of the word at C-order flat index e flips iff
#     murmur3_fmix(e*32 + p  XOR  seed * 0x9E3779B9) < round(ber * 2^32),
# independently per (seed, e, p). Per-plane seeds derive from the caller's
# PRNG key via `plane_seeds`, so static injection (here) and per-read dynamic
# injection (in-kernel) draw bit-identical fault patterns from the same key.
# ---------------------------------------------------------------------------


def plane_seeds(key) -> dict:
    """Per-plane uint32 counter-PRNG seeds from one PRNG key.

    'man' seeds the mantissa plane; 'meta' the raw exponent plane; 'cw' the
    codeword plane (protected) or the raw sign plane (unprotected).
    """
    k_man, k_meta, k_cw = jax.random.split(key, 3)
    return {"man": jax.random.bits(k_man, (), jnp.uint32),
            "meta": jax.random.bits(k_meta, (), jnp.uint32),
            "cw": jax.random.bits(k_cw, (), jnp.uint32)}


def fold_seed(seed, i):
    """Decorrelate a plane seed per read index (dynamic injection streams)."""
    from repro.kernels.fault_inject.kernel import hash_u32
    salt = jnp.asarray(i, jnp.uint32) * jnp.uint32(0x85EBCA6B) \
        + jnp.uint32(0x9E3779B9)
    return hash_u32(jnp.asarray(seed, jnp.uint32) ^ salt)


def counter_flip_words(words: jnp.ndarray, seed, threshold, valid,
                       model=None) -> jnp.ndarray:
    """Flip bits of a packed word plane per the counter-PRNG contract.

    ``valid`` is a uint32 mask (scalar or array broadcastable to
    ``words.shape``) of the bit lanes that are real stored cells; only those
    see Bernoulli draws. ``model`` (a :class:`~repro.core.faultmodels
    .FaultProcess`) compiles to per-element thresholds before the draw;
    ``None``/``iid`` leave the threshold — and the streams — untouched. Pure
    jnp — usable under jit/vmap (the Pallas kernels implement the identical
    streams for the batched/fused paths).
    """
    elem = jnp.arange(words.size, dtype=jnp.uint32).reshape(words.shape)
    threshold = fm.plane_thresholds(model, threshold, elem, seed, words.shape)
    return _flip_gathered(words, elem, seed, threshold, valid)


def codeword_valid_masks(cfg: CIMConfig) -> np.ndarray:
    """Per-word stored-bit masks of the active codeword plane."""
    if cfg.protect == "per_weight":
        return np.asarray(bitpack.word_masks(cfg.pw_code.n)[0], np.uint32)
    return cfg.codec.code.code_word_masks


def inject_with_seeds(store: CIMStore, seeds: dict, thr_man, thr_meta,
                      model=None) -> CIMStore:
    """Flip stored bits from explicit per-plane seeds + field thresholds.

    ``thr_man`` gates the mantissa plane, ``thr_meta`` the exponent/sign
    cells (codeword words when protected — payload and check bits alike are
    SRAM cells). A zero threshold leaves that field untouched. ``model``
    compiles an error process (:mod:`repro.core.faultmodels`) into the
    per-element thresholds of every plane. This is the single source of
    truth for the flip streams: :func:`inject`, the sweep engine's kernel
    route and the fused ``cim_read`` kernel's in-VMEM dynamic injection all
    draw the same (seed, element, bit) decisions.
    """
    man, sign, exp, cw = store.man, store.sign, store.exp, store.codewords
    cfg = store.cfg
    mb = cfg.fmt.man_bits

    man = counter_flip_words(man, seeds["man"], thr_man, (1 << mb) - 1,
                             model=model)
    if cw is not None:
        cw = counter_flip_words(cw, seeds["cw"], thr_meta,
                                codeword_valid_masks(cfg), model=model)
    else:
        eb = cfg.fmt.exp_bits
        exp = counter_flip_words(exp, seeds["meta"], thr_meta, (1 << eb) - 1,
                                 model=model)
        k_pad = store.man.shape[0]
        sign = counter_flip_words(
            sign, seeds["cw"], thr_meta,
            bitpack.word_masks(k_pad, sign.shape[0])[:, None], model=model)
    return CIMStore(man=man, sign=sign, exp=exp, codewords=cw,
                    shape=store.shape, cfg=store.cfg)


def inject(key, store: CIMStore, ber, field: str = "full",
           model=None) -> CIMStore:
    """Flip stored bits at rate ``ber``; ``field`` restricts the target cells.

    field ∈ {'full', 'mantissa', 'exponent_sign'}: the macro stores mantissas,
    and (exponent+sign [+check]) rows — the paper's protected path. ``model``
    selects a :class:`~repro.core.faultmodels.FaultProcess` (default/``iid``
    is bit-for-bit the legacy stream).
    """
    if isinstance(ber, (int, float)) and ber <= 0.0:
        return store
    from repro.kernels.fault_inject.ops import ber_to_threshold
    thr = ber_to_threshold(ber)
    zero = jnp.uint32(0)
    return inject_with_seeds(
        store, plane_seeds(key),
        thr if field in ("full", "mantissa") else zero,
        thr if field in ("full", "exponent_sign") else zero, model=model)


# ---------------------------------------------------------------------------
# Read path: packed ECC decode + FP reconstruction.
# ---------------------------------------------------------------------------


def _decode_planes(store: CIMStore):
    """-> (e_block [B, J_pad], sign bit plane [K_pad, J_pad], status or None).

    For ``per_weight`` the exponent is per-weight; callers get
    ``e_block=None`` and a full ``e_full`` instead (second return slot)."""
    cfg = store.cfg
    n, rw = cfg.n_group, cfg.row_weights
    k_pad, j_pad = store.man.shape
    b, g = k_pad // n, j_pad // rw

    if store.codewords is not None and cfg.protect == "per_weight":
        cw32 = store.codewords.astype(jnp.uint32)[..., None]
        data, status = cfg.pw_code.decode_packed(cw32)
        data = data[..., 0]
        eb = cfg.fmt.exp_bits
        e_full = (data & ((1 << eb) - 1)).astype(jnp.uint8)
        sign = ((data >> eb) & 1).astype(jnp.uint8)
        return None, (e_full, sign), status
    if store.codewords is not None:
        codec = cfg.codec
        exp_rows, sign_words, status = codec.decode_packed(store.codewords)
        e_block = exp_rows.reshape(b, j_pad)
        # expand the packed sign words straight into [K_pad, J_pad] row order
        # (static window shifts; avoids a 4-D uint8 transpose on the hot path)
        sw_list = [sign_words[..., v] for v in range(sign_words.shape[-1])]
        shifts = jnp.arange(rw, dtype=jnp.uint32)
        rows = []
        for i_n in range(n):
            sv = bitpack.extract_window(sw_list, i_n * rw, rw)[0]   # [B, G]
            rows.append(((sv[..., None] >> shifts) & 1).reshape(b, j_pad))
        sign = jnp.stack(rows, axis=1).reshape(k_pad, j_pad).astype(jnp.uint8)
        return e_block, (None, sign), status
    sign = unpack_sign_plane(store.sign, k_pad)
    return store.exp, (None, sign), None


def read(store: CIMStore):
    """Packed ECC decode (if protected) + FP reconstruction.

    Returns (weights float32 [K, J], stats) with
    stats = {'corrected': #rows fixed, 'uncorrectable': #rows with >=2 errors}.
    """
    cfg = store.cfg
    n = cfg.n_group
    e_block, (e_full, sign), status = _decode_planes(store)
    if e_block is not None:
        e_full = jnp.repeat(e_block, n, axis=0)                 # [K_pad, J_pad]
    if status is None:
        stats = {"corrected": jnp.zeros((), jnp.int32),
                 "uncorrectable": jnp.zeros((), jnp.int32)}
    else:
        stats = {"corrected": jnp.sum(status == 1),
                 "uncorrectable": jnp.sum(status == 2)}
    w = bitops.combine_fields(sign.astype(jnp.uint32), e_full.astype(jnp.uint32),
                              store.man.astype(jnp.uint32), cfg.fmt)
    k, j = store.shape
    return jnp.asarray(w[:k, :j], jnp.float32), stats


def build_row_cache(store: CIMStore) -> CIMStore:
    """Attach the decoded-row cache: ``store.cache = read(store)[0]``.

    The cache is a serving-time materialization of the decoded fp32 matrix;
    the packed planes stay authoritative (``stored_bits``/``stored_bytes``,
    ECC stats and flip streams all keep reading the SRAM image). Every
    store-constructing function (:func:`pack`, :func:`inject_with_seeds`,
    :func:`inject_sharded`, sharding plumbing) builds stores *without* a
    cache, so any injection naturally invalidates it — a stale cache cannot
    survive a fault-image refresh.
    """
    return dataclasses.replace(store, cache=read(store)[0])


def drop_row_cache(store: CIMStore) -> CIMStore:
    """Return ``store`` without its decoded-row cache (no-op when absent)."""
    if store.cache is None:
        return store
    return dataclasses.replace(store, cache=None)


def read_reference(store: CIMStore):
    """Per-bit oracle for :func:`read`: unpack the packed planes to one-byte-
    per-bit arrays and decode with the per-bit SECDED codec.

    Kept as the equivalence baseline (tests) and the legacy-representation
    arm of ``benchmarks/cim_store_bench.py``; never used on the hot path.
    """
    cfg = store.cfg
    n, rw = cfg.n_group, cfg.row_weights
    k_pad, j_pad = store.man.shape
    b, g = k_pad // n, j_pad // rw

    if store.codewords is not None and cfg.protect == "per_weight":
        code = cfg.pw_code
        cw_bits = bitpack.unpack_words(
            store.codewords.astype(jnp.uint32)[..., None], code.n)
        data, status = code.decode(cw_bits)
        eb = cfg.fmt.exp_bits
        e_full = bitops.pack_bits(data[..., :eb], jnp.uint8)
        sign = data[..., eb]
        stats = {"corrected": jnp.sum(status == 1),
                 "uncorrectable": jnp.sum(status == 2)}
    elif store.codewords is not None:
        codec = cfg.codec
        cw_bits = bitpack.unpack_words(store.codewords, codec.code.n)
        exp_rows, signs, status = codec.decode(cw_bits)
        e_block = exp_rows.reshape(b, j_pad)
        sign = signs.transpose(0, 2, 1, 3).reshape(k_pad, j_pad)
        e_full = jnp.repeat(e_block, n, axis=0)
        stats = {"corrected": jnp.sum(status == 1),
                 "uncorrectable": jnp.sum(status == 2)}
    else:
        e_full = jnp.repeat(store.exp, n, axis=0)
        sign = unpack_sign_plane(store.sign, k_pad)
        stats = {"corrected": jnp.zeros((), jnp.int32),
                 "uncorrectable": jnp.zeros((), jnp.int32)}
    w = bitops.combine_fields(sign.astype(jnp.uint32), e_full.astype(jnp.uint32),
                              store.man.astype(jnp.uint32), cfg.fmt)
    k, j = store.shape
    return jnp.asarray(w[:k, :j], jnp.float32), stats


def store_stats(store: CIMStore):
    """ECC status counts without reconstructing weights (serve reporting)."""
    if store.codewords is None:
        z = jnp.zeros((), jnp.int32)
        return {"corrected": z, "uncorrectable": z}
    if store.cfg.protect == "per_weight":
        _, status = store.cfg.pw_code.decode_packed(
            store.codewords.astype(jnp.uint32)[..., None])
    else:
        _, _, status = store.cfg.codec.decode_packed(store.codewords)
    return {"corrected": jnp.sum(status == 1),
            "uncorrectable": jnp.sum(status == 2)}


def read_rows(store: CIMStore, idx: jnp.ndarray, seeds=None, thr_man=0,
              thr_meta=0, model=None):
    """Decode-on-read row gather: FP32 rows ``[*idx.shape, J]`` of the stored
    matrix, decoding ONLY the gathered rows' codewords (embedding-table serving
    path — the full weight matrix is never materialized).

    With ``seeds`` set (see :func:`plane_seeds`), fresh faults hit the
    gathered cells first — bit-identical to :func:`inject_with_seeds` on the
    whole store restricted to those cells (same counter-PRNG streams;
    ``thr_man`` gates mantissa cells, ``thr_meta`` exponent/sign cells, and
    ``model`` compiles a :class:`~repro.core.faultmodels.FaultProcess` into
    per-element thresholds at the gathered cells' GLOBAL indices).
    """
    cfg = store.cfg
    n, rw = cfg.n_group, cfg.row_weights
    k_pad, j_pad = store.man.shape
    g = j_pad // rw
    mb = cfg.fmt.man_bits
    dyn = seeds is not None

    def mthr(thr, elem_, seed_, shape_):
        return fm.plane_thresholds(model, thr, elem_, seed_, shape_)

    man = store.man[idx]                                   # [..., J_pad]
    if dyn:
        elem = (idx[..., None].astype(jnp.uint32) * jnp.uint32(j_pad)
                + jnp.arange(j_pad, dtype=jnp.uint32))
        man = _flip_gathered(man, elem, seeds["man"],
                             mthr(thr_man, elem, seeds["man"],
                                  store.man.shape), (1 << mb) - 1)

    if store.codewords is not None and cfg.protect == "per_weight":
        cw = store.codewords[idx]                          # [..., J_pad]
        if dyn:
            cw = _flip_gathered(cw, elem, seeds["cw"],
                                mthr(thr_meta, elem, seeds["cw"],
                                     store.codewords.shape),
                                int(codeword_valid_masks(cfg)))
        data, _ = cfg.pw_code.decode_packed(cw.astype(jnp.uint32)[..., None])
        data = data[..., 0]
        eb = cfg.fmt.exp_bits
        e_rows = (data & ((1 << eb) - 1)).astype(jnp.uint32)
        s_rows = ((data >> eb) & 1).astype(jnp.uint32)
    elif store.codewords is not None:
        codec = cfg.codec
        blk = (idx // n).astype(jnp.int32)
        i_n = (idx % n).astype(jnp.uint32)
        cw = store.codewords[blk]                          # [..., G, S, W]
        if dyn:
            s_, w_ = codec.n_segments, codec.codeword_words
            inner = jnp.arange(g * s_ * w_, dtype=jnp.uint32).reshape(g, s_, w_)
            celem = blk[..., None, None, None].astype(jnp.uint32) \
                * jnp.uint32(g * s_ * w_) + inner
            cw = _flip_gathered(cw, celem, seeds["cw"],
                                mthr(thr_meta, celem, seeds["cw"],
                                     store.codewords.shape),
                                codeword_valid_masks(cfg)[None, None, :])
        exp_rows, sign_words, _ = codec.decode_packed(cw)  # [..., G, rw], [..., G, Sw]
        e_rows = exp_rows.reshape(exp_rows.shape[:-2] + (j_pad,)).astype(jnp.uint32)
        signs = codec.unpack_signs(sign_words)             # [..., G, N, rw]
        s_sel = jnp.take_along_axis(
            signs, i_n[..., None, None, None].astype(jnp.int32), axis=-2)
        s_rows = s_sel[..., 0, :].reshape(s_sel.shape[:-3] + (j_pad,))
        s_rows = s_rows.astype(jnp.uint32)
    else:
        blk = (idx // n).astype(jnp.int32)
        e_rows = store.exp[blk].astype(jnp.uint32)
        sw = store.sign[(idx // 32).astype(jnp.int32)]     # [..., J_pad] words
        if dyn:
            eelem = (blk[..., None].astype(jnp.uint32) * jnp.uint32(j_pad)
                     + jnp.arange(j_pad, dtype=jnp.uint32))
            e_rows = _flip_gathered(e_rows, eelem, seeds["meta"],
                                    mthr(thr_meta, eelem, seeds["meta"],
                                         store.exp.shape),
                                    (1 << cfg.fmt.exp_bits) - 1)
            selem = ((idx // 32)[..., None].astype(jnp.uint32)
                     * jnp.uint32(j_pad) + jnp.arange(j_pad, dtype=jnp.uint32))
            svalid = np.uint32(0xFFFFFFFF) if k_pad % 32 == 0 \
                else np.uint32((1 << (k_pad % 32)) - 1)
            # rows in a full word see all 32 lanes; the last partial word only
            # its valid lanes (same masks as `inject`)
            full = (idx // 32 + 1) * 32 <= k_pad
            vmask = jnp.where(full[..., None], jnp.uint32(0xFFFFFFFF),
                              jnp.uint32(svalid))
            sw = _flip_gathered(sw, selem, seeds["cw"],
                                mthr(thr_meta, selem, seeds["cw"],
                                     store.sign.shape), vmask)
        s_rows = (sw >> (idx % 32)[..., None].astype(jnp.uint32)) & 1
    w = bitops.combine_fields(s_rows, e_rows, man.astype(jnp.uint32), cfg.fmt)
    return jnp.asarray(w[..., :store.shape[1]], jnp.float32)


def _plane_dict(store: CIMStore) -> dict:
    """The store's populated planes by name (sharding / shard_map plumbing)."""
    planes = {"man": store.man, "sign": store.sign, "exp": store.exp,
              "cw": store.codewords}
    return {k: v for k, v in planes.items() if v is not None}


def _restore_planes(store: CIMStore, planes: dict) -> CIMStore:
    return CIMStore(man=planes["man"], sign=planes.get("sign"),
                    exp=planes.get("exp"), codewords=planes.get("cw"),
                    shape=store.shape, cfg=store.cfg)


def can_shard_store(store: CIMStore, n_shards: int, dim: str = "j") -> bool:
    """Whether every plane splits evenly into ``n_shards`` along ``dim``.

    ``dim='j'`` splits output columns in whole ``row_weights`` groups (one
    shard ≈ one macro column group); ``dim='k'`` splits word lines in whole
    exponent blocks (and whole 32-row sign words for ``protect='none'``).
    """
    if n_shards == 1:
        return True
    k_pad, j_pad = store.man.shape
    cfg = store.cfg
    if dim == "j":
        return j_pad % (n_shards * cfg.row_weights) == 0
    if dim == "k":
        if k_pad % (n_shards * cfg.n_group) != 0:
            return False
        return store.sign is None or k_pad % (n_shards * 32) == 0
    raise ValueError(f"dim must be 'j' or 'k', got {dim!r}")


def store_plane_specs(store: CIMStore, axis: str = "model", dim: str = "j"):
    """Per-plane ``PartitionSpec``s of the packed SRAM image.

    Every plane carries its shard axis in the same position: dimension 1
    (columns / column groups) for ``dim='j'``, dimension 0 (K rows, exponent
    blocks, sign words) for ``dim='k'`` — C-order strides are unchanged, so
    the counter-PRNG flip contract keeps holding shard by shard.
    """
    from jax.sharding import PartitionSpec as P
    sdim = 0 if dim == "k" else 1
    return {name: P(*[axis if d == sdim else None for d in range(p.ndim)])
            for name, p in _plane_dict(store).items()}


def store_shardings(store: CIMStore, mesh, *, axis: str = "model",
                    dim: str = "j") -> CIMStore:
    """A CIMStore-shaped pytree of ``NamedSharding``s for the packed planes
    (jit ``in_shardings`` / ``device_put`` target). Planes that do not split
    evenly fall back to replication — callers degrade cleanly on any mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    n_sh = int(mesh.shape[axis]) if axis in mesh.axis_names else 1
    if can_shard_store(store, n_sh, dim):
        specs = store_plane_specs(store, axis, dim)
    else:
        specs = {name: P() for name in _plane_dict(store)}
    named = {name: NamedSharding(mesh, spec) for name, spec in specs.items()}
    cache_sh = None
    if store.cache is not None:
        # The decoded cache is logical [K, J]; split it along the same dim as
        # the planes when it divides evenly, else replicate.
        sdim = 0 if dim == "k" else 1
        if (can_shard_store(store, n_sh, dim)
                and store.cache.shape[sdim] % n_sh == 0):
            spec = P(*[axis if d == sdim else None for d in range(2)])
        else:
            spec = P()
        cache_sh = NamedSharding(mesh, spec)
    return CIMStore(man=named["man"], sign=named.get("sign"),
                    exp=named.get("exp"), codewords=named.get("cw"),
                    shape=store.shape, cfg=store.cfg, cache=cache_sh)


def shard_store(store: CIMStore, mesh, *, axis: str = "model",
                dim: str = "j") -> CIMStore:
    """Place the packed planes on ``mesh`` with the model axis split along
    ``dim`` (one shard ≈ one macro column group). The arrays stay global-view
    jax arrays: ``stored_bits`` / ``stored_bytes`` / ``read_reference`` are
    unchanged, and GSPMD partitions the pure-jnp paths automatically."""
    return jax.device_put(store, store_shardings(store, mesh, axis=axis,
                                                 dim=dim))


def _global_elem(local_shape, global_shape, sdim: int, start) -> jnp.ndarray:
    """C-order flat indices into the GLOBAL plane for a local shard block
    whose ``sdim`` dimension starts at (traced) offset ``start``."""
    elem = jnp.zeros(local_shape, jnp.uint32)
    stride = 1
    for d in reversed(range(len(global_shape))):
        idx = jax.lax.broadcasted_iota(jnp.uint32, local_shape, d)
        if d == sdim:
            idx = idx + jnp.asarray(start, jnp.uint32)
        elem = elem + idx * jnp.uint32(stride)
        stride *= int(global_shape[d])
    return elem


def inject_sharded(key, store: CIMStore, ber, field: str = "full", *, mesh,
                   axis: str = "model", dim: str = "j",
                   model=None) -> CIMStore:
    """``shard_map`` twin of :func:`inject` for a mesh-sharded store.

    Each shard draws flips for its LOCAL plane block at the block's GLOBAL
    C-order element indices (``axis_index * local_extent`` offset along the
    shard dimension), so the flip streams are bit-identical to the
    single-device image for the same key — no resharding, no all-gather.
    ``model`` thresholds compile from the same global indices against the
    GLOBAL plane shapes, so burst/correlated/drift masks are likewise
    bit-identical shard by shard.

    Call under ``jit`` on hot paths: the per-bit-lane mask loop is ~100 tiny
    ops, and eager ``shard_map`` dispatch of those across many host devices
    is orders of magnitude slower than the compiled executable.
    """
    if isinstance(ber, (int, float)) and ber <= 0.0:
        return store
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.kernels.fault_inject.ops import ber_to_threshold

    cfg = store.cfg
    n_sh = int(mesh.shape[axis]) if axis in mesh.axis_names else 1
    assert can_shard_store(store, n_sh, dim), \
        f"store {store.man.shape} does not split {n_sh}-way along {dim!r}"
    thr = ber_to_threshold(ber)
    zero = jnp.uint32(0)
    rt = {"seeds": plane_seeds(key),
          "thr_man": thr if field in ("full", "mantissa") else zero,
          "thr_meta": thr if field in ("full", "exponent_sign") else zero}

    planes = _plane_dict(store)
    gshapes = {name: p.shape for name, p in planes.items()}
    sdim = 0 if dim == "k" else 1
    mb, eb = cfg.fmt.man_bits, cfg.fmt.exp_bits
    valids = {"man": (1 << mb) - 1}
    seed_of = {"man": "man", "cw": "cw", "exp": "meta", "sign": "cw"}
    if "cw" in planes:
        valids["cw"] = codeword_valid_masks(cfg)
    else:
        valids["exp"] = (1 << eb) - 1
        k_pad = store.man.shape[0]
        smasks = bitpack.word_masks(k_pad, store.sign.shape[0])
        # dim='k' splits the sign word rows; divisibility by 32*n_sh (checked
        # above) guarantees no ragged word, so the scalar mask is exact
        valids["sign"] = np.uint32(0xFFFFFFFF) if dim == "k" and n_sh > 1 \
            else smasks[:, None]

    def local(planes_loc, rt_loc):
        i = jax.lax.axis_index(axis)
        out = {}
        for name, words in planes_loc.items():
            t = rt_loc["thr_man"] if name == "man" else rt_loc["thr_meta"]
            elem = _global_elem(words.shape, gshapes[name], sdim,
                                i * words.shape[sdim])
            seed = rt_loc["seeds"][seed_of[name]]
            t = fm.plane_thresholds(model, t, elem, seed, gshapes[name])
            out[name] = _flip_gathered(words, elem, seed, t, valids[name])
        return out

    pspecs = store_plane_specs(store, axis, dim)
    rt_specs = jax.tree_util.tree_map(lambda _: P(), rt)
    flipped = shard_map(local, mesh=mesh, in_specs=(pspecs, rt_specs),
                        out_specs=pspecs, check_rep=False)(planes, rt)
    return _restore_planes(store, flipped)


def _flip_gathered(words, elem, seed, threshold, valid):
    """Counter-PRNG flips on gathered cells, streams identical to
    :func:`counter_flip_words` at the same flat ``elem`` indices.

    ``valid`` may be a static mask (int / np array) — skipping dead bit
    lanes — or a traced jnp mask (all 32 lanes drawn, then masked)."""
    from repro.kernels.fault_inject.kernel import hash_u32
    if isinstance(valid, jnp.ndarray):
        union = 0xFFFFFFFF
    else:
        valid = np.asarray(valid, np.uint32)
        union = int(np.bitwise_or.reduce(valid.ravel())) if valid.ndim \
            else int(valid)
    seed = jnp.asarray(seed, jnp.uint32) * jnp.uint32(0x9E3779B9)
    threshold = jnp.asarray(threshold, jnp.uint32)
    mask = jnp.zeros(words.shape, jnp.uint32)
    for p in range(32):
        if not (union >> p) & 1:
            continue
        z = (elem * jnp.uint32(32) + jnp.uint32(p)) ^ seed
        flip = (hash_u32(z) < threshold).astype(jnp.uint32)
        mask = mask | (flip << p)
    mask = mask & jnp.asarray(valid, jnp.uint32)
    return (words.astype(jnp.uint32) ^ mask).astype(words.dtype)


# ---------------------------------------------------------------------------
# Pytree-level API: deploy a whole model onto emulated CIM macros.
#
# The public entry point is now :class:`repro.core.deployment.CIMDeployment`
# (per-layer reliability policies, placement, dispatch); the free functions
# below are kept as deprecation shims over the private ``*_impl`` twins,
# which internal callers (deployment, sweep engine, benches) use directly.
# ---------------------------------------------------------------------------

def _deprecated(old: str, new: str) -> None:
    import warnings
    warnings.warn(
        f"repro.core.cim.{old} is deprecated; use {new} "
        f"(repro.core.deployment) instead", DeprecationWarning, stacklevel=3)


def _deployable(path, leaf) -> bool:
    return hasattr(leaf, "ndim") and leaf.ndim == 2 and \
        jnp.issubdtype(leaf.dtype, jnp.floating)


def deploy_pytree(params, cfg: CIMConfig, align_cfg=None, predicate=_deployable):
    """Deprecated shim: use ``CIMDeployment.deploy`` with a policy."""
    _deprecated("deploy_pytree", "CIMDeployment.deploy")
    return deploy_pytree_impl(params, cfg, align_cfg, predicate)


def deploy_pytree_impl(params, cfg: CIMConfig, align_cfg=None,
                       predicate=_deployable):
    """Align (optionally) + pack every 2-D weight; other leaves pass through.

    Returns (stores_pytree, aligned_params). Leaves >2-D are reshaped to 2-D
    by callers (conv kernels etc.) before deployment.
    """
    if align_cfg is None:
        align_cfg = align_lib.AlignmentConfig(n_group=cfg.n_group, index=cfg.index,
                                              fmt=cfg.fmt)
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(params)[0]]
    flat, treedef = jax.tree_util.tree_flatten(params)
    stores, aligned = [], []
    for path, leaf in zip(paths, flat):
        if predicate(path, leaf):
            w_al, _ = align_lib.align_matrix(leaf, align_cfg)
            stores.append(pack(w_al, cfg))
            aligned.append(w_al)
        else:
            stores.append(leaf)
            aligned.append(leaf)
    return (jax.tree_util.tree_unflatten(treedef, stores),
            jax.tree_util.tree_unflatten(treedef, aligned))


def _is_store(x) -> bool:
    return isinstance(x, CIMStore)


def inject_pytree(key, stores, ber, field: str = "full"):
    """Deprecated shim: use ``CIMDeployment.inject``."""
    _deprecated("inject_pytree", "CIMDeployment.inject")
    return inject_pytree_impl(key, stores, ber, field)


def inject_pytree_impl(key, stores, ber, field: str = "full", model=None):
    """Fresh faults into every store of a deployed model."""
    flat, treedef = jax.tree_util.tree_flatten(stores, is_leaf=_is_store)
    keys = jax.random.split(key, len(flat))
    out = [inject(k, s, ber, field, model=model) if _is_store(s) else s
           for k, s in zip(keys, flat)]
    return jax.tree_util.tree_unflatten(treedef, out)


def read_pytree(stores):
    """Deprecated shim: use ``CIMDeployment.read``."""
    _deprecated("read_pytree", "CIMDeployment.read")
    return read_pytree_impl(stores)


def read_pytree_impl(stores):
    """Decode every store -> (params, aggregated stats)."""
    flat, treedef = jax.tree_util.tree_flatten(stores, is_leaf=_is_store)
    out, corrected, uncorrectable = [], 0, 0
    for s in flat:
        if _is_store(s):
            w, st = read(s)
            out.append(w)
            corrected = corrected + st["corrected"]
            uncorrectable = uncorrectable + st["uncorrectable"]
        else:
            out.append(s)
    params = jax.tree_util.tree_unflatten(treedef, out)
    return params, {"corrected": corrected, "uncorrectable": uncorrectable}
