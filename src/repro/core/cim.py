"""Bit-accurate emulation of the Unicorn-CIM weight memory (paper Fig. 3/4).

A :class:`CIMStore` holds one weight matrix the way the macro's SRAM does:

* a mantissa plane (10 bits per weight) — the Mantissa Multiplication Array;
* ONE shared exponent per ``N x 16-weight`` block — the reduced Exponent
  Summation Array (8x fewer exponent bit cells for N=8, Table III);
* per-weight sign bits;
* for ``protect='one4n'``: the exponent row + sign bits of each block packed
  into SECDED codewords (:class:`~repro.core.ecc.One4NRowCodec`) — check bits
  live in SRAM next to the payload, exactly as in Fig. 4 ①;
* for ``protect='none'``: raw exponent/sign bit cells (the unprotected
  baseline of Fig. 6).

``inject`` flips stored bits (including check bits — they are SRAM cells too)
at a given BER; ``read`` runs the ECC decode path (Fig. 4 ②③) and
reconstructs FP16 weights. Static injection = inject once then read many;
dynamic injection = fresh inject before every read.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import align as align_lib
from repro.core import bitops
from repro.core.bitops import FP16, FloatFormat
from repro.core.ecc import One4NRowCodec


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    n_group: int = 8            # N
    index: int = 2              # exponent rank used at alignment time
    protect: str = "one4n"      # 'one4n' | 'per_weight' | 'none'
                                # per_weight = Table III "traditional ECC for
                                # exponent & sign": SECDED(6) per weight,
                                # 5 redundant bits each (83.3% SRAM overhead)
    fmt: FloatFormat = FP16
    row_weights: int = 16       # weights per SRAM row (256-bit rows of FP16)

    @property
    def codec(self) -> One4NRowCodec:
        return One4NRowCodec(n_group=self.n_group, row_weights=self.row_weights,
                             exp_bits=self.fmt.exp_bits,
                             sign_bits_per_row=self.row_weights)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CIMStore:
    """Packed SRAM image of one [K, J] weight matrix."""

    man: jnp.ndarray                      # uint16 [K_pad, J_pad], 10-bit mantissas
    sign: jnp.ndarray                     # uint8  [K_pad, J_pad] (authoritative when protect='none')
    exp: jnp.ndarray                      # uint8  [B, J_pad]     (authoritative when protect='none')
    codewords: Optional[jnp.ndarray]      # uint8 bits [B, G, n_seg, n_code] or None
    shape: Tuple[int, int]                # logical (K, J)
    cfg: CIMConfig

    def tree_flatten(self):
        children = (self.man, self.sign, self.exp, self.codewords)
        return children, (self.shape, self.cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        man, sign, exp, codewords = children
        shape, cfg = aux
        return cls(man, sign, exp, codewords, shape, cfg)

    @property
    def stored_bits(self) -> int:
        """Total SRAM bits of this image (for the overhead accounting)."""
        n = int(self.man.size) * self.cfg.fmt.man_bits + int(self.sign.size)
        if self.codewords is not None:
            n += int(self.codewords.size)          # payload+check bits
        else:
            n += int(self.exp.size) * self.cfg.fmt.exp_bits
        return n


def _pad_to(x: jnp.ndarray, k: int, j: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, k - x.shape[0]), (0, j - x.shape[1])))


def pack(w: jnp.ndarray, cfg: CIMConfig) -> CIMStore:
    """Pack an exponent-aligned [K, J] weight matrix into its SRAM image.

    Weights must already be aligned (``align_matrix``): every N-block along K
    shares a biased exponent. The shared exponent is taken as the block max —
    exact for aligned input.
    """
    assert w.ndim == 2, "pack() operates on 2-D [in, out] matrices"
    k, j = w.shape
    n, rw = cfg.n_group, cfg.row_weights
    k_pad = math.ceil(k / n) * n
    j_pad = math.ceil(j / rw) * rw
    b = k_pad // n
    g = j_pad // rw

    s, e, m = bitops.split_fields(w.astype(jnp.float32), cfg.fmt)
    s = _pad_to(s.astype(jnp.uint8), k_pad, j_pad)
    e = _pad_to(e.astype(jnp.uint8), k_pad, j_pad)
    m = _pad_to(m.astype(jnp.uint16), k_pad, j_pad)

    e_block = jnp.max(e.reshape(b, n, j_pad), axis=1)          # [B, J_pad]
    codewords = None
    if cfg.protect == "one4n":
        codec = cfg.codec
        exp_rows = e_block.reshape(b, g, rw)                    # [B, G, 16]
        signs = s.reshape(b, n, g, rw).transpose(0, 2, 1, 3)    # [B, G, N, 16]
        codewords = codec.encode(exp_rows, signs)               # [B, G, seg, n]
    elif cfg.protect == "per_weight":
        # traditional scheme: one SECDED word per weight over its 6
        # sign+exponent bits (per-weight exponents — no alignment assumed)
        from repro.core.bitops import unpack_bits
        from repro.core.ecc import SecdedCode
        payload = jnp.concatenate(
            [unpack_bits(e, cfg.fmt.exp_bits),
             s[..., None].astype(jnp.uint8)], axis=-1)          # [K, J, 6]
        codewords = SecdedCode(cfg.fmt.exp_bits + 1).encode(payload)
    return CIMStore(man=m, sign=s, exp=e_block, codewords=codewords,
                    shape=(k, j), cfg=cfg)


def inject(key: jax.Array, store: CIMStore, ber: float,
           field: str = "full") -> CIMStore:
    """Flip stored bits at rate ``ber``; ``field`` restricts the target cells.

    field ∈ {'full', 'mantissa', 'exponent_sign'}: the macro stores mantissas,
    and (exponent+sign [+check]) rows — the paper's protected path.
    """
    if isinstance(ber, (int, float)) and ber <= 0.0:
        return store
    k_man, k_meta, k_cw = jax.random.split(key, 3)
    man, sign, exp, cw = store.man, store.sign, store.exp, store.codewords
    mb = store.cfg.fmt.man_bits

    if field in ("full", "mantissa"):
        flips = jax.random.bernoulli(k_man, ber, man.shape + (mb,))
        mask = jnp.sum(flips.astype(jnp.uint32) << jnp.arange(mb, dtype=jnp.uint32),
                       axis=-1).astype(jnp.uint16)
        man = man ^ mask

    if field in ("full", "exponent_sign"):
        if cw is not None:
            # Protected mode: exponent+sign live ONLY inside the codewords
            # (payload and check bits alike are SRAM cells).
            flips = jax.random.bernoulli(k_cw, ber, cw.shape)
            cw = cw ^ flips.astype(jnp.uint8)
        else:
            eb = store.cfg.fmt.exp_bits
            eflips = jax.random.bernoulli(k_meta, ber, exp.shape + (eb,))
            emask = jnp.sum(eflips.astype(jnp.uint32) << jnp.arange(eb, dtype=jnp.uint32),
                            axis=-1).astype(jnp.uint8)
            exp = exp ^ emask
            sflips = jax.random.bernoulli(k_cw, ber, sign.shape)
            sign = sign ^ sflips.astype(jnp.uint8)

    return CIMStore(man=man, sign=sign, exp=exp, codewords=cw,
                    shape=store.shape, cfg=store.cfg)


def read(store: CIMStore):
    """ECC decode (if protected) + FP reconstruction.

    Returns (weights float32 [K, J], stats) with
    stats = {'corrected': #rows fixed, 'uncorrectable': #rows with >=2 errors}.
    """
    cfg = store.cfg
    n, rw = cfg.n_group, cfg.row_weights
    k_pad, j_pad = store.man.shape
    b, g = k_pad // n, j_pad // rw

    if store.codewords is not None and cfg.protect == "per_weight":
        from repro.core.bitops import pack_bits
        from repro.core.ecc import SecdedCode
        data, status = SecdedCode(cfg.fmt.exp_bits + 1).decode(store.codewords)
        e_full = pack_bits(data[..., :cfg.fmt.exp_bits], jnp.uint8)
        sign = data[..., cfg.fmt.exp_bits]
        w = bitops.combine_fields(sign.astype(jnp.uint32),
                                  e_full.astype(jnp.uint32),
                                  store.man.astype(jnp.uint32), cfg.fmt)
        k, j = store.shape
        return jnp.asarray(w[:k, :j], jnp.float32), \
            {"corrected": jnp.sum(status == 1),
             "uncorrectable": jnp.sum(status == 2)}
    if store.codewords is not None:
        exp_rows, signs, status = cfg.codec.decode(store.codewords)
        e_block = exp_rows.reshape(b, j_pad)
        sign = signs.transpose(0, 2, 1, 3).reshape(k_pad, j_pad)
        stats = {"corrected": jnp.sum(status == 1),
                 "uncorrectable": jnp.sum(status == 2)}
    else:
        e_block = store.exp
        sign = store.sign
        stats = {"corrected": jnp.zeros((), jnp.int32),
                 "uncorrectable": jnp.zeros((), jnp.int32)}

    e_full = jnp.repeat(e_block, n, axis=0)                     # [K_pad, J_pad]
    w = bitops.combine_fields(sign.astype(jnp.uint32), e_full.astype(jnp.uint32),
                              store.man.astype(jnp.uint32), cfg.fmt)
    k, j = store.shape
    return jnp.asarray(w[:k, :j], jnp.float32), stats


# ---------------------------------------------------------------------------
# Pytree-level API: deploy a whole model onto emulated CIM macros.
# ---------------------------------------------------------------------------

def _deployable(path, leaf) -> bool:
    return hasattr(leaf, "ndim") and leaf.ndim == 2 and \
        jnp.issubdtype(leaf.dtype, jnp.floating)


def deploy_pytree(params, cfg: CIMConfig, align_cfg=None, predicate=_deployable):
    """Align (optionally) + pack every 2-D weight; other leaves pass through.

    Returns (stores_pytree, aligned_params). Leaves >2-D are reshaped to 2-D
    by callers (conv kernels etc.) before deployment.
    """
    if align_cfg is None:
        align_cfg = align_lib.AlignmentConfig(n_group=cfg.n_group, index=cfg.index,
                                              fmt=cfg.fmt)
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(params)[0]]
    flat, treedef = jax.tree_util.tree_flatten(params)
    stores, aligned = [], []
    for path, leaf in zip(paths, flat):
        if predicate(path, leaf):
            w_al, _ = align_lib.align_matrix(leaf, align_cfg)
            stores.append(pack(w_al, cfg))
            aligned.append(w_al)
        else:
            stores.append(leaf)
            aligned.append(leaf)
    return (jax.tree_util.tree_unflatten(treedef, stores),
            jax.tree_util.tree_unflatten(treedef, aligned))


def _is_store(x) -> bool:
    return isinstance(x, CIMStore)


def inject_pytree(key, stores, ber: float, field: str = "full"):
    """Fresh faults into every store of a deployed model."""
    flat, treedef = jax.tree_util.tree_flatten(stores, is_leaf=_is_store)
    keys = jax.random.split(key, len(flat))
    out = [inject(k, s, ber, field) if _is_store(s) else s
           for k, s in zip(keys, flat)]
    return jax.tree_util.tree_unflatten(treedef, out)


def read_pytree(stores):
    """Decode every store -> (params, aggregated stats)."""
    flat, treedef = jax.tree_util.tree_flatten(stores, is_leaf=_is_store)
    out, corrected, uncorrectable = [], 0, 0
    for s in flat:
        if _is_store(s):
            w, st = read(s)
            out.append(w)
            corrected = corrected + st["corrected"]
            uncorrectable = uncorrectable + st["uncorrectable"]
        else:
            out.append(s)
    params = jax.tree_util.tree_unflatten(treedef, out)
    return params, {"corrected": corrected, "uncorrectable": uncorrectable}
