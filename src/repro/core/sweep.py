"""Vectorized characterization engine (paper §III-A at production scale).

The paper's Fig. 2 / Fig. 6 / Table III evidence is a ~24,000-run
fault-injection grid over (field-or-protection arm × BER × trial). The naive
harness drives that grid with nested Python loops — one device dispatch per
cell. This module evaluates the whole (BER × trial) *plane* of an arm in a
single compiled executable:

* **trials** are batched with ``jax.vmap`` over a stacked batch of PRNG keys
  (XLA backend) or counter-PRNG seeds (Pallas backend);
* the **BER axis** is folded in with ``jax.lax.map`` over a stacked BER
  vector, so BER is a traced scalar and never triggers recompilation;
* the **trial axis is sharded** across available devices via a 1-D
  ``("trial",)`` mesh from :mod:`repro.launch.mesh` — fault-injection trials
  are embarrassingly parallel;
* on a 2-D ``("trial", "model")`` sweep mesh (``make_sweep_mesh``) the CIM
  deployment itself is **column-sharded over "model"**
  (:func:`repro.core.cim.shard_store`), composing trial parallelism with the
  mesh-sharded SRAM image — one Fig. 6 arm spans the whole mesh;
* the inner bit-flip step routes through the trial-batched
  :mod:`repro.kernels.fault_inject` Pallas kernel when the backend supports it
  (TPU, or interpret mode for CPU testing), with the pure-JAX
  :mod:`repro.core.fault` path as the default CPU fallback.

Net effect: **one compile per arm**, one (or a handful of) device dispatches
per sweep, instead of ``n_bers * n_trials`` of each.

The XLA backend reproduces the loop harness's PRNG stream exactly (the key
schedule is the same sequential ``jax.random.split`` chain, computed with
``lax.scan``), so ``SweepEngine`` results match the legacy loop functions
trial-for-trial — see ``tests/test_sweep.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import bitops, bitpack
from repro.core import cim as cim_lib
from repro.core import fault as fault_lib
from repro.core import faultmodels as fm_lib
from repro.core.bitops import FP16, FloatFormat
from repro.kernels.fault_inject import ops as fi_ops
from repro.kernels.fault_inject.kernel import hash_u32


@dataclasses.dataclass
class SweepResult:
    """One (BER, arm) cell of the characterization grid."""

    ber: float
    field: str
    protect: str            # 'raw' (plain tensors), 'none' (CIM unprotected), 'one4n'
    accuracies: List[float]
    corrected: float = 0.0
    uncorrectable: float = 0.0
    stored_bits: int = 0    # deployment SRAM cells of the arm (policy sweeps:
                            # the cost axis the policy search minimizes)
    fault_model: str = "iid"    # error-process arm (faultmodels grammar)

    @property
    def mean(self) -> float:
        return float(np.mean(self.accuracies))

    @property
    def std(self) -> float:
        return float(np.std(self.accuracies))


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """Static description of a characterization grid.

    One compiled executor is built per *arm* (a field for Fig. 2 sweeps, a
    protection mode for Fig. 6 sweeps); ``bers`` and ``n_trials`` are folded
    into that executor as traced values.
    """

    bers: Tuple[float, ...]
    n_trials: int = 10
    fields: Tuple[str, ...] = ("sign", "exponent", "mantissa", "full")
    protects: Tuple[str, ...] = ("none", "one4n")
    fmt: FloatFormat = FP16
    backend: str = "auto"               # 'auto' | 'xla' | 'pallas'
    shard_trials: bool = True
    interpret: Optional[bool] = None    # Pallas interpret-mode override
    fault_models: Tuple[str, ...] = ("iid",)   # error-process axis (specs in
                                               # the faultmodels grammar)

    def __post_init__(self):
        object.__setattr__(self, "bers", tuple(float(b) for b in self.bers))
        object.__setattr__(self, "fields", tuple(self.fields))
        object.__setattr__(self, "protects", tuple(self.protects))
        object.__setattr__(self, "fault_models",
                          tuple(str(m) for m in self.fault_models))
        for m in self.fault_models:
            fm_lib.parse_fault_model(m)        # validate the grammar eagerly
        if self.backend not in ("auto", "xla", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")


@functools.partial(jax.jit, static_argnames=("steps",))
def _split_schedule(key, steps: int):
    """The loop harness's sequential ``key, sub = split(key)`` chain, on
    device: returns (carried key, subkeys [steps, ...])."""
    def step(k, _):
        k, sub = jax.random.split(k)
        return k, sub
    return jax.lax.scan(step, key, None, length=steps)


def _salted(seeds: jnp.ndarray, salt: int) -> jnp.ndarray:
    """Decorrelate the per-trial counter-PRNG streams of distinct planes."""
    return hash_u32(seeds ^ jnp.uint32((salt * 0x85EBCA6B + 0x9E3779B9)
                                       & 0xFFFFFFFF))


def _arm_model(spec) -> Optional[fm_lib.FaultProcess]:
    """Fault-model arm spec -> process; ``iid`` maps to ``None`` so default
    arms take the zero-cost legacy code path (bit-identical streams)."""
    model = fm_lib.parse_fault_model(spec)
    return None if model is not None and model.kind == "iid" else model


def _leaf_inject_batched(bits2d, seeds, threshold, positions, interpret,
                         model=None, col_div: int = 1):
    return fi_ops.fault_inject_bits_batched(
        bits2d, seeds, threshold, positions=tuple(positions),
        interpret=interpret, model=model, col_div=col_div)


def inject_pytree_batched(params, seeds: jnp.ndarray, threshold, field: str,
                          fmt: FloatFormat = FP16, *,
                          predicate=fault_lib._is_injectable,
                          interpret: Optional[bool] = None, model=None):
    """Kernel-backed batched static injection: every injectable leaf gains a
    leading trial axis [T, ...]; pass-through leaves are broadcast to match.

    The per-leaf streams are decorrelated by salting ``seeds`` with the leaf
    index, mirroring ``fault.inject_pytree``'s per-leaf key split.
    """
    positions = tuple(int(p) for p in fmt.field_bit_positions(field))
    t = seeds.shape[0]
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        if predicate(path, leaf):
            bits = bitops.to_bits(leaf.reshape(-1, leaf.shape[-1]), fmt)
            faulted = _leaf_inject_batched(bits, _salted(seeds, i), threshold,
                                           positions, interpret, model)
            w = bitops.from_bits(faulted, fmt)
            out.append(jnp.asarray(w, leaf.dtype).reshape((t,) + leaf.shape))
        else:
            out.append(jnp.broadcast_to(leaf, (t,) + jnp.shape(leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)


def _store_inject_batched(store: cim_lib.CIMStore, seeds, threshold,
                          interpret, model=None) -> cim_lib.CIMStore:
    """Batched SRAM-plane injection (field='full' of ``cim.inject``) on the
    word-packed planes: the trial-batched kernel draws per-word 32-lane flip
    masks, and lanes that are not stored cells (codeword tail words, the sign
    plane's ragged last word) are masked back to their original bits."""
    t = seeds.shape[0]
    mb = store.cfg.fmt.man_bits
    eb = store.cfg.fmt.exp_bits

    man = _leaf_inject_batched(store.man, _salted(seeds, 101), threshold,
                               tuple(range(mb)), interpret, model)
    sign = exp = cw = None
    if store.codewords is not None:
        cw_arr = store.codewords
        masks = cim_lib.codeword_valid_masks(store.cfg)
        if cw_arr.ndim == 2:
            # per-weight SECDED: one uint16 word per weight, n stored bits
            positions = tuple(p for p in range(16) if (int(masks) >> p) & 1)
            cw = _leaf_inject_batched(cw_arr, _salted(seeds, 102), threshold,
                                      positions, interpret, model)
        else:
            cw2d = cw_arr.reshape(cw_arr.shape[0], -1)     # [B, G*S*W] uint32
            # macro-column units of the flattened plane are S*W words wide
            # (same geometry faultmodels.plane_geometry derives from 4-D)
            cdiv = int(cw_arr.shape[2]) * int(cw_arr.shape[3])
            flipped = _leaf_inject_batched(cw2d, _salted(seeds, 102), threshold,
                                           tuple(range(32)), interpret, model,
                                           col_div=cdiv)
            valid = jnp.asarray(np.tile(masks, cw2d.shape[1] // masks.size),
                                jnp.uint32)
            flipped = (flipped & valid) | (cw2d[None] & ~valid)
            cw = flipped.reshape((t,) + cw_arr.shape)
    else:
        exp = _leaf_inject_batched(store.exp, _salted(seeds, 103), threshold,
                                   tuple(range(eb)), interpret, model)
        k_pad = store.man.shape[0]
        smasks = bitpack.word_masks(k_pad, store.sign.shape[0])
        sflip = _leaf_inject_batched(store.sign, _salted(seeds, 104), threshold,
                                     tuple(range(32)), interpret, model)
        valid = jnp.asarray(smasks, jnp.uint32)[:, None]
        sign = (sflip & valid) | (store.sign[None] & ~valid)
    return cim_lib.CIMStore(man=man, sign=sign, exp=exp, codewords=cw,
                            shape=store.shape, cfg=store.cfg)


def cim_inject_pytree_batched(stores, seeds, threshold,
                              interpret: Optional[bool] = None, model=None):
    """Batched ``cim.inject_pytree``: every leaf (store plane or pass-through)
    gains a leading [T] axis so the decode→eval pipeline can be vmapped."""
    t = seeds.shape[0]
    flat, treedef = jax.tree_util.tree_flatten(stores, is_leaf=cim_lib._is_store)
    out = []
    for i, leaf in enumerate(flat):
        if cim_lib._is_store(leaf):
            out.append(_store_inject_batched(leaf, _salted(seeds, 7 * i + 1),
                                             threshold, interpret, model))
        else:
            out.append(jnp.broadcast_to(leaf, (t,) + jnp.shape(leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)


class SweepEngine:
    """Batched/sharded executor for characterization grids.

    One jitted *plane function* per arm, cached across calls; each plane
    function maps (params-or-stores, per-trial randomness [B, T], bers [B])
    to accuracies [B, T] (plus ECC stats for protection sweeps) in a single
    dispatch chain. ``engine.compiles()`` exposes the per-arm compile count so
    benchmarks can assert the one-compile-per-arm contract.
    """

    MAX_CACHED_EXECUTORS = 64

    def __init__(self, plan: SweepPlan, mesh=None):
        self.plan = plan
        if plan.backend == "auto":
            self.backend = "pallas" if jax.default_backend() == "tpu" else "xla"
        else:
            self.backend = plan.backend
        self.interpret = (plan.interpret if plan.interpret is not None
                          else jax.default_backend() != "tpu")
        self._mesh = mesh
        self._mesh_built = mesh is not None
        self._executors: Dict[tuple, Callable] = {}

    # ------------------------------------------------------------- plumbing

    @property
    def mesh(self):
        if not self._mesh_built:
            self._mesh_built = True
            if self.plan.shard_trials:
                from repro.launch import mesh as mesh_lib
                self._mesh = mesh_lib.make_trial_mesh()
        return self._mesh

    def _shard_trials(self, arr, trial_axis: int = 1):
        """Place ``arr`` with its trial axis split across the mesh's "trial"
        axis (the whole mesh for the 1-D trial mesh, one axis of a 2-D
        ``("trial", "model")`` sweep mesh). The executors' outputs then
        inherit trial-sharded layouts from jit."""
        mesh = self.mesh
        if mesh is None:
            return arr
        n = int(mesh.shape["trial"]) if "trial" in mesh.axis_names \
            else int(np.prod(mesh.devices.shape))
        if arr.shape[trial_axis] % n != 0:
            return arr                       # ragged trial count: replicate
        spec = [None] * arr.ndim
        spec[trial_axis] = "trial" if "trial" in mesh.axis_names else None
        return jax.device_put(arr, NamedSharding(mesh, PartitionSpec(*spec)))

    def _shard_stores(self, stores):
        """Model-axis placement of a CIM deployment: on a 2-D sweep mesh
        (:func:`repro.launch.mesh.make_sweep_mesh`) every store's packed
        planes are column-sharded over "model" — one Fig. 6 arm then spans
        trials x macro column groups, the whole mesh. Stores that do not
        split evenly stay replicated (``shard_store`` degrades per plane)."""
        mesh = self.mesh
        if mesh is None or "model" not in mesh.axis_names:
            return stores
        return jax.tree_util.tree_map(
            lambda s: cim_lib.shard_store(s, mesh, axis="model", dim="j")
            if cim_lib._is_store(s) else s,
            stores, is_leaf=cim_lib._is_store)

    def _executor(self, cache_key, build: Callable):
        # Keys include id(eval_fn); the cached plane closes over eval_fn so
        # ids stay unique while cached. Evict oldest arms beyond the bound so
        # a long-lived engine fed fresh eval_fn closures cannot grow (and pin
        # eval data) without limit.
        if cache_key not in self._executors:
            while len(self._executors) >= self.MAX_CACHED_EXECUTORS:
                self._executors.pop(next(iter(self._executors)))
            self._executors[cache_key] = build()
        return self._executors[cache_key]

    def compiles(self) -> Dict[tuple, int]:
        """Per-arm jit cache sizes (1 == the one-compile-per-arm contract)."""
        out = {}
        for k, fn in self._executors.items():
            out[k] = int(fn._cache_size()) if hasattr(fn, "_cache_size") else -1
        return out

    def _trial_randomness(self, key, n_bers: int, backend: str = None):
        """(carried key, per-trial randomness [B, T, ...]) for one arm."""
        t = self.plan.n_trials
        if (backend or self.backend) == "pallas":
            key, sub = jax.random.split(key)
            seeds = jax.random.bits(sub, (n_bers, t), jnp.uint32)
            return key, self._shard_trials(seeds)
        key, subs = _split_schedule(key, n_bers * t)
        subs = subs.reshape((n_bers, t) + subs.shape[1:])
        return key, self._shard_trials(subs)

    # ------------------------------------------------------- Fig. 2 sweeps

    def _field_backend(self, fault_model: str) -> str:
        """Per-arm backend of a Fig. 2 field sweep: the XLA ``jax.random``
        path has no counter-PRNG streams to compile a structured process
        onto, so non-i.i.d. arms route through the batched kernel (interpret
        mode off-TPU) regardless of the engine backend."""
        return "pallas" if _arm_model(fault_model) is not None else self.backend

    def _build_field_plane(self, field: str, eval_fn: Callable,
                           fault_model: str = "iid"):
        fmt = self.plan.fmt
        fp = _arm_model(fault_model)
        if self._field_backend(fault_model) == "pallas":
            interpret = self.interpret

            def ber_step(params, seeds, ber):
                thr = fi_ops.ber_to_threshold(ber)
                corrupted = inject_pytree_batched(params, seeds, thr, field,
                                                  fmt, interpret=interpret,
                                                  model=fp)
                return jax.vmap(eval_fn)(corrupted)
        else:
            model = fault_lib.FaultModel(ber=1.0, field=field, fmt=fmt)

            def one_trial(params, k, ber):
                corrupted = fault_lib.inject_pytree(k, params, model,
                                                    ber_override=ber)
                return eval_fn(corrupted)

            ber_step = jax.vmap(one_trial, in_axes=(None, 0, None))

        @jax.jit
        def plane(params, randomness, bers):
            return jax.lax.map(lambda rb: ber_step(params, rb[0], rb[1]),
                               (randomness, bers))
        return plane

    def run_fields(self, key, params, eval_fn: Callable) -> List[SweepResult]:
        """Fig. 2: per-field sensitivity, whole (BER × trial) plane per field
        (× fault-model arm when the plan sweeps the process axis)."""
        plan = self.plan
        bers_arr = jnp.asarray(plan.bers, jnp.float32)
        results = []
        for fm_spec in plan.fault_models:
            for field in plan.fields:
                key, rand = self._trial_randomness(
                    key, len(plan.bers), self._field_backend(fm_spec))
                plane = self._executor(
                    ("fields", field, fm_spec, self.backend, id(eval_fn)),
                    lambda: self._build_field_plane(field, eval_fn, fm_spec))
                accs = np.asarray(jax.device_get(plane(params, rand, bers_arr)))
                for i, ber in enumerate(plan.bers):
                    results.append(SweepResult(ber, field, "raw",
                                               [float(a) for a in accs[i]],
                                               fault_model=fm_spec))
        return results

    # ------------------------------------------------------- Fig. 6 sweeps

    def _build_protect_plane(self, eval_fn: Callable,
                             fault_model: str = "iid"):
        fp = _arm_model(fault_model)
        if self.backend == "pallas":
            interpret = self.interpret

            def ber_step(stores, seeds, ber):
                thr = fi_ops.ber_to_threshold(ber)
                batched = cim_inject_pytree_batched(stores, seeds, thr,
                                                    interpret, model=fp)

                def decode_eval(st):
                    restored, stats = cim_lib.read_pytree_impl(st)
                    return eval_fn(restored), stats
                return jax.vmap(decode_eval)(batched)
        else:
            def one_trial(stores, k, ber):
                faulty = cim_lib.inject_pytree_impl(k, stores, ber, model=fp)
                restored, stats = cim_lib.read_pytree_impl(faulty)
                return eval_fn(restored), stats

            ber_step = jax.vmap(one_trial, in_axes=(None, 0, None))

        @jax.jit
        def plane(stores, randomness, bers):
            return jax.lax.map(lambda rb: ber_step(stores, rb[0], rb[1]),
                               (randomness, bers))
        return plane

    def run_protection(self, key, params, eval_fn: Callable,
                       cim_cfg: Optional[cim_lib.CIMConfig] = None
                       ) -> List[SweepResult]:
        """Fig. 6: accuracy vs BER per protection arm on the CIM deployment
        (× fault-model arm when the plan sweeps the process axis)."""
        plan = self.plan
        bers_arr = jnp.asarray(plan.bers, jnp.float32)
        results = []
        for fm_spec in plan.fault_models:
            for protect in plan.protects:
                cfg = dataclasses.replace(cim_cfg or cim_lib.CIMConfig(),
                                          protect=protect)
                stores, _ = cim_lib.deploy_pytree_impl(params, cfg)
                stores = self._shard_stores(stores)
                key, rand = self._trial_randomness(key, len(plan.bers))
                plane = self._executor(
                    ("protect", protect, fm_spec, self.backend, id(eval_fn)),
                    lambda: self._build_protect_plane(eval_fn, fm_spec))
                accs, stats = plane(stores, rand, bers_arr)
                accs = np.asarray(jax.device_get(accs))
                corr = np.asarray(jax.device_get(stats["corrected"]),
                                  np.float64)
                unc = np.asarray(jax.device_get(stats["uncorrectable"]),
                                 np.float64)
                for i, ber in enumerate(plan.bers):
                    results.append(SweepResult(
                        ber, "exponent_sign+mantissa", protect,
                        [float(a) for a in accs[i]],
                        float(corr[i].mean()), float(unc[i].mean()),
                        fault_model=fm_spec))
        return results

    # ------------------------------------------------- policy (mixed) sweeps

    def _build_policy_plane(self, dep, eval_fn: Callable):
        """One compiled (BER x trial) plane for a policy arm.

        The inject route is the packed counter-PRNG jnp path
        (``CIMDeployment.inject``) — per-leaf rules carry their own field and
        BER scale, which the uniform-threshold batched kernel cannot express;
        it is fully vmappable so the one-compile-per-arm contract holds on
        every backend.
        """
        def one_trial(stores, k, ber):
            d = dep._replace_stores(stores)
            faulty = d.inject(k, ber)
            restored, stats = faulty.read()
            return eval_fn(restored), stats

        ber_step = jax.vmap(one_trial, in_axes=(None, 0, None))

        @jax.jit
        def plane(stores, randomness, bers):
            return jax.lax.map(lambda rb: ber_step(stores, rb[0], rb[1]),
                               (randomness, bers))
        return plane

    def run_policies(self, key, params, eval_fn: Callable, policies
                     ) -> List[SweepResult]:
        """Fig. 6 arms as reliability POLICIES: each arm is a (possibly
        mixed-protection) :class:`repro.core.deployment.ReliabilityPolicy`
        deployed over the whole pytree — e.g. One4N on the unembed while MLP
        mantissas go unprotected — swept over the plan's (BER x trial) grid
        in one compiled executable per arm.

        ``policies`` is a sequence of ``(name, ReliabilityPolicy)`` pairs (or
        a dict); results carry ``protect=name``.
        """
        from repro.core import deployment as dep_lib
        plan = self.plan
        if isinstance(policies, dict):
            policies = list(policies.items())
        bers_arr = jnp.asarray(plan.bers, jnp.float32)
        results = []
        for name, policy in policies:
            if not isinstance(policy, dep_lib.ReliabilityPolicy):
                raise TypeError(f"arm {name!r}: expected ReliabilityPolicy, "
                                f"got {type(policy).__name__}")
            dep = dep_lib.CIMDeployment.deploy(params, policy)
            arm_bits = dep.bit_cost()["stored_bits"]
            dep = dep._replace_stores(self._shard_stores(dep.stores))
            key, subs = _split_schedule(key, len(plan.bers) * plan.n_trials)
            rand = self._shard_trials(
                subs.reshape((len(plan.bers), plan.n_trials) + subs.shape[1:]))
            # the plane closes over the deployment's per-leaf rule/path table
            # (dep._replace_stores), so the cache key must carry it: a second
            # params pytree with the same arm name must not inherit the first
            # deployment's leaf->rule assignment
            plane = self._executor(
                ("policy", name, policy, dep.rules, dep.paths, id(eval_fn)),
                lambda: self._build_policy_plane(dep, eval_fn))
            accs, stats = plane(dep.stores, rand, bers_arr)
            accs = np.asarray(jax.device_get(accs))
            corr = np.asarray(jax.device_get(stats["corrected"]), np.float64)
            unc = np.asarray(jax.device_get(stats["uncorrectable"]), np.float64)
            for i, ber in enumerate(plan.bers):
                results.append(SweepResult(
                    ber, "policy", name, [float(a) for a in accs[i]],
                    float(corr[i].mean()), float(unc[i].mean()),
                    stored_bits=arm_bits))
        return results
