"""Fault-injection framework for FP DNN weights (paper §III-A).

Implements the paper's two injection modes on arbitrary weight pytrees:

* **static injection** — flip bits once in the deployed weights (inference on a
  CIM macro whose SRAM cells hold the model).
* **dynamic injection** — flip fresh bits on *every access* (training, where
  weights are re-read each step and soft errors recur).

Faults are i.i.d. Bernoulli(BER) per *stored bit*, restricted to a field of the
FP representation: ``sign`` / ``exponent`` / ``mantissa`` / ``full`` (and
``exponent_sign``, the One4N-protected payload). This mirrors Fig. 2's
per-field characterization axes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops
from repro.core.bitops import FP16, FloatFormat


def field_flip_mask(key: jax.Array, shape, ber: float, field: str,
                    fmt: FloatFormat = FP16) -> jnp.ndarray:
    """XOR mask (uint) with each bit of ``field`` set i.i.d. w.p. ``ber``."""
    positions = fmt.field_bit_positions(field)
    flips = jax.random.bernoulli(key, ber, tuple(shape) + (len(positions),))
    weights = jnp.asarray((1 << positions.astype(np.int64)), jnp.uint32)
    mask = jnp.sum(flips.astype(jnp.uint32) * weights, axis=-1)
    return mask.astype(fmt.uint_dtype)


def inject(key: jax.Array, x: jnp.ndarray, ber: float, field: str = "full",
           fmt: FloatFormat = FP16) -> jnp.ndarray:
    """Flip bits of ``x``'s ``fmt`` representation at rate ``ber`` in ``field``.

    ``x`` may be float32 storage of fp16-grid values; the result is returned in
    ``x``'s original dtype (values exactly on the fmt grid).
    """
    if isinstance(ber, (int, float)) and ber <= 0.0:
        return x
    bits = bitops.to_bits(x, fmt)
    mask = field_flip_mask(key, x.shape, ber, field, fmt)
    corrupted = bitops.from_bits(bits ^ mask, fmt)
    return jnp.asarray(corrupted, x.dtype)


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Configuration of the memory-error model.

    ber:     bit error rate (probability of a stored bit flipping per access).
    field:   which FP field faults land in (characterization axis).
    fmt:     stored number format (paper: fp16).
    mode:    'static' (inject once into deployed weights) or
             'dynamic' (fresh faults every weight access / train step).
    """

    ber: float = 0.0
    field: str = "full"
    fmt: FloatFormat = FP16
    mode: str = "static"

    def is_active(self) -> bool:
        return self.ber > 0.0


def _is_injectable(path: tuple, leaf) -> bool:
    """Weights (>=2-D float leaves) live in the CIM macro; vectors (norm scales,
    biases, decay parameters) live in protected register files per DESIGN.md."""
    return hasattr(leaf, "ndim") and leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating)


def inject_pytree(key: jax.Array, params, model: FaultModel,
                  predicate=_is_injectable, ber_override=None):
    """Static/dynamic injection over every injectable leaf of a pytree.

    ``ber_override`` may be a traced scalar (jit-able BER sweeps)."""
    if ber_override is None and not model.is_active():
        return params
    ber = model.ber if ber_override is None else ber_override
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    keys = jax.random.split(key, len(leaves_with_paths))

    flat, treedef = jax.tree_util.tree_flatten(params)
    paths = [p for p, _ in leaves_with_paths]
    out = []
    for k, path, leaf in zip(keys, paths, flat):
        if predicate(path, leaf):
            out.append(inject(k, leaf, ber, model.field, model.fmt))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def expected_flips(n_values: int, ber: float, field: str, fmt: FloatFormat = FP16) -> float:
    """E[#flipped bits] — used by tests and the characterization report."""
    return float(n_values) * len(fmt.field_bit_positions(field)) * ber
