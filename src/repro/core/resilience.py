"""Resilience characterization harness (paper §III-A / Fig. 2 / Fig. 6).

Drives repeated fault-injection trials over a BER sweep and reports accuracy
statistics per (BER, field, protection) cell — the experiment grid behind the
paper's 24,000-run characterization, sized down by ``n_trials``.

The (inject -> eval) pipeline is jitted ONCE per field/protection arm with the
BER as a *dynamic* scalar, so a full sweep costs one compile per arm instead
of one per (BER, trial).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim as cim_lib
from repro.core import fault as fault_lib
from repro.core.bitops import FP16


@dataclasses.dataclass
class SweepResult:
    ber: float
    field: str
    protect: str            # 'raw' (plain tensors), 'none' (CIM unprotected), 'one4n'
    accuracies: List[float]
    corrected: float = 0.0
    uncorrectable: float = 0.0

    @property
    def mean(self) -> float:
        return float(np.mean(self.accuracies))

    @property
    def std(self) -> float:
        return float(np.std(self.accuracies))


def characterize_fields(key, params, eval_fn: Callable, bers: Sequence[float],
                        fields: Sequence[str] = ("sign", "exponent", "mantissa", "full"),
                        n_trials: int = 10, fmt=FP16) -> List[SweepResult]:
    """Fig. 2: per-field sensitivity of plain FP weights (static injection).

    ``eval_fn(params) -> scalar accuracy`` must be jit-compatible."""
    results = []
    for field in fields:
        @jax.jit
        def trial(key, ber, field=field):
            model = fault_lib.FaultModel(ber=1.0, field=field, fmt=fmt)
            corrupted = fault_lib.inject_pytree(key, params, model,
                                                ber_override=ber)
            return eval_fn(corrupted)

        for ber in bers:
            accs = []
            for t in range(n_trials):
                key, sub = jax.random.split(key)
                accs.append(float(trial(sub, jnp.float32(ber))))
            results.append(SweepResult(ber, field, "raw", accs))
    return results


def characterize_protection(key, params, eval_fn: Callable, bers: Sequence[float],
                            cim_cfg: Optional[cim_lib.CIMConfig] = None,
                            n_trials: int = 10,
                            protects: Sequence[str] = ("none", "one4n")) -> List[SweepResult]:
    """Fig. 6: accuracy vs BER with/without One4N (optionally also the
    Table III "traditional" per-weight SECDED arm) on the CIM deployment."""
    results = []
    for protect in protects:
        cfg = dataclasses.replace(cim_cfg or cim_lib.CIMConfig(), protect=protect)
        stores, _ = cim_lib.deploy_pytree(params, cfg)

        @jax.jit
        def trial(key, ber, stores=stores):
            faulty = cim_lib.inject_pytree(key, stores, ber)
            restored, stats = cim_lib.read_pytree(faulty)
            return eval_fn(restored), stats

        for ber in bers:
            accs, corr, unc = [], 0.0, 0.0
            for t in range(n_trials):
                key, sub = jax.random.split(key)
                acc, stats = trial(sub, jnp.float32(ber))
                accs.append(float(acc))
                corr += float(stats["corrected"])
                unc += float(stats["uncorrectable"])
            results.append(SweepResult(ber, "exponent_sign+mantissa", protect, accs,
                                       corr / n_trials, unc / n_trials))
    return results


def format_table(results: Sequence[SweepResult]) -> str:
    lines = ["field/protect,ber,acc_mean,acc_std,corrected,uncorrectable"]
    for r in results:
        tag = r.field if r.protect == "raw" else r.protect
        lines.append(f"{tag},{r.ber:.1e},{r.mean:.4f},{r.std:.4f},"
                     f"{r.corrected:.1f},{r.uncorrectable:.1f}")
    return "\n".join(lines)
