"""Resilience characterization harness (paper §III-A / Fig. 2 / Fig. 6).

Drives repeated fault-injection trials over a BER sweep and reports accuracy
statistics per (BER, field, protection) cell — the experiment grid behind the
paper's 24,000-run characterization, sized down by ``n_trials``.

``characterize_fields`` / ``characterize_protection`` are thin wrappers over
the vectorized :class:`repro.core.sweep.SweepEngine`, which evaluates each
arm's whole (BER × trial) plane in one compiled executable (vmap over trials,
``lax.map`` over the BER vector, trial axis sharded across devices). The
original per-trial loop harness is kept as ``characterize_fields_loop`` /
``characterize_protection_loop`` — it is the PRNG-stream reference the engine
must match (see ``tests/test_sweep.py``) and the baseline that
``benchmarks/sweep_bench.py`` measures speedup against.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cim as cim_lib
from repro.core import fault as fault_lib
from repro.core import sweep as sweep_lib
from repro.core.bitops import FP16
from repro.core.sweep import SweepResult  # noqa: F401  (re-export, stable API)


def characterize_fields(key, params, eval_fn: Callable, bers: Sequence[float],
                        fields: Sequence[str] = ("sign", "exponent", "mantissa", "full"),
                        n_trials: int = 10, fmt=FP16,
                        engine: Optional[sweep_lib.SweepEngine] = None,
                        fault_models: Sequence[str] = ("iid",)
                        ) -> List[SweepResult]:
    """Fig. 2: per-field sensitivity of plain FP weights (static injection).

    ``eval_fn(params) -> scalar accuracy`` must be jit-compatible. Pass a
    prebuilt ``engine`` to reuse its compiled executors across calls; its plan
    must describe the same grid as the explicit arguments. ``fault_models``
    adds an error-process axis (:mod:`repro.core.faultmodels` grammar): the
    grid runs once per process arm."""
    if engine is None:
        plan = sweep_lib.SweepPlan(bers=tuple(bers), n_trials=n_trials,
                                   fields=tuple(fields), fmt=fmt,
                                   fault_models=tuple(fault_models))
        engine = sweep_lib.SweepEngine(plan)
    else:
        _check_engine_grid(engine, bers=tuple(float(b) for b in bers),
                           n_trials=n_trials, fields=tuple(fields), fmt=fmt,
                           fault_models=tuple(str(m) for m in fault_models))
    return engine.run_fields(key, params, eval_fn)


def characterize_protection(key, params, eval_fn: Callable, bers: Sequence[float],
                            cim_cfg: Optional[cim_lib.CIMConfig] = None,
                            n_trials: int = 10,
                            protects: Sequence[str] = ("none", "one4n"),
                            engine: Optional[sweep_lib.SweepEngine] = None,
                            fault_models: Sequence[str] = ("iid",)
                            ) -> List[SweepResult]:
    """Fig. 6: accuracy vs BER with/without One4N (optionally also the
    Table III "traditional" per-weight SECDED arm) on the CIM deployment.
    ``fault_models`` adds an error-process axis (one full grid per arm)."""
    if engine is None:
        plan = sweep_lib.SweepPlan(bers=tuple(bers), n_trials=n_trials,
                                   protects=tuple(protects),
                                   fault_models=tuple(fault_models))
        engine = sweep_lib.SweepEngine(plan)
    else:
        _check_engine_grid(engine, bers=tuple(float(b) for b in bers),
                           n_trials=n_trials, protects=tuple(protects),
                           fault_models=tuple(str(m) for m in fault_models))
    return engine.run_protection(key, params, eval_fn, cim_cfg)


def characterize_policies(key, params, eval_fn: Callable, bers: Sequence[float],
                          policies, n_trials: int = 10,
                          engine: Optional[sweep_lib.SweepEngine] = None
                          ) -> List[SweepResult]:
    """Fig. 6 arms as per-layer reliability POLICIES (mixed protection).

    ``policies`` is a dict or sequence of ``(name, ReliabilityPolicy)``: each
    arm deploys the whole pytree under its policy
    (:class:`repro.core.deployment.CIMDeployment`) — e.g. One4N on the
    unembed while MLP mantissas go unprotected — and sweeps the (BER x
    trial) plane in one compiled executable per arm. ``results[i].protect``
    carries the arm name."""
    if engine is None:
        plan = sweep_lib.SweepPlan(bers=tuple(bers), n_trials=n_trials)
        engine = sweep_lib.SweepEngine(plan)
    else:
        _check_engine_grid(engine, bers=tuple(float(b) for b in bers),
                           n_trials=n_trials)
    return engine.run_policies(key, params, eval_fn, policies)


def search_policies(params, eval_fn: Callable, ber: float, groups,
                    max_drop: float = 0.02, n_trials: int = 3, key=None,
                    **space_kw):
    """One-call co-design policy search: the cheapest per-layer protection
    (by deployed ``stored_bits``) whose mean accuracy at ``ber`` stays within
    ``max_drop`` of clean. ``groups`` is the ordered ``(name, pattern)``
    grammar of :class:`repro.training.codesign.SearchSpace`; extra kwargs
    (``protects``, ``fields``, ``n_groups``, ``default``) refine the grid.
    Returns a :class:`repro.training.codesign.SearchResult`. For staged /
    resumable searches use :class:`repro.training.codesign.PolicySearch`
    directly."""
    from repro.training.codesign import AccuracySLO, PolicySearch, SearchSpace
    space = SearchSpace(groups=tuple(groups), **space_kw)
    slo = AccuracySLO(ber=ber, max_drop=max_drop)
    return PolicySearch(params, eval_fn, slo, space, n_trials=n_trials,
                        key=key).search()


def _check_engine_grid(engine: sweep_lib.SweepEngine, **expected) -> None:
    """A prebuilt engine runs ITS plan's grid — refuse silently diverging
    explicit arguments instead of ignoring them."""
    for name, want in expected.items():
        got = getattr(engine.plan, name)
        if got != want:
            raise ValueError(
                f"engine.plan.{name}={got!r} conflicts with explicit "
                f"argument {name}={want!r}; build the engine from a matching "
                f"SweepPlan or drop the explicit argument")


# ---------------------------------------------------------------------------
# Loop-based reference harness: one jitted device call per (BER, trial) cell.
# Kept as the PRNG-stream oracle for the vectorized engine and as the
# benchmark baseline; do not use for large grids.
# ---------------------------------------------------------------------------

def characterize_fields_loop(key, params, eval_fn: Callable, bers: Sequence[float],
                             fields: Sequence[str] = ("sign", "exponent", "mantissa", "full"),
                             n_trials: int = 10, fmt=FP16) -> List[SweepResult]:
    results = []
    for field in fields:
        @jax.jit
        def trial(key, ber, field=field):
            model = fault_lib.FaultModel(ber=1.0, field=field, fmt=fmt)
            corrupted = fault_lib.inject_pytree(key, params, model,
                                                ber_override=ber)
            return eval_fn(corrupted)

        for ber in bers:
            accs = []
            for t in range(n_trials):
                key, sub = jax.random.split(key)
                accs.append(float(trial(sub, jnp.float32(ber))))
            results.append(SweepResult(ber, field, "raw", accs))
    return results


def characterize_protection_loop(key, params, eval_fn: Callable, bers: Sequence[float],
                                 cim_cfg: Optional[cim_lib.CIMConfig] = None,
                                 n_trials: int = 10,
                                 protects: Sequence[str] = ("none", "one4n")
                                 ) -> List[SweepResult]:
    results = []
    for protect in protects:
        cfg = dataclasses.replace(cim_cfg or cim_lib.CIMConfig(), protect=protect)
        stores, _ = cim_lib.deploy_pytree_impl(params, cfg)

        @jax.jit
        def trial(key, ber, stores=stores):
            faulty = cim_lib.inject_pytree_impl(key, stores, ber)
            restored, stats = cim_lib.read_pytree_impl(faulty)
            return eval_fn(restored), stats

        for ber in bers:
            accs, corr, unc = [], 0.0, 0.0
            for t in range(n_trials):
                key, sub = jax.random.split(key)
                acc, stats = trial(sub, jnp.float32(ber))
                accs.append(float(acc))
                corr += float(stats["corrected"])
                unc += float(stats["uncorrectable"])
            results.append(SweepResult(ber, "exponent_sign+mantissa", protect, accs,
                                       corr / n_trials, unc / n_trials))
    return results


def format_table(results: Sequence[SweepResult]) -> str:
    lines = ["field/protect,ber,acc_mean,acc_std,corrected,uncorrectable"]
    for r in results:
        tag = r.field if r.protect == "raw" else r.protect
        lines.append(f"{tag},{r.ber:.1e},{r.mean:.4f},{r.std:.4f},"
                     f"{r.corrected:.1f},{r.uncorrectable:.1f}")
    return "\n".join(lines)
