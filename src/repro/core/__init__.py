# The paper's primary contribution: FP fault injection, exponent alignment,
# One4N ECC, and bit-accurate CIM weight-memory emulation.
from repro.core import align, api, bitops, cim, ecc, fault, resilience, sweep  # noqa: F401
from repro.core.api import ReliabilityConfig  # noqa: F401
