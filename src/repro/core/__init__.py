# The paper's primary contribution: FP fault injection, exponent alignment,
# One4N ECC, bit-accurate CIM weight-memory emulation, and the unified
# policy-driven deployment surface.
from repro.core import (align, api, bitops, cim, deployment, ecc, fault,  # noqa: F401
                        resilience, sweep)
from repro.core.api import ReliabilityConfig  # noqa: F401
from repro.core.deployment import (CIMDeployment, PolicyRule,  # noqa: F401
                                   ReliabilityPolicy)
