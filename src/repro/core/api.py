"""Framework-level reliability configuration (first-class feature surface).

``ReliabilityConfig`` is carried by every training/serving config in the
framework; the launcher wires it into the optimizer (frozen-exponent
projection), the weight path (CIM emulation + ECC) and the fault scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.align import AlignmentConfig
from repro.core.bitops import FORMATS, FP16
from repro.core.cim import CIMConfig
from repro.core.fault import FaultModel


@dataclasses.dataclass(frozen=True)
class ReliabilityConfig:
    """mode:
         'off'   — vanilla training/serving;
         'align' — exponent-aligned weights + frozen-exponent fine-tuning
                   (paper §III-C algorithm side; used at dry-run scale);
         'cim'   — 'align' + bit-accurate CIM store emulation with fault
                   injection and (optional) One4N ECC on every weight read.
    """

    mode: str = "off"                 # off | align | cim
    n_group: int = 8                  # N
    index: int = 2                    # exponent rank (1-based)
    protect: str = "one4n"            # one4n | none  (cim mode)
    ber: float = 0.0                  # bit error rate of the emulated SRAM
    field: str = "full"               # fault target field
    inject: str = "dynamic"           # static | dynamic
    fmt_name: str = "fp16"
    serve_path: str = "fused"         # fused  — serve straight from the packed
                                      #          SRAM image (decode-on-read
                                      #          kernels, no fp16 weight
                                      #          matrices in HBM);
                                      # hbm    — decode once, serve fp16 copies

    @property
    def fmt(self):
        return FORMATS[self.fmt_name]

    @property
    def align_cfg(self) -> AlignmentConfig:
        return AlignmentConfig(n_group=self.n_group, index=self.index, fmt=self.fmt)

    @property
    def cim_cfg(self) -> CIMConfig:
        return CIMConfig(n_group=self.n_group, index=self.index,
                         protect=self.protect, fmt=self.fmt)

    @property
    def fault_model(self) -> FaultModel:
        return FaultModel(ber=self.ber, field=self.field, fmt=self.fmt,
                          mode=self.inject)

    @property
    def residual_exp_ber(self) -> float:
        """Closed-form post-ECC exponent/sign BER of the active codec (the
        launcher's dynamic-injection rate; raw BER when unprotected)."""
        from repro.core.ecc import residual_ber_after_secded
        if self.protect == "one4n":
            return residual_ber_after_secded(self.ber, codec=self.cim_cfg.codec)
        return self.ber

    def enabled(self) -> bool:
        return self.mode != "off"
