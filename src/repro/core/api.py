"""Framework-level reliability configuration (first-class feature surface).

``ReliabilityConfig`` is carried by every training/serving config in the
framework; the launcher wires it into the optimizer (frozen-exponent
projection), the weight path (CIM emulation + ECC) and the fault scheduler.

Since the unified deployment API (:mod:`repro.core.deployment`), this config
is a thin **single-rule policy factory**: ``.policy`` compiles it into a
uniform :class:`~repro.core.deployment.ReliabilityPolicy` — one rule, every
leaf — which is exactly what the legacy one-global-``CIMConfig`` surface
could express. Heterogeneous per-layer protection is written directly as a
multi-rule policy and handed to ``CIMDeployment.deploy``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.align import AlignmentConfig
from repro.core.bitops import FORMATS, FP16
from repro.core.cim import CIMConfig
from repro.core.fault import FaultModel

# fault.FaultModel accepts the per-field characterization axes on top of the
# CIM cell classes (Fig. 2 sweeps go through the same config surface)
_FAULT_FIELDS = ("full", "mantissa", "exponent_sign", "sign", "exponent")


@dataclasses.dataclass(frozen=True)
class ReliabilityConfig:
    """mode:
         'off'   — vanilla training/serving;
         'align' — exponent-aligned weights + frozen-exponent fine-tuning
                   (paper §III-C algorithm side; used at dry-run scale);
         'cim'   — 'align' + bit-accurate CIM store emulation with fault
                   injection and (optional) One4N ECC on every weight read.
    """

    mode: str = "off"                 # off | align | cim
    n_group: int = 8                  # N
    index: int = 2                    # exponent rank (1-based)
    protect: str = "one4n"            # one4n | per_weight | none  (cim mode)
    ber: float = 0.0                  # bit error rate of the emulated SRAM
    field: str = "full"               # fault target field
    inject: str = "dynamic"           # static | dynamic
    fmt_name: str = "fp16"
    serve_path: str = "fused"         # fused  — serve straight from the packed
                                      #          SRAM image (decode-on-read
                                      #          kernels, no fp16 weight
                                      #          matrices in HBM);
                                      # hbm    — decode once, serve fp16 copies
    policy_override: Optional[object] = None
                                      # a full ReliabilityPolicy for per-layer
                                      # protection; when set, `.policy` (and
                                      # therefore the training fault schedule)
                                      # uses it instead of the uniform
                                      # single-rule bridge built from the
                                      # scalar fields above

    def __post_init__(self):
        # Fail on typos ("one4N", "dynamyc") at construction with the allowed
        # vocabulary, not deep inside cim.py once a store is half-built.
        from repro.core import deployment as dep_lib
        where = "ReliabilityConfig"
        dep_lib.check_enum("mode", self.mode, dep_lib.VALID_MODES, where)
        dep_lib.check_enum("protect", self.protect, dep_lib.VALID_PROTECTS,
                           where)
        dep_lib.check_enum("field", self.field, _FAULT_FIELDS, where)
        dep_lib.check_enum("inject", self.inject, dep_lib.VALID_INJECTS, where)
        dep_lib.check_enum("serve_path", self.serve_path,
                           dep_lib.VALID_SERVE_PATHS, where)
        dep_lib.check_enum("fmt_name", self.fmt_name, tuple(FORMATS), where)
        if self.ber < 0:
            raise ValueError(f"{where}: ber must be >= 0, got {self.ber}")
        if self.policy_override is not None and \
                not isinstance(self.policy_override, dep_lib.ReliabilityPolicy):
            raise TypeError(f"{where}: policy_override must be a "
                            f"ReliabilityPolicy, got "
                            f"{type(self.policy_override).__name__}")

    @classmethod
    def from_policy(cls, policy, ber: float = 0.0,
                    inject: str = "dynamic") -> "ReliabilityConfig":
        """Compile a :class:`ReliabilityPolicy` into a ``ReliabilityConfig``
        (the policy-native training path, ``RunConfig.policy``).

        A **uniform** policy (no per-layer rules) whose default rule carries
        legacy semantics (``field='full'``, ``ber_scale=1``) maps onto the
        scalar fields with ``policy_override`` unset — the training fault
        schedule then takes the legacy uniform branch, so the key/stream
        schedule is bit-identical to the equivalent hand-built config. Any
        other policy rides in ``policy_override`` unchanged (the rule-honoring
        branch applies its field restrictions and BER scales per leaf).
        """
        from repro.core import deployment as dep_lib
        if not isinstance(policy, dep_lib.ReliabilityPolicy):
            raise TypeError(f"from_policy: expected ReliabilityPolicy, got "
                            f"{type(policy).__name__}")
        d = policy.default
        legacy = policy.uniform and d.field == "full" and d.ber_scale == 1.0
        return cls(mode="cim", n_group=d.n_group, index=d.index,
                   protect=d.protect, ber=ber, field=d.field, inject=inject,
                   fmt_name=d.fmt_name, serve_path=d.serve_path,
                   policy_override=None if legacy else policy)

    @property
    def fmt(self):
        return FORMATS[self.fmt_name]

    @property
    def align_cfg(self) -> AlignmentConfig:
        return AlignmentConfig(n_group=self.n_group, index=self.index, fmt=self.fmt)

    @property
    def cim_cfg(self) -> CIMConfig:
        return CIMConfig(n_group=self.n_group, index=self.index,
                         protect=self.protect, fmt=self.fmt)

    @property
    def policy(self):
        """The :class:`ReliabilityPolicy` of this config: ``policy_override``
        when set (per-layer rules on the standard launcher/training path),
        else the uniform single-rule bridge built from the scalar fields.
        A Fig. 2 characterization axis ('sign'/'exponent') maps to the
        'exponent_sign' cell class — sign and exponent cells share one
        stored class in the packed image — never silently widening the
        fault set onto mantissa cells."""
        from repro.core import deployment as dep_lib
        if self.policy_override is not None:
            return self.policy_override
        field = self.field if field_is_cell_class(self.field) \
            else "exponent_sign"
        rule = dep_lib.PolicyRule(
            pattern="*", deploy=True, protect=self.protect, field=field,
            n_group=self.n_group, index=self.index, fmt_name=self.fmt_name,
            serve_path=self.serve_path)
        return dep_lib.ReliabilityPolicy(rules=(), default=rule)

    @property
    def fault_model(self) -> FaultModel:
        return FaultModel(ber=self.ber, field=self.field, fmt=self.fmt,
                          mode=self.inject)

    @property
    def residual_exp_ber(self) -> float:
        """Closed-form post-ECC exponent/sign BER of the active codec (the
        launcher's dynamic-injection rate; raw BER when unprotected)."""
        from repro.core.ecc import residual_ber_after_secded
        if self.protect == "one4n":
            return residual_ber_after_secded(self.ber, codec=self.cim_cfg.codec)
        if self.protect == "per_weight":
            return residual_ber_after_secded(
                self.ber, codeword_bits=self.cim_cfg.pw_code.n)
        return self.ber

    def enabled(self) -> bool:
        return self.mode != "off"


def field_is_cell_class(field: str) -> bool:
    """Whether ``field`` names a stored-cell class of the packed image
    (mantissa plane vs exponent/sign/check cells) rather than a Fig. 2
    characterization axis."""
    from repro.core import deployment as dep_lib
    return field in dep_lib.VALID_FIELDS
