"""Unified deployment API: put a model on the emulated CIM macro, once.

Unicorn-CIM's co-design insight is that protection should be spent where
sensitivity lives — exponent bits, and by extension the layers whose exponent
distributions matter most. A :class:`ReliabilityPolicy` expresses exactly
that: an ordered list of pytree-path rules (glob or regex, first match wins,
with a default rule) mapping each weight matrix to its own protection level
(``protect`` ∈ {none, one4n, per_weight}), injection field, BER scale, number
format and grouping — so e.g. the unembed gets One4N while MLP mantissas go
unprotected, in ONE deployment.

The policy compiles into a pytree-registered :class:`CIMDeployment` that owns
the packed stores and passthrough leaves, optional mesh placement, fault
state and cumulative ECC statistics, and exposes the whole lifecycle::

    policy = ReliabilityPolicy(
        rules=(PolicyRule("unembed", protect="one4n"),
               PolicyRule("embed",   protect="per_weight"),
               PolicyRule("*mlp*",   protect="none", field="mantissa")),
        default=PolicyRule(deploy=False))
    dep = CIMDeployment.deploy(params, policy)      # align + pack per rule
    dep = dep.shard(mesh)                           # optional mesh placement
    dep = dep.inject(key, ber)                      # static soft errors
    logits = dep.linear(x, "unembed")               # auto-dispatched matmul
    restored, stats = dep.read()                    # decode + ECC stats

``linear`` dispatches automatically from the store's placement and dtype
(see :func:`dispatch_linear`):

    ==========================  =============================================
    store placement / dtype      route
    ==========================  =============================================
    mesh with a "model" axis    ``cim_linear_store_sharded`` — shard_map'd
                                fused kernel, one shard per macro column
                                group (falls through to the rows below when
                                the store cannot shard or tile)
    fp16, one4n/none            ``cim_linear_store`` — fused Pallas decode-
                                on-read kernel, packed planes straight to
                                VMEM
    per_weight / non-fp16       GSPMD reference path (packed jnp decode
                                fused by XLA into the matmul)
    rule.serve_path == 'hbm'    decode once to fp16, plain ``x @ w``
    passthrough leaf            plain ``x @ w``
    ==========================  =============================================

Counter-PRNG contract: ``CIMDeployment.inject`` splits its key across the
flat leaves of the deployment exactly like the legacy ``cim.inject_pytree``,
so a mixed-protection policy deployment is bit-identical — stores, inject
streams, decoded reads, ECC stats — to manually composing per-leaf
``deploy_pytree`` calls with the same per-rule configs (tested in
``tests/test_deployment.py``, single-device and on a forced-8-device mesh).

``cim.deploy_pytree`` / ``inject_pytree`` / ``read_pytree`` remain as
deprecation shims forwarding here.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import re
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as align_lib
from repro.core import cim as cim_lib
from repro.core import faultmodels as fm_lib
from repro.core.bitops import FORMATS

# ---------------------------------------------------------------------------
# Validated vocabularies of every enum-like policy field. A typo like
# protect="one4N" must fail at construction with a clear message, not deep
# inside cim.py.
# ---------------------------------------------------------------------------

VALID_PROTECTS = ("one4n", "per_weight", "none")
VALID_FIELDS = ("full", "mantissa", "exponent_sign")
VALID_SERVE_PATHS = ("fused", "hbm")
VALID_MODES = ("off", "align", "cim")
VALID_INJECTS = ("static", "dynamic")


def check_enum(name: str, value, allowed: Sequence[str], where: str) -> None:
    """Raise ``ValueError`` with the allowed vocabulary on a bad enum value."""
    if value not in allowed:
        raise ValueError(
            f"{where}: {name}={value!r} is not valid; expected one of "
            f"{', '.join(repr(a) for a in allowed)}")


def path_str(path) -> str:
    """A ``tree_flatten_with_path`` key path as a '/'-joined match string.

    ``{'groups': {'blk0': {'attn': {'wq': ...}}}}`` flattens to
    ``"groups/blk0/attn/wq"`` — the string policy rules glob against.
    """
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """One per-layer reliability setting, keyed by a pytree-path pattern.

    ``pattern`` is an ``fnmatch`` glob against the '/'-joined leaf path
    (``"unembed"``, ``"groups/*/attn/*"``); prefix with ``re:`` for a full
    regex (``"re:.*mlp\\.(w1|w2)"``). Matching is whole-string for globs
    unless the pattern contains no wildcard, in which case it matches any
    path *segment* equal to it (so ``"embed"`` hits ``"embed"`` but not
    ``"unembed"``).

    ``deploy=False`` makes matching leaves pass through undeployed;
    ``ber_scale`` scales the deployment-level BER for matching stores (cells
    with tighter retention margins); ``field`` restricts which stored cells
    the faults land in.
    """

    pattern: str = "*"
    deploy: bool = True
    protect: str = "one4n"           # one4n | per_weight | none
    field: str = "full"              # full | mantissa | exponent_sign
    ber_scale: float = 1.0
    n_group: int = 8
    index: int = 2
    row_weights: int = 16
    fmt_name: str = "fp16"
    serve_path: str = "fused"        # fused | hbm
    row_cache: bool = True           # fused static serving: materialize the
                                     # decoded-row cache at serving_params
                                     # time (hot full-matrix reads, e.g. the
                                     # unembed projection). Leaves served by
                                     # sparse row gathers (embed tables)
                                     # should opt out — the packed image is
                                     # the whole point there.
    fault_model: str = ""            # error process of matching stores
                                     # (repro.core.faultmodels grammar, e.g.
                                     # "burst:rate=0.3,axis=col"); "" means
                                     # the deployment-level model (i.i.d. by
                                     # default)

    def __post_init__(self):
        where = f"PolicyRule(pattern={self.pattern!r})"
        check_enum("protect", self.protect, VALID_PROTECTS, where)
        check_enum("field", self.field, VALID_FIELDS, where)
        check_enum("serve_path", self.serve_path, VALID_SERVE_PATHS, where)
        check_enum("fmt_name", self.fmt_name, tuple(FORMATS), where)
        if self.ber_scale < 0:
            raise ValueError(f"{where}: ber_scale must be >= 0, "
                             f"got {self.ber_scale}")
        fm_lib.parse_fault_model(self.fault_model)   # validate eagerly

    @property
    def fault_process(self):
        """Parsed :class:`~repro.core.faultmodels.FaultProcess` (or None)."""
        return fm_lib.parse_fault_model(self.fault_model)

    @property
    def fmt(self):
        return FORMATS[self.fmt_name]

    @property
    def cim_cfg(self) -> cim_lib.CIMConfig:
        return cim_lib.CIMConfig(n_group=self.n_group, index=self.index,
                                 protect=self.protect, fmt=self.fmt,
                                 row_weights=self.row_weights)

    @property
    def align_cfg(self) -> align_lib.AlignmentConfig:
        return align_lib.AlignmentConfig(n_group=self.n_group,
                                         index=self.index, fmt=self.fmt)

    def matches(self, leaf_path: str) -> bool:
        if self.pattern.startswith("re:"):
            return re.fullmatch(self.pattern[3:], leaf_path) is not None
        if not any(c in self.pattern for c in "*?["):
            return self.pattern == leaf_path or \
                self.pattern in leaf_path.split("/")
        return fnmatch.fnmatchcase(leaf_path, self.pattern)


@dataclasses.dataclass(frozen=True)
class ReliabilityPolicy:
    """Ordered pytree-path rules (first match wins) plus a default rule.

    The default rule catches every leaf no rule matches; a policy with no
    ``rules`` applies the default uniformly — that is exactly what the legacy
    one-global-``CIMConfig`` API could express
    (:attr:`repro.core.api.ReliabilityConfig.policy` builds it).
    """

    rules: Tuple[PolicyRule, ...] = ()
    default: PolicyRule = PolicyRule()

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        for r in tuple(self.rules) + (self.default,):
            if not isinstance(r, PolicyRule):
                raise TypeError(f"policy rules must be PolicyRule, got "
                                f"{type(r).__name__}")

    def rule_for(self, leaf_path: str) -> PolicyRule:
        for rule in self.rules:
            if rule.matches(leaf_path):
                return rule
        return self.default

    @property
    def uniform(self) -> bool:
        """True when every leaf sees the same settings (no per-layer rules)."""
        return not self.rules

    def deploy(self, params, predicate=None) -> "CIMDeployment":
        return CIMDeployment.deploy(params, self, predicate=predicate)


# single definition of leaf deployability, shared with the legacy cim shims
_deployable = cim_lib._deployable


def _zero_stats():
    return {"corrected": jnp.zeros((), jnp.int32),
            "uncorrectable": jnp.zeros((), jnp.int32)}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False)
class CIMDeployment:
    """A model deployed on the emulated macro under a reliability policy.

    Children: ``stores`` (the params pytree with deployed leaves replaced by
    packed :class:`~repro.core.cim.CIMStore`\\ s) and ``ecc_stats``
    (cumulative corrected/uncorrectable counters, accumulated by ``read``).
    Aux: the policy, the per-flat-leaf rule/path assignment, and the mesh
    placement — all hashable, so a deployment passes through ``jax.jit``.
    """

    stores: object
    ecc_stats: dict
    policy: ReliabilityPolicy
    rules: Tuple[Optional[PolicyRule], ...]   # per flat leaf; None=passthrough
    paths: Tuple[str, ...]
    placement: Optional[tuple] = None         # (mesh, axis, dim) or None

    def tree_flatten(self):
        return ((self.stores, self.ecc_stats),
                (self.policy, self.rules, self.paths, self.placement))

    @classmethod
    def tree_unflatten(cls, aux, children):
        stores, ecc_stats = children
        policy, rules, paths, placement = aux
        return cls(stores, ecc_stats, policy, rules, paths, placement)

    # ------------------------------------------------------------ lifecycle

    @classmethod
    def deploy(cls, params, policy: ReliabilityPolicy,
               predicate: Optional[Callable] = None) -> "CIMDeployment":
        """Align + pack every leaf per its first matching rule.

        A leaf is deployed when its rule says ``deploy=True``, it is a 2-D
        float matrix, and ``predicate(path, leaf)`` (if given) holds; every
        other leaf passes through untouched. Per-leaf packing is identical to
        ``cim.deploy_pytree`` with the rule's config, so mixed policies are
        bit-identical to manual per-leaf composition.
        """
        leaves_wp, treedef = jax.tree_util.tree_flatten_with_path(params)
        out, rules, paths = [], [], []
        for path, leaf in leaves_wp:
            p = path_str(path)
            rule = policy.rule_for(p)
            paths.append(p)
            if rule.deploy and _deployable(path, leaf) and \
                    (predicate is None or predicate(path, leaf)):
                w_al, _ = align_lib.align_matrix(leaf, rule.align_cfg)
                out.append(cim_lib.pack(w_al, rule.cim_cfg))
                rules.append(rule)
            else:
                out.append(leaf)
                rules.append(None)
        return cls(stores=jax.tree_util.tree_unflatten(treedef, out),
                   ecc_stats=_zero_stats(), policy=policy,
                   rules=tuple(rules), paths=tuple(paths))

    @property
    def mesh(self):
        return self.placement[0] if self.placement else None

    def _flat(self):
        return jax.tree_util.tree_flatten(self.stores,
                                          is_leaf=cim_lib._is_store)

    def _replace_stores(self, stores) -> "CIMDeployment":
        # each derived deployment owns its cumulative counters — reads on one
        # branch must not bleed into siblings or the base
        return CIMDeployment(stores, dict(self.ecc_stats), self.policy,
                             self.rules, self.paths, self.placement)

    def store_leaves(self):
        """[(path, rule, store)] of the deployed leaves, tree order."""
        flat, _ = self._flat()
        return [(p, r, s) for p, r, s in zip(self.paths, self.rules, flat)
                if cim_lib._is_store(s)]

    # ------------------------------------------------------------ fault state

    def inject(self, key, ber, field: Optional[str] = None,
               request_id: Optional[int] = None,
               model=None) -> "CIMDeployment":
        """Fresh soft errors into every store at ``ber * rule.ber_scale`` in
        the rule's ``field`` (or the ``field`` override for all stores).

        The key splits across the flat leaves exactly like the legacy
        ``cim.inject_pytree``; sharded placements route through
        ``cim.inject_sharded`` (bit-identical streams, PR-3 contract).
        ``request_id`` folds the key per serving request before the split, so
        a request-scoped static image draws the same streams no matter which
        engine slot (or co-batch) serves it.

        ``model`` (a :class:`~repro.core.faultmodels.FaultProcess` or grammar
        string) selects the error process for every store; per-rule
        ``fault_model`` settings fill in where no override is given. The
        default i.i.d. process reproduces the legacy streams bit for bit.
        """
        if field is not None:
            # a Fig. 2 axis like 'exponent' would silently inject NOTHING
            # downstream (both cim.inject threshold gates test False)
            check_enum("field", field, VALID_FIELDS, "CIMDeployment.inject")
        model = fm_lib.parse_fault_model(model)
        if request_id is not None:
            key = jax.random.fold_in(key, request_id)
        flat, treedef = self._flat()
        keys = jax.random.split(key, len(flat))
        out = []
        for k, leaf, rule in zip(keys, flat, self.rules):
            if cim_lib._is_store(leaf):
                leaf_ber = ber * rule.ber_scale
                leaf_field = field if field is not None else rule.field
                leaf_model = model if model is not None else rule.fault_process
                out.append(self._inject_one(k, leaf, leaf_ber, leaf_field,
                                            leaf_model))
            else:
                out.append(leaf)
        return self._replace_stores(jax.tree_util.tree_unflatten(treedef, out))

    def _inject_one(self, key, store, ber, field, model=None):
        if self.placement is not None:
            mesh, axis, dim = self.placement
            n_sh = int(mesh.shape[axis]) if axis in mesh.axis_names else 1
            if n_sh > 1 and cim_lib.can_shard_store(store, n_sh, dim):
                return cim_lib.inject_sharded(key, store, ber, field,
                                              mesh=mesh, axis=axis, dim=dim,
                                              model=model)
        return cim_lib.inject(key, store, ber, field, model=model)

    def runtime(self, key, ber, field: str = "full", model=None) -> dict:
        """Per-read dynamic-injection runtime (the ``_cim`` entry the serving
        model folds per leaf and per read index): base counter-PRNG plane
        seeds plus per-cell-class Bernoulli thresholds.

        ``model`` (process or grammar string) rides along as static pytree
        structure; serving reads compile it to per-element thresholds — drift
        keys its tick on the request-local read position."""
        from repro.kernels.fault_inject.ops import ber_to_threshold
        check_enum("field", field, VALID_FIELDS, "CIMDeployment.runtime")
        thr = ber_to_threshold(ber)
        zero = jnp.uint32(0)
        rt = {"seeds": cim_lib.plane_seeds(key),
              "thr_man": thr if field in ("full", "mantissa") else zero,
              "thr_meta": thr if field in ("full", "exponent_sign") else zero}
        model = fm_lib.parse_fault_model(model)
        if model is not None and model.kind != "iid":
            rt["model"] = model
        return rt

    # ------------------------------------------------------------ read paths

    def _accumulate(self, stats) -> None:
        # Cumulative ECC accounting. Eager calls fold into the running
        # counters in place; under a trace the counters cannot absorb tracer
        # values, so traced reads simply return their stats to the caller.
        if any(isinstance(v, jax.core.Tracer) for v in stats.values()) or \
                any(isinstance(v, jax.core.Tracer)
                    for v in self.ecc_stats.values()):
            return
        for k_ in ("corrected", "uncorrectable"):
            self.ecc_stats[k_] = self.ecc_stats[k_] + stats[k_]

    def read(self):
        """Decode every store -> (params pytree, {'corrected','uncorrectable'}).

        Eager reads also fold the stats into the deployment's cumulative
        ``ecc_stats`` counters."""
        flat, treedef = self._flat()
        out, stats = [], _zero_stats()
        for leaf in flat:
            if cim_lib._is_store(leaf):
                w, st = cim_lib.read(leaf)
                out.append(w)
                stats = {k_: stats[k_] + st[k_] for k_ in stats}
            else:
                out.append(leaf)
        self._accumulate(stats)
        return jax.tree_util.tree_unflatten(treedef, out), stats

    def stats(self) -> dict:
        """Aggregate ECC status counts without reconstructing any weights."""
        agg = _zero_stats()
        for _, _, s in self.store_leaves():
            st = cim_lib.store_stats(s)
            agg = {k_: agg[k_] + st[k_] for k_ in agg}
        return agg

    def _leaf(self, path: str):
        for i, p in enumerate(self.paths):
            if p == path:
                return self._flat()[0][i], self.rules[i]
        raise KeyError(f"no leaf at path {path!r}; deployment has "
                       f"{sorted(self.paths)}")

    def read_rows(self, idx, path: str = "embed", *, seeds=None, thr_man=0,
                  thr_meta=0, model=None):
        """Decode-on-read row gather of the store at ``path`` (embedding
        serving: only the gathered rows' codewords are decoded). ``seeds``
        (see ``cim.plane_seeds``) turns on per-read dynamic injection;
        ``model`` shapes it into a structured error process."""
        leaf, _ = self._leaf(path)
        if not cim_lib._is_store(leaf):
            return jnp.asarray(leaf, jnp.float32)[idx]
        return cim_lib.read_rows(leaf, idx, seeds=seeds, thr_man=thr_man,
                                 thr_meta=thr_meta, model=model)

    def linear(self, x, path: str, *, scalars=None, request=None, runtime=None,
               with_info: bool = False, model=None):
        """``x [..., K] @ leaf(path) -> [..., J]``, route auto-dispatched.

        A passthrough leaf is a plain matmul. A store follows the module
        dispatch table (:func:`dispatch_linear`) — fused Pallas, sharded
        shard_map, or the GSPMD reference — except when its rule pins
        ``serve_path='hbm'``, which decodes once and matmuls the fp16 copy
        (stats fold into the cumulative ECC counters on eager calls).

        ``request=(req_salt, pos)`` with a ``runtime`` (see :meth:`runtime`)
        derives per-request dynamic-injection scalars for this read —
        counter-PRNG streams keyed by (leaf, request, read index), the
        serving engine's batch-invariance contract. Mutually exclusive with
        an explicit ``scalars`` vector.
        """
        if request is not None:
            if scalars is not None:
                raise ValueError(
                    f"linear({path!r}): pass either scalars= or request=, "
                    f"not both")
            if runtime is None:
                raise ValueError(
                    f"linear({path!r}): request= needs the runtime= dict "
                    f"(see CIMDeployment.runtime)")
            from repro.kernels.cim_read import ops as cr_ops
            req_salt, pos = request
            seeds = request_read_seeds(runtime["seeds"], leaf_salt(path),
                                       req_salt, pos)
            model = runtime.get("model")
            # drift keys its tick on the request-local read position; the
            # thresholds absorb the time scaling here, so the model handed
            # downstream carries tick=0 (no double scaling)
            thr_man = fm_lib.compiled_threshold(model, runtime["thr_man"],
                                                tick=pos)
            thr_meta = fm_lib.compiled_threshold(model, runtime["thr_meta"],
                                                 tick=pos)
            if model is not None and model.kind == "drift":
                model = dataclasses.replace(model, tick=0)
            scalars = cr_ops.make_scalars(seeds, thr_man, thr_meta,
                                          model=model)
        leaf, rule = self._leaf(path)
        if not cim_lib._is_store(leaf):
            if scalars is not None:
                raise ValueError(
                    f"linear({path!r}): scalars (per-read dynamic injection) "
                    f"given, but the leaf is a passthrough — no stored cells "
                    f"to fault")
            out = x @ leaf.astype(x.dtype)
            return (out, {"route": "passthrough"}) if with_info else out
        if rule.serve_path == "hbm":
            if scalars is not None:
                raise ValueError(
                    f"linear({path!r}): scalars given, but the rule pins "
                    f"serve_path='hbm' (decode-once) — per-read dynamic "
                    f"injection only exists on the fused/GSPMD routes")
            w, st = cim_lib.read(leaf)
            self._accumulate(st)
            out = x.astype(jnp.float32) @ w
            return (out, {"route": "hbm"}) if with_info else out
        _, axis, dim = self.placement or (None, "model", "j")
        return dispatch_linear(x, leaf, scalars=scalars, mesh=self.mesh,
                               axis=axis, dim=dim, with_info=with_info,
                               model=model)

    # ------------------------------------------------------------ placement

    def shard(self, mesh, *, axis: str = "model", dim: str = "j"
              ) -> "CIMDeployment":
        """Mesh placement: every store's packed planes split over ``axis``
        along ``dim`` (one shard ≈ one macro column group,
        ``cim.shard_store``); every passthrough leaf replicated. Subsequent
        ``inject`` calls draw per-shard counter-PRNG streams at global store
        coordinates; ``linear`` routes through the shard_map'd fused kernel."""
        stores = place_stores(self.stores, mesh, axis=axis, dim=dim)
        return CIMDeployment(stores, dict(self.ecc_stats), self.policy,
                             self.rules, self.paths, (mesh, axis, dim))

    # ------------------------------------------------------------ serving

    def serving_params(self, *, dynamic_key=None, ber: float = 0.0,
                       field: str = "full", row_cache: bool = True,
                       model=None):
        """The params pytree handed to the jitted model steps.

        Fused rules keep their stores packed; ``serve_path='hbm'`` rules are
        decoded to fp16 up front (stats fold into ``ecc_stats``). With
        ``dynamic_key`` set, the ``_cim`` per-read dynamic-injection runtime
        rides along (dict pytrees only).

        Static fused serving additionally warms the **decoded-row cache** on
        stores whose rule has ``row_cache=True``: ``store.cache`` is set to
        the jit-decoded fp32 matrix, and :func:`dispatch_linear` /
        :func:`dispatch_read_rows` consult it instead of re-decoding per
        step. The packed planes stay authoritative (ECC stats keep reading
        the SRAM image), every ``inject`` rebuilds stores cache-less (so a
        stale cache cannot survive a fault refresh), and dynamic per-request
        streams bypass the cache entirely — pass ``row_cache=False`` to
        disable warming outright.
        """
        static = not (dynamic_key is not None and ber > 0)
        flat, treedef = self._flat()
        out = []
        for leaf, rule in zip(flat, self.rules):
            if cim_lib._is_store(leaf) and rule.serve_path == "hbm":
                w, st = cim_lib.read(leaf)
                self._accumulate(st)
                out.append(w)
            elif (cim_lib._is_store(leaf) and rule.serve_path == "fused"
                  and row_cache and rule.row_cache and static
                  and leaf.cache is None):
                out.append(dataclasses.replace(leaf, cache=_read_w_jit(leaf)))
            else:
                out.append(leaf)
        params = jax.tree_util.tree_unflatten(treedef, out)
        if dynamic_key is not None and ber > 0:
            if not isinstance(params, dict):
                raise TypeError("dynamic serving runtime needs a dict params "
                                f"pytree, got {type(params).__name__}")
            params = dict(params)
            rt = self.runtime(dynamic_key, ber, field, model=model)
            if self.placement is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P
                rep = NamedSharding(self.placement[0], P())
                rt = jax.tree_util.tree_map(
                    lambda a: jax.device_put(a, rep), rt)
            params["_cim"] = rt
        return params

    # ------------------------------------------------------------ accounting

    def bit_cost(self) -> dict:
        """Stored-cell cost of the deployment — the policy search's axis.

        ``stored_bits`` counts logical SRAM cells across every deployed store
        (:attr:`~repro.core.cim.CIMStore.stored_bits`: codewords at
        ``code.n`` bits, signs once); ``raw_bits`` is the unencoded
        ``K*J*fmt.total_bits`` of the same leaves, so ``overhead`` is the
        ECC/packing cost the paper reports (~8.98% for One4N fp16 N=8).
        Passthrough leaves cost nothing (they are not on the macro).
        """
        stored = raw = byts = 0
        for _, rule, s in self.store_leaves():
            stored += s.stored_bits
            raw += int(np.prod(s.shape)) * rule.fmt.total_bits
            byts += s.stored_bytes
        return {"stored_bits": int(stored), "raw_bits": int(raw),
                "stored_bytes": int(byts),
                "overhead": (stored / raw - 1.0) if raw else 0.0}

    # ------------------------------------------------------------ reporting

    def report(self) -> str:
        """One line per deployed leaf: path, rule, image bytes."""
        lines = []
        for p, rule, s in self.store_leaves():
            lines.append(
                f"{p}: protect={rule.protect} field={rule.field} "
                f"ber_scale={rule.ber_scale:g} fmt={rule.fmt_name} "
                f"N={rule.n_group} {s.shape[0]}x{s.shape[1]} "
                f"packed={s.stored_bytes}B")
        if not lines:
            return "(no deployed leaves)"
        return "\n".join(lines)


def place_stores(stores, mesh, *, axis: str = "model", dim: str = "j"):
    """Mesh placement of a stores pytree: every packed store split over
    ``axis`` along ``dim`` (``cim.shard_store``, replication degrade per
    plane); every other leaf replicated. The single placement rule behind
    ``CIMDeployment.shard`` and ``launch.serve.place_on_mesh``."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())

    def place(leaf):
        if cim_lib._is_store(leaf):
            return cim_lib.shard_store(leaf, mesh, axis=axis, dim=dim)
        return jax.device_put(leaf, rep)

    return jax.tree_util.tree_map(place, stores, is_leaf=cim_lib._is_store)


# ---------------------------------------------------------------------------
# Expert-parallel MoE deployment: each expert is its own macro.
# ---------------------------------------------------------------------------

# the stacked MoE expert tensors ([E, D, F] per block, [G, E, D, F] under
# group-scan stacking) — >2-D, so the plain CIMDeployment never touches them
EXPERT_LEAF_NAMES = ("moe_win", "moe_wgate", "moe_wout")


@dataclasses.dataclass(eq=False)
class ExpertDeployment:
    """Per-expert CIM deployment of a model's stacked MoE weights.

    Physically each expert's matrices live on their own macro (that is what
    expert parallelism shards), so each expert can carry its own protection
    level and BER scale. This class slices every stacked expert tensor
    (:data:`EXPERT_LEAF_NAMES`, ``[E, D, F]`` or group-stacked
    ``[G, E, D, F]``) into per-expert 2-D matrices at paths like
    ``groups/blk0/moe_win/g0/expert3`` and deploys them through one
    :class:`CIMDeployment` — :class:`ReliabilityPolicy` rules match the
    per-expert paths (``PolicyRule("*/expert3", ber_scale=4.0)`` targets one
    weak expert across all its matrices).

    Serving is decode-once (hbm-style): :meth:`serving_params` reads every
    expert store back, restacks the dense tensors in the model's dtype, and
    the existing ``moe`` / ``moe_a2a`` dispatch consumes them unchanged — the
    a2a all-to-all IS the expert-parallel routing; this class only decides
    what image those expert weights were read from. Injection is therefore
    **static only**: faults flip each expert's packed image once, and every
    read of the restacked tensor sees the same faulted weights (which keeps
    the engine's bitwise solo-vs-cobatched guarantee intact — the faults are
    a deterministic property of the image, not of the read). Per-read
    dynamic streams would need a per-expert fused-read path inside the
    dispatch kernels; that is out of scope here.

    ECC accounting is per expert: :meth:`stats_by_expert` exposes each
    expert store's corrected/uncorrectable counters (the serving launcher's
    ``--expert-cim`` artifact).
    """

    inner: CIMDeployment
    leaves: Tuple[Tuple[str, tuple], ...]   # (params path, stacked shape)

    @classmethod
    def deploy(cls, params, policy: ReliabilityPolicy) -> "ExpertDeployment":
        """Slice + deploy every stacked expert tensor of ``params``.

        Raises if ``params`` has no expert leaves (deploying nothing would
        silently serve unprotected experts)."""
        leaves_wp, _ = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=cim_lib._is_store)
        expert_params, meta = {}, []
        for path, leaf in leaves_wp:
            p = path_str(path)
            if cim_lib._is_store(leaf) or \
                    p.split("/")[-1] not in EXPERT_LEAF_NAMES:
                continue
            if getattr(leaf, "ndim", 0) == 4:      # [G, E, D, F]
                expert_params[p] = {
                    f"g{g}": {f"expert{e}": leaf[g, e]
                              for e in range(leaf.shape[1])}
                    for g in range(leaf.shape[0])}
            elif getattr(leaf, "ndim", 0) == 3:    # [E, D, F]
                expert_params[p] = {f"expert{e}": leaf[e]
                                    for e in range(leaf.shape[0])}
            else:
                continue
            meta.append((p, tuple(leaf.shape)))
        if not expert_params:
            raise ValueError(
                "ExpertDeployment.deploy: params has no stacked MoE expert "
                f"leaves (looked for {', '.join(EXPERT_LEAF_NAMES)})")
        return cls(inner=CIMDeployment.deploy(expert_params, policy),
                   leaves=tuple(meta))

    def inject(self, key, ber, field: Optional[str] = None,
               model=None) -> "ExpertDeployment":
        """Static soft errors into every expert store (per-rule BER scales
        apply, so a per-expert rule can age one expert harder)."""
        return ExpertDeployment(
            inner=self.inner.inject(key, ber, field=field, model=model),
            leaves=self.leaves)

    def serving_params(self, params):
        """Decode every expert store once and restack the dense tensors into
        ``params`` (the model's moe/moe_a2a dispatch consumes them as-is).

        ``params`` may already be a fused/hbm serving pytree — store leaves
        and the ``_cim`` runtime pass through untouched; only the expert
        leaf paths recorded at deploy time are replaced. ECC stats of the
        read fold into the inner deployment's cumulative counters.
        """
        decoded, _ = self.inner.read()
        shapes = dict(self.leaves)
        leaves_wp, treedef = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=cim_lib._is_store)
        out = []
        for path, leaf in leaves_wp:
            p = path_str(path)
            if p not in shapes or cim_lib._is_store(leaf):
                out.append(leaf)
                continue
            shape, sub = shapes[p], decoded[p]
            if len(shape) == 4:
                w = jnp.stack([
                    jnp.stack([sub[f"g{g}"][f"expert{e}"]
                               for e in range(shape[1])])
                    for g in range(shape[0])])
            else:
                w = jnp.stack([sub[f"expert{e}"] for e in range(shape[0])])
            out.append(w.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    def stats_by_expert(self) -> dict:
        """Per-expert-store ECC counters: path -> counts + rule settings."""
        out = {}
        for p, rule, s in self.inner.store_leaves():
            st = cim_lib.store_stats(s)
            out[p] = {"corrected": int(st["corrected"]),
                      "uncorrectable": int(st["uncorrectable"]),
                      "protect": rule.protect,
                      "ber_scale": rule.ber_scale}
        return out

    def report(self) -> str:
        return self.inner.report()


# ---------------------------------------------------------------------------
# Per-request counter-PRNG key derivation (the serving engine's contract).
#
# A dynamic-injection read's flip streams are keyed by the chain
#
#   plane seed --fold leaf_salt--> --fold request_salt--> --fold pos--> seed
#
# where ``pos`` is the REQUEST-LOCAL read index (its decode position), never
# an engine-global step. Every link is cim.fold_seed, so a request's fault
# streams depend only on (deployment key, leaf, request id, position) — bit-
# identical whether the request is served alone or continuously co-batched,
# and on any engine slot. With no request salt the chain degrades to the
# PR-2 single-stream serving contract (fold leaf, fold pos).
#
# Two salt families fill the ``request`` link, both REPLICA-INVARIANT (they
# derive from globally-assigned request ids or prompt content, never from a
# slot index, replica name, mesh, or engine step — the fleet router's bitwise
# replica-invariance contract rests on this):
#
#   * ``request_salt(rid)`` — decode (generation) reads: each request draws
#     its own soft-error streams while generating;
#   * ``prefix_salt(tokens)`` — prompt-prefill reads: the salt is a hash of
#     the token *content* up through the chunk being prefilled, so two
#     requests sharing a prompt prefix draw bit-identical fault streams over
#     it. That is what makes prefix/KV-cache reuse exact under per-request
#     dynamic injection: a cached prefix chunk's KV equals what a cold
#     prefill of the same tokens would compute, to the bit.
# ---------------------------------------------------------------------------

# distinct per-leaf salts: each CIM-deployed matrix is its own macro and must
# draw independent fault streams (mirrors inject_pytree's per-store key split)
CIM_LEAF_SALTS = {"embed": 0x1001, "unembed": 0x2002}

_REQUEST_SALT_CONST = 0x7FEED5A1
_PREFIX_SALT_CONST = 0x5EEDC0DE


def leaf_salt(path: str) -> int:
    """The per-macro seed salt of a deployed leaf. The embed/unembed table
    keeps the PR-2 serving streams bit-stable; any other path hashes to a
    deterministic uint32 (FNV-1a over the path string)."""
    if path in CIM_LEAF_SALTS:
        return CIM_LEAF_SALTS[path]
    h = 0x811C9DC5
    for ch in path.encode():
        h = ((h ^ ch) * 0x01000193) & 0xFFFFFFFF
    return h


def request_salt(request_id: int):
    """uint32 counter-PRNG salt of a serving request id (engine slots fold it
    into every CIM read seed — slot index never enters the chain)."""
    return cim_lib.fold_seed(jnp.uint32(_REQUEST_SALT_CONST), request_id)


def prefix_salt(tokens) -> int:
    """Content salt of a prompt prefix: deterministic uint32 FNV-1a over the
    token ids (as little-endian uint32 words), seeded off its own constant so
    prefix streams never alias the ``request_salt`` family.

    The serving engine salts every prompt-prefill CIM read with the salt of
    the tokens *up through that chunk* — a pure function of prompt content,
    independent of request id, slot, replica, and arrival order. Cold
    prefill is therefore deterministic in content, and a prefix-cache hit
    (reusing another request's prefilled KV for the same tokens) is bitwise
    identical to recomputing."""
    h = (0x811C9DC5 ^ _PREFIX_SALT_CONST) & 0xFFFFFFFF
    for b in np.asarray(tokens, np.uint32).tobytes():
        h = ((h ^ b) * 0x01000193) & 0xFFFFFFFF
    return h


def request_read_seeds(seeds: dict, leaf_salt_: int, req_salt, pos) -> dict:
    """Fold base plane seeds down to one (leaf, request, read) stream set.

    ``req_salt=None`` skips the request link — byte-compatible with the
    pre-engine per-read chain (fold leaf, fold pos).
    """
    out = {k: cim_lib.fold_seed(v, leaf_salt_) for k, v in seeds.items()}
    if req_salt is not None:
        out = {k: cim_lib.fold_seed(v, req_salt) for k, v in out.items()}
    return {k: cim_lib.fold_seed(v, pos) for k, v in out.items()}


# ---------------------------------------------------------------------------
# Dispatch: the single place that picks the execution route for a CIM matmul
# or row gather. models/lm.py and launch/serve.py call these instead of
# branching on mesh/dtype themselves.
# ---------------------------------------------------------------------------


@jax.jit
def _read_w_jit(store):
    """Jitted full decode of one store (cache warming / fault refresh)."""
    return cim_lib.read(store)[0]


def dispatch_linear(x, store, *, scalars=None, mesh=None, axis: str = "model",
                    dim: str = "j", with_info: bool = False, model=None):
    """Route ``x @ store`` by placement and dtype (module dispatch table).

    With a mesh carrying ``axis`` (default: the ambient mesh's "model" axis),
    the shard_map'd fused kernel runs one program per macro column group —
    degrading internally to GSPMD when the store cannot shard or tile.
    Otherwise a warmed decoded-row cache (``serving_params(row_cache=True)``)
    serves static reads as a plain matmul against ``store.cache`` — bitwise
    identical to the fused kernel's single-K-tile grids — and the
    single-device fused Pallas kernel handles everything else, itself falling
    back to the packed-jnp reference for ``per_weight`` / non-fp16 stores.
    ``scalars`` (``cim_read.ops.make_scalars``) turns on per-read dynamic
    injection and always bypasses the cache: per-request dynamic streams are
    keyed per read, never against a materialized image.
    """
    from repro.distributed import sharding as shlib
    from repro.kernels.cim_read import ops as cr_ops
    if mesh is None:
        mesh = shlib.get_mesh()
    if mesh is not None and axis in mesh.axis_names:
        return cr_ops.cim_linear_store_sharded(
            x, store, scalars=scalars, mesh=mesh, axis=axis, dim=dim,
            with_info=with_info, model=model)
    if scalars is None and store.cache is not None:
        b_shape = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        out = (x2 @ store.cache).reshape(*b_shape, store.shape[1])
        if with_info:
            return out, {"used_kernel": False, "sharded": False,
                         "route": "cached"}
        return out
    return cr_ops.cim_linear_store(x, store, scalars=scalars,
                                   with_info=with_info, model=model)


def dispatch_read_rows(store, idx, *, seeds=None, thr_man=0, thr_meta=0,
                       model=None):
    """Row-gather route: decode-on-read off the packed image (no sharded
    variant — gathers are data-local; GSPMD partitions the jnp decode). A
    warmed decoded-row cache short-circuits static gathers; dynamic seeds
    bypass it (per-read streams are never served from a materialization)."""
    if seeds is None and store.cache is not None:
        return store.cache[idx]
    return cim_lib.read_rows(store, idx, seeds=seeds, thr_man=thr_man,
                             thr_meta=thr_meta, model=model)


# ---------------------------------------------------------------------------
# Training-time dynamic fault schedule (paper Fig. 7), policy-aware.
# ---------------------------------------------------------------------------


def training_fault_schedule(rel) -> Optional[Callable]:
    """Per-step weight corruption for dynamic-injection training, or None.

    With a uniform policy this is byte-for-byte the legacy schedule (same
    ``fault.inject_pytree`` key splits — training streams unchanged): the
    exponent/sign field sees the post-ECC residual rate of the active codec,
    mantissas the raw BER. With per-layer rules each leaf sees ITS rule's
    residual rate and BER scale.
    """
    from repro.core import fault as fault_lib
    if rel.mode != "cim" or rel.ber <= 0 or rel.inject != "dynamic":
        return None
    policy = getattr(rel, "policy", None)
    legacy_uniform = policy is None or (
        policy.uniform and policy.default.field == "full"
        and policy.default.ber_scale == 1.0)
    if legacy_uniform:
        exp_ber = rel.residual_exp_ber

        def corrupt(params, key):
            k1, k2 = jax.random.split(key)
            params = fault_lib.inject_pytree(
                k1, params, fault_lib.FaultModel(ber=exp_ber,
                                                 field="exponent_sign",
                                                 fmt=rel.fmt))
            params = fault_lib.inject_pytree(
                k2, params, fault_lib.FaultModel(ber=rel.ber, field="mantissa",
                                                 fmt=rel.fmt))
            return params

        return corrupt

    def residual(rule: PolicyRule) -> float:
        from repro.core.ecc import residual_ber_after_secded
        b = rel.ber * rule.ber_scale
        if rule.protect == "one4n":
            return residual_ber_after_secded(b, codec=rule.cim_cfg.codec)
        if rule.protect == "per_weight":
            return residual_ber_after_secded(b, codeword_bits=rule.cim_cfg
                                             .pw_code.n)
        return b

    def corrupt(params, key):
        k1, k2 = jax.random.split(key)
        leaves_wp, treedef = jax.tree_util.tree_flatten_with_path(params)
        keys1 = jax.random.split(k1, len(leaves_wp))
        keys2 = jax.random.split(k2, len(leaves_wp))
        out = []
        for ka, kb, (path, leaf) in zip(keys1, keys2, leaves_wp):
            rule = policy.rule_for(path_str(path))
            if rule.deploy and fault_lib._is_injectable(path, leaf):
                # honor the rule's cell-class restriction, matching
                # CIMDeployment.inject on the same policy
                if rule.field in ("full", "exponent_sign"):
                    leaf = fault_lib.inject(ka, leaf, residual(rule),
                                            "exponent_sign", rule.fmt)
                if rule.field in ("full", "mantissa"):
                    leaf = fault_lib.inject(kb, leaf,
                                            rel.ber * rule.ber_scale,
                                            "mantissa", rule.fmt)
            out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out)

    return corrupt
