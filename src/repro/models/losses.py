"""Loss functions: masked LM cross-entropy (+ MoE aux is added by the step).

The CE is written to stay *vocab-sharded* under GSPMD: no one-hot, no
``take_along_axis`` gather over the sharded vocab axis, no fp32 [B,S,V]
buffer. max / logsumexp / masked-pick are plain reductions over the last
axis, which XLA fuses and partially-reduces per shard (the only collective is
a tiny [B,S] combine). This matters at the assigned shapes: a fp32
log-softmax of 1M tokens x 152k vocab would be ~26 GB/device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE = -100


def lm_loss(logits, labels):
    """logits [B,S,V] (may be vocab-sharded), labels [B,S] int (IGNORE masked).

    Returns (loss, metrics)."""
    v = logits.shape[-1]
    mask = labels != IGNORE
    safe = jnp.where(mask, labels, 0)
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m[..., None]), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == safe[..., None], x, 0.0), axis=-1)
    nll = lse - picked
    denom = jnp.maximum(jnp.sum(mask), 1)
    loss = jnp.sum(nll * mask) / denom
    # accuracy without argmax over the (sharded) vocab axis: the prediction is
    # correct iff the label's logit equals the row max (an argmax over a
    # sharded axis makes GSPMD all-gather the full fp32 logits — measured
    # 13 GB/step/device at olmo-1b train_4k).
    acc = jnp.sum((picked >= m) & mask) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}


# ------------------------------------------------- exponent-compression reg
#
# Co-design fine-tuning stage 1 (paper §III-C in spirit, before alignment):
# exponent alignment forces every N-block onto one shared exponent, so any
# weight whose magnitude sits far from its block's chosen octave gets crushed
# by the min–max rescale. The regularizer pre-shrinks that damage: it
# penalizes each block's log2-magnitude *spread* beyond a margin, pushing the
# distribution toward block-shareable exponents while the task loss keeps
# accuracy — measured as before/after accuracy-at-BER in
# benchmarks/fig7_training.py.


def exponent_spread_penalty(w, n_group: int = 8, margin: float = 1.0,
                            eps: float = 1e-8):
    """Mean ReLU(log2-magnitude spread − margin) over N-blocks of ``w``.

    Blocks group along the input-channel axis (axis ``ndim-2``, edge-padded),
    matching :func:`repro.core.align.align_matrix`'s block view. ``margin``
    is the spread (in octaves) a shared-exponent block can represent without
    loss — one octave for the [LL, UL] mantissa range of Fig. 5. Smooth a.e.,
    so it trains with plain SGD/AdamW."""
    from repro.core.align import _block_view
    blocks, _ = _block_view(w.astype(jnp.float32), n_group, w.ndim - 2)
    loge = jnp.log2(jnp.maximum(jnp.abs(blocks), eps))
    spread = jnp.max(loge, axis=1) - jnp.min(loge, axis=1)
    return jnp.mean(jax.nn.relu(spread - margin))


def exponent_compression_penalty(params, policy, margin: float = 1.0):
    """Policy-weighted exponent-compression regularizer over a params pytree.

    Each leaf that its :class:`~repro.core.deployment.ReliabilityPolicy` rule
    deploys contributes ``exponent_spread_penalty`` at the RULE's ``n_group``
    (so the penalty targets exactly the block structure the leaf will be
    aligned and packed with); ``deploy=False`` leaves contribute nothing.
    Returns a scalar (0 when the policy deploys no leaf).
    """
    from repro.core.align import is_alignable
    from repro.core.deployment import path_str
    leaves_wp, _ = jax.tree_util.tree_flatten_with_path(params)
    pens = []
    for path, leaf in leaves_wp:
        rule = policy.rule_for(path_str(path))
        if rule.deploy and is_alignable(path, leaf):
            pens.append(exponent_spread_penalty(leaf, rule.n_group, margin))
    if not pens:
        return jnp.zeros(())
    return jnp.mean(jnp.stack(pens))
