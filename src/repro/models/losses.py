"""Loss functions: masked LM cross-entropy (+ MoE aux is added by the step).

The CE is written to stay *vocab-sharded* under GSPMD: no one-hot, no
``take_along_axis`` gather over the sharded vocab axis, no fp32 [B,S,V]
buffer. max / logsumexp / masked-pick are plain reductions over the last
axis, which XLA fuses and partially-reduces per shard (the only collective is
a tiny [B,S] combine). This matters at the assigned shapes: a fp32
log-softmax of 1M tokens x 152k vocab would be ~26 GB/device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE = -100


def lm_loss(logits, labels):
    """logits [B,S,V] (may be vocab-sharded), labels [B,S] int (IGNORE masked).

    Returns (loss, metrics)."""
    v = logits.shape[-1]
    mask = labels != IGNORE
    safe = jnp.where(mask, labels, 0)
    x = logits.astype(jnp.float32)
    m = jnp.max(x, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m[..., None]), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == safe[..., None], x, 0.0), axis=-1)
    nll = lse - picked
    denom = jnp.maximum(jnp.sum(mask), 1)
    loss = jnp.sum(nll * mask) / denom
    # accuracy without argmax over the (sharded) vocab axis: the prediction is
    # correct iff the label's logit equals the row max (an argmax over a
    # sharded axis makes GSPMD all-gather the full fp32 logits — measured
    # 13 GB/step/device at olmo-1b train_4k).
    acc = jnp.sum((picked >= m) & mask) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
