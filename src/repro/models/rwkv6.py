"""RWKV6 ("Finch") time-mix block — attention-free, data-dependent decay.

The matrix-valued state per head, ``S in R^{hd x hd}``, evolves as

    S_t = diag(w_t) S_{t-1} + k_t v_t^T           (w_t in (0,1), per channel)
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Training/prefill use a *chunked* linear-attention formulation (``lax.scan``
over chunks of 16 tokens carrying S): within a chunk the interaction is a
masked [C, C] matmul; across chunks only the decayed state flows. This keeps
memory at O(T·hd) instead of O(T·hd^2) and maps onto the MXU. fp32 is used for
the recurrence (matching the official CUDA kernels); decays are clamped to
keep the ``k/a`` rescaling inside fp32 range (DESIGN.md notes).

Decode is the O(1) single-token recurrence on the cached state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import dense_init

CHUNK = 16
TS_LORA = 32     # token-shift lora rank
W_LORA = 64      # decay lora rank


def init_rwkv_tmix(key, cfg):
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.head_dim_
    dt = cfg.pdtype()
    ks = jax.random.split(key, 12)
    return {
        "w_r": dense_init(ks[0], (d, h * hd), dtype=dt),
        "w_k": dense_init(ks[1], (d, h * hd), dtype=dt),
        "w_v": dense_init(ks[2], (d, h * hd), dtype=dt),
        "w_g": dense_init(ks[3], (d, h * hd), dtype=dt),
        "w_o": dense_init(ks[4], (h * hd, d), dtype=dt),
        # data-dependent token shift (5 targets: r,k,v,g,w)
        "ts_mu0": jnp.zeros((d,), dt),
        "ts_mu": jnp.zeros((5, d), dt),
        "ts_lora_a": dense_init(ks[5], (d, 5 * TS_LORA), dtype=dt),
        "ts_lora_b": (jax.random.normal(ks[6], (5, TS_LORA, d)) * 0.01).astype(dt),
        # data-dependent decay w_t = exp(-exp(w0 + lora(x_w)))
        "decay_w0": jnp.full((h * hd,), -6.0, dt),
        "decay_lora_a": dense_init(ks[7], (d, W_LORA), dtype=dt),
        "decay_lora_b": (jax.random.normal(ks[8], (W_LORA, h * hd)) * 0.01).astype(dt),
        "bonus_u": (jax.random.normal(ks[9], (h, hd)) * 0.1).astype(dt),
        "gn_scale": jnp.ones((h * hd,), dt),
    }


def _token_shift_targets(params, x, x_prev_last):
    """Data-dependent lerp between x_t and x_{t-1} for the 5 projection inputs.

    x [B,T,D]; x_prev_last [B,D] is the token before the window (decode carry).
    Returns xs [5, B, T, D].
    """
    dt = x.dtype
    xp = jnp.concatenate([x_prev_last[:, None], x[:, :-1]], axis=1)
    delta = xp - x
    base = x + delta * params["ts_mu0"].astype(dt)
    lora = jnp.tanh(base @ params["ts_lora_a"].astype(dt))
    b, t, _ = x.shape
    lora = lora.reshape(b, t, 5, TS_LORA)
    offs = jnp.einsum("btir,ird->ibtd", lora, params["ts_lora_b"].astype(dt))
    mu = params["ts_mu"].astype(dt)[:, None, None, :]
    return x[None] + delta[None] * (mu + offs)


def _decay(params, xw):
    """Per-channel decay in (0,1); clamped for fp32-safe chunk rescaling."""
    dt = xw.dtype
    raw = params["decay_w0"].astype(dt) + \
        jnp.tanh(xw @ params["decay_lora_a"].astype(dt)) @ params["decay_lora_b"].astype(dt)
    return jnp.exp(-jnp.exp(jnp.clip(raw.astype(jnp.float32), -8.0, 1.0)))


def _group_norm(x, scale, h):
    """Per-head RMS-style normalization of the wkv output. x [B,T,H*hd]."""
    b, t, dh = x.shape
    xs = x.reshape(b, t, h, dh // h).astype(jnp.float32)
    var = jnp.mean(jnp.square(xs), axis=-1, keepdims=True)
    out = (xs * jax.lax.rsqrt(var + 1e-5)).reshape(b, t, dh)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def _wkv_chunked(r, k, v, w, u, s0):
    """Chunked WKV6 scan. r,k,v,w [B,T,H,hd] fp32; u [H,hd]; s0 [B,H,hd,hd].

    Returns (o [B,T,H,hd], sT)."""
    b, t, h, hd = r.shape
    pad = (-t) % CHUNK
    if pad:
        # identity-pad the tail: w=1 (no decay), r=k=v=0 (no contribution)
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zpad(r), zpad(k), zpad(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    tp = t + pad
    n = tp // CHUNK

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(b, n, CHUNK, h, hd), 1, 0)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    tri = jnp.tril(jnp.ones((CHUNK, CHUNK), jnp.float32), -1)  # strict lower

    def body(s, xs):
        rr, kk, vv, ww = xs                       # [B,C,H,hd]
        a = jnp.cumprod(ww, axis=1)               # inclusive cumprod
        a_prev = jnp.concatenate([jnp.ones_like(a[:, :1]), a[:, :-1]], axis=1)
        k_div = kk / a                            # bounded by decay clamp
        r_sc = rr * a_prev
        # intra-chunk interaction [B,H,C,C] (strictly causal) + bonus diagonal
        m = jnp.einsum("bthc,bshc->bhts", r_sc, k_div) * tri
        diag = jnp.einsum("bthc,bthc->bth", rr * u[None, None], kk)
        o = jnp.einsum("bhts,bshd->bthd", m, vv) + diag[..., None] * vv
        # carry-in contribution and state update
        o = o + jnp.einsum("bthc,bhcd->bthd", r_sc, s)
        a_last = a[:, -1]                         # [B,H,hd]
        s = a_last[..., None] * (s + jnp.einsum("bshc,bshd->bhcd", k_div, vv))
        return s, o

    sT, oc = jax.lax.scan(body, s0, (rc, kc, vc, wc))
    return jnp.moveaxis(oc, 0, 1).reshape(b, tp, h, hd)[:, :t], sT


def init_rwkv_state(cfg, batch: int):
    h, hd = cfg.n_heads, cfg.head_dim_
    return {
        "s": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "x_tmix": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "x_cmix": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }


def apply_rwkv_tmix(params, cfg, x, state=None) -> Tuple[jnp.ndarray, dict]:
    """Sequence mode (train/prefill). x [B,T,D] -> (out, final state)."""
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    dt = x.dtype
    if state is None:
        state = init_rwkv_state(cfg, b)
    # NOTE (§Perf rwkv iteration, REFUTED): gathering the sequence once here
    # and projecting on the gathered stream cuts collectives 1.4x but doubles
    # per-device flops/bytes — the token-shift LoRA then runs REPLICATED over
    # the model axis instead of seq-sharded. Net dominant-term regression
    # (5.68 s -> 7.88 s); the seq-sharded projections below are kept. The real
    # next lever is a sequence-parallel WKV ring (state handoff via
    # collective_permute), documented as future work.
    xs = _token_shift_targets(params, x, state["x_tmix"].astype(dt))
    xr, xk, xv, xg, xw = xs[0], xs[1], xs[2], xs[3], xs[4]

    def proj(inp, name):
        y = inp @ params[name].astype(dt)
        return shard(y.reshape(b, t, h, hd).astype(jnp.float32),
                     "batch", None, "heads", None)

    r, k, v = proj(xr, "w_r"), proj(xk, "w_k"), proj(xv, "w_v")
    g = jax.nn.silu(xg @ params["w_g"].astype(dt))
    w = _decay(params, xw).reshape(b, t, h, hd)
    w = shard(w, "batch", None, "heads", None)
    u = params["bonus_u"].astype(jnp.float32)

    o, sT = _wkv_chunked(r, k, v, w, u, state["s"])
    o = _group_norm(o.reshape(b, t, h * hd).astype(dt), params["gn_scale"], h)
    out = (o * g) @ params["w_o"].astype(dt)
    new_state = {"s": sT, "x_tmix": x[:, -1].astype(jnp.float32),
                 "x_cmix": state["x_cmix"]}
    return shard(out, "batch", "seq", None), new_state


def advance_rwkv_tmix(params, cfg, x, state, length) -> Tuple[jnp.ndarray, dict]:
    """Chunked slot-state advance (serving engine). x [B,T,D]; the first
    ``length`` tokens are valid, the ragged tail is padding.

    Padding is identity-masked out of the recurrence exactly the way
    :func:`_wkv_chunked` pads its own tail — w=1 (no decay), r=k=v=0 (no
    contribution) — so the carried state is the pure left fold of the valid
    tokens, and the token-shift carry is read at the last *valid* position.
    ``length`` is traced: one compile covers every ragged fill of a chunk
    shape. Output rows past ``length`` are garbage the caller must ignore.
    """
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    dt = x.dtype
    length = jnp.asarray(length, jnp.int32)
    xs = _token_shift_targets(params, x, state["x_tmix"].astype(dt))
    xr, xk, xv, xg, xw = xs[0], xs[1], xs[2], xs[3], xs[4]

    def proj(inp, name):
        y = inp @ params[name].astype(dt)
        return shard(y.reshape(b, t, h, hd).astype(jnp.float32),
                     "batch", None, "heads", None)

    r, k, v = proj(xr, "w_r"), proj(xk, "w_k"), proj(xv, "w_v")
    g = jax.nn.silu(xg @ params["w_g"].astype(dt))
    w = _decay(params, xw).reshape(b, t, h, hd)
    w = shard(w, "batch", None, "heads", None)
    u = params["bonus_u"].astype(jnp.float32)

    valid = (jnp.arange(t) < length)[None, :, None, None]
    r = jnp.where(valid, r, 0.0)
    k = jnp.where(valid, k, 0.0)
    v = jnp.where(valid, v, 0.0)
    w = jnp.where(valid, w, 1.0)

    o, sT = _wkv_chunked(r, k, v, w, u, state["s"])
    o = _group_norm(o.reshape(b, t, h * hd).astype(dt), params["gn_scale"], h)
    out = (o * g) @ params["w_o"].astype(dt)
    x_last = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)[:, 0]
    new_state = {"s": sT, "x_tmix": x_last.astype(jnp.float32),
                 "x_cmix": state["x_cmix"]}
    return out, new_state


def decode_rwkv_tmix(params, cfg, x, state) -> Tuple[jnp.ndarray, dict]:
    """Single-token recurrence. x [B,1,D]."""
    b, _, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim_
    dt = x.dtype
    xs = _token_shift_targets(params, x, state["x_tmix"].astype(dt))
    xr, xk, xv, xg, xw = (xs[i][:, 0] for i in range(5))

    def proj(inp, name):
        return (inp @ params[name].astype(dt)).reshape(b, h, hd).astype(jnp.float32)

    r, k, v = proj(xr, "w_r"), proj(xk, "w_k"), proj(xv, "w_v")
    g = jax.nn.silu(xg @ params["w_g"].astype(dt))
    w = _decay(params, xw).reshape(b, h, hd)
    u = params["bonus_u"].astype(jnp.float32)

    s = state["s"]
    kv = k[..., :, None] * v[..., None, :]                   # [B,H,hd,hd]
    o = jnp.einsum("bhc,bhcd->bhd", r, s + u[None, ..., None] * kv)
    s = w[..., None] * s + kv
    o = _group_norm(o.reshape(b, 1, h * hd).astype(dt), params["gn_scale"], h)
    out = (o * g[:, None]) @ params["w_o"].astype(dt)
    new_state = {"s": s, "x_tmix": x[:, -1].astype(jnp.float32),
                 "x_cmix": state["x_cmix"]}
    return out, new_state
