"""Expert-parallel MoE dispatch via shard_map + lax.all_to_all.

The GSPMD dense dispatch (``repro.models.moe``) leaves the compiler to infer
collectives for the token->expert scatter; §Perf 4.1 measured its residual
cost and refuted the pre-sharded-scatter fix. This module is the explicit
alternative: inside ``shard_map`` every device

  1. routes its LOCAL tokens (the residual stream is already sharded over
     batch x sequence = every mesh device holds a distinct token slice),
  2. packs them into per-(owner, local-expert) capacity slots,
  3. exchanges slots with ``lax.all_to_all`` over the "model" axis
     (= the expert-parallel axis),
  4. runs its local experts' FFN,
  5. all_to_all's results back and combines with the gates.

Collective cost per layer is exactly 2 all-to-alls of
``T_loc·k·cf·D`` bytes — no compiler guesswork. Enabled with
``cfg.moe_dispatch="a2a"`` (requires an active mesh with a "model" axis;
falls back to the dense dispatch on hosts without one, so CPU unit tests and
reduced configs run unchanged).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shlib


def _local_rank(flat_ids, n_buckets):
    """rank of each assignment within its bucket (sort-based, local)."""
    n = flat_ids.shape[0]
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(n_buckets, dtype=flat_ids.dtype))
    ranks_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_ids]
    return jnp.zeros((n,), jnp.int32).at[order].set(ranks_sorted)


def _moe_a2a_local(router, w_gate, w_in, w_out, x_loc, cfg, ep: int,
                   mesh_axes=("data", "model")):
    """Body inside shard_map. x_loc [Tl, D]; expert weights are the LOCAL
    slice [E_loc, D, F]; returns (out [Tl, D], aux scalar)."""
    tl, d = x_loc.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // ep
    dt = x_loc.dtype

    logits = (x_loc @ router.astype(dt)).astype(jnp.float32)          # [Tl, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), 1), 0)
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)
    aux = jax.lax.pmean(aux, mesh_axes)

    # pack assignments into [ep owners, E_loc, C, D] send slots
    c = max(8, int(math.ceil(tl * k * cfg.capacity_factor / e)))
    flat_ids = ids.reshape(tl * k)                                    # global e
    rank = _local_rank(flat_ids, e)
    keep = rank < c
    # destination slot: owner = e // e_loc ; slot = (e % e_loc) * c + rank
    dest = jnp.where(keep, flat_ids * c + rank, e * c)
    src = jnp.repeat(x_loc, k, axis=0)
    send = jnp.zeros((e * c + 1, d), dt).at[dest].add(src)[:e * c]
    send = send.reshape(ep, e_loc * c, d)                             # by owner

    recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                              tiled=False)                            # [ep, elc, d]
    buf = recv.reshape(ep, e_loc, c, d).transpose(1, 0, 2, 3) \
        .reshape(e_loc, ep * c, d)                                    # senders merged

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w_in.astype(dt))
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_out.astype(dt))         # [elc, ep*c, d]

    back = out_buf.reshape(e_loc, ep, c, d).transpose(1, 0, 2, 3) \
        .reshape(ep, e_loc * c, d)
    ret = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0,
                             tiled=False).reshape(e * c, d)

    gathered = jnp.where(keep[:, None], ret[jnp.minimum(dest, e * c - 1)], 0)
    out = jnp.sum((gathered * gates.reshape(tl * k, 1).astype(dt))
                  .reshape(tl, k, d), axis=1)
    return out, aux


def apply_moe_a2a(params, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B,S,D] -> (out, aux). Requires an active mesh with "model"+"data"."""
    from jax.experimental.shard_map import shard_map
    mesh = shlib.get_mesh()
    ep = mesh.shape["model"]
    b, s, d = x.shape

    def body(router, w_gate, w_in, w_out, x_blk):
        # blocks: router full; w_* are the LOCAL [E_loc, D, F] slices
        blk_shape = x_blk.shape
        out, aux = _moe_a2a_local(router, w_gate, w_in, w_out,
                                  x_blk.reshape(-1, d), cfg, ep,
                                  tuple(mesh.axis_names))
        return out.reshape(blk_shape), aux[None]

    batch_axes = shlib.batch_axes()
    x_spec = P(batch_axes, "model", None)         # tokens: batch x seq sharded
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None),                  # router replicated
                  P("model", None, None),         # experts on "model", D full
                  P("model", None, None),
                  P("model", None, None),
                  x_spec),
        out_specs=(x_spec, P("model")),
        check_rep=False,
    )(params["router"], params["moe_wgate"], params["moe_win"],
      params["moe_wout"], x)
    return out, jnp.mean(aux)
