"""GQA attention: full / local-window / q-chunked prefill / cached decode.

Sharding: heads are tensor-parallel ("model" axis); the caller keeps the
residual stream sequence-sharded (SP) — constraints here trigger the
all-gather (seq) -> head-parallel compute -> reduce-scatter (seq) pattern
under GSPMD.

For long sequences (``seq > cfg.attn_chunk_threshold``) the query axis is
processed in chunks of ``cfg.attn_chunk_q`` under ``lax.scan`` so the
``S x T`` logits never materialize at once (32k prefill would otherwise
allocate ~17 GB/layer/device at the assigned shapes).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import apply_rope, dense_init, rope_frequencies

NEG_INF = -2.0 ** 30  # large-but-finite: keeps fully-masked rows NaN-free


def init_attention(key, cfg):
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    dt = cfg.pdtype()
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k0, (d, h * hd), dtype=dt),
        "wk": dense_init(k1, (d, k * hd), dtype=dt),
        "wv": dense_init(k2, (d, k * hd), dtype=dt),
        "wo": dense_init(k3, (h * hd, d), dtype=dt),
    }


def _project_qkv(params, cfg, x, positions, constrain: bool = True):
    b, s, _ = x.shape
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    dt = cfg.cdtype()
    q = (x @ params["wq"].astype(dt)).reshape(b, s, h, hd)
    kk = (x @ params["wk"].astype(dt)).reshape(b, s, k, hd)
    vv = (x @ params["wv"].astype(dt)).reshape(b, s, k, hd)
    sin, cos = rope_frequencies(hd, cfg.rope_theta, positions)
    q = apply_rope(q, sin, cos)
    kk = apply_rope(kk, sin, cos)
    if constrain:
        # TP layout: heads on "model", full sequence (all-gather out of SP).
        q = shard(q, "batch", None, "heads", None)
        kk = shard(kk, "batch", None, "kv_heads", None)
        vv = shard(vv, "batch", None, "kv_heads", None)
    return q, kk, vv


def _attend(q, k, v, mask):
    """q [B,S,K,G,hd], k/v [B,T,K,hd], mask broadcastable to [B,K,G,S,T].

    Grouped form — used on the decode path where the cache keeps K heads."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out


def _attend_mha(q, k, v, mask):
    """q/k/v [B,S|T,H,hd] (kv pre-expanded), mask broadcast to [B,H,S,T].

    Training/prefill path. The merged-head layout keeps the model axis on a
    SINGLE tensor dimension: with the grouped [B,K,G,S,T] layout GSPMD factors
    model=16 as kv x group (e.g. 4x4 at qwen3) and then "involuntarily fully
    rematerializes" the S x T probability tensors when resharding — measured
    ~240 GB/layer of backward all-gathers (EXPERIMENTS.md §Perf iteration 2).
    """
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out


def _expand_kv(k, g: int):
    """[B,T,K,hd] -> [B,T,K*g,hd] (each kv head repeated over its q group)."""
    if g == 1:
        return k
    b, t, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None], (b, t, kh, g, hd)) \
        .reshape(b, t, kh * g, hd)


def _causal_mask(q_pos, k_pos, window: int):
    """[..., S, T] boolean; local-window band when ``window`` > 0."""
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    if window > 0:
        m &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return m


def full_attention(params, cfg, x, positions, window: int = 0):
    """Training / short-prefill path. x [B,S,D] -> [B,S,D]."""
    b, s, d = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    g = h // kh
    if cfg.attn_impl == "cp" and s <= cfg.attn_chunk_threshold:
        # Context-parallel attention (§Perf): the query/output KEEP the
        # residual stream's sequence sharding; only K/V leave it (replicated
        # over "model"). Per layer the only collectives are the K/V gathers
        # (fwd) and their reduce-scatters (bwd) — no head<->seq reshard of
        # the residual at all. Grouped einsum: the model axis touches a
        # single tensor dim (S), so no kv x group factorization either.
        q, k, v = _project_qkv(params, cfg, x, positions, constrain=False)
        q = shard(q, "batch", "seq", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
        q = q.reshape(b, s, kh, g, hd)
        mask = _causal_mask(positions[0], positions[0], window)[None, None, None]
        out = _attend(q, k, v, mask).reshape(b, s, h * hd)
        out = shard(out, "batch", "seq", None)
    else:
        q, k, v = _project_qkv(params, cfg, x, positions)
        k = shard(_expand_kv(k, g), "batch", None, "heads", None)
        v = shard(_expand_kv(v, g), "batch", None, "heads", None)

        if s > cfg.attn_chunk_threshold:
            out = _q_chunked(q, k, v, positions, window, cfg.attn_chunk_q)
        else:
            # positions are uniform across batch -> a [S,T] mask (a [B,1,S,T]
            # mask gets all-gathered as a ~0.3 GB pred tensor per layer)
            mask = _causal_mask(positions[0], positions[0], window)[None, None]
            out = _attend_mha(q, k, v, mask)
        out = out.reshape(b, s, h * hd)
    out = out @ params["wo"].astype(x.dtype)
    return shard(out, "batch", "seq", None)


def _q_chunked(q, k, v, positions, window: int, chunk: int):
    """Scan over query chunks; logits bounded to [B,H,chunk,T]."""
    b, s, h, hd = q.shape
    n = s // chunk
    assert s % chunk == 0, "seq must divide the q-chunk size"
    qc = jnp.moveaxis(q.reshape(b, n, chunk, h, hd), 1, 0)
    pc = jnp.moveaxis(positions.reshape(b, n, chunk), 1, 0)

    def body(_, xs):
        q_i, p_i = xs
        mask = _causal_mask(p_i[0], positions[0], window)[None, None]
        return None, _attend_mha(q_i, k, v, mask)

    _, out = jax.lax.scan(body, None, (qc, pc))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


# ---------------------------------------------------------------- decode

def init_kv_cache(cfg, batch: int, max_len: int, dtype=None):
    k, hd = cfg.n_kv_heads, cfg.head_dim_
    if cfg.kv_cache_dtype == "int8":
        # quantized cache: int8 values + one scale per (token, head) —
        # halves the decode-dominant cache traffic (§Perf granite iter. 3)
        return {
            "k": jnp.zeros((batch, max_len, k, hd), jnp.int8),
            "v": jnp.zeros((batch, max_len, k, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, k, 1), jnp.bfloat16),
            "v_scale": jnp.zeros((batch, max_len, k, 1), jnp.bfloat16),
        }
    dt = dtype or cfg.cdtype()
    return {
        "k": jnp.zeros((batch, max_len, k, hd), dt),
        "v": jnp.zeros((batch, max_len, k, hd), dt),
    }


def _quant_kv(x):
    """[B,1,K,hd] -> (int8 values, bf16 scale [B,1,K,1])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _dequant_kv(q, scale, dt):
    return q.astype(dt) * scale.astype(dt)


def cache_spec(cfg):
    """Logical sharding of the KV cache [B, S, K, hd].

    KV heads go on "model" when they divide the axis (musicgen kv=32);
    otherwise the cache is sharded over the *sequence* (flash-decoding
    layout): per-shard partial logits combine through the softmax max/sum
    reductions, tiny [B, heads] collectives instead of padded kv storage."""
    from repro.distributed import sharding as shlib
    if cfg.n_kv_heads % max(shlib.axis_size("model"), 1) == 0:
        return ("batch", None, "kv_heads", None)
    return ("batch", "kv_seq", None, None)


def init_local_cache(cfg, batch: int, window: int, dtype=None):
    """Rolling-window cache for local attention (recurrentgemma): O(window)
    memory regardless of decode length — slot ``pos % window`` is overwritten
    and per-slot absolute positions drive the mask."""
    k, hd = cfg.n_kv_heads, cfg.head_dim_
    dt = dtype or cfg.cdtype()
    return {
        "k": jnp.zeros((batch, window, k, hd), dt),
        "v": jnp.zeros((batch, window, k, hd), dt),
        "pos": jnp.full((batch, window), -1, jnp.int32),
    }


def decode_local_attention(params, cfg, x, cache, pos, window: int):
    """One-token decode against a rolling window cache.

    ``pos`` is a scalar (lock-step serve path: contiguous
    ``dynamic_update_slice`` at the shared ring slot) or a per-slot ``[B]``
    vector (continuous batching: each batch row overwrites its own ring
    slot ``pos_b % W`` via scatter)."""
    b, _, d = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    g = h // kh
    w = cache["k"].shape[1]
    pos_arr = jnp.asarray(pos, jnp.int32)
    per_slot = pos_arr.ndim > 0
    positions = pos_arr[:, None] if per_slot \
        else jnp.full((b, 1), pos_arr, jnp.int32)                 # [B,1]
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    q = q.reshape(b, 1, kh, g, hd)

    slot = jnp.mod(positions, w)                                  # [B,1]
    if per_slot:
        bi = jnp.arange(b)[:, None]
        ck = cache["k"].at[bi, slot].set(k_new.astype(cache["k"].dtype))
        cv = cache["v"].at[bi, slot].set(v_new.astype(cache["v"].dtype))
        cpos = cache["pos"].at[bi, slot].set(positions)
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, slot[0, 0], 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, slot[0, 0], 0, 0))
        cpos = jax.lax.dynamic_update_slice(cache["pos"], positions,
                                            (0, slot[0, 0]))

    valid = (cpos >= 0) & (cpos <= positions) & ((positions - cpos) < window)
    mask = valid[:, None, None, None, :]                  # [B,1,1,1,W]
    out = _attend(q, ck.astype(q.dtype), cv.astype(q.dtype), mask)
    out = out.reshape(b, 1, h * hd) @ params["wo"].astype(x.dtype)
    return out, {"k": ck, "v": cv, "pos": cpos}


def advance_local_attention(params, cfg, x, cache, pos, window: int,
                            length=None):
    """Chunked advance of the rolling-window cache. x [B,S,D] is one prompt
    chunk at scalar offset ``pos``; the first ``length`` tokens are valid,
    the ragged tail is padding.

    Valid rows scatter into ring slots ``(pos + i) % W``; padded rows are
    routed to the out-of-range slot ``W`` and dropped (``mode='drop'``), so
    they never clobber ring entries that earlier queries' windows still need
    (with ``slot = pos % W`` a pad at position p would land exactly where
    position ``p - W`` lives — inside the window of every valid query past
    ``p - W``). Chunk length must not exceed the ring (the engine clamps
    ``chunk <= window``) so valid writes never collide. Per-query masks
    handle intra-chunk causality; output rows past ``length`` are garbage
    the caller must ignore.
    """
    b, s, d = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    g = h // kh
    w = cache["k"].shape[1]
    assert s <= w, f"chunk {s} exceeds the local ring ({w} slots)"
    if length is None:
        length = s
    length = jnp.asarray(length, jnp.int32)
    base = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(base + jnp.arange(s, dtype=jnp.int32)[None],
                                 (b, s))
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    q = q.reshape(b, s, kh, g, hd)

    valid_tok = jnp.arange(s, dtype=jnp.int32) < length           # [S]
    slots = jnp.where(valid_tok[None], jnp.mod(positions, w), w)  # [B,S]
    bi = jnp.arange(b)[:, None]
    ck = cache["k"].at[bi, slots].set(k_new.astype(cache["k"].dtype),
                                      mode="drop")
    cv = cache["v"].at[bi, slots].set(v_new.astype(cache["v"].dtype),
                                      mode="drop")
    cpos = cache["pos"].at[bi, slots].set(positions, mode="drop")

    valid = (cpos[:, None, :] >= 0) & (cpos[:, None, :] <= positions[:, :, None]) \
        & ((positions[:, :, None] - cpos[:, None, :]) < window)   # [B,S,W]
    mask = valid[:, None, None]                                   # [B,1,1,S,W]
    out = _attend(q, ck.astype(q.dtype), cv.astype(q.dtype), mask)
    out = out.reshape(b, s, h * hd) @ params["wo"].astype(x.dtype)
    return out, {"k": ck, "v": cv, "pos": cpos}


def decode_attention(params, cfg, x, cache, pos, window: int = 0):
    """Cache-append decode. x [B,S,D] (S=1 token decode, S=C chunked
    prefill); cache k/v [B,Smax,K,hd]; ``pos`` = number of tokens already in
    the cache — a scalar, or a per-slot ``[B]`` vector (continuous batching:
    every batch row decodes at its own position). Returns
    (out [B,S,D], new cache).

    A scalar ``pos`` keeps the original contiguous ``dynamic_update_slice``
    write; a vector scatters each row's new K/V at its own offset. Rows
    beyond a slot's current position hold stale values, but the causal mask
    (``k_pos <= q_pos``) hides every row until the step that overwrites it,
    so they never reach a softmax.
    """
    b, s, d = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    g = h // kh
    pos_arr = jnp.asarray(pos, jnp.int32)
    per_slot = pos_arr.ndim > 0
    base = pos_arr[:, None] if per_slot else jnp.full((b, 1), pos_arr)
    positions = base + jnp.arange(s, dtype=jnp.int32)[None]       # [B,S]
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    q = q.reshape(b, s, kh, g, hd)
    int8_cache = "k_scale" in cache

    def write(buf, val):
        val = val.astype(buf.dtype)
        if per_slot:
            return buf.at[jnp.arange(b)[:, None], positions].set(val)
        return jax.lax.dynamic_update_slice(buf, val, (0, pos_arr, 0, 0))

    if int8_cache:
        kq, ks = _quant_kv(k_new)
        vq, vs = _quant_kv(v_new)
        new_cache = {}
        for name, val in (("k", kq), ("v", vq), ("k_scale", ks), ("v_scale", vs)):
            new_cache[name] = shard(write(cache[name], val), *cache_spec(cfg))
        ck = _dequant_kv(new_cache["k"], new_cache["k_scale"], q.dtype)
        cv = _dequant_kv(new_cache["v"], new_cache["v_scale"], q.dtype)
    else:
        ck = shard(write(cache["k"], k_new), *cache_spec(cfg))
        cv = shard(write(cache["v"], v_new), *cache_spec(cfg))
        new_cache = {"k": ck, "v": cv}

    t = new_cache["k"].shape[1]
    k_pos = jnp.arange(t, dtype=jnp.int32)[None]
    mask = _causal_mask(positions, k_pos, window)[:, None, None]
    out = _attend(q, ck.astype(q.dtype), cv.astype(q.dtype), mask)
    out = out.reshape(b, s, h * hd) @ params["wo"].astype(x.dtype)
    return out, new_cache
