"""Unified decoder LM covering all ten assigned architectures.

A model is a cycle of block kinds (``cfg.block_pattern``) over ``n_layers``:

  * ``attn``  — GQA attention + dense MLP          (dense family, VLM, audio)
  * ``local`` — windowed attention + dense MLP      (recurrentgemma 1/3 layers)
  * ``moe``   — GQA attention + MoE FFN             (qwen3-moe, dbrx)
  * ``rwkv``  — RWKV6 time-mix + channel-mix        (attention-free)
  * ``rec``   — RG-LRU recurrent block + dense MLP  (recurrentgemma 2/3 layers)

Layers are stacked into pattern *groups* and iterated with ``lax.scan``
(+ optional ``jax.checkpoint``), which keeps HLO size and compile time bounded
at 80–94 layers and makes the saved residual stream a single ``[G, B, S, D]``
tensor that the sharding rules distribute over both mesh axes.

Three entry points per model: ``forward`` (training), ``prefill`` (returns
last-token logits + caches) and ``decode`` (one token against caches).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cim as cim_lib
from repro.distributed import sharding as shlib
from repro.distributed.sharding import shard
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.common import apply_norm, embed_init, init_norm


# ---------------------------------------------------------------- init

def init_block(key, cfg: ModelConfig, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.pdtype()
    p = {"norm1": init_norm(k3, cfg.norm_type, cfg.d_model, dt),
         "norm2": init_norm(k3, cfg.norm_type, cfg.d_model, dt)}
    if kind in ("attn", "local"):
        p["attn"] = attn_lib.init_attention(k1, cfg)
        p["mlp"] = mlp_lib.init_mlp(k2, cfg)
    elif kind == "moe":
        p["attn"] = attn_lib.init_attention(k1, cfg)
        p["moe"] = moe_lib.init_moe(k2, cfg)
    elif kind == "rwkv":
        p["tmix"] = rwkv_lib.init_rwkv_tmix(k1, cfg)
        p["cmix"] = mlp_lib.init_mlp(k2, cfg)
    elif kind == "rec":
        p["rec"] = rglru_lib.init_rglru_block(k1, cfg)
        p["mlp"] = mlp_lib.init_mlp(k2, cfg)
    else:
        raise ValueError(kind)
    return p


def _group_kinds(cfg: ModelConfig):
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    tail = tuple(pat[i] for i in range(cfg.n_layers % len(pat)))
    return pat, n_groups, tail


def init_lm(key, cfg: ModelConfig):
    pat, n_groups, tail = _group_kinds(cfg)
    k_embed, k_unembed, k_layers, k_tail, k_norm = jax.random.split(key, 5)
    dt = cfg.pdtype()

    def init_group(k):
        ks = jax.random.split(k, len(pat))
        return {f"blk{i}": init_block(ks[i], cfg, kind)
                for i, kind in enumerate(pat)}

    group_keys = jax.random.split(k_layers, max(n_groups, 1))
    groups = jax.vmap(init_group)(group_keys) if n_groups else None
    tail_keys = jax.random.split(k_tail, max(len(tail), 1))
    tail_params = tuple(init_block(tail_keys[i], cfg, kind)
                        for i, kind in enumerate(tail))

    params = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "unembed": embed_init(k_unembed, (cfg.d_model, cfg.vocab_size), dt),
        "final_norm": init_norm(k_norm, cfg.norm_type, cfg.d_model, dt),
        "groups": groups,
        "tail": tail_params,
    }
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------- sequence

def apply_block_seq(p, cfg: ModelConfig, kind: str, x, positions,
                    want_cache: bool = False):
    """-> (x, aux_loss, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind in ("attn", "local", "moe"):
        window = cfg.local_window if kind == "local" else 0
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        x = x + attn_lib.full_attention(p["attn"], cfg, h, positions, window)
        h2 = apply_norm(cfg.norm_type, p["norm2"], x)
        if kind == "moe":
            out, aux = moe_lib.apply_moe(p["moe"], cfg, h2)
        else:
            out = mlp_lib.apply_mlp(p["mlp"], cfg, h2)
        x = x + out
        # (attn-kind caches are built by the caller via _prefill_block_cache)
    elif kind == "rwkv":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, state = rwkv_lib.apply_rwkv_tmix(p["tmix"], cfg, h)
        x = x + o
        h2 = apply_norm(cfg.norm_type, p["norm2"], x)
        h2s = jnp.concatenate([jnp.zeros_like(h2[:, :1]), h2[:, :-1]], axis=1)
        x = x + mlp_lib.apply_mlp(p["cmix"], cfg, h2, h2s)
        if want_cache:
            state["x_cmix"] = h2[:, -1].astype(jnp.float32)
            cache = state
    elif kind == "rec":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, state = rglru_lib.apply_rglru_block(p["rec"], cfg, h)
        x = x + o
        x = x + mlp_lib.apply_mlp(p["mlp"], cfg, apply_norm(cfg.norm_type, p["norm2"], x))
        if want_cache:
            cache = state
    else:
        raise ValueError(kind)
    return x, aux, cache


def _prefill_block_cache(p, cfg: ModelConfig, kind: str, h, positions):
    """Recompute k/v of the (normed) layer input to build the decode cache."""
    b, s, _ = h.shape
    _, k, v = attn_lib._project_qkv(p["attn"], cfg, h, positions)
    if kind == "local":
        w = min(cfg.local_window, s)
        kw, vw = k[:, -w:], v[:, -w:]
        pw = positions[:, -w:]
        cache = attn_lib.init_local_cache(cfg, b, cfg.local_window, k.dtype)
        slots = jnp.mod(pw[0], cfg.local_window)
        cache["k"] = cache["k"].at[:, slots].set(kw)
        cache["v"] = cache["v"].at[:, slots].set(vw)
        cache["pos"] = cache["pos"].at[:, slots].set(pw)
        return cache
    return {"k": k, "v": v}


def _cim_read_state(params, pos, leaf, req_salt=None):
    """(per-plane seeds, thr_man, thr_meta, model) for CIM decode-on-read
    leaves.

    ``params['_cim']`` (optional, serving only) carries the dynamic-injection
    runtime: base counter-PRNG plane seeds plus per-field Bernoulli
    thresholds. Seeds are folded per the deployment key-derivation chain
    (:func:`repro.core.deployment.request_read_seeds`): a per-``leaf`` salt
    (so embed/unembed faults are uncorrelated), an optional per-request salt
    (the serving engine's batch-invariance contract), and the read index
    ``pos`` (so every prefill/decode step draws fresh soft errors) — per-read
    dynamic injection straight off the packed SRAM image. Absent, reads are
    static (the image serves whatever faults `cim.inject` left in it).

    An optional fault ``model`` in the runtime shapes the streams into a
    structured error process: a drift schedule keys its tick on the
    request-local ``pos`` — the thresholds returned here absorb that time
    scaling, so the model handed downstream always carries tick=0."""
    rt = params.get("_cim") if isinstance(params, dict) else None
    if rt is None:
        return None, 0, 0, None
    from repro.core import deployment as dep_lib
    from repro.core import faultmodels as fm_lib
    seeds = dep_lib.request_read_seeds(rt["seeds"], dep_lib.leaf_salt(leaf),
                                       req_salt, pos)
    model = rt.get("model")
    tm = fm_lib.compiled_threshold(model, rt["thr_man"], tick=pos)
    tt = fm_lib.compiled_threshold(model, rt["thr_meta"], tick=pos)
    if model is not None and model.kind == "drift":
        import dataclasses as _dc
        model = _dc.replace(model, tick=0)
    return seeds, tm, tt, model


def _embed_lookup(params, cfg: ModelConfig, tokens, pos=0, req_salt=None):
    """Token embedding gather; a CIMStore leaf is decoded row-by-row on read
    (only the gathered rows' codewords — no materialized fp16 table). The
    route lives in :func:`repro.core.deployment.dispatch_read_rows`."""
    dt = cfg.cdtype()
    emb = params["embed"]
    if isinstance(emb, cim_lib.CIMStore):
        from repro.core import deployment as dep_lib
        seeds, tm, tt, model = _cim_read_state(params, pos, "embed", req_salt)
        rows = dep_lib.dispatch_read_rows(emb, tokens, seeds=seeds,
                                          thr_man=tm, thr_meta=tt,
                                          model=model)
        return rows.astype(dt)
    return shard(emb.astype(dt), "vocab", None)[tokens]


def _unembed_logits(params, x, pos=0, req_salt=None):
    """Final projection; a CIMStore leaf routes through
    :func:`repro.core.deployment.dispatch_linear` — the single dispatch
    point that picks the fused decode-on-read Pallas kernel, its
    shard_map'd mesh twin (one program per macro column group, logits back
    vocab-sharded) or the GSPMD reference from the store's placement and
    dtype. No decoded weight matrix in HBM on any route."""
    w_un = params["unembed"]
    if isinstance(w_un, cim_lib.CIMStore):
        from repro.core import deployment as dep_lib
        from repro.kernels.cim_read import ops as cr_ops
        seeds, tm, tt, model = _cim_read_state(params, pos, "unembed",
                                               req_salt)
        scalars = cr_ops.make_scalars(seeds, tm, tt, model=model) \
            if seeds is not None else None
        return dep_lib.dispatch_linear(x, w_un, scalars=scalars, model=model)
    # FSDP: gather the (small, bf16) weight rather than partial-summing the
    # contraction over its "data"-sharded D axis — the latter all-reduces the
    # full fp32 logits (13 GB/step/device measured; the gather is 0.2 GB).
    w = shard(w_un.astype(x.dtype), None, "vocab")
    return x @ w


def _embed_inputs(params, cfg: ModelConfig, batch: Dict, pos=0):
    dt = cfg.cdtype()
    if cfg.modality == "vision_stub" and "vision_embeds" in batch:
        tok = _embed_lookup(params, cfg, batch["tokens"], pos)
        vis = batch["vision_embeds"].astype(dt)
        x = jnp.concatenate([vis, tok], axis=1)
    elif cfg.modality == "audio_stub" and "embeds" in batch:
        x = batch["embeds"].astype(dt)
    else:
        x = _embed_lookup(params, cfg, batch["tokens"], pos)
    return shard(x, "batch", "seq", None)


def forward(params, cfg: ModelConfig, batch: Dict, remat: bool = True,
            unroll: bool = False):
    """-> (logits [B,S,V], aux_loss, caches_or_None)."""
    pat, n_groups, tail = _group_kinds(cfg)
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def apply_group(gp, x, aux):
        for i, kind in enumerate(pat):
            x, a, _ = apply_block_seq(gp[f"blk{i}"], cfg, kind, x, positions)
            aux = aux + a
            x = shard(x, "batch", "seq", None)
        return x, aux

    group_fn = apply_group
    if remat:
        group_fn = jax.checkpoint(apply_group)

    aux = jnp.zeros((), jnp.float32)
    if n_groups:
        if unroll:
            # Python-loop over groups: every layer's ops/collectives appear
            # explicitly in the HLO (scan bodies are counted once by XLA cost
            # analysis — the dry-run extrapolates exact roofline terms from
            # 1-group and 2-group unrolled lowerings; DESIGN.md §6).
            for gi in range(n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[gi], params["groups"])
                x, aux = group_fn(gp, x, aux)
        else:
            def body(carry, gp):
                x, aux = carry
                x, aux = group_fn(gp, x, aux)
                return (x, aux), None
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["groups"])
    for i, kind in enumerate(tail):
        x, a, _ = apply_block_seq(params["tail"][i], cfg, kind, x, positions)
        aux = aux + a

    # Leave SP before the unembed: tokens unsharded on "model" so dlogits and
    # the hidden agree on the contraction layout — otherwise GSPMD computes
    # the unembed grad by all-gathering full-vocab fp32 dlogits (13 GB/step
    # per device measured at olmo-1b train_4k vs a 0.27 GB bf16 gather here).
    x = shard(x, "batch", None, None)
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = _unembed_logits(params, x)
    return shard(logits, "batch", None, "vocab"), aux, None


def prefill(params, cfg: ModelConfig, batch: Dict, unroll: bool = False):
    """Inference prefill: runs the sequence, returns last-token logits and the
    decode caches for every layer (scan-stacked for groups)."""
    pat, n_groups, tail = _group_kinds(cfg)
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def apply_group_cached(gp, x):
        caches = {}
        for i, kind in enumerate(pat):
            h_in = apply_norm(cfg.norm_type, gp[f"blk{i}"]["norm1"], x)
            x, _, c = apply_block_seq(gp[f"blk{i}"], cfg, kind, x, positions,
                                      want_cache=(kind in ("rwkv", "rec")))
            if kind in ("attn", "local", "moe"):
                c = _prefill_block_cache(gp[f"blk{i}"], cfg, kind, h_in, positions)
            caches[f"blk{i}"] = c
            x = shard(x, "batch", "seq", None)
        return x, caches

    group_caches = None
    if n_groups:
        if unroll:
            percall = []
            for gi in range(n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[gi], params["groups"])
                x, caches = apply_group_cached(gp, x)
                percall.append(caches)
            group_caches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *percall)
        else:
            def body(x, gp):
                x, caches = apply_group_cached(gp, x)
                return x, caches
            x, group_caches = jax.lax.scan(body, x, params["groups"])

    tail_caches = []
    for i, kind in enumerate(tail):
        h_in = apply_norm(cfg.norm_type, params["tail"][i]["norm1"], x)
        x, _, c = apply_block_seq(params["tail"][i], cfg, kind, x, positions,
                                  want_cache=(kind in ("rwkv", "rec")))
        if kind in ("attn", "local", "moe"):
            c = _prefill_block_cache(params["tail"][i], cfg, kind, h_in, positions)
        tail_caches.append(c)

    x = apply_norm(cfg.norm_type, params["final_norm"], x[:, -1:])
    logits = _unembed_logits(params, x)[:, 0]
    return logits, {"groups": group_caches, "tail": tuple(tail_caches),
                    "pos": jnp.asarray(s, jnp.int32)}


# ---------------------------------------------------------------- decode

def init_slot_state(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    """Protocol op 1: one block's zero slot state — KV rows for attn/moe, a
    rolling-window ring for local, the recurrent ``(wkv, x_shift)`` /
    rg-lru hidden state for rwkv/rec. Unknown kinds fail with the
    allowed-vocabulary error at :func:`slot_state_spec`."""
    slot_state_spec(kind)
    if kind in ("attn", "moe"):
        return attn_lib.init_kv_cache(cfg, batch, max_len)
    if kind == "local":
        return attn_lib.init_local_cache(cfg, batch,
                                         min(cfg.local_window, max_len))
    if kind == "rwkv":
        return rwkv_lib.init_rwkv_state(cfg, batch)
    return rglru_lib.init_rglru_state(cfg, batch)


def init_slot_states(cfg: ModelConfig, batch: int, max_len: int,
                     prefilled: int = 0):
    """Zero slot states for every layer, sized for ``max_len`` (dry-run
    serve_step input spec; the engine's decode batch)."""
    pat, n_groups, tail = _group_kinds(cfg)

    def stack(kind):
        c = init_slot_state(cfg, kind, batch, max_len)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), c)

    groups = {f"blk{i}": stack(kind) for i, kind in enumerate(pat)} \
        if n_groups else None
    return {"groups": groups,
            "tail": tuple(init_slot_state(cfg, kind, batch, max_len)
                          for kind in tail),
            "pos": jnp.asarray(prefilled, jnp.int32)}


def init_caches(cfg: ModelConfig, batch: int, max_len: int, prefilled: int = 0):
    """Deprecated shim: use :func:`init_slot_states` (bit-identical)."""
    warnings.warn("lm.init_caches is deprecated; use lm.init_slot_states",
                  DeprecationWarning, stacklevel=2)
    return init_slot_states(cfg, batch, max_len, prefilled)


def apply_block_decode(p, cfg: ModelConfig, kind: str, x, cache, pos):
    if kind in ("attn", "local", "moe"):
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        if kind == "local":
            o, cache = attn_lib.decode_local_attention(p["attn"], cfg, h, cache,
                                                       pos, cfg.local_window)
        else:
            o, cache = attn_lib.decode_attention(p["attn"], cfg, h, cache, pos)
        x = x + o
        h2 = apply_norm(cfg.norm_type, p["norm2"], x)
        if kind == "moe":
            out, _ = moe_lib.apply_moe(p["moe"], cfg, h2)
        else:
            out = mlp_lib.apply_mlp(p["mlp"], cfg, h2)
        x = x + out
    elif kind == "rwkv":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, state = rwkv_lib.decode_rwkv_tmix(p["tmix"], cfg, h, cache)
        x = x + o
        h2 = apply_norm(cfg.norm_type, p["norm2"], x)
        x = x + mlp_lib.apply_mlp(p["cmix"], cfg, h2,
                                  cache["x_cmix"].astype(h2.dtype)[:, None])
        state["x_cmix"] = h2[:, 0].astype(jnp.float32)
        cache = state
    elif kind == "rec":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, cache = rglru_lib.decode_rglru_block(p["rec"], cfg, h, cache)
        x = x + o
        x = x + mlp_lib.apply_mlp(p["mlp"], cfg, apply_norm(cfg.norm_type, p["norm2"], x))
    else:
        raise ValueError(kind)
    return x, cache


def apply_block_advance(p, cfg: ModelConfig, kind: str, x, cache, pos,
                        length):
    """Protocol op 2 (chunked prefill): advance one block's slot state by a
    prompt chunk x [B,C,D] at scalar offset ``pos``; the first ``length``
    tokens are valid, the ragged tail padding.

    ``'parallel'`` kinds are position-parallel: attn/moe pad rows land at
    positions the causal mask hides until overwritten (`decode_attention`
    handles S=C natively); local scatters valid rows into the ring and
    *drops* pad writes. ``'scan'`` kinds (rwkv/rec) run the sequence
    formulation with the carried state, identity-masking pads out of the
    left fold — compiled once per chunk shape, ``length`` traced. Output
    rows past ``length`` are garbage the caller must ignore.
    """
    if kind in ("attn", "moe"):
        return apply_block_decode(p, cfg, kind, x, cache, pos)
    if kind == "local":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, cache = attn_lib.advance_local_attention(p["attn"], cfg, h, cache,
                                                    pos, cfg.local_window,
                                                    length)
        x = x + o
        h2 = apply_norm(cfg.norm_type, p["norm2"], x)
        x = x + mlp_lib.apply_mlp(p["mlp"], cfg, h2)
    elif kind == "rwkv":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, state = rwkv_lib.advance_rwkv_tmix(p["tmix"], cfg, h, cache,
                                              length)
        x = x + o
        h2 = apply_norm(cfg.norm_type, p["norm2"], x)
        h2s = jnp.concatenate(
            [cache["x_cmix"].astype(h2.dtype)[:, None], h2[:, :-1]], axis=1)
        x = x + mlp_lib.apply_mlp(p["cmix"], cfg, h2, h2s)
        state["x_cmix"] = jax.lax.dynamic_slice_in_dim(
            h2, length - 1, 1, axis=1)[:, 0].astype(jnp.float32)
        cache = state
    elif kind == "rec":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, cache = rglru_lib.advance_rglru_block(p["rec"], cfg, h, cache,
                                                 length)
        x = x + o
        x = x + mlp_lib.apply_mlp(p["mlp"], cfg,
                                  apply_norm(cfg.norm_type, p["norm2"], x))
    else:
        raise ValueError(kind)
    return x, cache


def _decode_stack(params, cfg: ModelConfig, caches, x, pos,
                  unroll: bool = False, length=None):
    """Shared decode-path block stack: x [B,S,D] appended to the caches at
    offset ``pos`` (scalar, or per-slot [B] vector) -> (final-normed hidden
    [B,S,D], new group caches, new tail caches). With ``length`` (chunked
    prefill) blocks advance via :func:`apply_block_advance` — ragged chunks
    mask their padded tail out of recurrent folds and ring writes."""
    pat, n_groups, tail = _group_kinds(cfg)

    def step(p, kind, x, c):
        if length is None:
            return apply_block_decode(p, cfg, kind, x, c, pos)
        return apply_block_advance(p, cfg, kind, x, c, pos, length)

    new_group_caches = None
    if n_groups:
        def body(x, xs):
            gp, gc = xs
            out_c = {}
            for i, kind in enumerate(pat):
                x, c = step(gp[f"blk{i}"], kind, x, gc[f"blk{i}"])
                out_c[f"blk{i}"] = c
            return x, out_c
        if unroll:
            # measurement mode: do NOT restack the per-group caches — a
            # jnp.stack of sharded cache slices adds reshard copies that the
            # real scan path never performs (it would inflate decode roofline
            # terms ~20x; see EXPERIMENTS.md §Roofline methodology).
            percall = []
            for gi in range(n_groups):
                sel = lambda a: a[gi]
                x, out_c = body(x, (jax.tree_util.tree_map(sel, params["groups"]),
                                    jax.tree_util.tree_map(sel, caches["groups"])))
                percall.append(out_c)
            new_group_caches = tuple(percall)
        else:
            x, new_group_caches = jax.lax.scan(body, x,
                                               (params["groups"], caches["groups"]))

    new_tail = []
    for i, kind in enumerate(tail):
        x, c = step(params["tail"][i], kind, x, caches["tail"][i])
        new_tail.append(c)

    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    return x, new_group_caches, tuple(new_tail)


def decode(params, cfg: ModelConfig, caches, tokens, pos=None,
           unroll: bool = False):
    """One decode step. tokens [B,1] -> (logits [B,V], new caches)."""
    if pos is None:
        pos = caches["pos"]
    dt = cfg.cdtype()
    if isinstance(params["embed"], cim_lib.CIMStore):
        x = _embed_lookup(params, cfg, tokens, pos=pos)
    else:
        x = params["embed"].astype(dt)[tokens]
    x = shard(x, "batch", None, None)
    x, new_group_caches, new_tail = _decode_stack(params, cfg, caches, x, pos,
                                                  unroll=unroll)
    logits = _unembed_logits(params, x, pos=pos)[:, 0]
    return logits, {"groups": new_group_caches, "tail": new_tail,
                    "pos": pos + 1}


# ------------------------------------------------- continuous-batching engine
#
# Slot-state protocol: the engine/model boundary. Every block kind declares a
# SlotStateSpec, and the engine drives four kind-dispatched operations —
# init_slot_state / advance (prefill_chunk + decode_slots) /
# extract_state_chunk / inject_state_chunk — against it. The engine,
# PrefixCache and Fleet consume only this protocol; they never look inside a
# block's state pytree.

ENGINE_KINDS = ("attn", "local", "moe", "rwkv", "rec")

_SPEC_VOCAB = {"kind": ENGINE_KINDS,
               "advance": ("parallel", "scan"),
               "cache_unit": ("rows", "state")}


@dataclasses.dataclass(frozen=True)
class SlotStateSpec:
    """Per-block-kind contract of the serving engine's slot-state protocol.

    * ``advance`` — how a prompt chunk enters the state: ``'parallel'``
      (position-parallel attention over KV rows / ring slots) or ``'scan'``
      (strictly-recurrent left fold, compiled once per chunk shape).
    * ``cache_unit`` — the prefix cache's unit of reuse: ``'rows'`` states
      are position-addressable (a chunk extracts/injects the rows it wrote);
      ``'state'`` kinds cache the *final* state snapshot per trie node,
      which is exact because the state is a pure left fold over the salted
      prefix (see docs/architecture.md §8).
    * ``fold_state`` — the state is a destructive left fold with no position
      gating: the engine zeroes it on admission (``pos == 0``) and freezes
      it for inactive slots, where attention-style states instead rely on
      the causal mask to hide stale rows until overwritten.
    * ``window_bound`` — the state is a rolling window: the engine clamps
      its prefill chunk to the window so valid writes never collide.
    * ``capacity_coupled`` — co-batched tokens *may* couple through
      capacity-based dispatch; :func:`repro.models.moe.drop_free` decides
      whether a given engine shape actually voids the bitwise guarantee.

    Unknown vocabulary fails here, at construction — not deep inside
    ``advance``.
    """
    kind: str
    advance: str = "parallel"
    cache_unit: str = "rows"
    fold_state: bool = False
    window_bound: bool = False
    capacity_coupled: bool = False

    def __post_init__(self):
        for field, allowed in _SPEC_VOCAB.items():
            got = getattr(self, field)
            if got not in allowed:
                raise ValueError(
                    f"SlotStateSpec.{field}: unknown value {got!r}; allowed: "
                    f"{', '.join(repr(a) for a in allowed)}")


SLOT_STATE_SPECS = {
    "attn": SlotStateSpec("attn"),
    "moe": SlotStateSpec("moe", capacity_coupled=True),
    "local": SlotStateSpec("local", cache_unit="state", window_bound=True),
    "rwkv": SlotStateSpec("rwkv", advance="scan", cache_unit="state",
                          fold_state=True),
    "rec": SlotStateSpec("rec", advance="scan", cache_unit="state",
                         fold_state=True),
}


def slot_state_spec(kind: str) -> SlotStateSpec:
    """The :class:`SlotStateSpec` for one block kind (allowed-vocabulary
    error for unknown kinds)."""
    if kind not in SLOT_STATE_SPECS:
        raise ValueError(
            f"slot_state_spec: unknown block kind {kind!r}; allowed: "
            f"{', '.join(repr(k) for k in ENGINE_KINDS)}")
    return SLOT_STATE_SPECS[kind]


def slot_state_specs(cfg: ModelConfig) -> Tuple[SlotStateSpec, ...]:
    """The distinct specs an arch's block pattern uses (validates every
    kind up front — the engine calls this once at construction)."""
    pat, _, tail = _group_kinds(cfg)
    seen, out = set(), []
    for kind in tuple(pat) + tuple(tail):
        if kind not in seen:
            seen.add(kind)
            out.append(slot_state_spec(kind))
    return tuple(out)


def check_engine_kinds(cfg: ModelConfig) -> Tuple[SlotStateSpec, ...]:
    """Validate every block kind of ``cfg`` against the slot-state protocol
    (allowed-vocabulary error on unknown kinds) and return the specs.

    Since the protocol redesign every shipped kind is servable; MoE's
    capacity coupling is no longer a blanket warning here but a tested
    contract boundary the engine checks per shape
    (:func:`engine_capacity_coupled`)."""
    return slot_state_specs(cfg)


def engine_capacity_coupled(cfg: ModelConfig, tokens: int) -> bool:
    """True when serving ``cfg`` at batches up to ``tokens`` tokens can
    couple co-batched requests through capacity-based MoE dispatch — i.e.
    some spec is ``capacity_coupled`` AND the shape is not provably
    drop-free. Drop-free configs keep the bitwise solo-vs-cobatched
    guarantee (see :func:`repro.models.moe.drop_free`)."""
    if not any(s.capacity_coupled for s in slot_state_specs(cfg)):
        return False
    return not moe_lib.drop_free(cfg, tokens)


def _map_block_states(cfg: ModelConfig, sub, fn):
    """Apply ``fn(kind, *block_states)`` to every block of one or more
    structurally-aligned slot-cache views (the protocol's kind-dispatch
    walk)."""
    pat, n_groups, tail = _group_kinds(cfg)
    subs = sub if isinstance(sub, tuple) else (sub,)
    g = None
    if subs[0]["groups"] is not None:
        g = {f"blk{i}": fn(kind, *(s["groups"][f"blk{i}"] for s in subs))
             for i, kind in enumerate(pat)}
    t = tuple(fn(kind, *(s["tail"][i] for s in subs))
              for i, kind in enumerate(tail))
    return {"groups": g, "tail": t}


def slot_caches(caches, slot):
    """One slot's decode caches as a batch-1 view (the batch axis sits at
    axis 1 under the scan-stacked groups, axis 0 in the tail)."""
    g = caches["groups"]
    if g is not None:
        g = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), g)
    t = jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0),
        caches["tail"])
    return {"groups": g, "tail": t}


def merge_slot_caches(caches, slot, sub):
    """Write a batch-1 slot cache view back into the batched caches."""
    g = caches["groups"]
    if g is not None:
        g = jax.tree_util.tree_map(
            lambda a, b: jax.lax.dynamic_update_slice_in_dim(
                a, b.astype(a.dtype), slot, axis=1), g, sub["groups"])
    t = jax.tree_util.tree_map(
        lambda a, b: jax.lax.dynamic_update_slice_in_dim(
            a, b.astype(a.dtype), slot, axis=0), caches["tail"], sub["tail"])
    return {"groups": g, "tail": t, "pos": caches["pos"]}


def extract_state_chunk(cfg: ModelConfig, caches, slot, pos, length: int):
    """Protocol op 3: one slot's per-block state contribution of the chunk
    that just prefilled positions ``[pos, pos + length)``.

    Kind-dispatched on ``SlotStateSpec.cache_unit``: ``'rows'`` blocks
    (attn/moe — KV leaves and their int8 scales carry the position axis at
    ``-3``) return exactly the rows the chunk wrote; ``'state'`` blocks
    (local/rwkv/rec) return the full post-chunk state snapshot — exact as a
    prefix-cache unit because their state at a chunk boundary is a pure
    left fold of the salted prefix (ring writes are position-gated, the
    recurrences fold left-to-right). The returned pytree is what
    :func:`inject_state_chunk` consumes. ``length`` is static (one trace
    per chunk shape); ``slot``/``pos`` are traced.
    """
    check_engine_kinds(cfg)
    sub = slot_caches(caches, slot)

    def ex(kind, c):
        if slot_state_spec(kind).cache_unit == "rows":
            return jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, pos, length,
                                                       axis=a.ndim - 3), c)
        return c
    return _map_block_states(cfg, sub, ex)


def inject_state_chunk(cfg: ModelConfig, caches, slot, pos, chunk):
    """Protocol op 4: prefill-from-cache entry — write a previously
    extracted state chunk into ``slot`` at positions
    ``[pos, pos + chunk_len)`` and return the updated caches.

    ``'rows'`` blocks write the rows back in place; ``'state'`` blocks
    overwrite the whole snapshot (injecting a trie path's chunks in order
    leaves the last — deepest — snapshot standing, which IS the state after
    that prefix). Injecting what another request prefilled for the same
    token prefix (same content-salted fault streams, same image) leaves the
    caches bitwise identical to having run :func:`prefill_chunk` on the
    chunk — the prefix cache skips the compute, not the contract. The
    caller still owns ``caches['pos']``.
    """
    check_engine_kinds(cfg)
    sub = slot_caches(caches, slot)

    def inj(kind, c, ch):
        if slot_state_spec(kind).cache_unit == "rows":
            return jax.tree_util.tree_map(
                lambda a, b: jax.lax.dynamic_update_slice_in_dim(
                    a, b.astype(a.dtype), pos, axis=a.ndim - 3), c, ch)
        return jax.tree_util.tree_map(lambda a, b: b.astype(a.dtype), c, ch)
    upd = _map_block_states(cfg, (sub, chunk), inj)
    return merge_slot_caches(caches, slot, upd)


def extract_kv_chunk(cfg: ModelConfig, caches, slot, pos, length: int):
    """Deprecated shim: use :func:`extract_state_chunk` (bit-identical)."""
    warnings.warn(
        "lm.extract_kv_chunk is deprecated; use lm.extract_state_chunk",
        DeprecationWarning, stacklevel=2)
    return extract_state_chunk(cfg, caches, slot, pos, length)


def inject_kv_chunk(cfg: ModelConfig, caches, slot, pos, chunk):
    """Deprecated shim: use :func:`inject_state_chunk` (bit-identical)."""
    warnings.warn(
        "lm.inject_kv_chunk is deprecated; use lm.inject_state_chunk",
        DeprecationWarning, stacklevel=2)
    return inject_state_chunk(cfg, caches, slot, pos, chunk)


def prefill_chunk(params, cfg: ModelConfig, caches, tokens, slot, pos,
                  length=None, req_salt=None):
    """Chunked prefill of ONE slot into the batched decode caches.

    ``tokens`` [C] is one prompt chunk (the first ``length`` entries valid;
    the ragged tail is padding — attn/moe pad K/V land at positions the
    causal mask hides until a later write overwrites them, local drops pad
    ring writes, and the recurrent kinds identity-mask pads out of their
    left fold; see :func:`apply_block_advance`). ``slot`` indexes the batch
    row, ``pos`` is the slot's current token count, ``req_salt`` keys this
    request's dynamic-injection streams (the chunk reads the CIM image
    once, at read index ``pos``).

    A chunk at ``pos == 0`` starts a fresh request: ``fold_state`` blocks
    (rwkv/rec) have their slot state zeroed first — without position-gated
    writes, the previous occupant's fold would otherwise leak into the new
    request (attention-style states need no reset; stale rows stay masked
    until overwritten).

    Returns (last-valid-token logits [V], updated caches with
    ``caches['pos'][slot] = pos + length``). Both ``slot`` and ``pos`` are
    traced, so one jit covers every slot and offset per chunk shape.
    """
    check_engine_kinds(cfg)
    if length is None:
        length = tokens.shape[0]
    length = jnp.asarray(length, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    dt = cfg.cdtype()
    toks = tokens[None]                                       # [1, C]
    if isinstance(params["embed"], cim_lib.CIMStore):
        x = _embed_lookup(params, cfg, toks, pos=pos, req_salt=req_salt)
    else:
        x = params["embed"].astype(dt)[toks]
    x = shard(x, "batch", None, None)
    sub = slot_caches(caches, slot)
    if any(s.fold_state for s in slot_state_specs(cfg)):
        fresh = pos == 0

        def reset(kind, c):
            if not slot_state_spec(kind).fold_state:
                return c
            return jax.tree_util.tree_map(
                lambda a: jnp.where(fresh, jnp.zeros_like(a), a), c)
        sub = _map_block_states(cfg, sub, reset)
    x, gc, tc = _decode_stack(params, cfg, sub, x, pos, length=length)
    h = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)  # [1,1,D]
    logits = _unembed_logits(params, h, pos=pos, req_salt=req_salt)[:, 0]
    out = merge_slot_caches(caches, slot, {"groups": gc, "tail": tc})
    out["pos"] = caches["pos"].at[slot].set(pos + length)
    return logits[0], out


def decode_slots(params, cfg: ModelConfig, caches, tokens, active,
                 req_salts=None):
    """One continuous-batching decode step across the slot batch.

    ``tokens`` [S,1] (each slot's last token; inactive slots' values are
    irrelevant), per-slot positions ride in ``caches['pos']`` [S], ``active``
    [S] bool. ``req_salts`` [S] uint32 (see
    :func:`repro.core.deployment.request_salt`) key each slot's
    dynamic-injection CIM reads by (request, position) — never by slot index
    or engine step — so a request's logits and fault streams are
    bit-identical served alone or continuously co-batched. Per-request reads
    run one slot at a time against the packed image (each slot IS a distinct
    macro read with its own counter-PRNG streams); static images read
    batched, which is invariant for free (no seeds in the chain).

    Inactive slots flow through the fixed-shape batch but their positions do
    not advance; their stale cache writes stay causally masked (see
    ``attention.decode_attention``), and ``fold_state`` blocks (rwkv/rec —
    no position gating) have their state frozen to the old value so an idle
    slot's garbage tokens never advance a fold.

    Returns (logits [S,V], new caches).
    """
    check_engine_kinds(cfg)
    pos = caches["pos"]                                       # [S]
    s = tokens.shape[0]
    dt = cfg.cdtype()
    dynamic = isinstance(params, dict) and params.get("_cim") is not None
    if dynamic and req_salts is None:
        raise ValueError(
            "decode_slots: params carry a dynamic-injection '_cim' runtime "
            "but no req_salts — per-read seeds would alias across requests; "
            "pass deployment.request_salt(rid) per slot")
    emb = params["embed"]
    if isinstance(emb, cim_lib.CIMStore) and dynamic:
        x = jnp.concatenate(
            [_embed_lookup(params, cfg, tokens[i:i + 1], pos=pos[i],
                           req_salt=req_salts[i]) for i in range(s)], axis=0)
    elif isinstance(emb, cim_lib.CIMStore):
        x = _embed_lookup(params, cfg, tokens)
    else:
        x = emb.astype(dt)[tokens]
    x = shard(x, "batch", None, None)
    x, gc, tc = _decode_stack(params, cfg, caches, x, pos)
    if any(sp.fold_state for sp in slot_state_specs(cfg)):
        act = jnp.asarray(active, bool)

        def keep_active(axis):
            def f(n, o):
                shape = [1] * n.ndim
                shape[axis] = act.shape[0]
                return jnp.where(act.reshape(shape), n, o)
            return f

        pat, _, tail_kinds = _group_kinds(cfg)
        if gc is not None:
            gc = {f"blk{i}": jax.tree_util.tree_map(
                      keep_active(1), gc[f"blk{i}"],
                      caches["groups"][f"blk{i}"])
                  if slot_state_spec(kind).fold_state else gc[f"blk{i}"]
                  for i, kind in enumerate(pat)}
        tc = tuple(jax.tree_util.tree_map(keep_active(0), tc[i],
                                          caches["tail"][i])
                   if slot_state_spec(kind).fold_state else tc[i]
                   for i, kind in enumerate(tail_kinds))
    if isinstance(params["unembed"], cim_lib.CIMStore) and dynamic:
        logits = jnp.concatenate(
            [_unembed_logits(params, x[i:i + 1], pos=pos[i],
                             req_salt=req_salts[i]) for i in range(s)],
            axis=0)[:, 0]
    else:
        logits = _unembed_logits(params, x)[:, 0]
    return logits, {"groups": gc, "tail": tc,
                    "pos": pos + active.astype(jnp.int32)}
