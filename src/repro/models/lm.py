"""Unified decoder LM covering all ten assigned architectures.

A model is a cycle of block kinds (``cfg.block_pattern``) over ``n_layers``:

  * ``attn``  — GQA attention + dense MLP          (dense family, VLM, audio)
  * ``local`` — windowed attention + dense MLP      (recurrentgemma 1/3 layers)
  * ``moe``   — GQA attention + MoE FFN             (qwen3-moe, dbrx)
  * ``rwkv``  — RWKV6 time-mix + channel-mix        (attention-free)
  * ``rec``   — RG-LRU recurrent block + dense MLP  (recurrentgemma 2/3 layers)

Layers are stacked into pattern *groups* and iterated with ``lax.scan``
(+ optional ``jax.checkpoint``), which keeps HLO size and compile time bounded
at 80–94 layers and makes the saved residual stream a single ``[G, B, S, D]``
tensor that the sharding rules distribute over both mesh axes.

Three entry points per model: ``forward`` (training), ``prefill`` (returns
last-token logits + caches) and ``decode`` (one token against caches).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cim as cim_lib
from repro.distributed import sharding as shlib
from repro.distributed.sharding import shard
from repro.models import attention as attn_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models.common import apply_norm, embed_init, init_norm


# ---------------------------------------------------------------- init

def init_block(key, cfg: ModelConfig, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.pdtype()
    p = {"norm1": init_norm(k3, cfg.norm_type, cfg.d_model, dt),
         "norm2": init_norm(k3, cfg.norm_type, cfg.d_model, dt)}
    if kind in ("attn", "local"):
        p["attn"] = attn_lib.init_attention(k1, cfg)
        p["mlp"] = mlp_lib.init_mlp(k2, cfg)
    elif kind == "moe":
        p["attn"] = attn_lib.init_attention(k1, cfg)
        p["moe"] = moe_lib.init_moe(k2, cfg)
    elif kind == "rwkv":
        p["tmix"] = rwkv_lib.init_rwkv_tmix(k1, cfg)
        p["cmix"] = mlp_lib.init_mlp(k2, cfg)
    elif kind == "rec":
        p["rec"] = rglru_lib.init_rglru_block(k1, cfg)
        p["mlp"] = mlp_lib.init_mlp(k2, cfg)
    else:
        raise ValueError(kind)
    return p


def _group_kinds(cfg: ModelConfig):
    pat = cfg.block_pattern
    n_groups = cfg.n_layers // len(pat)
    tail = tuple(pat[i] for i in range(cfg.n_layers % len(pat)))
    return pat, n_groups, tail


def init_lm(key, cfg: ModelConfig):
    pat, n_groups, tail = _group_kinds(cfg)
    k_embed, k_unembed, k_layers, k_tail, k_norm = jax.random.split(key, 5)
    dt = cfg.pdtype()

    def init_group(k):
        ks = jax.random.split(k, len(pat))
        return {f"blk{i}": init_block(ks[i], cfg, kind)
                for i, kind in enumerate(pat)}

    group_keys = jax.random.split(k_layers, max(n_groups, 1))
    groups = jax.vmap(init_group)(group_keys) if n_groups else None
    tail_keys = jax.random.split(k_tail, max(len(tail), 1))
    tail_params = tuple(init_block(tail_keys[i], cfg, kind)
                        for i, kind in enumerate(tail))

    params = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "unembed": embed_init(k_unembed, (cfg.d_model, cfg.vocab_size), dt),
        "final_norm": init_norm(k_norm, cfg.norm_type, cfg.d_model, dt),
        "groups": groups,
        "tail": tail_params,
    }
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------- sequence

def apply_block_seq(p, cfg: ModelConfig, kind: str, x, positions,
                    want_cache: bool = False):
    """-> (x, aux_loss, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind in ("attn", "local", "moe"):
        window = cfg.local_window if kind == "local" else 0
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        x = x + attn_lib.full_attention(p["attn"], cfg, h, positions, window)
        h2 = apply_norm(cfg.norm_type, p["norm2"], x)
        if kind == "moe":
            out, aux = moe_lib.apply_moe(p["moe"], cfg, h2)
        else:
            out = mlp_lib.apply_mlp(p["mlp"], cfg, h2)
        x = x + out
        # (attn-kind caches are built by the caller via _prefill_block_cache)
    elif kind == "rwkv":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, state = rwkv_lib.apply_rwkv_tmix(p["tmix"], cfg, h)
        x = x + o
        h2 = apply_norm(cfg.norm_type, p["norm2"], x)
        h2s = jnp.concatenate([jnp.zeros_like(h2[:, :1]), h2[:, :-1]], axis=1)
        x = x + mlp_lib.apply_mlp(p["cmix"], cfg, h2, h2s)
        if want_cache:
            state["x_cmix"] = h2[:, -1].astype(jnp.float32)
            cache = state
    elif kind == "rec":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, state = rglru_lib.apply_rglru_block(p["rec"], cfg, h)
        x = x + o
        x = x + mlp_lib.apply_mlp(p["mlp"], cfg, apply_norm(cfg.norm_type, p["norm2"], x))
        if want_cache:
            cache = state
    else:
        raise ValueError(kind)
    return x, aux, cache


def _prefill_block_cache(p, cfg: ModelConfig, kind: str, h, positions):
    """Recompute k/v of the (normed) layer input to build the decode cache."""
    b, s, _ = h.shape
    _, k, v = attn_lib._project_qkv(p["attn"], cfg, h, positions)
    if kind == "local":
        w = min(cfg.local_window, s)
        kw, vw = k[:, -w:], v[:, -w:]
        pw = positions[:, -w:]
        cache = attn_lib.init_local_cache(cfg, b, cfg.local_window, k.dtype)
        slots = jnp.mod(pw[0], cfg.local_window)
        cache["k"] = cache["k"].at[:, slots].set(kw)
        cache["v"] = cache["v"].at[:, slots].set(vw)
        cache["pos"] = cache["pos"].at[:, slots].set(pw)
        return cache
    return {"k": k, "v": v}


def _cim_read_state(params, pos, leaf, req_salt=None):
    """(per-plane seeds, thr_man, thr_meta, model) for CIM decode-on-read
    leaves.

    ``params['_cim']`` (optional, serving only) carries the dynamic-injection
    runtime: base counter-PRNG plane seeds plus per-field Bernoulli
    thresholds. Seeds are folded per the deployment key-derivation chain
    (:func:`repro.core.deployment.request_read_seeds`): a per-``leaf`` salt
    (so embed/unembed faults are uncorrelated), an optional per-request salt
    (the serving engine's batch-invariance contract), and the read index
    ``pos`` (so every prefill/decode step draws fresh soft errors) — per-read
    dynamic injection straight off the packed SRAM image. Absent, reads are
    static (the image serves whatever faults `cim.inject` left in it).

    An optional fault ``model`` in the runtime shapes the streams into a
    structured error process: a drift schedule keys its tick on the
    request-local ``pos`` — the thresholds returned here absorb that time
    scaling, so the model handed downstream always carries tick=0."""
    rt = params.get("_cim") if isinstance(params, dict) else None
    if rt is None:
        return None, 0, 0, None
    from repro.core import deployment as dep_lib
    from repro.core import faultmodels as fm_lib
    seeds = dep_lib.request_read_seeds(rt["seeds"], dep_lib.leaf_salt(leaf),
                                       req_salt, pos)
    model = rt.get("model")
    tm = fm_lib.compiled_threshold(model, rt["thr_man"], tick=pos)
    tt = fm_lib.compiled_threshold(model, rt["thr_meta"], tick=pos)
    if model is not None and model.kind == "drift":
        import dataclasses as _dc
        model = _dc.replace(model, tick=0)
    return seeds, tm, tt, model


def _embed_lookup(params, cfg: ModelConfig, tokens, pos=0, req_salt=None):
    """Token embedding gather; a CIMStore leaf is decoded row-by-row on read
    (only the gathered rows' codewords — no materialized fp16 table). The
    route lives in :func:`repro.core.deployment.dispatch_read_rows`."""
    dt = cfg.cdtype()
    emb = params["embed"]
    if isinstance(emb, cim_lib.CIMStore):
        from repro.core import deployment as dep_lib
        seeds, tm, tt, model = _cim_read_state(params, pos, "embed", req_salt)
        rows = dep_lib.dispatch_read_rows(emb, tokens, seeds=seeds,
                                          thr_man=tm, thr_meta=tt,
                                          model=model)
        return rows.astype(dt)
    return shard(emb.astype(dt), "vocab", None)[tokens]


def _unembed_logits(params, x, pos=0, req_salt=None):
    """Final projection; a CIMStore leaf routes through
    :func:`repro.core.deployment.dispatch_linear` — the single dispatch
    point that picks the fused decode-on-read Pallas kernel, its
    shard_map'd mesh twin (one program per macro column group, logits back
    vocab-sharded) or the GSPMD reference from the store's placement and
    dtype. No decoded weight matrix in HBM on any route."""
    w_un = params["unembed"]
    if isinstance(w_un, cim_lib.CIMStore):
        from repro.core import deployment as dep_lib
        from repro.kernels.cim_read import ops as cr_ops
        seeds, tm, tt, model = _cim_read_state(params, pos, "unembed",
                                               req_salt)
        scalars = cr_ops.make_scalars(seeds, tm, tt, model=model) \
            if seeds is not None else None
        return dep_lib.dispatch_linear(x, w_un, scalars=scalars, model=model)
    # FSDP: gather the (small, bf16) weight rather than partial-summing the
    # contraction over its "data"-sharded D axis — the latter all-reduces the
    # full fp32 logits (13 GB/step/device measured; the gather is 0.2 GB).
    w = shard(w_un.astype(x.dtype), None, "vocab")
    return x @ w


def _embed_inputs(params, cfg: ModelConfig, batch: Dict, pos=0):
    dt = cfg.cdtype()
    if cfg.modality == "vision_stub" and "vision_embeds" in batch:
        tok = _embed_lookup(params, cfg, batch["tokens"], pos)
        vis = batch["vision_embeds"].astype(dt)
        x = jnp.concatenate([vis, tok], axis=1)
    elif cfg.modality == "audio_stub" and "embeds" in batch:
        x = batch["embeds"].astype(dt)
    else:
        x = _embed_lookup(params, cfg, batch["tokens"], pos)
    return shard(x, "batch", "seq", None)


def forward(params, cfg: ModelConfig, batch: Dict, remat: bool = True,
            unroll: bool = False):
    """-> (logits [B,S,V], aux_loss, caches_or_None)."""
    pat, n_groups, tail = _group_kinds(cfg)
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def apply_group(gp, x, aux):
        for i, kind in enumerate(pat):
            x, a, _ = apply_block_seq(gp[f"blk{i}"], cfg, kind, x, positions)
            aux = aux + a
            x = shard(x, "batch", "seq", None)
        return x, aux

    group_fn = apply_group
    if remat:
        group_fn = jax.checkpoint(apply_group)

    aux = jnp.zeros((), jnp.float32)
    if n_groups:
        if unroll:
            # Python-loop over groups: every layer's ops/collectives appear
            # explicitly in the HLO (scan bodies are counted once by XLA cost
            # analysis — the dry-run extrapolates exact roofline terms from
            # 1-group and 2-group unrolled lowerings; DESIGN.md §6).
            for gi in range(n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[gi], params["groups"])
                x, aux = group_fn(gp, x, aux)
        else:
            def body(carry, gp):
                x, aux = carry
                x, aux = group_fn(gp, x, aux)
                return (x, aux), None
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["groups"])
    for i, kind in enumerate(tail):
        x, a, _ = apply_block_seq(params["tail"][i], cfg, kind, x, positions)
        aux = aux + a

    # Leave SP before the unembed: tokens unsharded on "model" so dlogits and
    # the hidden agree on the contraction layout — otherwise GSPMD computes
    # the unembed grad by all-gathering full-vocab fp32 dlogits (13 GB/step
    # per device measured at olmo-1b train_4k vs a 0.27 GB bf16 gather here).
    x = shard(x, "batch", None, None)
    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = _unembed_logits(params, x)
    return shard(logits, "batch", None, "vocab"), aux, None


def prefill(params, cfg: ModelConfig, batch: Dict, unroll: bool = False):
    """Inference prefill: runs the sequence, returns last-token logits and the
    decode caches for every layer (scan-stacked for groups)."""
    pat, n_groups, tail = _group_kinds(cfg)
    x = _embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def apply_group_cached(gp, x):
        caches = {}
        for i, kind in enumerate(pat):
            h_in = apply_norm(cfg.norm_type, gp[f"blk{i}"]["norm1"], x)
            x, _, c = apply_block_seq(gp[f"blk{i}"], cfg, kind, x, positions,
                                      want_cache=(kind in ("rwkv", "rec")))
            if kind in ("attn", "local", "moe"):
                c = _prefill_block_cache(gp[f"blk{i}"], cfg, kind, h_in, positions)
            caches[f"blk{i}"] = c
            x = shard(x, "batch", "seq", None)
        return x, caches

    group_caches = None
    if n_groups:
        if unroll:
            percall = []
            for gi in range(n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[gi], params["groups"])
                x, caches = apply_group_cached(gp, x)
                percall.append(caches)
            group_caches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *percall)
        else:
            def body(x, gp):
                x, caches = apply_group_cached(gp, x)
                return x, caches
            x, group_caches = jax.lax.scan(body, x, params["groups"])

    tail_caches = []
    for i, kind in enumerate(tail):
        h_in = apply_norm(cfg.norm_type, params["tail"][i]["norm1"], x)
        x, _, c = apply_block_seq(params["tail"][i], cfg, kind, x, positions,
                                  want_cache=(kind in ("rwkv", "rec")))
        if kind in ("attn", "local", "moe"):
            c = _prefill_block_cache(params["tail"][i], cfg, kind, h_in, positions)
        tail_caches.append(c)

    x = apply_norm(cfg.norm_type, params["final_norm"], x[:, -1:])
    logits = _unembed_logits(params, x)[:, 0]
    return logits, {"groups": group_caches, "tail": tuple(tail_caches),
                    "pos": jnp.asarray(s, jnp.int32)}


# ---------------------------------------------------------------- decode

def init_caches(cfg: ModelConfig, batch: int, max_len: int, prefilled: int = 0):
    """Zero caches sized for ``max_len`` (dry-run serve_step input spec)."""
    pat, n_groups, tail = _group_kinds(cfg)

    def one(kind):
        if kind in ("attn", "moe"):
            return attn_lib.init_kv_cache(cfg, batch, max_len)
        if kind == "local":
            return attn_lib.init_local_cache(cfg, batch,
                                             min(cfg.local_window, max_len))
        if kind == "rwkv":
            return rwkv_lib.init_rwkv_state(cfg, batch)
        if kind == "rec":
            return rglru_lib.init_rglru_state(cfg, batch)
        raise ValueError(kind)

    def stack(kind):
        c = one(kind)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), c)

    groups = {f"blk{i}": stack(kind) for i, kind in enumerate(pat)} \
        if n_groups else None
    return {"groups": groups,
            "tail": tuple(one(kind) for kind in tail),
            "pos": jnp.asarray(prefilled, jnp.int32)}


def apply_block_decode(p, cfg: ModelConfig, kind: str, x, cache, pos):
    if kind in ("attn", "local", "moe"):
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        if kind == "local":
            o, cache = attn_lib.decode_local_attention(p["attn"], cfg, h, cache,
                                                       pos, cfg.local_window)
        else:
            o, cache = attn_lib.decode_attention(p["attn"], cfg, h, cache, pos)
        x = x + o
        h2 = apply_norm(cfg.norm_type, p["norm2"], x)
        if kind == "moe":
            out, _ = moe_lib.apply_moe(p["moe"], cfg, h2)
        else:
            out = mlp_lib.apply_mlp(p["mlp"], cfg, h2)
        x = x + out
    elif kind == "rwkv":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, state = rwkv_lib.decode_rwkv_tmix(p["tmix"], cfg, h, cache)
        x = x + o
        h2 = apply_norm(cfg.norm_type, p["norm2"], x)
        x = x + mlp_lib.apply_mlp(p["cmix"], cfg, h2,
                                  cache["x_cmix"].astype(h2.dtype)[:, None])
        state["x_cmix"] = h2[:, 0].astype(jnp.float32)
        cache = state
    elif kind == "rec":
        h = apply_norm(cfg.norm_type, p["norm1"], x)
        o, cache = rglru_lib.decode_rglru_block(p["rec"], cfg, h, cache)
        x = x + o
        x = x + mlp_lib.apply_mlp(p["mlp"], cfg, apply_norm(cfg.norm_type, p["norm2"], x))
    else:
        raise ValueError(kind)
    return x, cache


def _decode_stack(params, cfg: ModelConfig, caches, x, pos,
                  unroll: bool = False):
    """Shared decode-path block stack: x [B,S,D] appended to the caches at
    offset ``pos`` (scalar, or per-slot [B] vector) -> (final-normed hidden
    [B,S,D], new group caches, new tail caches)."""
    pat, n_groups, tail = _group_kinds(cfg)
    new_group_caches = None
    if n_groups:
        def body(x, xs):
            gp, gc = xs
            out_c = {}
            for i, kind in enumerate(pat):
                x, c = apply_block_decode(gp[f"blk{i}"], cfg, kind, x,
                                          gc[f"blk{i}"], pos)
                out_c[f"blk{i}"] = c
            return x, out_c
        if unroll:
            # measurement mode: do NOT restack the per-group caches — a
            # jnp.stack of sharded cache slices adds reshard copies that the
            # real scan path never performs (it would inflate decode roofline
            # terms ~20x; see EXPERIMENTS.md §Roofline methodology).
            percall = []
            for gi in range(n_groups):
                sel = lambda a: a[gi]
                x, out_c = body(x, (jax.tree_util.tree_map(sel, params["groups"]),
                                    jax.tree_util.tree_map(sel, caches["groups"])))
                percall.append(out_c)
            new_group_caches = tuple(percall)
        else:
            x, new_group_caches = jax.lax.scan(body, x,
                                               (params["groups"], caches["groups"]))

    new_tail = []
    for i, kind in enumerate(tail):
        x, c = apply_block_decode(params["tail"][i], cfg, kind, x,
                                  caches["tail"][i], pos)
        new_tail.append(c)

    x = apply_norm(cfg.norm_type, params["final_norm"], x)
    return x, new_group_caches, tuple(new_tail)


def decode(params, cfg: ModelConfig, caches, tokens, pos=None,
           unroll: bool = False):
    """One decode step. tokens [B,1] -> (logits [B,V], new caches)."""
    if pos is None:
        pos = caches["pos"]
    dt = cfg.cdtype()
    if isinstance(params["embed"], cim_lib.CIMStore):
        x = _embed_lookup(params, cfg, tokens, pos=pos)
    else:
        x = params["embed"].astype(dt)[tokens]
    x = shard(x, "batch", None, None)
    x, new_group_caches, new_tail = _decode_stack(params, cfg, caches, x, pos,
                                                  unroll=unroll)
    logits = _unembed_logits(params, x, pos=pos)[:, 0]
    return logits, {"groups": new_group_caches, "tail": new_tail,
                    "pos": pos + 1}


# ------------------------------------------------- continuous-batching engine

# block kinds the slot-based serving engine supports. "local"/"rwkv"/"rec"
# decode strictly token-by-token (rolling-window slots, recurrent state), so
# they cannot chunk-prefill; MoE *runs* (with a warning) but its
# capacity-based dispatch couples co-batched tokens, which voids the
# bit-invariance contract (dense blocks are row-independent — see
# docs/architecture.md §8).
ENGINE_KINDS = ("attn", "moe")


def check_engine_kinds(cfg: ModelConfig) -> None:
    pat, _, tail = _group_kinds(cfg)
    kinds = tuple(pat) + tuple(tail)
    bad = sorted(set(k for k in kinds if k not in ENGINE_KINDS))
    if bad:
        raise ValueError(
            f"serving engine supports block kinds {ENGINE_KINDS}, but arch "
            f"{cfg.arch_id!r} uses {bad}: local/rwkv/rec blocks decode "
            f"strictly token-by-token and cannot chunk-prefill into slots")
    if "moe" in kinds:
        import warnings
        warnings.warn(
            f"serving engine on MoE arch {cfg.arch_id!r}: capacity-based "
            f"expert dispatch couples co-batched tokens, so the engine's "
            f"bitwise batch-invariance contract does NOT hold (fault-stream "
            f"keying is still per-request)", stacklevel=2)


def slot_caches(caches, slot):
    """One slot's decode caches as a batch-1 view (the batch axis sits at
    axis 1 under the scan-stacked groups, axis 0 in the tail)."""
    g = caches["groups"]
    if g is not None:
        g = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), g)
    t = jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0),
        caches["tail"])
    return {"groups": g, "tail": t}


def merge_slot_caches(caches, slot, sub):
    """Write a batch-1 slot cache view back into the batched caches."""
    g = caches["groups"]
    if g is not None:
        g = jax.tree_util.tree_map(
            lambda a, b: jax.lax.dynamic_update_slice_in_dim(
                a, b.astype(a.dtype), slot, axis=1), g, sub["groups"])
    t = jax.tree_util.tree_map(
        lambda a, b: jax.lax.dynamic_update_slice_in_dim(
            a, b.astype(a.dtype), slot, axis=0), caches["tail"], sub["tail"])
    return {"groups": g, "tail": t, "pos": caches["pos"]}


def extract_kv_chunk(cfg: ModelConfig, caches, slot, pos, length: int):
    """One slot's KV-cache rows for positions ``[pos, pos + length)``.

    The engine-kind cache leaves (k/v and their int8 scales) all carry the
    position axis at ``-3``, so a chunk is a uniform slice. The returned
    pytree is exactly what :func:`inject_kv_chunk` consumes — the prefix
    cache's unit of reuse. ``length`` is static (one trace per chunk shape);
    ``slot``/``pos`` are traced.
    """
    check_engine_kinds(cfg)
    sub = slot_caches(caches, slot)
    return jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, pos, length,
                                               axis=a.ndim - 3), sub)


def inject_kv_chunk(cfg: ModelConfig, caches, slot, pos, chunk):
    """Prefill-from-cached-KV entry: write a previously extracted KV chunk
    into ``slot`` at positions ``[pos, pos + chunk_len)`` and return the
    updated caches.

    For engine block kinds (attn/moe) the KV rows are the *complete* layer
    state of those positions, so injecting rows another request prefilled
    for the same token prefix (same content-salted fault streams, same
    image) leaves the caches bitwise identical to having run
    :func:`prefill_chunk` on the chunk — the prefix cache skips the compute,
    not the contract. The caller still owns ``caches['pos']``.
    """
    check_engine_kinds(cfg)
    sub = slot_caches(caches, slot)
    upd = jax.tree_util.tree_map(
        lambda a, c: jax.lax.dynamic_update_slice_in_dim(
            a, c.astype(a.dtype), pos, axis=a.ndim - 3), sub, chunk)
    return merge_slot_caches(caches, slot, upd)


def prefill_chunk(params, cfg: ModelConfig, caches, tokens, slot, pos,
                  length=None, req_salt=None):
    """Chunked prefill of ONE slot into the batched decode caches.

    ``tokens`` [C] is one prompt chunk (the first ``length`` entries valid;
    the ragged tail is padding — its K/V land at positions the causal mask
    hides until a later write overwrites them, so padding never reaches a
    softmax). ``slot`` indexes the batch row, ``pos`` is the slot's current
    token count, ``req_salt`` keys this request's dynamic-injection streams
    (the chunk reads the CIM image once, at read index ``pos``).

    Returns (last-valid-token logits [V], updated caches with
    ``caches['pos'][slot] = pos + length``). Both ``slot`` and ``pos`` are
    traced, so one jit covers every slot and offset per chunk shape.
    """
    check_engine_kinds(cfg)
    if length is None:
        length = tokens.shape[0]
    length = jnp.asarray(length, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    dt = cfg.cdtype()
    toks = tokens[None]                                       # [1, C]
    if isinstance(params["embed"], cim_lib.CIMStore):
        x = _embed_lookup(params, cfg, toks, pos=pos, req_salt=req_salt)
    else:
        x = params["embed"].astype(dt)[toks]
    x = shard(x, "batch", None, None)
    sub = slot_caches(caches, slot)
    x, gc, tc = _decode_stack(params, cfg, sub, x, pos)
    h = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)  # [1,1,D]
    logits = _unembed_logits(params, h, pos=pos, req_salt=req_salt)[:, 0]
    out = merge_slot_caches(caches, slot, {"groups": gc, "tail": tc})
    out["pos"] = caches["pos"].at[slot].set(pos + length)
    return logits[0], out


def decode_slots(params, cfg: ModelConfig, caches, tokens, active,
                 req_salts=None):
    """One continuous-batching decode step across the slot batch.

    ``tokens`` [S,1] (each slot's last token; inactive slots' values are
    irrelevant), per-slot positions ride in ``caches['pos']`` [S], ``active``
    [S] bool. ``req_salts`` [S] uint32 (see
    :func:`repro.core.deployment.request_salt`) key each slot's
    dynamic-injection CIM reads by (request, position) — never by slot index
    or engine step — so a request's logits and fault streams are
    bit-identical served alone or continuously co-batched. Per-request reads
    run one slot at a time against the packed image (each slot IS a distinct
    macro read with its own counter-PRNG streams); static images read
    batched, which is invariant for free (no seeds in the chain).

    Inactive slots flow through the fixed-shape batch but their positions do
    not advance; their stale cache writes stay causally masked (see
    ``attention.decode_attention``).

    Returns (logits [S,V], new caches).
    """
    check_engine_kinds(cfg)
    pos = caches["pos"]                                       # [S]
    s = tokens.shape[0]
    dt = cfg.cdtype()
    dynamic = isinstance(params, dict) and params.get("_cim") is not None
    if dynamic and req_salts is None:
        raise ValueError(
            "decode_slots: params carry a dynamic-injection '_cim' runtime "
            "but no req_salts — per-read seeds would alias across requests; "
            "pass deployment.request_salt(rid) per slot")
    emb = params["embed"]
    if isinstance(emb, cim_lib.CIMStore) and dynamic:
        x = jnp.concatenate(
            [_embed_lookup(params, cfg, tokens[i:i + 1], pos=pos[i],
                           req_salt=req_salts[i]) for i in range(s)], axis=0)
    elif isinstance(emb, cim_lib.CIMStore):
        x = _embed_lookup(params, cfg, tokens)
    else:
        x = emb.astype(dt)[tokens]
    x = shard(x, "batch", None, None)
    x, gc, tc = _decode_stack(params, cfg, caches, x, pos)
    if isinstance(params["unembed"], cim_lib.CIMStore) and dynamic:
        logits = jnp.concatenate(
            [_unembed_logits(params, x[i:i + 1], pos=pos[i],
                             req_salt=req_salts[i]) for i in range(s)],
            axis=0)[:, 0]
    else:
        logits = _unembed_logits(params, x)[:, 0]
    return logits, {"groups": gc, "tail": tc,
                    "pos": pos + active.astype(jnp.int32)}
