"""Channel MLPs: SwiGLU (llama-family), GeLU (musicgen), RWKV channel-mix."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import dense_init


def init_mlp(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.pdtype()
    k0, k1, k2 = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {"w_gate": dense_init(k0, (d, f), dtype=dt),
                "w_in": dense_init(k1, (d, f), dtype=dt),
                "w_out": dense_init(k2, (f, d), dtype=dt)}
    if cfg.mlp_type == "gelu":
        return {"w_in": dense_init(k0, (d, f), dtype=dt),
                "w_out": dense_init(k1, (f, d), dtype=dt)}
    if cfg.mlp_type == "rwkv_cmix":
        # RWKV channel mix: r = sigmoid(W_r x'); out = r * (W_out relu(W_in x')^2)
        return {"w_r": dense_init(k0, (d, d), dtype=dt),
                "w_in": dense_init(k1, (d, f), dtype=dt),
                "w_out": dense_init(k2, (f, d), dtype=dt),
                "mix_k": jnp.full((d,), 0.5, dt),
                "mix_r": jnp.full((d,), 0.5, dt)}
    raise ValueError(cfg.mlp_type)


def apply_mlp(params, cfg, x, x_shifted=None):
    dt = x.dtype
    fsdp = cfg.mlp_impl == "fsdp"

    def W(name):
        w = params[name].astype(dt)
        # fsdp mode (§Perf command-r iteration 4): gather the bf16 weight
        # (ZeRO-3 style, ~0.37 GB/layer at command-r) and keep the tokens
        # sequence-sharded — Megatron-TP instead all-gathers ~2.1 GB of
        # activations per matmul to unshard the sequence.
        return shard(w, None, None) if fsdp else w

    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ W("w_gate")) * (x @ W("w_in"))
        if not fsdp:
            h = shard(h, "batch", None, "ff")
        return shard(h @ W("w_out"), "batch", "seq", None)
    if cfg.mlp_type == "gelu":
        h = jax.nn.gelu(x @ W("w_in"))
        if not fsdp:
            h = shard(h, "batch", None, "ff")
        return shard(h @ W("w_out"), "batch", "seq", None)
    if cfg.mlp_type == "rwkv_cmix":
        assert x_shifted is not None, "rwkv channel-mix needs the shifted stream"
        xk = x * params["mix_k"].astype(dt) + x_shifted * (1 - params["mix_k"].astype(dt))
        xr = x * params["mix_r"].astype(dt) + x_shifted * (1 - params["mix_r"].astype(dt))
        h = jnp.square(jax.nn.relu(xk @ params["w_in"].astype(dt)))
        h = shard(h, "batch", None, "ff")
        out = jax.nn.sigmoid(xr @ params["w_r"].astype(dt)) * (h @ params["w_out"].astype(dt))
        return shard(out, "batch", "seq", None)
    raise ValueError(cfg.mlp_type)
