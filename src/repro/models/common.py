"""Shared layer primitives for the architecture zoo (pure-functional JAX)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32):
    """Truncated-normal fan-in init (stddev 1/sqrt(fan_in))."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        out = out * (1.0 + scale.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def nonparametric_ln(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm: no learnable scale/bias."""
    return layernorm(x, None, None, eps)


def apply_norm(norm_type: str, params, x):
    if norm_type == "rmsnorm":
        return rmsnorm(x, params["scale"])
    if norm_type == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    if norm_type == "nonparametric_ln":
        return nonparametric_ln(x)
    raise ValueError(norm_type)


def init_norm(key, norm_type: str, d: int, dtype=jnp.float32):
    if norm_type == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    if norm_type == "layernorm":
        return {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if norm_type == "nonparametric_ln":
        return {}
    raise ValueError(norm_type)


# ---------------------------------------------------------------- rotary

def rope_frequencies(head_dim: int, theta: float, positions):
    """positions [...,S] -> (sin, cos) each [..., S, head_dim//2], fp32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, sin, cos):
    """x [..., S, H, head_dim]; sin/cos [..., S, head_dim//2] (broadcast H)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin_ = sin[..., None, :] if x.ndim == sin.ndim + 1 else sin
    cos_ = cos[..., None, :] if x.ndim == cos.ndim + 1 else cos
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos_ - x2f * sin_,
                            x2f * cos_ + x1f * sin_], axis=-1).astype(x.dtype)
