"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU gated recurrence.

    y = W_down( GeLU(W_gate_br x) ⊙ RGLRU(conv4(W_x x)) )

RG-LRU (per channel, fp32):
    r_t = σ(w_a·x̃_t + b_a)        (recurrence gate)
    i_t = σ(w_i·x̃_t + b_i)        (input gate)
    log a_t = -c · softplus(Λ) · r_t
    h_t = a_t · h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x̃_t)

The sequence recurrence is a first-order elementwise linear recurrence →
``jax.lax.associative_scan`` (log-depth, parallel over the sequence). The
gates here are per-channel (diagonal) — a documented simplification of the
block-diagonal linear gates in the reference implementation (DESIGN.md §1).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import dense_init

RG_C = 8.0


def init_rglru_block(key, cfg):
    d, r = cfg.d_model, cfg.d_rnn or cfg.d_model
    dt = cfg.pdtype()
    ks = jax.random.split(key, 6)
    # Λ init so that a^c ≈ uniform in [0.9, 0.999] at r_t=1 (Griffin appendix)
    u = jax.random.uniform(ks[3], (r,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RG_C))      # softplus^-1(-log u / c)
    return {
        "w_x": dense_init(ks[0], (d, r), dtype=dt),
        "w_gate_br": dense_init(ks[1], (d, r), dtype=dt),
        "w_down": dense_init(ks[2], (r, d), dtype=dt),
        "rg_lambda": lam.astype(dt),
        "rg_wa": jnp.zeros((r,), dt), "rg_ba": jnp.zeros((r,), dt),
        "rg_wi": jnp.zeros((r,), dt), "rg_bi": jnp.zeros((r,), dt),
        "conv_w": (jax.random.normal(ks[4], (cfg.conv_width, r)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((r,), dt),
    }


def _conv1d_causal(x, w, b, x_init=None):
    """Depthwise causal conv. x [B,T,R]; w [W,R]; x_init [B,W-1,R] carry."""
    wlen = w.shape[0]
    if x_init is None:
        x_init = jnp.zeros((x.shape[0], wlen - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([x_init, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[wlen - 1 - i].astype(x.dtype)
              for i in range(wlen))
    return out + b.astype(x.dtype), xp[:, -(wlen - 1):]


def _rg_lru_coeffs(params, xt):
    """-> (a, bx) fp32: h_t = a_t h_{t-1} + bx_t."""
    x32 = xt.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(x32 * params["rg_wa"].astype(jnp.float32)
                            + params["rg_ba"].astype(jnp.float32))
    i_gate = jax.nn.sigmoid(x32 * params["rg_wi"].astype(jnp.float32)
                            + params["rg_bi"].astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(params["rg_lambda"].astype(jnp.float32)) * r_gate
    a = jnp.exp(log_a)
    bx = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i_gate * x32)
    return a, bx


def init_rglru_state(cfg, batch: int):
    r = cfg.d_rnn or cfg.d_model
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, r), jnp.float32),
    }


def apply_rglru_block(params, cfg, x, state=None) -> Tuple[jnp.ndarray, dict]:
    """Sequence mode. x [B,T,D] -> (out [B,T,D], final state)."""
    b, t, d = x.shape
    dt = x.dtype
    if state is None:
        state = init_rglru_state(cfg, b)
    gate = jax.nn.gelu(x @ params["w_gate_br"].astype(dt))
    xb = x @ params["w_x"].astype(dt)
    gate = shard(gate, "batch", None, "heads")
    xb = shard(xb, "batch", None, "heads")
    xb, conv_carry = _conv1d_causal(xb, params["conv_w"], params["conv_b"],
                                    state["conv"].astype(dt))
    a, bx = _rg_lru_coeffs(params, xb)                    # [B,T,R] fp32
    # fold the carried state into the first step: h_1 = a_1 h_0 + bx_1
    bx = bx.at[:, 0].add(a[:, 0] * state["h"])

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    out = (gate * h.astype(dt)) @ params["w_down"].astype(dt)
    new_state = {"h": h[:, -1], "conv": conv_carry.astype(jnp.float32)}
    return shard(out, "batch", "seq", None), new_state


def advance_rglru_block(params, cfg, x, state, length) -> Tuple[jnp.ndarray, dict]:
    """Chunked slot-state advance (serving engine). x [B,T,D]; the first
    ``length`` tokens are valid, the ragged tail is padding.

    ``associative_scan`` is a prefix scan — its output at index i folds
    inputs 0..i only — so the hidden carry is simply read at ``length - 1``
    (pads never enter it), and the conv carry is the last ``conv_width - 1``
    *valid* inputs, sliced dynamically out of the carry-in ++ chunk stream.
    ``length`` is traced: one compile per chunk shape. Output rows past
    ``length`` are garbage the caller must ignore.
    """
    b, t, d = x.shape
    dt = x.dtype
    length = jnp.asarray(length, jnp.int32)
    gate = jax.nn.gelu(x @ params["w_gate_br"].astype(dt))
    xb = x @ params["w_x"].astype(dt)
    gate = shard(gate, "batch", None, "heads")
    xb = shard(xb, "batch", None, "heads")
    xp = jnp.concatenate([state["conv"].astype(dt), xb], axis=1)
    conv_carry = jax.lax.dynamic_slice_in_dim(xp, length,
                                              cfg.conv_width - 1, axis=1)
    xc, _ = _conv1d_causal(xb, params["conv_w"], params["conv_b"],
                           state["conv"].astype(dt))
    a, bx = _rg_lru_coeffs(params, xc)
    bx = bx.at[:, 0].add(a[:, 0] * state["h"])

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h_last = jax.lax.dynamic_slice_in_dim(hs, length - 1, 1, axis=1)[:, 0]
    out = (gate * hs.astype(dt)) @ params["w_down"].astype(dt)
    return out, {"h": h_last, "conv": conv_carry.astype(jnp.float32)}


def decode_rglru_block(params, cfg, x, state) -> Tuple[jnp.ndarray, dict]:
    """Single-token recurrence. x [B,1,D]."""
    b, _, d = x.shape
    dt = x.dtype
    gate = jax.nn.gelu(x[:, 0] @ params["w_gate_br"].astype(dt))
    xb = x[:, 0] @ params["w_x"].astype(dt)
    wlen = cfg.conv_width
    hist = jnp.concatenate([state["conv"].astype(dt), xb[:, None]], axis=1)
    xb = sum(hist[:, wlen - 1 - i] * params["conv_w"][i].astype(dt)
             for i in range(wlen)) + params["conv_b"].astype(dt)
    a, bx = _rg_lru_coeffs(params, xb)
    h = a * state["h"] + bx
    out = (gate * h.astype(dt)) @ params["w_down"].astype(dt)
    return out[:, None], {"h": h, "conv": hist[:, 1:].astype(jnp.float32)}
