"""Mixture-of-Experts layer (qwen3-moe, dbrx) with expert parallelism.

Dispatch is a GSPMD-friendly capacity-based gather/scatter: no ``[T, E, C]``
one-hot dispatch tensor is ever materialized (that would be ~10^10 elements
at the assigned shapes). Assignments are ranked per expert (sort-based by
default — see §Perf iteration 1 for why the cumsum baseline is catastrophic),
scattered into an ``[E, C, D]`` buffer sharded (experts -> "model",
capacity -> "data"), processed with per-expert einsums, and gathered back.
Under a mesh the default is the explicit shard_map all-to-all dispatch
(``repro.models.moe_a2a``, §Perf 4.1 iteration 4 — 13-79x less collective
traffic); the GSPMD dense path remains the fallback for hosts without a mesh
and for indivisible shapes (decode's seq=1).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models.common import dense_init


def init_moe(key, cfg):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    dt = cfg.pdtype()
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": dense_init(k0, (d, e), dtype=dt),
        "moe_win": dense_init(k1, (e, d, f), in_axis=-2, dtype=dt),
        "moe_wgate": dense_init(k2, (e, d, f), in_axis=-2, dtype=dt),
        "moe_wout": dense_init(k3, (e, f, d), in_axis=-2, dtype=dt),
    }


def capacity(cfg, tokens: int) -> int:
    c = int(math.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, c)


def drop_free(cfg, tokens: int) -> bool:
    """True when capacity-based dispatch provably never drops a token for any
    batch of up to ``tokens`` tokens — the serving engine's contract boundary.

    ``top_k`` expert ids are distinct per token, so an expert's worst-case
    load in a ``t``-token batch is ``t`` (every token ranks it once). When
    ``capacity(cfg, t) >= t`` for every batch size up to ``tokens``, no
    assignment can rank past capacity: each kept token's expert output is
    computed from its own buffer row alone (row-independent einsums), so
    co-batched tokens cannot couple and the engine's bitwise
    solo-vs-cobatched guarantee holds. The ``max(8, .)`` capacity floor makes
    every batch of <= 8 tokens drop-free regardless of ``capacity_factor`` —
    small engine shapes (slots, chunk <= 8) get the guarantee for free.
    """
    return all(capacity(cfg, t) >= t for t in range(1, tokens + 1))


def apply_moe(params, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B,S,D] -> (out [B,S,D], aux load-balancing loss scalar)."""
    if cfg.moe_dispatch == "a2a":
        from repro.distributed import sharding as shlib
        mesh = shlib.get_mesh()
        if mesh is not None and "model" in mesh.axis_names \
                and cfg.n_experts % mesh.shape["model"] == 0:
            bsz = 1
            for a in (shlib.batch_axes() or ()):
                bsz *= mesh.shape[a]
            if x.shape[0] % bsz == 0 and x.shape[1] % mesh.shape["model"] == 0:
                from repro.models.moe_a2a import apply_moe_a2a
                return apply_moe_a2a(params, cfg, x)
        # no mesh (host tests) or indivisible shapes (decode: seq=1)
        # -> GSPMD dense dispatch fallback
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    dt = x.dtype
    xt = x.reshape(t, d)

    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                              # [T, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # Aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=1), axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(me * ce)

    # ---- rank each assignment within its expert ---------------------------
    flat_ids = ids.reshape(t * k)                                     # [T*k]
    if cfg.moe_dispatch == "cumsum":
        # baseline (flax-switch style): one-hot + cumsum over [T*k, E].
        # XLA lowers the cumsum to reduce-windows — measured ~360x the expert
        # einsum FLOPs at qwen3 shapes (EXPERIMENTS.md §Perf iteration 1).
        onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)         # [T*k, E]
        ranks_all = jnp.cumsum(onehot, axis=0) - onehot
        rank = jnp.take_along_axis(ranks_all, flat_ids[:, None], axis=1)[:, 0]
    else:
        # optimized: sort-based ranking — 1-D ops only, no [T*k, E] tensor.
        # rank(i) = position of assignment i within its expert's sorted run.
        n = t * k
        order = jnp.argsort(flat_ids)                                 # [n]
        sorted_ids = flat_ids[order]
        starts = jnp.searchsorted(sorted_ids, jnp.arange(e, dtype=flat_ids.dtype))
        ranks_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_ids]
        rank = jnp.zeros((n,), jnp.int32).at[order].set(ranks_sorted)
    c = capacity(cfg, t)
    keep = rank < c
    dest = jnp.where(keep, flat_ids * c + rank, e * c)                # drop slot

    # ---- dispatch: scatter tokens into the [E*C(+1), D] buffer ------------
    # (a 2-D (expert, rank) scatter onto a pre-sharded [E, C, D] buffer was
    # tried and REFUTED: GSPMD rematerializes the scatter, 10x more collective
    # bytes — EXPERIMENTS.md §Perf iteration 3. The 1-D linearized scatter +
    # post-constraint is the best GSPMD formulation; the next step beyond it
    # is a shard_map all-to-all dispatch.)
    src = jnp.repeat(xt, k, axis=0)                                   # [T*k, D]
    buf = jnp.zeros((e * c + 1, d), dt).at[dest].add(src)
    buf = shard(buf[:e * c].reshape(e, c, d), "experts", "batch", None)

    # ---- expert computation (per-expert SwiGLU) ----------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["moe_wgate"].astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["moe_win"].astype(dt))
    h = shard(h, "experts", "batch", None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["moe_wout"].astype(dt))
    out_buf = shard(out_buf, "experts", "batch", None).reshape(e * c, d)

    # ---- combine: gather + gate-weighted sum over the k assignments -------
    gathered = jnp.where(keep[:, None], out_buf[jnp.minimum(dest, e * c - 1)], 0)
    weighted = gathered * gates.reshape(t * k, 1).astype(dt)
    out = jnp.sum(weighted.reshape(t, k, d), axis=1)
    return shard(out.reshape(b, s, d), "batch", "seq", None), aux
