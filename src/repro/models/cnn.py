"""Small ConvNet — the paper's benchmark family (ResNet18/YOLOv5/nnUNet are
CNNs) at container scale, used by the Fig. 2/6/7 and Table I benchmarks with
the GaussianBlobs classification task.

Conv kernels are [kh, kw, cin, cout]; exponent alignment groups along the
input channel (axis -2), exactly the paper's Fig. 3 ① grouping for conv
layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init_cnn(key, n_classes: int = 10, channels: int = 3, width: int = 32):
    ks = jax.random.split(key, 4)
    return {
        "conv1": dense_init(ks[0], (3, 3, channels, width)),
        "conv2": dense_init(ks[1], (3, 3, width, 2 * width)),
        "dense": dense_init(ks[2], (2 * width * 16, 4 * width)),
        "head": dense_init(ks[3], (4 * width, n_classes)),
    }


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def apply_cnn(params, x):
    """x [B, 16, 16, C] -> logits [B, n_classes]."""
    h = jax.nn.relu(_conv(x, params["conv1"], stride=2))    # [B, 8, 8, w]
    h = jax.nn.relu(_conv(h, params["conv2"], stride=2))    # [B, 4, 4, 2w]
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["dense"])
    return h @ params["head"]


def cnn_loss(params, x, y):
    logits = apply_cnn(params, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], 1)[:, 0]
    acc = jnp.mean(jnp.argmax(logits, -1) == y)
    return jnp.mean(nll), acc


def accuracy(params, x, y) -> float:
    logits = apply_cnn(params, x)
    return float(jnp.mean(jnp.argmax(logits, -1) == y))
