"""Fig. 7 + the co-design gate: before/after accuracy-vs-BER on the trained
LM, with the searched per-layer policy required to dominate uniform One4N.

Three measurements, one artifact:

1. **before** — the cached base LM deployed under uniform One4N and under no
   protection, evaluated at the derived BER (accuracy-vs-BER, paper Fig. 6/7
   framing);
2. **fine-tune** — :class:`repro.training.codesign.Finetuner` trains the base
   model through the deployment (exponent-compression reshape, then aligned
   training under the dynamic fault schedule) and the protected arm is
   re-measured (**after**);
3. **search** — :class:`repro.training.codesign.PolicySearch` finds the
   cheapest per-layer protection on the fine-tuned weights meeting the
   accuracy SLO. The gate (``check_regression.py --training``) requires the
   searched policy to meet the SLO (``searched_slo_met`` hard floor 1.0) at
   *strictly lower* stored-bit cost than uniform One4N
   (``searched_vs_one4n_bits_ratio`` hard ceiling 0.99).

The injection BER is **derived from the deployment**, not hand-rolled: the
paper's operating point (~1e-6 raw BER on 10M+-parameter fp16 models) fixes
the expected soft-error count per step at ``1e-6 * 10e6 * 16 = 160`` flips;
the bench solves ``ber = flips / stored_bits`` against the uniform-One4N
deployment's actual ``bit_cost()`` so the reduced model sees the same error
*pressure* per step regardless of how the packing (ECC codewords, shared
exponents) changes the cell count.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import QUICK, emit, lm_setup
from repro.core.deployment import (CIMDeployment, PolicyRule,
                                   ReliabilityPolicy)
from repro.core.resilience import characterize_policies
from repro.training.codesign import AccuracySLO, Finetuner, PolicySearch, \
    SearchSpace

# expected soft errors per step at the paper's operating point:
# 1e-6 raw BER x ~10e6 params x 16 bits/param
PAPER_FLIPS_PER_STEP = 160.0

UNIFORM_ONE4N = ReliabilityPolicy()
UNPROTECTED = ReliabilityPolicy(default=PolicyRule(protect="none"))


def derived_ber(params) -> tuple:
    """BER matching the paper's expected flips/step against the ACTUAL
    stored-cell count of the uniform-One4N deployment."""
    bits = CIMDeployment.deploy(params, UNIFORM_ONE4N).bit_cost()
    ber = PAPER_FLIPS_PER_STEP / max(bits["stored_bits"], 1)
    return float(np.clip(ber, 1e-6, 1e-3)), bits


def acc_of(results, name: str) -> float:
    return next(r.mean for r in results if r.protect == name)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write the artifact here")
    args = ap.parse_args(argv)

    t0 = time.time()
    n_trials = 4 if QUICK else 6
    ft_steps = 15 if QUICK else 40

    params, cfg, eval_fn, data = lm_setup()
    ber, bits = derived_ber(params)
    clean_acc = float(jax.device_get(eval_fn(params)))

    key = jax.random.PRNGKey(42)
    before = characterize_policies(
        key, params, eval_fn, bers=(ber,), n_trials=n_trials,
        policies={"one4n": UNIFORM_ONE4N, "none": UNPROTECTED})
    before_one4n, before_none = acc_of(before, "one4n"), acc_of(before, "none")

    ft = Finetuner(cfg, UNIFORM_ONE4N, ber=ber, reshape_steps=ft_steps,
                   aligned_steps=ft_steps, learning_rate=1e-3, seed=0)
    res = ft.run(lambda: iter(data), params=params)
    losses = np.asarray(
        [h["loss"] for h in res.info["reshape"]["history"]] +
        [h["loss"] for h in res.history])
    tuned = res.state.params
    tuned_clean = float(jax.device_get(eval_fn(tuned)))

    after = characterize_policies(
        jax.random.fold_in(key, 1), tuned, eval_fn, bers=(ber,),
        n_trials=n_trials, policies={"one4n": UNIFORM_ONE4N})
    after_one4n = acc_of(after, "one4n")

    # 1% drop: tight enough that fully-unprotected arms miss the floor (the
    # searched policy must actually buy protection, not just ride the
    # fine-tuned model's resilience)
    slo = AccuracySLO(ber=ber, max_drop=0.01)
    # n_group=16 halves both the shared-exponent count and the One4N parity
    # cells — the real stored-bit lever the search can trade against the
    # coarser alignment it implies
    space = SearchSpace(groups=(("embed", "embed"), ("unembed", "unembed")),
                        protects=("none", "one4n"), n_groups=(8, 16))
    search = PolicySearch(tuned, eval_fn, slo, space, n_trials=n_trials,
                          key=jax.random.fold_in(key, 2))
    sres = search.search()
    one4n_bits = bits["stored_bits"]
    bits_ratio = sres.stored_bits / one4n_bits

    wall_s = time.time() - t0
    out = {
        "quick": QUICK,
        "ber": ber,
        "wall_s": wall_s,
        "before": {"clean_acc": clean_acc, "one4n_acc": before_one4n,
                   "none_acc": before_none,
                   "one4n_stored_bits": one4n_bits,
                   "one4n_overhead": bits["overhead"]},
        "finetune": {"steps": int(len(losses)),
                     "final_loss": float(losses[-1]),
                     "losses_finite": bool(np.isfinite(losses).all()),
                     "clean_acc": tuned_clean,
                     "ecc_stats": res.ecc_stats},
        "after": {"one4n_acc": after_one4n},
        "search": {"name": sres.name, "accuracy": sres.accuracy,
                   "floor": sres.floor, "slo_met": bool(sres.slo_met),
                   "stored_bits": sres.stored_bits,
                   "bits_ratio": bits_ratio,
                   "slo_margin": sres.accuracy - sres.floor,
                   "assignment": sres.assignment, "evals": sres.evals},
    }
    rows = [
        ("fig7.before", None,
         f"clean={clean_acc:.4f};one4n@{ber:.1e}={before_one4n:.4f};"
         f"none@{ber:.1e}={before_none:.4f}"),
        ("fig7.finetune", None,
         f"steps={len(losses)};final_loss={losses[-1]:.4f};"
         f"finite={np.isfinite(losses).all()};clean={tuned_clean:.4f}"),
        ("fig7.after", None, f"one4n@{ber:.1e}={after_one4n:.4f}"),
        ("fig7.search", None,
         f"acc={sres.accuracy:.4f};floor={sres.floor:.4f};"
         f"slo_met={sres.slo_met};bits_ratio={bits_ratio:.3f};"
         f"evals={sres.evals}"),
        ("fig7.wall", round(wall_s * 1e6), f"wall_s={wall_s:.1f}"),
    ]
    emit(rows)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    main()
