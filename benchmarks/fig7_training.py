"""Fig. 7: training under dynamic error injection — clean vs unprotected vs
exponent-aligned + One4N (residual-rate) protection."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import QUICK, emit
from repro.configs import RunConfig, get_config
from repro.core.api import ReliabilityConfig
from repro.data.synthetic import MarkovLM
from repro.training.loop import run_training

BER = 1e-4   # scaled to the reduced model's weight count; cf. paper's 1e-6
             # on 10M+-param models (errors per step ~ params x bits x BER)


def arm(mode):
    if mode == "clean":
        return ReliabilityConfig(mode="align")
    protect = "one4n" if mode == "one4n" else "none"
    return ReliabilityConfig(mode="cim", ber=BER, protect=protect,
                             inject="dynamic")


def main():
    cfg = get_config("olmo-1b").reduced()
    steps = 40 if QUICK else 120
    rows = []
    finals = {}
    for mode in ("clean", "none", "one4n"):
        data = MarkovLM(cfg.vocab_size, 64, 8, seed=0)
        run = RunConfig(arch="olmo-1b", steps=steps, checkpoint_dir="",
                        remat=False, learning_rate=1e-3, reliability=arm(mode))
        t0 = time.time()
        _, hist, _ = run_training(cfg, run, iter(data))
        us = (time.time() - t0) * 1e6 / steps
        losses = np.asarray([h["loss"] for h in hist])
        tail = losses[-10:]
        finals[mode] = tail
        nan_steps = int((~np.isfinite(losses)).sum())
        rows.append((f"fig7.{mode}", round(us),
                     f"final_loss={np.nanmean(tail):.4f};nan_steps={nan_steps};"
                     f"first_loss={losses[0]:.3f}"))
    ok_clean = np.isfinite(finals["clean"]).all()
    ok_prot = np.isfinite(finals["one4n"]).all()
    bad = finals["none"]
    degraded = (~np.isfinite(bad)).any() or \
        np.nanmean(bad) > np.nanmean(finals["one4n"]) + 0.2
    rows.append(("fig7.check", None,
                 f"clean_finite={ok_clean};one4n_finite={ok_prot};"
                 f"unprotected_degraded={degraded}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
