"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

Usage: PYTHONPATH=src python -m benchmarks.render_experiments
Writes artifacts/roofline_table.md + artifacts/dryrun_table.md (included into
EXPERIMENTS.md by the final assembly step).
"""
from __future__ import annotations

import glob
import json
import os


def _fmt(x):
    return f"{x:.4f}" if x >= 1e-3 else f"{x:.2e}"


def roofline_table(tag: str = "opt") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO flops |",
            "|---|---|---|---|---|---|---|"]
    files = sorted(glob.glob(f"artifacts/dryrun/*__roofline__{tag}.json")) if tag \
        else sorted(glob.glob("artifacts/dryrun/*__roofline.json"))
    for f in files:
        r = json.load(open(f))
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                        f"(full attention @500k) | — |")
            continue
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | |")
            continue
        ro = r["roofline"]
        mf = r["model_flops"] / max(r["per_device"]["flops"] * 256, 1)
        rows.append(f"| {r['arch']} | {r['shape']} | {_fmt(ro['compute_s'])} | "
                    f"{_fmt(ro['memory_s'])} | {_fmt(ro['collective_s'])} | "
                    f"{ro['dominant']} | {mf:.3f} |")
    return "\n".join(rows)


def dryrun_table() -> str:
    rows = ["| arch | shape | mesh | compile s | args GB/dev | HLO flops/dev | coll GB/dev |",
            "|---|---|---|---|---|---|---|"]
    for f in sorted(glob.glob("artifacts/dryrun/*__single.json")) + \
            sorted(glob.glob("artifacts/dryrun/*__multi.json")):
        if "__single__" in f or "__multi__" in f:   # tagged variants
            continue
        r = json.load(open(f))
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | skipped | — |")
            continue
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | |")
            continue
        mem = r.get("memory_analysis", {})
        args = mem.get("argument_size_in_bytes", 0) / 1e9
        coll = sum(v for k, v in r["collectives"].items() if k != "count") / 1e9
        rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                    f"{r.get('compile_s', 0)} | {args:.2f} | "
                    f"{r['cost_analysis']['flops']:.2e} | {coll:.2f} |")
    return "\n".join(rows)


def pass_summary() -> str:
    ok = fails = skips = 0
    for f in glob.glob("artifacts/dryrun/*__single.json") + \
            glob.glob("artifacts/dryrun/*__multi.json"):
        if "__single__" in f or "__multi__" in f:
            continue
        r = json.load(open(f))
        if "error" in r:
            fails += 1
        elif "skipped" in r:
            skips += 1
        else:
            ok += 1
    return (f"**{ok} compiled, {fails} failed, {skips} skipped** "
            f"(skips = long_500k on the 8 full-attention archs, by assignment)")


def main():
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline_table.md", "w") as f:
        f.write(roofline_table("opt"))
    with open("artifacts/roofline_table_baseline.md", "w") as f:
        f.write(roofline_table(""))
    with open("artifacts/dryrun_table.md", "w") as f:
        f.write(pass_summary() + "\n\n" + dryrun_table())
    print(pass_summary())


if __name__ == "__main__":
    main()
