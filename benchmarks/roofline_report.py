"""Aggregate the dry-run artifacts into the §Roofline table (40 cells)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def load(pattern="artifacts/dryrun/*__roofline*.json"):
    recs = []
    for f in sorted(glob.glob(pattern)):
        r = json.load(open(f))
        r["_file"] = os.path.basename(f)
        recs.append(r)
    return recs


def main():
    rows = []
    for r in load():
        name = f"roofline.{r['arch']}.{r['shape']}"
        if r.get("tag"):
            name += f".{r['tag']}"
        if "skipped" in r:
            rows.append((name, None, "skipped=sub-quadratic-only"))
            continue
        if "error" in r:
            rows.append((name, None, f"ERROR={r['error'][:60]}"))
            continue
        ro = r["roofline"]
        mf = r["model_flops"] / max(r["per_device"]["flops"] * 256, 1)
        rows.append((name, None,
                     f"compute_s={ro['compute_s']:.4f};memory_s={ro['memory_s']:.4f};"
                     f"collective_s={ro['collective_s']:.4f};dominant={ro['dominant']};"
                     f"model/hlo_flops={mf:.3f}"))
    # compile-pass summary over the required single/multi cells
    ok = fails = skips = 0
    for f in glob.glob("artifacts/dryrun/*__single.json") + \
            glob.glob("artifacts/dryrun/*__multi.json"):
        r = json.load(open(f))
        if "error" in r:
            fails += 1
        elif "skipped" in r:
            skips += 1
        else:
            ok += 1
    rows.append(("roofline.dryrun_pass", None,
                 f"compiled={ok};failed={fails};skipped={skips}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
