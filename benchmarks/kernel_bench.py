"""Kernel micro-benchmarks: bfp_matmul + fault_inject vs their jnp oracles.

NOTE on semantics: this container executes Pallas in interpret mode on CPU, so
``us_per_call`` here measures the *oracle-equivalence harness*, not TPU
performance — TPU-side cost is assessed structurally in §Roofline (the kernel
reduces HBM weight traffic to 11.6 bits/weight vs 16 for bf16; see
EXPERIMENTS.md §Perf decode hillclimb)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import align
from repro.kernels.bfp_matmul import ops as bfp_ops
from repro.kernels.bfp_matmul import ref as bfp_ref
from repro.kernels.fault_inject import ops as fi_ops
from repro.kernels.fault_inject import ref as fi_ref


def _time(fn, *args, iters=5):
    fn(*args)  # warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6, out


def main():
    rows = []
    for m, k, n in ((128, 1024, 256), (256, 2048, 512)):
        w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.05
        w_al, _ = align.align_matrix(w, align.AlignmentConfig(8, 2))
        man, exp = bfp_ref.pack_bfp(w_al, 8)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
        us_k, out_k = _time(lambda: bfp_ops.bfp_matmul(x, man, exp))
        us_r, out_r = _time(lambda: jax.jit(bfp_ref.bfp_matmul_ref)(x, man, exp))
        err = float(jnp.max(jnp.abs(out_k - out_r)))
        bits_per_weight = 10 + 1 + 5 / 8.0
        rows.append((f"kernel.bfp_matmul.{m}x{k}x{n}", round(us_k),
                     f"ref_us={us_r:.0f};max_err={err:.1e};"
                     f"weight_bits={bits_per_weight:.1f}vs16"))
    for shape in ((512, 512), (2048, 1024)):
        bits = jnp.zeros(shape, jnp.uint16)
        pos = tuple(range(10, 16))
        us_k, out_k = _time(lambda: fi_ops.fault_inject_bits(
            bits, seed=3, ber=1e-3, positions=pos))
        us_r, out_r = _time(lambda: jax.jit(
            lambda b: fi_ref.fault_inject_ref(b, seed=3, ber=1e-3,
                                              positions=pos))(bits))
        exact = bool((np.asarray(out_k) == np.asarray(out_r)).all())
        rows.append((f"kernel.fault_inject.{shape[0]}x{shape[1]}", round(us_k),
                     f"ref_us={us_r:.0f};bit_exact={exact}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
