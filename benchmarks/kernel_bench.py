"""Kernel micro-benchmarks: cim_read tuning matrix + bfp_matmul/fault_inject.

Four cim_read fronts, each timed separately so ``check_regression`` can gate
them individually:

* **fused_call_us** — one autotuned fused decode-on-read call at the serving
  decode-step shape (absolute wall clock, coarse 2x-tolerance gate);
* **autotune_speedup** — autotuned grid (full-K tiles, wide-J columns) vs the
  legacy fixed 128-cube tiles on the same store (report-only, see below);
* **hoist_speedup** — decode-hoist VMEM strip reuse on a tall-M call vs the
  same grid re-decoding every M-revisit (report-only, see below);
* **cache_speedup** — deployment dispatch through a warmed decoded-row cache
  vs the fused kernel on the same store (machine-relative, gated).

A tile-shape sweep over ``autotuned_tile_shapes`` plus the legacy cube is
reported (and written to the ``--json`` artifact for the CI kernel-tuning
step) but never gated — it exists to audit the autotune policy, not to race
individual tiles.

NOTE on semantics: this container executes Pallas in interpret mode on CPU,
so ``us_per_call`` here measures the *oracle-equivalence harness*, not TPU
performance — TPU-side cost is assessed structurally in §Roofline (the kernel
reduces HBM weight traffic to 11.6 bits/weight vs 16 for bf16; see
EXPERIMENTS.md §Perf decode hillclimb). Interpret mode unrolls the grid into
one XLA graph, whose CSE pass hoists the (identical) per-revisit decode
subexpressions itself — so ``autotune_speedup``/``hoist_speedup`` hover near
1.0 here and are reported, not gated: their win is the on-TPU pipeline
structure (fewer grid steps, one decode fold per plane tile), while their
*correctness* (bitwise identity hoist-vs-nohoist, autotuned-vs-legacy tiles)
is what ``tests/test_kernels.py`` locks. ``cache_speedup`` (a cached matmul
vs running the kernel at all) is structural on every backend and is gated.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, emit
from repro.core import align, cim
from repro.core import deployment as dep_lib
from repro.kernels.bfp_matmul import ops as bfp_ops
from repro.kernels.bfp_matmul import ref as bfp_ref
from repro.kernels.cim_read import ops as cr_ops
from repro.kernels.fault_inject import ops as fi_ops
from repro.kernels.fault_inject import ref as fi_ref

ITERS = 2 if QUICK else 5


def _time(fn, *args, iters=ITERS):
    fn(*args)  # warm (compile) before the timed loop
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out


def _best_pair(fn_a, fn_b, repeats=3):
    """Best-of timing for two arms with alternating order per repeat, so
    interpret-mode scheduler drift cancels. Both arms pre-warmed."""
    fn_a(), fn_b()
    best_a = best_b = float("inf")
    for r in range(repeats):
        arms = [("a", fn_a), ("b", fn_b)]
        if r % 2:
            arms.reverse()
        for name, fn in arms:
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            us = (time.perf_counter() - t0) * 1e6
            if name == "a":
                best_a = min(best_a, us)
            else:
                best_b = min(best_b, us)
    return best_a, best_b


def _store(k, j, protect="one4n", n=8, rw=16, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, j)) * 0.1
    w_al, _ = align.align_matrix(w, align.AlignmentConfig(n_group=n, index=2))
    return cim.pack(w_al, cim.CIMConfig(n_group=n, row_weights=rw,
                                        protect=protect))


def bfp_section():
    rows, res = [], {}
    shapes = ((128, 1024, 256),) if QUICK else ((128, 1024, 256),
                                                (256, 2048, 512))
    for m, k, n in shapes:
        w = jax.random.normal(jax.random.PRNGKey(0), (k, n)) * 0.05
        w_al, _ = align.align_matrix(w, align.AlignmentConfig(8, 2))
        man, exp = bfp_ref.pack_bfp(w_al, 8)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
        us_k, out_k = _time(lambda: bfp_ops.bfp_matmul(x, man, exp))
        us_r, out_r = _time(lambda: jax.jit(bfp_ref.bfp_matmul_ref)(x, man, exp))
        err = float(jnp.max(jnp.abs(out_k - out_r)))
        bits_per_weight = 10 + 1 + 5 / 8.0
        rows.append((f"kernel.bfp_matmul.{m}x{k}x{n}", round(us_k),
                     f"ref_us={us_r:.0f};max_err={err:.1e};"
                     f"weight_bits={bits_per_weight:.1f}vs16"))
        res[f"{m}x{k}x{n}"] = {"kernel_us": us_k, "ref_us": us_r,
                               "max_err": err}
    return rows, res


def fault_section():
    rows, res = [], {}
    shapes = ((512, 512),) if QUICK else ((512, 512), (2048, 1024))
    for shape in shapes:
        bits = jnp.zeros(shape, jnp.uint16)
        pos = tuple(range(10, 16))
        us_k, out_k = _time(lambda: fi_ops.fault_inject_bits(
            bits, seed=3, ber=1e-3, positions=pos))
        us_r, out_r = _time(lambda: jax.jit(
            lambda b: fi_ref.fault_inject_ref(b, seed=3, ber=1e-3,
                                              positions=pos))(bits))
        exact = bool((np.asarray(out_k) == np.asarray(out_r)).all())
        rows.append((f"kernel.fault_inject.{shape[0]}x{shape[1]}", round(us_k),
                     f"ref_us={us_r:.0f};bit_exact={exact}"))
        res[f"{shape[0]}x{shape[1]}"] = {"kernel_us": us_k, "ref_us": us_r,
                                         "bit_exact": exact}
    return rows, res


def cim_read_section():
    rows = []
    k, j = (512, 256) if QUICK else (1024, 512)
    store = _store(k, j)

    # -- front 1: autotuned grid vs legacy fixed 128-cube tiles ------------
    m = 8                                        # serving decode-step shape
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    auto_us, fixed_us = _best_pair(
        lambda: cr_ops.cim_linear_store(x, store),
        lambda: cr_ops.cim_linear_store(x, store, block_m=128, block_n=128,
                                        block_k=128, hoist=False))
    autotune_speedup = fixed_us / auto_us
    tiles = cr_ops.resolve_tiles(store, m)
    rows.append((f"kernel.cim_read.fused_call.{m}x{k}x{j}", round(auto_us),
                 f"fixed128_us={fixed_us:.0f};"
                 f"autotune_speedup={autotune_speedup:.2f}x;"
                 f"tiles={tiles[:3]};hoist={tiles[3]}"))

    # -- front 2: decode hoist on a tall-M call ----------------------------
    m_tall = 256 if QUICK else 512
    bm = 64                                      # force several M revisits
    x_tall = jax.random.normal(jax.random.PRNGKey(2), (m_tall, k))
    hoist_us, nohoist_us = _best_pair(
        lambda: cr_ops.cim_linear_store(x_tall, store, block_m=bm,
                                        hoist=True),
        lambda: cr_ops.cim_linear_store(x_tall, store, block_m=bm,
                                        hoist=False))
    hoist_speedup = nohoist_us / hoist_us
    rows.append((f"kernel.cim_read.hoist.{m_tall}x{k}x{j}", round(hoist_us),
                 f"nohoist_us={nohoist_us:.0f};"
                 f"hoist_speedup={hoist_speedup:.2f}x;block_m={bm}"))

    # -- front 3: decoded-row cache dispatch vs the fused kernel -----------
    cached = cim.build_row_cache(store)
    cache_us, kernel_us = _best_pair(
        lambda: dep_lib.dispatch_linear(x, cached),
        lambda: dep_lib.dispatch_linear(x, store))
    cache_speedup = kernel_us / cache_us
    rows.append((f"kernel.cim_read.row_cache.{m}x{k}x{j}", round(cache_us),
                 f"kernel_us={kernel_us:.0f};"
                 f"cache_speedup={cache_speedup:.2f}x"))

    # -- tile-shape sweep (report-only; CI kernel-tuning artifact) ---------
    sweep = []
    m_sweep = 128
    x_sweep = jax.random.normal(jax.random.PRNGKey(3), (m_sweep, k))
    combos = cr_ops.autotuned_tile_shapes(store) + [(128, 128, 128, False)]
    seen = set()
    for bm_s, bn_s, bk_s, h in combos:
        if (bm_s, bn_s, bk_s, h) in seen:
            continue
        seen.add((bm_s, bn_s, bk_s, h))
        us, _ = _time(lambda: cr_ops.cim_linear_store(
            x_sweep, store, block_m=bm_s, block_n=bn_s, block_k=bk_s,
            hoist=h))
        sweep.append({"block_m": bm_s, "block_n": bn_s, "block_k": bk_s,
                      "hoist": h, "us_per_call": us})
        rows.append((f"kernel.cim_read.tile.{bm_s}x{bn_s}x{bk_s}"
                     f"{'h' if h else ''}", round(us),
                     f"m={m_sweep};store={k}x{j}"))
    best = min(sweep, key=lambda s: s["us_per_call"])
    rows.append(("kernel.cim_read.tile_sweep_best", None,
                 f"{best['block_m']}x{best['block_n']}x{best['block_k']}"
                 f"{'h' if best['hoist'] else ''} at {best['us_per_call']:.0f}us"))

    return rows, {"store": f"{k}x{j}",
                  "fused_call_us": auto_us,
                  "fixed128_us": fixed_us,
                  "autotune_speedup": autotune_speedup,
                  "hoist_us": hoist_us, "nohoist_us": nohoist_us,
                  "hoist_speedup": hoist_speedup,
                  "cache_us": cache_us, "kernel_us": kernel_us,
                  "cache_speedup": cache_speedup,
                  "tile_sweep": sweep,
                  "note": "interpret-mode wall clock; XLA CSE hoists the "
                          "per-revisit decode in the unrolled interpret "
                          "graph, so autotune/hoist speedups are report-only "
                          "here (TPU pipeline structure); cache_speedup is "
                          "structural on every backend and gated"}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the results as a JSON artifact")
    args = ap.parse_args(argv)
    rows, payload = [], {"quick": QUICK}
    for name, section in (("cim_read", cim_read_section),
                          ("bfp_matmul", bfp_section),
                          ("fault_inject", fault_section)):
        srows, sres = section()
        rows.extend(srows)
        payload[name] = sres
    payload["backend"] = jax.default_backend()
    emit(rows)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
