"""Packed vs legacy CIM store: inject/read wall-clock, plane bytes, serving,
deployment-dispatch overhead.

Four measurements behind the packed bit-plane refactor and the unified
deployment API:

1. **inject+read wall-clock** over the Fig. 6 protection grid (protect arm ×
   BER × trial): the packed path (uint32 codeword words, counter-PRNG
   per-word flip masks, XOR-parity decode) against the legacy per-bit path
   (one uint8 per stored bit, one Bernoulli draw per bit, bit-matrix SECDED
   decode) — the legacy arm is reimplemented here exactly as the seed repo
   stored it, as the baseline;
2. **representation bytes** of the SRAM image planes (what HBM holds);
3. **serving tok/s**: decode-on-read off the packed image (fused
   ``kernels/cim_read`` path, no fp16 weight matrices in HBM) vs the legacy
   HBM-rematerialized path. NOTE: off-TPU the fused kernel executes in
   Pallas interpret mode, so on CPU this row measures correctness plumbing,
   not kernel speed — the inject/read rows are the CPU-meaningful ones;
4. **deployment-dispatch overhead**: ``CIMDeployment.linear`` (the unified
   API's auto-dispatch: rule lookup + route pick) vs calling
   ``cim_linear_store`` directly — the new layer must add no measurable
   per-call overhead (``overhead_ratio`` ≈ 1.0, gated by the regression
   harness).

Run:  PYTHONPATH=src python benchmarks/cim_store_bench.py --json out.json
Quick (CI smoke): BENCH_QUICK=1 ... --json artifacts/cim_store_bench.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import QUICK, emit
from repro.core import align, bitops, bitpack
from repro.core import cim as cim_lib

BERS = [1e-5, 1e-4, 1e-3, 1e-2] if not QUICK else [1e-4, 1e-2]
TRIALS = 6 if not QUICK else 2
SIZE = (1024, 1024) if not QUICK else (512, 512)
PROTECTS = ("none", "one4n")


# ---------------------------------------------------------------- legacy arm
# The seed repo's representation: one uint8 per codeword/sign bit, one
# jax.random.bernoulli draw per stored bit, per-bit SECDED decode.

def legacy_pack(store: cim_lib.CIMStore):
    cfg = store.cfg
    planes = {"man": store.man}
    if store.codewords is not None:
        planes["cw"] = bitpack.unpack_words(store.codewords,
                                            cfg.codec.code.n)
    else:
        planes["sign"] = cim_lib.unpack_sign_plane(store.sign,
                                                   store.man.shape[0])
        planes["exp"] = store.exp
    return planes


def legacy_bytes(planes) -> int:
    return sum(int(p.size) * p.dtype.itemsize for p in planes.values())


def legacy_inject(key, planes, ber, cfg):
    k_man, k_meta, k_cw = jax.random.split(key, 3)
    mb = cfg.fmt.man_bits
    out = dict(planes)
    flips = jax.random.bernoulli(k_man, ber, planes["man"].shape + (mb,))
    mask = jnp.sum(flips.astype(jnp.uint32)
                   << jnp.arange(mb, dtype=jnp.uint32), axis=-1)
    out["man"] = planes["man"] ^ mask.astype(jnp.uint16)
    if "cw" in planes:
        flips = jax.random.bernoulli(k_cw, ber, planes["cw"].shape)
        out["cw"] = planes["cw"] ^ flips.astype(jnp.uint8)
    else:
        eb = cfg.fmt.exp_bits
        eflips = jax.random.bernoulli(k_meta, ber, planes["exp"].shape + (eb,))
        emask = jnp.sum(eflips.astype(jnp.uint32)
                        << jnp.arange(eb, dtype=jnp.uint32), axis=-1)
        out["exp"] = planes["exp"] ^ emask.astype(jnp.uint8)
        sflips = jax.random.bernoulli(k_cw, ber, planes["sign"].shape)
        out["sign"] = planes["sign"] ^ sflips.astype(jnp.uint8)
    return out


def legacy_read(planes, cfg, shape):
    n, rw = cfg.n_group, cfg.row_weights
    k_pad, j_pad = planes["man"].shape
    b, g = k_pad // n, j_pad // rw
    if "cw" in planes:
        exp_rows, signs, status = cfg.codec.decode(planes["cw"])
        e_block = exp_rows.reshape(b, j_pad)
        sign = signs.transpose(0, 2, 1, 3).reshape(k_pad, j_pad)
        unc = jnp.sum(status == 2)
    else:
        e_block, sign = planes["exp"], planes["sign"]
        unc = jnp.zeros((), jnp.int32)
    e_full = jnp.repeat(e_block, n, axis=0)
    w = bitops.combine_fields(sign.astype(jnp.uint32), e_full.astype(jnp.uint32),
                              planes["man"].astype(jnp.uint32), cfg.fmt)
    k, j = shape
    return jnp.asarray(w[:k, :j], jnp.float32), unc


# ---------------------------------------------------------------- timing

def _time(fn, *args, repeats=3):
    fn(*args)                                   # compile + warm
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def inject_read_grid():
    k, j = SIZE
    w = jax.random.normal(jax.random.PRNGKey(0), (k, j)) * 0.1
    w_al, _ = align.align_matrix(w, align.AlignmentConfig(8, 2))
    rows, result = [], {}
    for protect in PROTECTS:
        cfg = cim_lib.CIMConfig(protect=protect)
        store = cim_lib.pack(w_al, cfg)
        planes = legacy_pack(store)

        @jax.jit
        def packed_cell(key, ber, store=store):
            out, stats = cim_lib.read(cim_lib.inject(key, store, ber))
            return out.sum(), stats["uncorrectable"]

        @jax.jit
        def legacy_cell(key, ber, planes=planes, cfg=cfg):
            faulty = legacy_inject(key, planes, ber, cfg)
            out, unc = legacy_read(faulty, cfg, store.shape)
            return out.sum(), unc

        def run(cell):
            def go():
                outs = []
                for i, ber in enumerate(BERS):
                    for t in range(TRIALS):
                        outs.append(cell(jax.random.PRNGKey(i * 131 + t),
                                         jnp.float32(ber)))
                jax.block_until_ready(outs)
            return _time(go)

        t_packed = run(packed_cell)
        t_legacy = run(legacy_cell)
        b_packed = store.stored_bytes
        b_legacy = legacy_bytes(planes)
        cells = len(BERS) * TRIALS
        rows.append((f"cim_store.inject_read.{protect}.packed",
                     round(t_packed / cells * 1e6),
                     f"bytes={b_packed}"))
        rows.append((f"cim_store.inject_read.{protect}.legacy",
                     round(t_legacy / cells * 1e6),
                     f"bytes={b_legacy}"))
        rows.append((f"cim_store.inject_read.{protect}.speedup", None,
                     f"{t_legacy / t_packed:.2f}x; "
                     f"bytes_ratio={b_legacy / b_packed:.2f}x"))
        result[protect] = {
            "packed_s_per_cell": t_packed / cells,
            "legacy_s_per_cell": t_legacy / cells,
            "speedup": t_legacy / t_packed,
            "packed_bytes": b_packed,
            "legacy_bytes": b_legacy,
        }
        if protect == "one4n":
            cw_packed = store.codewords.size * store.codewords.dtype.itemsize
            cw_legacy = int(planes["cw"].size)
            rows.append(("cim_store.codeword_plane_bytes", None,
                         f"packed={cw_packed};legacy={cw_legacy};"
                         f"ratio={cw_legacy / cw_packed:.2f}x"))
            result["codeword_plane_bytes"] = {
                "packed": cw_packed, "legacy": cw_legacy,
                "ratio": cw_legacy / cw_packed}
    return rows, result


# ------------------------------------------------------------ dispatch arm

def dispatch_bench():
    """Per-call wall-clock of the unified deployment dispatch vs the direct
    kernel entry point on the same packed store — the API layer's overhead."""
    from repro import CIMDeployment, ReliabilityPolicy
    from repro.kernels.cim_read import ops as cr_ops
    k, j = SIZE
    w = jax.random.normal(jax.random.PRNGKey(3), (k, j)) * 0.1
    w_al, _ = align.align_matrix(w, align.AlignmentConfig(8, 2))
    dep = CIMDeployment.deploy({"w": w_al}, ReliabilityPolicy())
    store = dep._leaf("w")[0]
    x = jax.random.normal(jax.random.PRNGKey(4), (32, k))
    calls = 4 if QUICK else 10
    arms = {"direct": lambda: cr_ops.cim_linear_store(x, store),
            "dep": lambda: dep.linear(x, "w")}

    def measure(fn):
        jax.block_until_ready([fn() for _ in range(calls)])   # warm
        t0 = time.perf_counter()
        outs = [fn() for _ in range(calls)]
        jax.block_until_ready(outs)
        return (time.perf_counter() - t0) / calls

    # interpret-mode call times drift by milliseconds run to run — alternate
    # the arm order per repeat and keep each arm's best so scheduler drift
    # cancels instead of landing on whichever arm ran second
    best = {name: np.inf for name in arms}
    for r in range(6):
        order = list(arms.items())
        if r % 2:
            order.reverse()
        for name, fn in order:
            best[name] = min(best[name], measure(fn))
    t_direct, t_dep = best["direct"], best["dep"]
    ratio = t_dep / t_direct
    rows = [
        ("cim_store.dispatch.direct_us_per_call", round(t_direct * 1e6), ""),
        ("cim_store.dispatch.deployment_us_per_call", round(t_dep * 1e6), ""),
        ("cim_store.dispatch.overhead_ratio", None, f"{ratio:.3f}x"),
    ]
    return rows, {"direct_s_per_call": t_direct,
                  "deployment_s_per_call": t_dep,
                  "overhead_ratio": ratio}


# ---------------------------------------------------------------- serving

def serving_bench():
    """Serving session with periodic fault refreshes, fused vs HBM arm.

    Real CIM serving is not one frozen fault image: retention faults
    accumulate and the serving stack periodically refreshes its view of the
    SRAM (here every ``REFRESH_EVERY`` decode steps, same counter-PRNG keys
    on both arms so the images are identical). What each arm pays per
    refresh is the structural difference this bench measures:

    * **fused** — jitted inject on the packed planes, then re-warm only the
      decoded-row caches that existed before (the unembed); the embed table
      is never fully decoded — its rows decode on read, straight off the
      refreshed packed image;
    * **hbm**  — jitted inject on the packed planes, then a full ECC decode
      of EVERY store to rematerialize the fp16 copies the serve step needs.

    Decode steps between refreshes run the same jitted serve step on both
    arms. Arm order alternates across repeats (best-of each) so interpret-
    mode scheduler drift cancels.
    """
    import dataclasses as _dc
    from repro.configs import get_config
    from repro.kernels.fault_inject.ops import ber_to_threshold
    from repro.launch.serve import deploy_fused
    from repro.models import lm
    from repro.training import steps as steps_lib
    cfg = get_config("olmo-1b").reduced()
    key = jax.random.PRNGKey(0)
    ber = 1e-4
    thr = ber_to_threshold(ber)
    params = lm.init_lm(key, cfg)
    stores = deploy_fused(params, ber=ber, protect="one4n", n_group=8,
                          index=2, key=key, inject_mode="static", field="full")
    decoded, _ = cim_lib.read_pytree_impl(stores)  # the HBM-rematerialized arm

    batch, plen, gen = 2, 16, 4 if QUICK else 8
    refresh_every = 2
    n_refresh = (gen + refresh_every - 1) // refresh_every
    rkeys = [jax.random.fold_in(key, 1000 + r) for r in range(n_refresh)]
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (batch, plen)))
    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    serve = jax.jit(steps_lib.make_serve_step(cfg))

    def _inject_tree(tree, rkey):
        flat, treedef = jax.tree_util.tree_flatten(tree,
                                                   is_leaf=cim_lib._is_store)
        keys = jax.random.split(rkey, max(len(flat), 1))
        out = [cim_lib.inject_with_seeds(leaf, cim_lib.plane_seeds(k),
                                         thr, thr)
               if cim_lib._is_store(leaf) else leaf
               for leaf, k in zip(flat, keys)]
        return jax.tree_util.tree_unflatten(treedef, out)

    @jax.jit
    def fused_refresh(tree, rkey):
        """Inject fresh faults; re-warm ONLY pre-existing decoded-row caches
        (inject_with_seeds builds cache-less stores — the invalidation
        contract)."""
        new = _inject_tree(tree, rkey)
        old_flat, treedef = jax.tree_util.tree_flatten(
            tree, is_leaf=cim_lib._is_store)
        new_flat = jax.tree_util.tree_flatten(new,
                                              is_leaf=cim_lib._is_store)[0]
        out = [_dc.replace(nw, cache=cim_lib.read(nw)[0])
               if cim_lib._is_store(nw) and old.cache is not None else nw
               for old, nw in zip(old_flat, new_flat)]
        return jax.tree_util.tree_unflatten(treedef, out)

    @jax.jit
    def hbm_refresh(tree, rkey):
        """Inject fresh faults, then fully decode EVERY store to fp16."""
        new = _inject_tree(tree, rkey)
        return new, cim_lib.read_pytree_impl(new)[0]

    def grow(a):
        if a.ndim >= 4 and a.shape[-3] == plen:
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, gen)
            return jnp.pad(a, pad)
        return a

    def run_fused():
        p = stores
        logits, caches = prefill(p, {"tokens": tokens})
        caches = jax.tree_util.tree_map(grow, caches)
        toks = jnp.argmax(logits, -1)[:, None]
        t0 = time.perf_counter()
        for step in range(gen):
            if step % refresh_every == 0:
                p = fused_refresh(p, rkeys[step // refresh_every])
            logits, caches = serve(p, caches, toks)
            toks = jnp.argmax(logits, -1)[:, None]
        jax.block_until_ready(toks)
        return batch * gen / (time.perf_counter() - t0)

    def run_hbm():
        cur, p = stores, decoded
        logits, caches = prefill(p, {"tokens": tokens})
        caches = jax.tree_util.tree_map(grow, caches)
        toks = jnp.argmax(logits, -1)[:, None]
        t0 = time.perf_counter()
        for step in range(gen):
            if step % refresh_every == 0:
                cur, p = hbm_refresh(cur, rkeys[step // refresh_every])
            logits, caches = serve(p, caches, toks)
            toks = jnp.argmax(logits, -1)[:, None]
        jax.block_until_ready(toks)
        return batch * gen / (time.perf_counter() - t0)

    arms = {"fused": run_fused, "hbm": run_hbm}
    for run in arms.values():                   # compile + warm both arms,
        run()                                   # refresh paths included
    best = {name: 0.0 for name in arms}
    for r in range(3):
        order = list(arms.items())
        if r % 2:
            order.reverse()
        for name, run in order:
            best[name] = max(best[name], run())
    fused_tok_s, hbm_tok_s = best["fused"], best["hbm"]
    store_leaves = [s for s in jax.tree_util.tree_leaves(
        stores, is_leaf=cim_lib._is_store) if cim_lib._is_store(s)]
    packed_bytes = sum(s.stored_bytes for s in store_leaves)
    fp16_bytes = sum(2 * s.shape[0] * s.shape[1] for s in store_leaves)
    cache_bytes = sum(int(s.cache.size) * s.cache.dtype.itemsize
                      for s in store_leaves if s.cache is not None)
    rows = [
        ("cim_store.serve.decode_on_read_tok_s", None, f"{fused_tok_s:.2f}"),
        ("cim_store.serve.hbm_remat_tok_s", None, f"{hbm_tok_s:.2f}"),
        ("cim_store.serve.weight_bytes", None,
         f"packed_image={packed_bytes};decoded_fp16={fp16_bytes};"
         f"row_cache={cache_bytes};embed table never fully decoded on the "
         f"fused path"),
    ]
    return rows, {"decode_on_read_tok_s": fused_tok_s,
                  "hbm_remat_tok_s": hbm_tok_s,
                  "packed_image_bytes": packed_bytes,
                  "decoded_fp16_bytes": fp16_bytes,
                  "row_cache_bytes": cache_bytes,
                  "gen_steps": gen, "refresh_every": refresh_every,
                  "note": "session includes periodic fault refreshes: the "
                          "hbm arm re-decodes every store per refresh, the "
                          "fused arm only re-warms the unembed row cache; "
                          "off-TPU the fused kernel runs in interpret mode"}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write the results as a JSON artifact")
    ap.add_argument("--skip-serving", action="store_true")
    args = ap.parse_args(argv)

    rows, grid = inject_read_grid()
    drows, dispatch = dispatch_bench()
    rows += drows
    serving = None
    if not args.skip_serving:
        srows, serving = serving_bench()
        rows += srows
    # headline contract: the packed representation must win the protection
    # grid outright (wall-clock AND bytes)
    ok = all(grid[p]["speedup"] > 1.0 for p in PROTECTS)
    rows.append(("cim_store.check.packed_wins_protection_grid", None,
                 f"{ok};speedups=" + ",".join(
                     f"{p}:{grid[p]['speedup']:.2f}x" for p in PROTECTS)))
    emit(rows)

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        payload = {"size": SIZE, "bers": BERS, "trials": TRIALS,
                   "quick": QUICK, "grid": grid, "serving": serving,
                   "dispatch": dispatch,
                   "packed_wins": ok, "backend": jax.default_backend(),
                   "devices": len(jax.devices())}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
