"""Continuous-batching engine throughput/latency vs sequential serving.

Two arms over the SAME synthetic request set (reduced olmo-1b, fused CIM
deployment, static injection at BER 1e-3):

1. **engine** — the slot-based continuous-batching scheduler
   (``repro.launch.engine``) at ``SLOTS`` decode slots: ragged prompts
   chunk-prefill into per-slot KV caches, finished requests evict and free
   slots mid-flight;
2. **sequential** — the degenerate single-slot engine (one request at a
   time, same code path), the baseline a lock-step launcher is stuck at
   when request lengths are ragged.

Two fleet arms ride along (``repro.launch.fleet``):

3. **fleet scaling** — the same closed burst through 1-replica and
   2-replica fleets (prefix cache off, ECC off): gated
   ``engine.fleet_scaling_tok_s`` = 2-replica / 1-replica ``tok_s_virtual``
   (the disjoint-device projection — this container steps replicas
   sequentially on shared cores, so real wall cannot show the overlap a
   fleet gets; see the ``fleet.py`` module doc). Hard bound: >= 1.7x.
4. **prefix reuse** — a shared-prefix load served twice on one replica,
   trie cold vs trie warm, all requests slotted at once (TTFT isolates
   prefill cost): gated ``engine.prefix_hit_ttft_ratio`` = warm / cold mean
   TTFT over the prefix-hit requests. Hard bound: <= 0.6x.

A fifth arm measures the online-scrubbing loop (``repro.launch.scrub``):

5. **scrub overhead** — the same drift-aging soak (per-step wear at
   ``AGE_BER``, drift process) served scrub-off vs scrub-on
   (ECC-threshold re-encode + params hot-swap mid-flight): gated
   ``engine.scrub_overhead_tok_s_ratio`` = scrub-on / scrub-off end-to-end
   ``tok_s``. Hard ``bound`` floor in the baseline — self-healing must not
   collapse serving throughput.

A sixth arm benches the slot-state protocol across architecture kinds:

6. **per-kind engines** — the same ragged load served through engines at
   **matched widths** (reduced configs share d_model=128 / 2 layers /
   d_ff=256 / vocab=256): full attention (olmo), an RWKV6 recurrent fold,
   and a pure rolling-window local-attention model. Gated
   ``engine.recurrent_vs_attn_tok_s_ratio`` and
   ``engine.local_vs_attn_tok_s_ratio`` = per-kind aggregate decode tok/s
   over the attn baseline, with hard ``bound`` floors — serving a
   recurrent or windowed architecture through the unified protocol must
   not become disproportionately slower than attention.

Gated metrics (``benchmarks/check_regression.py --engine``):

* ``engine.continuous_vs_sequential_tok_s`` — aggregate decode tok/s ratio,
  machine-relative (the continuous-batching win must not erode);
* ``engine.decode_s_per_tok`` / ``engine.ttft_s_mean`` — absolute
  wall-clock guards (coarse 2x bound, runner-dependent);
* ``engine.fleet_scaling_tok_s`` / ``engine.prefix_hit_ttft_ratio`` — the
  fleet wins above, with hard ``bound`` floors/ceilings in the baseline;
* ``engine.scrub_overhead_tok_s_ratio`` — the scrub-on throughput cost,
  hard floor;
* ``engine.recurrent_vs_attn_tok_s_ratio`` /
  ``engine.local_vs_attn_tok_s_ratio`` — the per-kind arm above, hard
  floors.

Every arm runs once unmeasured to absorb jit compiles (TTFT would otherwise
be compile time, not scheduling latency).

Run:  PYTHONPATH=src:. python benchmarks/engine_bench.py --json out.json
Quick (CI smoke): BENCH_QUICK=1 ... --json artifacts/engine_bench.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax

from benchmarks.common import QUICK
from repro.configs import get_config
from repro.launch import engine as engine_lib
from repro.launch import fleet as fleet_lib
from repro.launch import serve as serve_lib
from repro.models import lm

N_REQUESTS = 32 if not QUICK else 10
SLOTS = 4
CHUNK = 8
PROMPTS = (8, 24)
GENS = (8, 16)
BER = 1e-3
PREFIX_REQS = 8 if not QUICK else 6
PREFIX_LEN = 24            # 3 full shared chunks; per-request tail runs cold
FLEET_REQS = 32 if not QUICK else 12
FLEET_SLOTS = 2            # keep per-replica decode batches full at half load
SCRUB_REQS = 12 if not QUICK else 6
AGE_BER = 1e-3             # per-step wear under the drift process
SCRUB_THRESHOLD = 8        # per-store ECC events before a re-encode fires
KIND_REQS = 16 if not QUICK else 8
LOCAL_WINDOW = 16          # < max prompt len, so the ring actually rolls


def _setup():
    cfg = get_config("olmo-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    sparams = serve_lib.deploy_fused(
        params, ber=BER, protect="one4n", n_group=8, index=2,
        key=jax.random.fold_in(key, 1), inject_mode="static", field="full")
    load = engine_lib.LoadGen(n_requests=N_REQUESTS, prompt_lens=PROMPTS,
                              gen_lens=GENS, vocab_size=cfg.vocab_size,
                              seed=0)
    return cfg, sparams, load


def _arm(cfg, sparams, load, n_slots: int) -> dict:
    def run():
        eng = engine_lib.Engine(cfg, sparams, n_slots=n_slots,
                                max_len=load.max_len(), chunk=CHUNK,
                                ecc_accounting=False)
        _, agg = eng.run(load.requests())
        return agg

    run()          # warm: compiles prefill-chunk + decode at this slot count
    return run()


def _fleet_arm(cfg, sparams) -> dict:
    """Same closed burst through 1- and 2-replica fleets; the gated ratio is
    over ``tok_s_virtual`` (disjoint-device projection — replicas share this
    host's cores, see the module doc). Narrow ``FLEET_SLOTS`` decode batches
    keep both arms' slots full, so the ratio measures replica fan-out rather
    than the 2-replica arm's emptier batch tails."""
    load = engine_lib.LoadGen(n_requests=FLEET_REQS, prompt_lens=PROMPTS,
                              gen_lens=GENS, vocab_size=cfg.vocab_size,
                              seed=2)

    def run(n):
        fl = fleet_lib.Fleet.from_serving_params(
            cfg, sparams, n_replicas=n, prefix_cache=False,
            n_slots=FLEET_SLOTS, max_len=load.max_len(), chunk=CHUNK,
            ecc_accounting=False)
        _, agg = fl.run(load.requests())
        return agg

    run(1)         # warm (jit cache is shared across replica counts)
    f1, f2 = run(1), run(2)
    scaling = f2["tok_s_virtual"] / max(f1["tok_s_virtual"], 1e-9)
    return {"fleet1": f1, "fleet2": f2, "fleet_scaling_tok_s": scaling}


def _prefix_arm(cfg, sparams) -> dict:
    """Shared-prefix load served trie-off then trie-on; the gated ratio is
    mean per-request admission latency (TTFT net of time spent admitting
    earlier requests in the same burst) over the prefix-hit rids."""
    pload = engine_lib.LoadGen(n_requests=PREFIX_REQS, prompt_lens=(4, 8),
                               gen_lens=(2, 4), vocab_size=cfg.vocab_size,
                               seed=1, prefix_len=PREFIX_LEN)
    reqs = pload.requests()

    def run(pc):
        eng = engine_lib.Engine(cfg, sparams, n_slots=PREFIX_REQS,
                                max_len=pload.max_len(), chunk=CHUNK,
                                ecc_accounting=False, prefix_cache=pc)
        return eng.run(reqs)

    run(None), run(True)           # warm (extract/inject shapes too)
    cold, _ = run(None)
    warm, wagg = run(True)
    hits = sorted(rid for rid, r in warm.items() if r.prefix_tokens > 0)
    assert hits, "prefix arm produced no trie hits"

    def mean_admit(res):
        return sum(res[r].ttft_s - res[r].queue_s for r in hits) / len(hits)

    cold_s, warm_s = mean_admit(cold), mean_admit(warm)
    return {"requests": PREFIX_REQS, "prefix_len": PREFIX_LEN,
            "chunk": CHUNK, "hits": len(hits),
            "prefix_tokens_reused": wagg["prefix_tokens"],
            "admit_cold_s": cold_s, "admit_warm_s": warm_s,
            "prefix_hit_ttft_ratio": warm_s / max(cold_s, 1e-9)}


def _scrub_arm(cfg) -> dict:
    """Drift-aging soak scrub-off vs scrub-on: the gated ratio is end-to-end
    ``tok_s`` (wall includes the on_step hook, so re-encode + hot-swap +
    decoded-row-cache rewarm all land in the scrub-on arm). Both arms pay
    the identical per-step wear injection, so the ratio isolates what the
    self-healing itself costs."""
    from repro.launch import scrub as scrub_lib

    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    dep = serve_lib.make_deployment(
        params, ber=0.0, protect="one4n", n_group=8, index=2,
        key=jax.random.fold_in(key, 1), inject_mode="static", field="full")
    load = engine_lib.LoadGen(n_requests=SCRUB_REQS, prompt_lens=PROMPTS,
                              gen_lens=GENS, vocab_size=cfg.vocab_size,
                              seed=3)

    def run(scrub: bool):
        thresh = SCRUB_THRESHOLD if scrub else 10 ** 12
        ctl = scrub_lib.ScrubController(
            dep, scrub_lib.ScrubPolicy(threshold=thresh),
            aging=scrub_lib.DriftAging(key=jax.random.PRNGKey(77),
                                       ber=AGE_BER))
        # accounting stays ON — it is the scrub-decision signal; the rotting
        # scrub-off arm may go non-finite, which is the point
        eng = engine_lib.Engine(cfg, dep.serving_params(), n_slots=SLOTS,
                                max_len=load.max_len(), chunk=CHUNK,
                                check_finite=False)
        _, agg = eng.run(load.requests(), on_step=ctl)
        return agg

    run(False)     # warm: compiles + first cache decode
    off, on = run(False), run(True)
    return {"off": off, "on": on,
            "scrub_events": on["scrub"]["events"],
            "uncorrectable_off": off["ecc"]["uncorrectable"],
            "uncorrectable_on": on["ecc"]["uncorrectable"],
            "scrub_overhead_tok_s_ratio":
                on["tok_s"] / max(off["tok_s"], 1e-9)}


def _kind_arms() -> dict:
    """Per-kind engine throughput at matched widths: the reduced configs all
    share d_model=128 / 2 layers / d_ff=256 / vocab=256, so the gated ratios
    compare what each slot-state kind costs the scheduler, not model size.
    Same ragged load, same slots/chunk, fused static-image serving."""
    arms = (("attn", get_config("olmo-1b").reduced()),
            ("rwkv", get_config("rwkv6-1.6b").reduced()),
            ("local", dataclasses.replace(
                get_config("olmo-1b").reduced(),
                block_pattern=("local",), local_window=LOCAL_WINDOW)))
    out = {}
    for kind, cfg in arms:
        key = jax.random.PRNGKey(0)
        params = lm.init_lm(key, cfg)
        sparams = serve_lib.deploy_fused(
            params, ber=BER, protect="one4n", n_group=8, index=2,
            key=jax.random.fold_in(key, 1), inject_mode="static",
            field="full")
        load = engine_lib.LoadGen(n_requests=KIND_REQS, prompt_lens=PROMPTS,
                                  gen_lens=GENS, vocab_size=cfg.vocab_size,
                                  seed=4)
        agg = _arm(cfg, sparams, load, SLOTS)
        out[kind] = {"decode_tok_s": agg["decode_tok_s"],
                     "ttft_s_mean": agg["ttft_s_mean"],
                     "total_tokens": agg["total_tokens"]}
    attn = max(out["attn"]["decode_tok_s"], 1e-9)
    out["recurrent_vs_attn_tok_s_ratio"] = \
        out["rwkv"]["decode_tok_s"] / attn
    out["local_vs_attn_tok_s_ratio"] = out["local"]["decode_tok_s"] / attn
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write metrics JSON")
    args = ap.parse_args(argv)

    cfg, sparams, load = _setup()
    eng = _arm(cfg, sparams, load, SLOTS)
    seq = _arm(cfg, sparams, load, 1)
    ratio = eng["decode_tok_s"] / max(seq["decode_tok_s"], 1e-9)

    print(f"engine ({SLOTS} slots): {eng['decode_tok_s']:.1f} tok/s, "
          f"TTFT mean {eng['ttft_s_mean']*1e3:.0f} ms, "
          f"occupancy {eng['slot_occupancy']:.2f}")
    print(f"sequential (1 slot):   {seq['decode_tok_s']:.1f} tok/s, "
          f"TTFT mean {seq['ttft_s_mean']*1e3:.0f} ms")
    print(f"continuous-batching speedup: {ratio:.2f}x over "
          f"{eng['n_requests']} requests / {eng['total_tokens']} tokens")

    fleet = _fleet_arm(cfg, sparams)
    print(f"fleet scaling 1->2 replicas: "
          f"{fleet['fleet1']['tok_s_virtual']:.1f} -> "
          f"{fleet['fleet2']['tok_s_virtual']:.1f} tok/s virtual "
          f"({fleet['fleet_scaling_tok_s']:.2f}x, routed "
          f"{fleet['fleet2']['requests_by_replica']})")

    prefix = _prefix_arm(cfg, sparams)
    fleet["prefix"] = prefix
    fleet["prefix_hit_ttft_ratio"] = prefix["prefix_hit_ttft_ratio"]
    print(f"prefix reuse ({prefix['hits']} hit requests, "
          f"{prefix['prefix_len']}-token shared prefix): admit "
          f"{prefix['admit_cold_s']*1e3:.1f} -> "
          f"{prefix['admit_warm_s']*1e3:.1f} ms "
          f"({prefix['prefix_hit_ttft_ratio']:.2f}x)")

    scrub = _scrub_arm(cfg)
    print(f"scrub soak ({SCRUB_REQS} requests, wear {AGE_BER:.0e}/step): "
          f"{scrub['off']['tok_s']:.1f} -> {scrub['on']['tok_s']:.1f} tok/s "
          f"({scrub['scrub_overhead_tok_s_ratio']:.2f}x, "
          f"{scrub['scrub_events']} scrubs, uncorrectable "
          f"{scrub['uncorrectable_off']} -> {scrub['uncorrectable_on']})")

    kinds = _kind_arms()
    print(f"per-kind engines (matched widths, {KIND_REQS} requests): "
          f"attn {kinds['attn']['decode_tok_s']:.1f}, "
          f"rwkv {kinds['rwkv']['decode_tok_s']:.1f} "
          f"({kinds['recurrent_vs_attn_tok_s_ratio']:.2f}x), "
          f"local {kinds['local']['decode_tok_s']:.1f} tok/s "
          f"({kinds['local_vs_attn_tok_s_ratio']:.2f}x)")

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        payload = {"quick": QUICK,
                   "n_requests": N_REQUESTS, "slots": SLOTS, "chunk": CHUNK,
                   "engine": eng, "sequential": seq,
                   "continuous_vs_sequential_tok_s": ratio,
                   "fleet": fleet, "scrub": scrub, "kinds": kinds}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
