"""Continuous-batching engine throughput/latency vs sequential serving.

Two arms over the SAME synthetic request set (reduced olmo-1b, fused CIM
deployment, static injection at BER 1e-3):

1. **engine** — the slot-based continuous-batching scheduler
   (``repro.launch.engine``) at ``SLOTS`` decode slots: ragged prompts
   chunk-prefill into per-slot KV caches, finished requests evict and free
   slots mid-flight;
2. **sequential** — the degenerate single-slot engine (one request at a
   time, same code path), the baseline a lock-step launcher is stuck at
   when request lengths are ragged.

Gated metrics (``benchmarks/check_regression.py --engine``):

* ``engine.continuous_vs_sequential_tok_s`` — aggregate decode tok/s ratio,
  machine-relative (the continuous-batching win must not erode);
* ``engine.decode_s_per_tok`` / ``engine.ttft_s_mean`` — absolute
  wall-clock guards (coarse 2x bound, runner-dependent).

Both arms run once unmeasured to absorb jit compiles (TTFT would otherwise
be compile time, not scheduling latency).

Run:  PYTHONPATH=src:. python benchmarks/engine_bench.py --json out.json
Quick (CI smoke): BENCH_QUICK=1 ... --json artifacts/engine_bench.json
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from benchmarks.common import QUICK
from repro.configs import get_config
from repro.launch import engine as engine_lib
from repro.launch import serve as serve_lib
from repro.models import lm

N_REQUESTS = 32 if not QUICK else 10
SLOTS = 4
CHUNK = 8
PROMPTS = (8, 24)
GENS = (8, 16)
BER = 1e-3


def _setup():
    cfg = get_config("olmo-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    sparams = serve_lib.deploy_fused(
        params, ber=BER, protect="one4n", n_group=8, index=2,
        key=jax.random.fold_in(key, 1), inject_mode="static", field="full")
    load = engine_lib.LoadGen(n_requests=N_REQUESTS, prompt_lens=PROMPTS,
                              gen_lens=GENS, vocab_size=cfg.vocab_size,
                              seed=0)
    return cfg, sparams, load


def _arm(cfg, sparams, load, n_slots: int) -> dict:
    def run():
        eng = engine_lib.Engine(cfg, sparams, n_slots=n_slots,
                                max_len=load.max_len(), chunk=CHUNK,
                                ecc_accounting=False)
        _, agg = eng.run(load.requests())
        return agg

    run()          # warm: compiles prefill-chunk + decode at this slot count
    return run()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, help="write metrics JSON")
    args = ap.parse_args(argv)

    cfg, sparams, load = _setup()
    eng = _arm(cfg, sparams, load, SLOTS)
    seq = _arm(cfg, sparams, load, 1)
    ratio = eng["decode_tok_s"] / max(seq["decode_tok_s"], 1e-9)

    print(f"engine ({SLOTS} slots): {eng['decode_tok_s']:.1f} tok/s, "
          f"TTFT mean {eng['ttft_s_mean']*1e3:.0f} ms, "
          f"occupancy {eng['slot_occupancy']:.2f}")
    print(f"sequential (1 slot):   {seq['decode_tok_s']:.1f} tok/s, "
          f"TTFT mean {seq['ttft_s_mean']*1e3:.0f} ms")
    print(f"continuous-batching speedup: {ratio:.2f}x over "
          f"{eng['n_requests']} requests / {eng['total_tokens']} tokens")

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        payload = {"quick": QUICK,
                   "n_requests": N_REQUESTS, "slots": SLOTS, "chunk": CHUNK,
                   "engine": eng, "sequential": seq,
                   "continuous_vs_sequential_tok_s": ratio}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
