"""Fig. 2: inference accuracy vs BER per FP16 field (static injection).

Driven by the vectorized sweep engine: one compiled (BER x trial) plane per
field arm (see repro/core/sweep.py and benchmarks/sweep_bench.py for the
engine-vs-loop comparison)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import QUICK, cnn_setup, emit, lm_setup, make_engine
from repro.core import resilience

BERS = [1e-6, 1e-5, 1e-4, 1e-3, 1e-2]
FIELDS = ("sign", "exponent", "mantissa", "full")


def main():
    rows = []
    trials = 3 if QUICK else 8
    for name, setup in (("lm", lambda: lm_setup()[:3]),
                        ("cnn", lambda: cnn_setup()[:2])):
        got = setup()
        params, eval_fn = got[0], got[-1]
        clean = float(eval_fn(params))
        rows.append((f"fig2.{name}.clean", None, f"acc={clean:.4f}"))
        engine = make_engine(BERS, trials, fields=FIELDS)
        t0 = time.time()
        results = resilience.characterize_fields(
            jax.random.PRNGKey(3), params, eval_fn, BERS,
            fields=FIELDS, n_trials=trials, engine=engine)
        us = (time.time() - t0) * 1e6 / max(len(results) * trials, 1)
        compiles = max(engine.compiles().values())
        rows.append((f"fig2.{name}.compiles_per_arm", None,
                     f"{compiles} (contract: 1):{compiles == 1}"))
        for r in results:
            rows.append((f"fig2.{name}.{r.field}.ber{r.ber:.0e}", round(us),
                         f"acc={r.mean:.4f};std={r.std:.4f}"))
        # the paper's headline orderings, as derived checks
        by = {(r.field, r.ber): r.mean for r in results}
        exp_cliff = by[("exponent", 1e-3)] <= by[("mantissa", 1e-3)] + 1e-9
        rows.append((f"fig2.{name}.check.exponent_most_sensitive", None,
                     f"exp@1e-3={by[('exponent', 1e-3)]:.3f}"
                     f"<=man@1e-3={by[('mantissa', 1e-3)]:.3f}:{exp_cliff}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
