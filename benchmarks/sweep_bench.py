"""Vectorized sweep engine vs the loop-based characterization baseline.

Runs the full Fig. 2-style grid (4 fields x 5 BERs x >=10 trials) and a
Fig. 6-style protection grid through BOTH harnesses on identical keys and
reports wall-clock speedup. Also asserts the engine's one-compile-per-arm
contract via the per-arm jit cache sizes, and exercises the trial-batched
Pallas fault-inject route (interpret mode off-TPU).

Rows: sweep.<grid>.{loop,vectorized}     us_per_cell, wall seconds
      sweep.<grid>.speedup               loop_wall / vectorized_wall
      sweep.<grid>.compiles_per_arm      max over arms (must be 1)

Run:  PYTHONPATH=src:. python benchmarks/sweep_bench.py --json out.json
Quick (CI smoke): BENCH_QUICK=1 ... --json artifacts/sweep_bench.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import QUICK, cnn_setup, emit
from repro.core import resilience
from repro.core import sweep as sweep_lib

BERS = (1e-6, 1e-5, 1e-4, 1e-3, 1e-2) if not QUICK else (1e-4, 1e-2)
FIELDS = ("sign", "exponent", "mantissa", "full") if not QUICK \
    else ("exponent", "full")
PROTECTS = ("none", "per_weight", "one4n")
N_TRIALS = 10 if not QUICK else 4


def _wall(fn):
    t0 = time.time()
    out = fn()
    return time.time() - t0, out


def _mean_diff(a, b):
    """NaN on both sides = agreement (inf propagation); one-sided NaN is a
    real divergence, not a cell to skip."""
    a_nan, b_nan = a.mean != a.mean, b.mean != b.mean
    if a_nan != b_nan:
        return float("inf")
    return 0.0 if a_nan else abs(a.mean - b.mean)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write the results as a JSON artifact")
    args = ap.parse_args(argv)

    params, eval_fn, _ = cnn_setup()
    rows = []
    payload = {"quick": QUICK, "backend": jax.default_backend(),
               "bers": list(BERS), "n_trials": N_TRIALS}

    # Timing methodology: the engine is warmed once (it caches compiled
    # executors across calls), so its timed run is compile-free. The loop
    # harness CANNOT be warmed from outside — it builds fresh @jax.jit
    # closures inside every invocation, so each call pays one trace+compile
    # per arm. That per-call compile is inherent to the loop design (and part
    # of what the engine eliminates); loop rows are labelled accordingly.

    # ---------------------------------------------------- Fig. 2-style grid
    n_cells = len(FIELDS) * len(BERS) * N_TRIALS
    key = jax.random.PRNGKey(21)
    engine = sweep_lib.SweepEngine(sweep_lib.SweepPlan(
        bers=BERS, n_trials=N_TRIALS, fields=FIELDS))
    engine.run_fields(key, params, eval_fn)     # warm the executor cache

    wall_vec, vec = _wall(lambda: engine.run_fields(key, params, eval_fn))
    wall_loop, loop = _wall(lambda: resilience.characterize_fields_loop(
        key, params, eval_fn, BERS, fields=FIELDS, n_trials=N_TRIALS))
    compiles = max(engine.compiles().values())
    assert compiles == 1, f"fields grid compiled {compiles}x per arm (want 1)"
    agree = max((_mean_diff(a, b) for a, b in zip(loop, vec)), default=0.0)
    rows += [
        ("sweep.fields.loop", round(wall_loop * 1e6 / n_cells),
         f"wall_s={wall_loop:.2f};cells={n_cells};"
         f"incl_compiles={len(FIELDS)}"),
        ("sweep.fields.vectorized", round(wall_vec * 1e6 / n_cells),
         f"wall_s={wall_vec:.2f};cells={n_cells}"),
        ("sweep.fields.speedup", None, f"x{wall_loop / wall_vec:.1f}"),
        ("sweep.fields.compiles_per_arm", None,
         f"{compiles} (contract: 1):{compiles == 1}"),
        ("sweep.fields.check.loop_vec_agree", None, f"max_mean_diff={agree:.1e}"),
    ]
    payload["fields"] = {"loop_wall_s": wall_loop,
                         "vectorized_wall_s": wall_vec,
                         "speedup": wall_loop / wall_vec,
                         "compiles_per_arm": compiles}

    # ---------------------------------------------------- Fig. 6-style grid
    n_cells = len(PROTECTS) * len(BERS) * N_TRIALS
    key = jax.random.PRNGKey(22)
    engine_p = sweep_lib.SweepEngine(sweep_lib.SweepPlan(
        bers=BERS, n_trials=N_TRIALS, protects=PROTECTS))
    engine_p.run_protection(key, params, eval_fn)   # warm the executor cache

    wall_vec, _ = _wall(lambda: engine_p.run_protection(key, params, eval_fn))
    wall_loop, _ = _wall(lambda: resilience.characterize_protection_loop(
        key, params, eval_fn, BERS, n_trials=N_TRIALS, protects=PROTECTS))
    compiles = max(engine_p.compiles().values())
    assert compiles == 1, f"protection grid compiled {compiles}x per arm (want 1)"
    rows += [
        ("sweep.protection.loop", round(wall_loop * 1e6 / n_cells),
         f"wall_s={wall_loop:.2f};cells={n_cells};"
         f"incl_compiles={len(PROTECTS)}"),
        ("sweep.protection.vectorized", round(wall_vec * 1e6 / n_cells),
         f"wall_s={wall_vec:.2f};cells={n_cells}"),
        ("sweep.protection.speedup", None, f"x{wall_loop / wall_vec:.1f}"),
        ("sweep.protection.compiles_per_arm", None,
         f"{compiles} (contract: 1):{compiles == 1}"),
    ]
    payload["protection"] = {"loop_wall_s": wall_loop,
                             "vectorized_wall_s": wall_vec,
                             "speedup": wall_loop / wall_vec,
                             "compiles_per_arm": compiles}

    # ------------------------------- kernel-backed route (interpret off-TPU)
    key = jax.random.PRNGKey(23)
    engine_k = sweep_lib.SweepEngine(sweep_lib.SweepPlan(
        bers=BERS, n_trials=N_TRIALS, fields=("exponent",), backend="pallas"))
    wall_pal, res = _wall(lambda: engine_k.run_fields(key, params, eval_fn))
    rows.append(("sweep.fields.pallas_route", None,
                 f"wall_s={wall_pal:.2f};backend={engine_k.backend};"
                 f"interpret={engine_k.interpret};"
                 f"acc@1e-2={res[-1].mean:.3f}"))
    payload["pallas_route_wall_s"] = wall_pal
    emit(rows)

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
