"""Beyond-paper: FP8 characterization — the paper's stated FUTURE WORK
("we will extend our research to DNN models with FP8 precision").

Sweeps BER x field for fp8_e4m3 and fp8_e5m2 weight storage on the trained
LM. Expected structure: the exponent field stays the catastrophic one; e5m2
(5 exponent bits, same as fp16) degrades harder than e4m3 at equal BER
because a flipped high exponent bit scales by up to 2^16 vs 2^8 — i.e. the
One4N design point transfers directly (6 protected bits/weight for e5m2+sign,
5 for e4m3+sign; Eq. 3 arithmetic unchanged)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import QUICK, emit, lm_setup
from repro.core import resilience
from repro.core.bitops import FP8_E4M3, FP8_E5M2

BERS = [1e-5, 1e-4, 1e-3]


def main():
    params, cfg, eval_fn, _ = lm_setup()
    rows = [("fp8.clean_fp16", None, f"acc={float(eval_fn(params)):.4f}")]
    trials = 2 if QUICK else 5
    means = {}
    for fmt in (FP8_E4M3, FP8_E5M2):
        # accuracy after quantizing weights to the fp8 grid, no faults
        from repro.core import bitops
        qparams = jax.tree_util.tree_map(
            lambda p: bitops.quantize_to_format(p, fmt).astype(p.dtype)
            if p.ndim >= 2 else p, params)
        rows.append((f"fp8.{fmt.name}.quantized_clean", None,
                     f"acc={float(eval_fn(qparams)):.4f}"))
        t0 = time.time()
        results = resilience.characterize_fields(
            jax.random.PRNGKey(11), qparams, eval_fn, BERS,
            fields=("exponent", "mantissa"), n_trials=trials, fmt=fmt)
        us = (time.time() - t0) * 1e6 / max(len(results) * trials, 1)
        for r in results:
            rows.append((f"fp8.{fmt.name}.{r.field}.ber{r.ber:.0e}", round(us),
                         f"acc={r.mean:.4f}"))
            means[(fmt.name, r.field, r.ber)] = r.mean
    ok = means[("fp8_e4m3", "exponent", 1e-3)] <= \
        means[("fp8_e4m3", "mantissa", 1e-3)] + 1e-9
    rows.append(("fp8.check.exponent_still_dominant", None, str(ok)))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
