"""Benchmark harness: one module per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV rows. ``BENCH_QUICK=1`` shrinks trial
counts (used by CI-style smoke runs); the default settings are what
EXPERIMENTS.md reports.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (fig2_characterization, fig6_protection,
                            fig7_training, fp8_future, kernel_bench,
                            roofline_report, sweep_bench, table1_alignment,
                            table3_overhead)
    modules = [
        ("table3", table3_overhead),        # pure arithmetic first (fast)
        ("roofline", roofline_report),
        ("kernels", kernel_bench),
        ("sweep", sweep_bench),             # vectorized vs loop characterization
        ("fig2", fig2_characterization),
        ("fig6", fig6_protection),
        ("table1", table1_alignment),
        ("fig7", fig7_training),
        ("fp8", fp8_future),                # beyond-paper: the stated future work
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.main()
            print(f"suite.{name},,wall_s={time.time() - t0:.1f}")
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"suite.{name},,FAILED={type(e).__name__}: {e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
