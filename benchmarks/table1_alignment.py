"""Table I: fine-tuning accuracy ratio vs retrained baseline, over
N in {4, 8, 16} x exponent index in {1..4} (paper's grid, CNN family)."""
from __future__ import annotations

import time

from benchmarks.common import QUICK, cnn_setup, emit, finetune_cnn
from repro.core.align import AlignmentConfig

GRID_N = (4, 8, 16)
GRID_INDEX = (1, 2, 3, 4)


def main():
    params, eval_fn, task = cnn_setup()
    baseline = float(eval_fn(params))
    rows = [("table1.cnn.baseline", None, f"acc={baseline:.4f}")]
    ratios = {}
    from repro.core import align as align_lib
    for n in GRID_N:
        for idx in GRID_INDEX:
            t0 = time.time()
            acfg = AlignmentConfig(n_group=n, index=idx)
            aligned, _ = align_lib.align_pytree(params, acfg)
            pre = float(eval_fn(aligned))
            tuned = finetune_cnn(params, task, acfg)
            acc = float(eval_fn(tuned))
            ratio = acc / max(baseline, 1e-9)
            ratios[(n, idx)] = ratio
            rows.append((f"table1.N{n}.idx{idx}",
                         round((time.time() - t0) * 1e6),
                         f"acc={acc:.4f};ratio={ratio:.4f};pre_ft={pre:.4f}"))
    # paper's findings as derived checks: N=8 best trade-off; middle indices
    # (2,3) >= extreme indices (1,4) on average
    n8 = sum(ratios[(8, i)] for i in GRID_INDEX) / 4
    n4 = sum(ratios[(4, i)] for i in GRID_INDEX) / 4
    mid = sum(ratios[(n, i)] for n in GRID_N for i in (2, 3)) / 6
    ext = sum(ratios[(n, i)] for n in GRID_N for i in (1, 4)) / 6
    rows.append(("table1.check.n8_beats_n4", None,
                 f"n8={n8:.4f};n4={n4:.4f};{n8 >= n4 - 0.02}"))
    rows.append(("table1.check.mid_indices_best", None,
                 f"mid={mid:.4f};ext={ext:.4f};{mid >= ext - 0.02}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
