"""Table III: hardware-efficiency comparison for a 256x256 SRAM array.

Redundant-bit and SRAM-bit-cell columns are exact arithmetic reproduced from
the SECDED structure (every count matches the paper's numbers). The logic
column requires the paper's TSMC N16 synthesis flow; we model it with an
XOR-tree gate-count estimate, normalized so the traditional full-FP scheme
matches the paper's 74.44%, and report our scheme's modeled overhead next to
the paper's measured 8.98% (DESIGN.md §1 fidelity notes).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.ecc import One4NRowCodec, secded_redundant_bits

ROWS, ROW_BITS, WPR = 256, 256, 16          # 256x256 array, 16 fp16 weights/row
N_WEIGHTS = ROWS * WPR                       # 4096
EXP_BITS, SIGN_BITS, MAN_BITS = 5, 1, 10


def xor_gates_secded(d: int) -> int:
    """Gate-count model: encode + syndrome XOR trees ~ 2 * d * r XOR2 gates."""
    r = secded_redundant_bits(d)
    return 2 * d * r


def main():
    rows = []

    # -- scheme 1: traditional per-weight ECC over the ENTIRE FP number ------
    # separate encoding for (sign+exp) and mantissa (different macro modules)
    bits_1 = N_WEIGHTS * (secded_redundant_bits(EXP_BITS + SIGN_BITS)
                          + secded_redundant_bits(MAN_BITS))
    gates_1 = N_WEIGHTS * (xor_gates_secded(6) + xor_gates_secded(10))

    # -- scheme 2: traditional per-weight ECC, exponent+sign only ------------
    bits_2 = N_WEIGHTS * secded_redundant_bits(EXP_BITS + SIGN_BITS)
    gates_2 = N_WEIGHTS * xor_gates_secded(6)

    # -- scheme 3: row-based ECC over the entire FP number -------------------
    # per 256-bit row: one SECDED over 96 sign+exp bits + one over 160 mantissa
    bits_3 = ROWS * (secded_redundant_bits(96) + secded_redundant_bits(160))
    gates_3 = ROWS * (xor_gates_secded(96) + xor_gates_secded(160))

    # -- ours: One4N (N=8) ----------------------------------------------------
    codec = One4NRowCodec(n_group=8)
    n_blocks = ROWS // 8
    bits_ours = n_blocks * codec.redundant_bits_per_block
    gates_ours = n_blocks * codec.n_segments * xor_gates_secded(codec.segment_bits)

    # SRAM bit cells for exponents
    cells_trad = N_WEIGHTS * EXP_BITS
    cells_ours = n_blocks * WPR * EXP_BITS

    # logic overhead normalized so scheme 1 == paper's 74.44%
    paper_full = 74.44
    scale = paper_full / gates_1
    logic = {k: g * scale for k, g in
             (("full", gates_1), ("expsign", gates_2), ("rowfull", gates_3),
              ("ours", gates_ours))}

    expect = {"full": 40960, "expsign": 20480, "rowfull": 4352, "ours": 512}
    got = {"full": bits_1, "expsign": bits_2, "rowfull": bits_3, "ours": bits_ours}
    for k in expect:
        rows.append((f"table3.redundant_bits.{k}", None,
                     f"bits={got[k]};paper={expect[k]};match={got[k] == expect[k]}"))
    rows.append(("table3.sram_cells.traditional", None,
                 f"cells={cells_trad};paper=20480;match={cells_trad == 20480}"))
    rows.append(("table3.sram_cells.ours", None,
                 f"cells={cells_ours};paper=2560;match={cells_ours == 2560};"
                 f"reduction={cells_trad // cells_ours}x"))
    for k, v in logic.items():
        rows.append((f"table3.logic_overhead_model.{k}", None,
                     f"modeled={v:.2f}%"))
    rows.append(("table3.logic_overhead.paper_ours", None,
                 "paper_measured=8.98% (TSMC N16 synthesis; not reproducible "
                 f"offline — model gives {logic['ours']:.2f}%)"))
    rows.append(("table3.improvements", None,
                 f"bits_vs_full={bits_1 // bits_ours}x(paper 80x);"
                 f"bits_vs_expsign={bits_2 // bits_ours}x(paper 40x)"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
