"""Fig. 6: accuracy vs BER with and without One4N ECC on the CIM deployment
(exponent-aligned weights, bit-accurate SRAM image).

Driven by the vectorized sweep engine: one compiled inject -> ECC-decode ->
eval plane per protection arm."""
from __future__ import annotations

import time

import jax

from benchmarks.common import QUICK, emit, lm_setup, make_engine
from repro.core import cim as cim_lib
from repro.core import resilience

BERS = [1e-6, 1e-5, 1e-4, 1e-3, 1e-2]
PROTECTS = ("none", "per_weight", "one4n")


def main():
    params, cfg, eval_fn, _ = lm_setup()
    rows = [("fig6.lm.clean", None, f"acc={float(eval_fn(params)):.4f}")]
    trials = 3 if QUICK else 8
    engine = make_engine(BERS, trials, protects=PROTECTS)
    t0 = time.time()
    results = resilience.characterize_protection(
        jax.random.PRNGKey(5), params, eval_fn, BERS,
        cim_cfg=cim_lib.CIMConfig(n_group=8, index=2), n_trials=trials,
        protects=PROTECTS, engine=engine)
    us = (time.time() - t0) * 1e6 / max(len(results) * trials, 1)
    compiles = max(engine.compiles().values())
    rows.append(("fig6.lm.compiles_per_arm", None,
                 f"{compiles} (contract: 1):{compiles == 1}"))
    by = {}
    for r in results:
        rows.append((f"fig6.lm.{r.protect}.ber{r.ber:.0e}", round(us),
                     f"acc={r.mean:.4f};corrected={r.corrected:.0f};"
                     f"uncorrectable={r.uncorrectable:.0f}"))
        by[(r.protect, r.ber)] = r.mean
    # headline: protection dominates at every damaging BER; One4N matches the
    # 40x-more-expensive traditional scheme until multi-error rows appear
    wins = sum(by[("one4n", b)] >= by[("none", b)] - 1e-9 for b in BERS)
    rows.append(("fig6.lm.check.one4n_dominates", None,
                 f"wins={wins}/{len(BERS)}"))
    close = sum(by[("one4n", b)] >= by[("per_weight", b)] - 0.02
                for b in BERS if b <= 1e-4)
    rows.append(("fig6.lm.check.one4n_matches_traditional_low_ber", None,
                 f"close={close}/{sum(1 for b in BERS if b <= 1e-4)} "
                 f"(at 40x fewer check bits)"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
