"""Bench regression gate: compare fresh ``--json`` bench artifacts against a
committed baseline (``benchmarks/baselines/BENCH_baseline.json``).

Two metric classes:

* **ratio metrics** (packed-vs-legacy speedup, loop-vs-vectorized speedup,
  decode-on-read vs HBM tok/s ratio, continuous-batching vs sequential
  engine tok/s) are machine-relative — they gate at the given
  ``--tolerance`` (fail if fresh < baseline / tol);
* **absolute wall-clock metrics** (seconds per cell, wall seconds, engine
  s/token and TTFT) vary with runner hardware, so they gate at
  ``2 x tolerance`` (fail if fresh > baseline * 2 * tol) — a coarse guard
  against order-of-magnitude regressions that ratio metrics cannot see
  (e.g. both arms slowing down).

Usage (CI smoke, after the benches wrote their artifacts):

  PYTHONPATH=src:. python benchmarks/check_regression.py \\
      --baseline benchmarks/baselines/BENCH_baseline.json \\
      --cim-store artifacts/cim_store_bench.json \\
      --kernel artifacts/kernel_bench.json \\
      --sweep artifacts/sweep_bench.json \\
      --engine artifacts/engine_bench.json \\
      --tolerance 1.5 --report artifacts/bench_regression_report.json

Refresh the committed baseline after an intentional perf change:

  ... check_regression.py --cim-store ... --sweep ... \\
      --write-baseline benchmarks/baselines/BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

LOWER = "lower_is_better"     # absolute wall-clock
HIGHER = "higher_is_better"   # machine-relative speedup ratio


def _flatten_cim_store(d: dict) -> dict:
    out = {}
    for protect, g in (d.get("grid") or {}).items():
        if not isinstance(g, dict) or "speedup" not in g:
            continue
        out[f"cim_store.inject_read.{protect}.packed_s_per_cell"] = \
            (LOWER, g["packed_s_per_cell"])
        out[f"cim_store.inject_read.{protect}.speedup"] = \
            (HIGHER, g["speedup"])
    serving = d.get("serving") or {}
    if serving.get("hbm_remat_tok_s"):
        out["cim_store.serve.fused_vs_hbm_ratio"] = \
            (HIGHER, serving["decode_on_read_tok_s"]
             / serving["hbm_remat_tok_s"])
    dispatch = d.get("dispatch") or {}
    if dispatch.get("overhead_ratio"):
        # deployment.linear vs direct kernel call on the same store: the
        # unified API layer must stay measurement-noise close to 1.0
        out["cim_store.dispatch.overhead_ratio"] = \
            (LOWER, dispatch["overhead_ratio"])
    return out


def _flatten_kernel(d: dict) -> dict:
    out = {}
    cr = d.get("cim_read") or {}
    if cr.get("fused_call_us"):
        # one autotuned fused decode-on-read call at the serving decode-step
        # shape — absolute wall clock, coarse 2x-tolerance guard
        out["kernel.cim_read.fused_call_us"] = (LOWER, cr["fused_call_us"])
    if cr.get("cache_speedup"):
        # decoded-row cache dispatch vs running the fused kernel: structural
        # on every backend (a cached matmul vs a full ECC decode), gated.
        # autotune_speedup / hoist_speedup stay report-only: interpret-mode
        # XLA CSE already hoists the per-revisit decode, so they hover near
        # 1.0 off-TPU (see kernel_bench.py module docstring).
        out["kernel.cim_read.cache_speedup"] = (HIGHER, cr["cache_speedup"])
    return out


def _flatten_sweep(d: dict) -> dict:
    out = {}
    for grid in ("fields", "protection"):
        g = d.get(grid) or {}
        if "speedup" not in g:
            continue
        out[f"sweep.{grid}.vectorized_wall_s"] = \
            (LOWER, g["vectorized_wall_s"])
        out[f"sweep.{grid}.speedup"] = (HIGHER, g["speedup"])
    return out


def _flatten_engine(d: dict) -> dict:
    out = {}
    if d.get("continuous_vs_sequential_tok_s"):
        # continuous batching vs the single-slot degenerate engine on the
        # same ragged request set: machine-relative, must not erode
        out["engine.continuous_vs_sequential_tok_s"] = \
            (HIGHER, d["continuous_vs_sequential_tok_s"])
    eng = d.get("engine") or {}
    if eng.get("decode_tok_s"):
        out["engine.decode_s_per_tok"] = (LOWER, 1.0 / eng["decode_tok_s"])
    if eng.get("ttft_s_mean"):
        out["engine.ttft_s_mean"] = (LOWER, eng["ttft_s_mean"])
    fleet = d.get("fleet") or {}
    if fleet.get("fleet_scaling_tok_s"):
        # 1 -> 2 replica aggregate tok/s (virtual, disjoint-device
        # projection): data-parallel fan-out must keep scaling
        out["engine.fleet_scaling_tok_s"] = \
            (HIGHER, fleet["fleet_scaling_tok_s"])
    if fleet.get("prefix_hit_ttft_ratio"):
        # warm-trie / cold-trie admission latency on prefix-hit requests:
        # KV reuse must keep beating recomputation
        out["engine.prefix_hit_ttft_ratio"] = \
            (LOWER, fleet["prefix_hit_ttft_ratio"])
    scrub = d.get("scrub") or {}
    if scrub.get("scrub_overhead_tok_s_ratio"):
        # scrub-on / scrub-off end-to-end tok/s under the drift soak: the
        # self-healing loop must not collapse throughput (hard floor)
        out["engine.scrub_overhead_tok_s_ratio"] = \
            (HIGHER, scrub["scrub_overhead_tok_s_ratio"])
    kinds = d.get("kinds") or {}
    if kinds.get("recurrent_vs_attn_tok_s_ratio"):
        # rwkv / attn aggregate decode tok/s at matched widths: serving a
        # recurrent fold through the slot-state protocol must not become
        # disproportionately slower than attention (hard floor)
        out["engine.recurrent_vs_attn_tok_s_ratio"] = \
            (HIGHER, kinds["recurrent_vs_attn_tok_s_ratio"])
    if kinds.get("local_vs_attn_tok_s_ratio"):
        # rolling-window local attention / attn, same contract
        out["engine.local_vs_attn_tok_s_ratio"] = \
            (HIGHER, kinds["local_vs_attn_tok_s_ratio"])
    return out


def _flatten_training(d: dict) -> dict:
    out = {}
    s = d.get("search") or {}
    if s.get("bits_ratio"):
        # searched-policy stored bits / uniform-One4N stored bits: the
        # co-design acceptance criterion — the search must find protection
        # that is STRICTLY cheaper (hard ceiling 0.99 in the baseline)
        out["training.fig7.searched_vs_one4n_bits_ratio"] = \
            (LOWER, s["bits_ratio"])
    if "slo_met" in s:
        # binary: searched policy meets the accuracy-vs-BER SLO
        # (hard floor 1.0 — no tolerance relaxes a missed SLO)
        out["training.fig7.searched_slo_met"] = \
            (HIGHER, 1.0 if s["slo_met"] else 0.0)
    after = d.get("after") or {}
    if after.get("one4n_acc"):
        # fine-tuned + uniform One4N accuracy at the derived BER: the
        # before/after training benefit must not erode
        out["training.fig7.finetuned_acc_at_ber"] = \
            (HIGHER, after["one4n_acc"])
    if d.get("wall_s"):
        out["training.fig7.wall_s"] = (LOWER, d["wall_s"])
    return out


def _load(path):
    with open(path) as f:
        return json.load(f)


def collect_metrics(args):
    """-> (metrics, quick): flattened metrics plus the artifacts' BENCH_QUICK
    provenance (grid sizes differ between quick and full runs, so baselines
    are only comparable against artifacts of the same kind)."""
    metrics, quick = {}, set()
    for path, flatten in ((args.cim_store, _flatten_cim_store),
                          (args.kernel, _flatten_kernel),
                          (args.sweep, _flatten_sweep),
                          (args.engine, _flatten_engine),
                          (args.training, _flatten_training)):
        if path:
            d = _load(path)
            metrics.update(flatten(d))
            quick.add(bool(d.get("quick")))
    if len(quick) > 1:
        raise SystemExit("check_regression: mixed quick/full artifacts — "
                         "run both benches with the same BENCH_QUICK setting")
    return metrics, (quick.pop() if quick else None)


def compare(baseline: dict, fresh: dict, tolerance: float):
    """-> (failures, lines). A fresh metric absent from the baseline is
    reported but never fails (forward compatibility for new benches); a
    BASELINE metric missing from the fresh artifacts fails — a bench that
    silently stops emitting a gated number must not turn the gate green.

    A baseline entry may carry a hard ``"bound"`` on top of the tolerance
    check: an absolute floor for HIGHER metrics / ceiling for LOWER ones
    that no tolerance relaxes (acceptance criteria like "fleet scaling
    >= 1.7x" gate on the literal number, not a drifting baseline)."""
    failures, lines = [], []
    base_metrics = baseline.get("metrics", {})
    for name in sorted(set(base_metrics) - set(fresh)):
        lines.append(f"  FAIL {name}: in baseline but missing from the "
                     f"fresh artifacts")
        failures.append(name)
    for name, (direction, value) in sorted(fresh.items()):
        base = base_metrics.get(name)
        if base is None:
            lines.append(f"  NEW  {name} = {value:.4g} (no baseline)")
            continue
        bval = base["value"]
        hard = base.get("bound")
        if direction == HIGHER:
            bound = bval / tolerance
            if hard is not None:
                bound = max(bound, hard)
            ok = value >= bound
            verdict = f">= {bound:.4g} (baseline {bval:.4g} / tol" + \
                (f", hard floor {hard:.4g})" if hard is not None else ")")
        else:
            bound = bval * 2 * tolerance
            if hard is not None:
                bound = min(bound, hard)
            ok = value <= bound
            verdict = f"<= {bound:.4g} (baseline {bval:.4g} * 2*tol" + \
                (f", hard ceiling {hard:.4g})" if hard is not None else ")")
        tag = "ok  " if ok else "FAIL"
        lines.append(f"  {tag} {name} = {value:.4g}  want {verdict}")
        if not ok:
            failures.append(name)
    return failures, lines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="benchmarks/baselines/BENCH_baseline.json")
    ap.add_argument("--cim-store", default=None,
                    help="fresh cim_store_bench.py --json artifact")
    ap.add_argument("--kernel", default=None,
                    help="fresh kernel_bench.py --json artifact")
    ap.add_argument("--sweep", default=None,
                    help="fresh sweep_bench.py --json artifact")
    ap.add_argument("--engine", default=None,
                    help="fresh engine_bench.py --json artifact")
    ap.add_argument("--training", default=None,
                    help="fresh fig7_training.py --json artifact "
                         "(co-design gate)")
    ap.add_argument("--tolerance", type=float, default=1.5,
                    help="ratio metrics fail below baseline/tol; absolute "
                         "wall-clock fails above baseline*2*tol")
    ap.add_argument("--report", default=None,
                    help="write the comparison as a JSON artifact")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write the fresh metrics out as a new baseline "
                         "instead of comparing")
    args = ap.parse_args(argv)

    fresh, quick = collect_metrics(args)
    if not fresh:
        print("check_regression: no artifacts given (nothing to compare)")
        return 2

    if args.write_baseline:
        # carry hard bounds over from the existing baseline: refreshing
        # values must not silently drop an acceptance-criterion gate
        bounds = {}
        if os.path.exists(args.baseline):
            for name, entry in _load(args.baseline).get("metrics", {}).items():
                if "bound" in entry:
                    bounds[name] = entry["bound"]
        payload = {"tolerance_default": args.tolerance,
                   "quick": quick,
                   "metrics": {name: dict({"direction": direction,
                                           "value": value},
                                          **({"bound": bounds[name]}
                                             if name in bounds else {}))
                               for name, (direction, value)
                               in sorted(fresh.items())}}
        os.makedirs(os.path.dirname(args.write_baseline) or ".",
                    exist_ok=True)
        with open(args.write_baseline, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote baseline with {len(fresh)} metrics to "
              f"{args.write_baseline}")
        return 0

    baseline = _load(args.baseline)
    if baseline.get("quick") is not None and quick is not None \
            and baseline["quick"] != quick:
        print(f"check_regression: baseline is a "
              f"{'quick' if baseline['quick'] else 'full'}-grid run but the "
              f"fresh artifacts are {'quick' if quick else 'full'} — grid "
              f"sizes differ, numbers are not comparable. Refresh the "
              f"baseline with --write-baseline under the same BENCH_QUICK.")
        return 2
    failures, lines = compare(baseline, fresh, args.tolerance)
    print(f"bench regression gate (tolerance {args.tolerance}x) "
          f"vs {args.baseline}:")
    print("\n".join(lines))
    if args.report:
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "w") as f:
            json.dump({"tolerance": args.tolerance,
                       "failures": failures,
                       "metrics": {k: {"direction": d, "value": v}
                                   for k, (d, v) in sorted(fresh.items())}},
                      f, indent=2)
        print(f"wrote {args.report}")
    if failures:
        print(f"REGRESSION: {len(failures)} metric(s) out of tolerance: "
              + ", ".join(failures))
        return 1
    print(f"all {len(fresh)} metric(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
