"""Shared benchmark substrate: small trained models (cached on disk)."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, get_config
from repro.data.synthetic import GaussianBlobs, MarkovLM
from repro.distributed import checkpoint as ckpt
from repro.models import cnn as cnn_lib
from repro.models import lm
from repro.models.losses import lm_loss
from repro.optim import adamw
from repro.training.loop import run_training

CACHE = "artifacts/bench_models"
QUICK = os.environ.get("BENCH_QUICK", "0") == "1"


def lm_setup(steps=300):
    """(params, cfg, eval_fn) for a trained tiny LM, cached across runs."""
    cfg = get_config("olmo-1b").reduced()
    data = MarkovLM(cfg.vocab_size, 64, 16, seed=0)
    cdir = os.path.join(CACHE, "lm")
    run = RunConfig(arch="olmo-1b", steps=steps if not QUICK else 120,
                    checkpoint_dir=cdir, checkpoint_every=10 ** 9,
                    remat=False, learning_rate=1e-3)
    state, _, _ = run_training(cfg, run, iter(data))

    eval_batches = [data.batch(5000 + i) for i in range(4)]

    def eval_fn(params):
        """jit-pure: returns a jnp scalar (resilience jits inject+eval)."""
        accs = []
        for batch in eval_batches:
            logits, _, _ = lm.forward(params, cfg, batch, remat=False)
            accs.append(lm_loss(logits, batch["labels"])[1]["accuracy"])
        return jnp.mean(jnp.stack(accs))

    return state.params, cfg, eval_fn, data


def cnn_setup(steps=400):
    """(params, eval_fn, task, train_more) for a trained CNN, cached."""
    task = GaussianBlobs()
    cdir = os.path.join(CACHE, "cnn")
    steps = steps if not QUICK else 150
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params)
    ocfg = adamw.AdamWConfig(weight_decay=0.0)

    @jax.jit
    def step(params, opt, x, y):
        (loss, acc), grads = jax.value_and_grad(cnn_lib.cnn_loss, has_aux=True)(
            params, x, y)
        p2, o2 = adamw.adamw_update(grads, opt, params, 3e-3, ocfg)
        return p2, o2, loss

    latest = ckpt.latest_step(cdir)
    if latest == steps:
        params, _ = ckpt.restore(params, cdir)
        params = jax.tree_util.tree_map(jnp.asarray, params)
    else:
        for i in range(steps):
            x, y = task.batch(64, i)
            params, opt, _ = step(params, opt, x, y)
        ckpt.save(params, steps, cdir)

    xe, ye = task.batch(1024, 99_999)

    def eval_fn(p):
        """jit-pure accuracy on a fixed eval batch."""
        logits = cnn_lib.apply_cnn(p, xe)
        return jnp.mean(jnp.argmax(logits, -1) == ye)

    return params, eval_fn, task


def finetune_cnn(params, task, align_cfg, steps=120, lr=1e-3):
    """Paper §III-C fine-tuning: align, then train with the frozen-exponent
    projection applied after every update."""
    from repro.core import align as align_lib
    aligned, exps = align_lib.align_pytree(params, align_cfg)
    signs = jax.tree_util.tree_map(
        lambda w, e: None if e is None else jnp.sign(w).astype(jnp.int8),
        aligned, exps, is_leaf=lambda x: x is None)
    opt = adamw.init_opt_state(aligned)
    ocfg = adamw.AdamWConfig(weight_decay=0.0)

    @jax.jit
    def step(params, opt, x, y):
        (loss, acc), grads = jax.value_and_grad(cnn_lib.cnn_loss, has_aux=True)(
            params, x, y)
        p2, o2 = adamw.adamw_update(grads, opt, params, lr, ocfg)
        p2 = align_lib.project_pytree(p2, exps, signs, align_cfg)
        return p2, o2, loss

    p = aligned
    for i in range(steps if not QUICK else 50):
        x, y = task.batch(64, 10_000 + i)
        p, opt, _ = step(p, opt, x, y)
    return p


def make_engine(bers, n_trials, fields=None, protects=None, backend="auto"):
    """A SweepEngine for a benchmark grid (vectorized characterization)."""
    from repro.core import sweep as sweep_lib
    kw = {}
    if fields is not None:
        kw["fields"] = tuple(fields)
    if protects is not None:
        kw["protects"] = tuple(protects)
    plan = sweep_lib.SweepPlan(bers=tuple(bers), n_trials=n_trials,
                               backend=backend, **kw)
    return sweep_lib.SweepEngine(plan)


def emit(rows):
    """CSV rows: name,us_per_call,derived."""
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")
