"""Batched serving on emulated CIM macros with the BFP Pallas weight path.

Shows the paper's deployment story end to end:
  * weights exponent-aligned and packed into the macro SRAM image,
  * static soft-error injection at a configurable BER,
  * One4N SECDED decode on the read path,
  * the block-shared-exponent matmul kernel (``kernels/bfp_matmul``)
    consuming the mantissa plane + shared exponents directly — the dequant
    happens in VMEM, exactly like the macro's exponent/mantissa split.

Run:  PYTHONPATH=src python examples/serve_cim.py --ber 1e-4
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as align_lib
from repro.core import cim as cim_lib
from repro.kernels.bfp_matmul import ops as bfp_ops
from repro.kernels.bfp_matmul import ref as bfp_ref


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ber", type=float, default=1e-4)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--d-in", type=int, default=1024)
    ap.add_argument("--d-out", type=int, default=512)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (args.d_in, args.d_out)) * 0.05
    w_al, _ = align_lib.align_matrix(w, align_lib.AlignmentConfig(8, 2))

    # pack the SRAM image two ways: protected and not
    x = jax.random.normal(jax.random.PRNGKey(1), (args.requests, args.d_in))
    clean = x @ jnp.asarray(w_al, jnp.float32)

    for protect in ("one4n", "none"):
        store = cim_lib.pack(w_al, cim_lib.CIMConfig(protect=protect))
        faulty = cim_lib.inject(jax.random.PRNGKey(2), store, args.ber,
                                "exponent_sign")
        w_read, stats = cim_lib.read(faulty)
        man, exp = bfp_ref.pack_bfp(w_read, 8)
        out = bfp_ops.bfp_matmul(x, man, exp)   # Pallas kernel (interpret on CPU)
        err = float(jnp.max(jnp.abs(out - clean)))
        rel = err / float(jnp.max(jnp.abs(clean)))
        print(f"protect={protect:6s} ber={args.ber:.0e}  corrected={int(stats['corrected'])} "
              f"uncorrectable={int(stats['uncorrectable'])}  "
              f"max output err {err:.3e} (rel {rel:.2e})")

    print("\nKernel sanity: bfp_matmul == x @ dequant(ref) on clean weights:",
          bool(np.allclose(
              np.asarray(bfp_ops.bfp_matmul(x, *bfp_ref.pack_bfp(w_al, 8))),
              np.asarray(clean), rtol=1e-5, atol=1e-5)))


if __name__ == "__main__":
    main()
