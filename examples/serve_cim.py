"""Batched serving on emulated CIM macros through the unified deployment API.

Shows the paper's deployment story end to end:
  * a :class:`repro.ReliabilityPolicy` maps each weight to its protection
    level — here One4N vs unprotected arms of the same matrix, then a mixed
    per-layer deployment,
  * ``CIMDeployment.deploy`` exponent-aligns and packs the weights into the
    word-packed SRAM image; ``.inject`` flips stored cells (check bits
    included) at a configurable BER,
  * ``.linear`` auto-dispatches the matmul: the fused ``kernels/cim_read``
    Pallas kernel consumes the packed planes directly (SECDED decode + FP16
    reconstruction + matmul in VMEM, exactly like the macro's read path —
    the decoded weight matrix never exists in HBM), with shard_map/GSPMD
    routes taking over under mesh placement,
  * per-read dynamic injection: the same kernel draws fresh counter-PRNG
    faults in-kernel, bit-identical to ``.inject`` with the same key.

Run:  PYTHONPATH=src python examples/serve_cim.py --ber 1e-4
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import CIMDeployment, PolicyRule, ReliabilityPolicy
from repro.core import align as align_lib
from repro.core import cim as cim_lib
from repro.kernels.cim_read import ops as cr_ops
from repro.kernels.fault_inject.ops import ber_to_threshold


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ber", type=float, default=1e-4)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--d-in", type=int, default=1024)
    ap.add_argument("--d-out", type=int, default=512)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (args.d_in, args.d_out)) * 0.05
    w_al, _ = align_lib.align_matrix(w, align_lib.AlignmentConfig(8, 2))

    x = jax.random.normal(jax.random.PRNGKey(1), (args.requests, args.d_in))
    clean = x @ jnp.asarray(w_al, jnp.float32)

    for protect in ("one4n", "none"):
        policy = ReliabilityPolicy(default=PolicyRule(protect=protect))
        dep = CIMDeployment.deploy({"proj": w_al}, policy)
        faulty = dep.inject(jax.random.PRNGKey(2), args.ber,
                            field="exponent_sign")
        stats = faulty.stats()
        # fused serve: decode-on-read straight off the packed image, route
        # picked by the deployment dispatch table
        out, info = faulty.linear(x, "proj", with_info=True)
        err = float(jnp.max(jnp.abs(out - clean)))
        rel = err / float(jnp.max(jnp.abs(clean)))
        print(f"protect={protect:6s} ber={args.ber:.0e}  "
              f"corrected={int(stats['corrected'])} "
              f"uncorrectable={int(stats['uncorrectable'])}  "
              f"kernel={info['used_kernel']}  "
              f"max output err {err:.3e} (rel {rel:.2e})")

    # a mixed per-layer deployment: One4N on the output projection, bare
    # mantissa-only faults on the hidden one — heterogeneous protection in
    # one CIMDeployment (the paper's spend-ECC-where-sensitivity-lives)
    w2 = jax.random.normal(jax.random.PRNGKey(5), (args.d_in, args.d_in)) * 0.05
    w2_al, _ = align_lib.align_matrix(w2, align_lib.AlignmentConfig(8, 2))
    mixed = ReliabilityPolicy(
        rules=(PolicyRule("out_proj", protect="one4n"),
               PolicyRule("hidden", protect="none", field="mantissa")))
    dep = CIMDeployment.deploy({"hidden": w2_al, "out_proj": w_al}, mixed)
    dep = dep.inject(jax.random.PRNGKey(3), args.ber)
    h = dep.linear(x, "hidden")
    out = dep.linear(jnp.tanh(h), "out_proj")
    print(f"\nmixed policy: {len(dep.store_leaves())} stores, "
          f"per-layer rules:\n{dep.report()}\n"
          f"pipeline output finite: {bool(jnp.isfinite(out).all())}")

    # dynamic mode: per-read faults drawn in-kernel — same streams as static
    # injection with the same key
    dep = CIMDeployment.deploy(
        {"proj": w_al}, ReliabilityPolicy(default=PolicyRule(protect="one4n")))
    thr = ber_to_threshold(args.ber)
    # .inject splits its key across the deployment's flat leaves (one macro =
    # one independent stream); replay the same split to seed the in-kernel
    # dynamic draws identically
    (leaf_key,) = jax.random.split(jax.random.PRNGKey(2), 1)
    scalars = cr_ops.make_scalars(cim_lib.plane_seeds(leaf_key),
                                  thr_man=0, thr_meta=thr)
    dyn = dep.linear(x, "proj", scalars=scalars)
    stat = dep.inject(jax.random.PRNGKey(2), args.ber,
                      field="exponent_sign").linear(x, "proj")
    print("Per-read dynamic == static inject with the same key:",
          bool(np.allclose(np.asarray(dyn), np.asarray(stat),
                           rtol=1e-5, atol=1e-5)))

    clean_out = dep.linear(x, "proj")
    print("Kernel sanity: fused decode-on-read == x @ w on a clean image:",
          bool(np.allclose(np.asarray(clean_out), np.asarray(clean),
                           rtol=1e-5, atol=1e-5)))


if __name__ == "__main__":
    main()
