"""Batched serving on emulated CIM macros with the fused decode-on-read path.

Shows the paper's deployment story end to end:
  * weights exponent-aligned and packed into the word-packed SRAM image,
  * static soft-error injection at a configurable BER (every stored cell —
    check bits included — is a target),
  * the fused ``kernels/cim_read`` Pallas kernel consuming the packed planes
    directly: SECDED decode + FP16 reconstruction + matmul in VMEM, exactly
    like the macro's read path — the decoded weight matrix never exists in
    HBM,
  * per-read dynamic injection: the same kernel draws fresh counter-PRNG
    faults in-kernel, bit-identical to ``cim.inject`` with the same key.

Run:  PYTHONPATH=src python examples/serve_cim.py --ber 1e-4
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as align_lib
from repro.core import cim as cim_lib
from repro.kernels.cim_read import ops as cr_ops
from repro.kernels.fault_inject.ops import ber_to_threshold


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ber", type=float, default=1e-4)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--d-in", type=int, default=1024)
    ap.add_argument("--d-out", type=int, default=512)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (args.d_in, args.d_out)) * 0.05
    w_al, _ = align_lib.align_matrix(w, align_lib.AlignmentConfig(8, 2))

    x = jax.random.normal(jax.random.PRNGKey(1), (args.requests, args.d_in))
    clean = x @ jnp.asarray(w_al, jnp.float32)

    for protect in ("one4n", "none"):
        store = cim_lib.pack(w_al, cim_lib.CIMConfig(protect=protect))
        faulty = cim_lib.inject(jax.random.PRNGKey(2), store, args.ber,
                                "exponent_sign")
        stats = cim_lib.store_stats(faulty)
        # fused serve: decode-on-read straight off the packed image
        out, info = cr_ops.cim_linear_store(x, faulty, with_info=True)
        err = float(jnp.max(jnp.abs(out - clean)))
        rel = err / float(jnp.max(jnp.abs(clean)))
        print(f"protect={protect:6s} ber={args.ber:.0e}  "
              f"corrected={int(stats['corrected'])} "
              f"uncorrectable={int(stats['uncorrectable'])}  "
              f"kernel={info['used_kernel']}  "
              f"max output err {err:.3e} (rel {rel:.2e})")

    # dynamic mode: per-read faults drawn in-kernel — same streams as the
    # static injection above when keyed identically
    store = cim_lib.pack(w_al, cim_lib.CIMConfig(protect="one4n"))
    thr = ber_to_threshold(args.ber)
    scalars = cr_ops.make_scalars(cim_lib.plane_seeds(jax.random.PRNGKey(2)),
                                  thr_man=0, thr_meta=thr)
    dyn = cr_ops.cim_linear_store(x, store, scalars=scalars)
    stat = cr_ops.cim_linear_store(
        x, cim_lib.inject(jax.random.PRNGKey(2), store, args.ber,
                          "exponent_sign"))
    print("\nPer-read dynamic == static inject with the same key:",
          bool(np.allclose(np.asarray(dyn), np.asarray(stat),
                           rtol=1e-5, atol=1e-5)))

    clean_out = cr_ops.cim_linear_store(x, store)
    print("Kernel sanity: fused decode-on-read == x @ w on a clean image:",
          bool(np.allclose(np.asarray(clean_out), np.asarray(clean),
                           rtol=1e-5, atol=1e-5)))


if __name__ == "__main__":
    main()
