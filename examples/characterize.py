"""Per-field vulnerability characterization (paper Fig. 2 methodology).

Trains a small LM and a small CNN, then sweeps BER x {sign, exponent,
mantissa, full} with static injection, reporting mean accuracy over trials.
Expected qualitative reproduction: exponent >> sign > full > mantissa
sensitivity; the exponent cliff sits orders of magnitude below the mantissa's.

The sweep runs on the vectorized engine (repro.core.sweep): each field's
whole (BER x trial) plane is one compiled executable, with the trial axis
sharded across devices. Pass ``--loop`` to use the legacy per-trial loop
harness instead (same PRNG stream, same results, many more dispatches).

Run:  PYTHONPATH=src python examples/characterize.py [--trials 5] [--loop]
"""
import argparse

import jax
import jax.numpy as jnp

from repro import PolicyRule, ReliabilityPolicy
from repro.configs import RunConfig, get_config
from repro.core import resilience
from repro.data.synthetic import GaussianBlobs, MarkovLM
from repro.models import cnn as cnn_lib
from repro.models import lm
from repro.models.losses import lm_loss
from repro.optim import adamw
from repro.training.loop import run_training


def train_lm(steps=120):
    cfg = get_config("olmo-1b").reduced()
    data = MarkovLM(cfg.vocab_size, 64, 16, seed=0)
    run = RunConfig(arch="olmo-1b", steps=steps, checkpoint_dir="",
                    remat=False, learning_rate=1e-3)
    state, _, _ = run_training(cfg, run, iter(data))

    batch = data.batch(999)

    def eval_fn(params):
        logits, _, _ = lm.forward(params, cfg, batch, remat=False)
        return lm_loss(logits, batch["labels"])[1]["accuracy"]

    return state.params, eval_fn


def train_cnn(steps=150):
    task = GaussianBlobs()
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params)
    ocfg = adamw.AdamWConfig(weight_decay=0.0)

    @jax.jit
    def step(params, opt, x, y):
        (loss, acc), grads = jax.value_and_grad(cnn_lib.cnn_loss, has_aux=True)(
            params, x, y)
        return (*adamw.adamw_update(grads, opt, params, 3e-3, ocfg), loss)

    for i in range(steps):
        x, y = task.batch(64, i)
        params, opt, loss = step(params, opt, x, y)

    xe, ye = task.batch(512, 10_000)

    def eval_fn(p):
        logits = cnn_lib.apply_cnn(p, xe)
        return jnp.mean(jnp.argmax(logits, -1) == ye)

    return params, eval_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--loop", action="store_true",
                    help="use the per-trial loop harness (baseline)")
    ap.add_argument("--policies", action="store_true",
                    help="also sweep mixed per-layer protection policies on "
                         "the LM (Fig. 6 arms as ReliabilityPolicies)")
    args = ap.parse_args()
    bers = [1e-6, 1e-5, 1e-4, 1e-3, 1e-2]
    characterize = (resilience.characterize_fields_loop if args.loop
                    else resilience.characterize_fields)

    lm_trained = train_lm()
    for name, (params, eval_fn) in (("lm", lm_trained),
                                    ("cnn", train_cnn())):
        clean = float(eval_fn(params))
        print(f"\n== {name}: clean accuracy {clean:.3f} ==")
        results = characterize(
            jax.random.PRNGKey(7), params, eval_fn, bers,
            n_trials=args.trials)
        print(resilience.format_table(results))

    if args.policies:
        # Fig. 6 arms as deployment POLICIES: uniform protection vs the
        # paper's co-design split (One4N where exponent sensitivity lives —
        # the embeds — bare mantissa-dominated blocks elsewhere).
        params, eval_fn = lm_trained
        arms = {
            "all_one4n": ReliabilityPolicy(default=PolicyRule(protect="one4n")),
            "all_none": ReliabilityPolicy(default=PolicyRule(protect="none")),
            "embeds_one4n": ReliabilityPolicy(
                rules=(PolicyRule("embed", protect="one4n"),
                       PolicyRule("unembed", protect="one4n")),
                default=PolicyRule(protect="none")),
        }
        print("\n== lm: mixed-protection policy arms ==")
        results = resilience.characterize_policies(
            jax.random.PRNGKey(11), params, eval_fn, bers, arms,
            n_trials=args.trials)
        print(resilience.format_table(results))


if __name__ == "__main__":
    main()
