"""End-to-end resilient-training driver (paper Fig. 7 scenario).

Trains a decoder LM with the FULL production loop — async checkpointing,
auto-resume, straggler watchdog — under dynamic soft-error injection
(fresh bit flips into the stored weights every step), in three arms:

  clean        no faults
  unprotected  BER on exponent/sign + mantissa (training typically NaNs)
  one4n        exponent/sign behind One4N SECDED (residual rate), aligned
               weights + frozen-exponent updates

Presets: --preset demo (default, ~11M params, 60 steps, minutes on CPU)
         --preset 100m (d_model 768 x 12L ≈ 100M params, 300 steps — the
         full-scale run for real hardware; identical code path).

Run:  PYTHONPATH=src python examples/train_resilient.py [--preset demo]
"""
import argparse
import dataclasses
import os
import shutil

import numpy as np

from repro.configs import RunConfig, get_config
from repro.core.deployment import PolicyRule, ReliabilityPolicy
from repro.data.synthetic import MarkovLM
from repro.models import lm
from repro.training.loop import run_training

PRESETS = {
    "demo": dict(d_model=256, n_layers=4, d_ff=1024, n_heads=4, n_kv_heads=4,
                 head_dim=64, vocab_size=512, steps=60, batch=8, seq=128),
    "100m": dict(d_model=768, n_layers=12, d_ff=3072, n_heads=12,
                 n_kv_heads=12, head_dim=64, vocab_size=32768, steps=300,
                 batch=32, seq=512),
}


def arm_config(preset, mode, ber):
    """Each arm is a uniform ReliabilityPolicy plus RunConfig reliability
    kwargs — the policy-native surface the training fault schedule
    (repro.core.deployment) applies every step. ``clean`` trains aligned but
    fault-free (ber 0)."""
    if mode == "clean":
        return dict(policy=ReliabilityPolicy(default=PolicyRule(
            n_group=8, index=2)), ber=0.0)
    protect = "one4n" if mode == "one4n" else "none"
    rule = PolicyRule(protect=protect, **({} if mode == "none" else
                                          dict(n_group=8, index=2)))
    return dict(policy=ReliabilityPolicy(default=rule), ber=ber,
                inject="dynamic")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=list(PRESETS))
    ap.add_argument("--ber", type=float, default=1e-4)
    ap.add_argument("--ckpt-root", default="/tmp/unicorn_resilient")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    base = get_config("olmo-1b")
    cfg = dataclasses.replace(
        base, d_model=p["d_model"], n_layers=p["n_layers"], d_ff=p["d_ff"],
        n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
        head_dim=p["head_dim"], vocab_size=p["vocab_size"],
        attn_chunk_threshold=10 ** 9)
    data = MarkovLM(cfg.vocab_size, p["seq"], p["batch"], seed=0)

    curves = {}
    for mode in ("clean", "none", "one4n"):
        ckdir = os.path.join(args.ckpt_root, mode)
        shutil.rmtree(ckdir, ignore_errors=True)
        run = RunConfig(arch="olmo-1b", steps=p["steps"], remat=False,
                        learning_rate=1e-3, checkpoint_dir=ckdir,
                        checkpoint_every=max(p["steps"] // 4, 10),
                        **arm_config(p, mode, args.ber))
        print(f"\n=== arm: {mode} (ber={0 if mode=='clean' else args.ber:.0e}) ===")
        if run.ber > 0:
            rel = run.rel
            print(f"  policy: {run.policy.default.protect} on every leaf "
                  f"(residual exp/sign BER {rel.residual_exp_ber:.2e})")
        every = max(p["steps"] // 6, 1)

        def log(s, m, every=every):
            if s % every == 0 or s == p["steps"] - 1:
                print(f"  step {s:4d} loss {m['loss']:.4f} acc {m['accuracy']:.3f}")

        res = run_training(cfg, run, iter(data), log_fn=log)
        curves[mode] = [h["loss"] for h in res.history]
        n = lm.param_count(res.state.params)
        print(f"  {n/1e6:.1f}M params; "
              f"stragglers={res.info['stragglers_flagged']}; "
              f"checkpoints in {ckdir}")
        if mode == "one4n":
            stats = res.ecc_stats
            print(f"  deployed: {stats['stored_bits']} stored bits "
                  f"({stats['overhead']:+.1%} vs raw fp16)")

    print("\n=== summary (final-10-step mean loss) ===")
    for mode, losses in curves.items():
        tail = np.asarray(losses[-10:], dtype=np.float64)
        status = "NaN/diverged" if not np.isfinite(tail).all() else f"{tail.mean():.4f}"
        print(f"  {mode:12s} {status}")
    print("Expected (paper Fig. 7): clean ≈ one4n, unprotected diverges/NaNs.")


if __name__ == "__main__":
    main()
