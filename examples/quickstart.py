"""Quickstart: the Unicorn-CIM reliability pipeline in ~60 seconds.

1. Train a small LM on a learnable synthetic task.
2. Exponent-align its weights (paper §III-C) and fine-tune with frozen
   exponents (mantissa-only updates).
3. Deploy onto the emulated CIM macro (pack -> SECDED-encode).
4. Inject soft errors at the paper's "standard operating voltage" BER (1e-6
   .. 1e-3) and compare protected vs unprotected accuracy (Fig. 6 in small).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import CIMDeployment, PolicyRule, ReliabilityPolicy, run_training
from repro.configs import RunConfig, get_config
from repro.data.synthetic import MarkovLM
from repro.models import lm
from repro.models.losses import lm_loss


def evaluate(params, cfg, data, n_batches=4):
    accs = []
    for i in range(n_batches):
        batch = data.batch(1000 + i)
        logits, _, _ = lm.forward(params, cfg, batch, remat=False)
        _, metrics = lm_loss(logits, batch["labels"])
        accs.append(float(metrics["accuracy"]))
    return sum(accs) / len(accs)


def main():
    cfg = get_config("olmo-1b").reduced()
    data = MarkovLM(cfg.vocab_size, 64, 16, seed=0)

    # --- 1+2: train with exponent alignment active from the start ----------
    # the policy-native training surface: a uniform ReliabilityPolicy with
    # ber=0 trains aligned (frozen exponents, mantissa-only updates) without
    # fault injection
    policy = ReliabilityPolicy(default=PolicyRule(n_group=8, index=2))
    run = RunConfig(arch="olmo-1b", steps=150, checkpoint_dir="",
                    policy=policy, ber=0.0, remat=False, learning_rate=1e-3)
    print("training 150 steps with frozen-exponent alignment (N=8, index=2)…")
    res = run_training(cfg, run, iter(data))
    state, hist = res.state, res.history
    print(f"  final loss {res.final_loss:.3f}  train acc {hist[-1]['accuracy']:.3f}")

    base_acc = evaluate(state.params, cfg, data)
    print(f"  clean eval accuracy: {base_acc:.3f}")
    print(f"  deployed under the run's policy: "
          f"{res.ecc_stats['stored_bits']} stored bits "
          f"({res.ecc_stats['overhead']:+.1%} vs raw fp16)")

    # --- 3+4: CIM deployment under soft errors -----------------------------
    # One policy per protection arm; CIMDeployment owns pack -> inject ->
    # decode for the whole pytree.
    key = jax.random.PRNGKey(42)
    for ber in (1e-6, 1e-4, 1e-3):
        row = [f"BER {ber:.0e}:"]
        for protect in ("one4n", "none"):
            arm = ReliabilityPolicy(default=PolicyRule(
                protect=protect, n_group=8, index=2))
            dep = CIMDeployment.deploy(state.params, arm)
            restored, stats = dep.inject(key, ber).read()
            acc = evaluate(restored, cfg, data)
            row.append(f"{protect}: acc {acc:.3f} "
                       f"(corrected {int(stats['corrected'])}, "
                       f"uncorrectable {int(stats['uncorrectable'])})")
        print("  " + "  |  ".join(row))
    print("One4N keeps accuracy at BERs where unprotected weights degrade — "
          "the paper's Fig. 6 at container scale.")

    # --- 5: per-layer protection in ONE deployment -------------------------
    # The paper's co-design insight, expressed as a policy: spend ECC on the
    # sensitive unembed exponents, leave MLP mantissa-heavy blocks bare.
    policy = ReliabilityPolicy(
        rules=(PolicyRule("unembed", protect="one4n"),
               PolicyRule("embed", protect="one4n"),
               PolicyRule("*", protect="none")))
    dep = CIMDeployment.deploy(state.params, policy)
    restored, stats = dep.inject(jax.random.PRNGKey(7), 1e-4).read()
    acc = evaluate(restored, cfg, data)
    print(f"mixed policy (One4N embeds, rest unprotected) @ BER 1e-4: "
          f"acc {acc:.3f} (corrected {int(stats['corrected'])}, "
          f"uncorrectable {int(stats['uncorrectable'])})")


if __name__ == "__main__":
    main()
