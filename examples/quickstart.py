"""Quickstart: the Unicorn-CIM reliability pipeline in ~60 seconds.

1. Train a small LM on a learnable synthetic task.
2. Exponent-align its weights (paper §III-C) and fine-tune with frozen
   exponents (mantissa-only updates).
3. Deploy onto the emulated CIM macro (pack -> SECDED-encode).
4. Inject soft errors at the paper's "standard operating voltage" BER (1e-6
   .. 1e-3) and compare protected vs unprotected accuracy (Fig. 6 in small).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config
from repro.core import cim as cim_lib
from repro.core.api import ReliabilityConfig
from repro.data.synthetic import MarkovLM
from repro.models import lm
from repro.models.losses import lm_loss
from repro.training.loop import run_training


def evaluate(params, cfg, data, n_batches=4):
    accs = []
    for i in range(n_batches):
        batch = data.batch(1000 + i)
        logits, _, _ = lm.forward(params, cfg, batch, remat=False)
        _, metrics = lm_loss(logits, batch["labels"])
        accs.append(float(metrics["accuracy"]))
    return sum(accs) / len(accs)


def main():
    cfg = get_config("olmo-1b").reduced()
    data = MarkovLM(cfg.vocab_size, 64, 16, seed=0)

    # --- 1+2: train with exponent alignment active from the start ----------
    rel = ReliabilityConfig(mode="align", n_group=8, index=2)
    run = RunConfig(arch="olmo-1b", steps=150, checkpoint_dir="",
                    reliability=rel, remat=False, learning_rate=1e-3)
    print("training 150 steps with frozen-exponent alignment (N=8, index=2)…")
    state, hist, _ = run_training(cfg, run, iter(data))
    print(f"  final loss {hist[-1]['loss']:.3f}  train acc {hist[-1]['accuracy']:.3f}")

    base_acc = evaluate(state.params, cfg, data)
    print(f"  clean eval accuracy: {base_acc:.3f}")

    # --- 3+4: CIM deployment under soft errors -----------------------------
    key = jax.random.PRNGKey(42)
    for ber in (1e-6, 1e-4, 1e-3):
        row = [f"BER {ber:.0e}:"]
        for protect in ("one4n", "none"):
            ccfg = cim_lib.CIMConfig(n_group=8, index=2, protect=protect)
            stores, _ = cim_lib.deploy_pytree(state.params, ccfg)
            faulty = cim_lib.inject_pytree(key, stores, ber)
            restored, stats = cim_lib.read_pytree(faulty)
            acc = evaluate(restored, cfg, data)
            row.append(f"{protect}: acc {acc:.3f} "
                       f"(corrected {int(stats['corrected'])}, "
                       f"uncorrectable {int(stats['uncorrectable'])})")
        print("  " + "  |  ".join(row))
    print("One4N keeps accuracy at BERs where unprotected weights degrade — "
          "the paper's Fig. 6 at container scale.")


if __name__ == "__main__":
    main()
