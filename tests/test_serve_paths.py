"""End-to-end serve-path equivalence: ``--serve-path fused`` vs ``hbm``.

The kernels suite checks the fused decode-on-read matmul against its oracle
one matrix at a time; here the WHOLE serving stack is compared at batch
level. Prefill + decode logits of a CIM-deployed LM served

* ``fused`` — packed stores all the way down (row-decoded embed gather +
  fused unembed kernel), and
* ``hbm``  — inject once, ECC-decode, rematerialize fp16 weights

must agree with a clean image and under static injection with the same key
(identical counter-PRNG streams hit identical cells on both paths, so the
decoded weights are bit-equal and only matmul summation order differs).

A 1-device mesh case drives the mesh-sharded serving path
(``cim_linear_store_sharded`` under ``shard_map``) to check it degrades
cleanly; the real multi-device equivalence runs in
``tests/test_sharded_store.py`` under 8 forced host devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import align, cim
from repro.distributed import sharding as shlib
from repro.kernels.cim_read import ops as cr_ops
from repro.kernels.fault_inject.ops import ber_to_threshold
from repro.launch import serve as serve_lib
from repro.launch.mesh import make_host_mesh
from repro.models import lm


def _deployments(ber, protect="one4n"):
    cfg = get_config("olmo-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    dkey = jax.random.fold_in(key, 1)
    stores = serve_lib.deploy_fused(params, ber=ber, protect=protect,
                                    n_group=8, index=2, key=dkey,
                                    inject_mode="static", field="full")
    hbm, _ = serve_lib.deploy(params, ber=ber, protect=protect, n_group=8,
                              index=2, key=dkey)
    return cfg, stores, hbm


def _grow(caches, plen, gen):
    def g(a):
        if a.ndim >= 4 and a.shape[-3] == plen:
            pad = [(0, 0)] * a.ndim
            pad[-3] = (0, gen)
            return jnp.pad(a, pad)
        return a
    return jax.tree_util.tree_map(g, caches)


@pytest.mark.parametrize("ber", [0.0, 1e-3])
def test_fused_vs_hbm_batch_logits(ber):
    """Batch-level logits parity, no-fault and static-inject (same key =>
    same faults on both paths; fp16-scale tolerance for summation order)."""
    cfg, stores, hbm = _deployments(ber)
    plen = 12
    tokens = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (4, plen)))
    lf, cf = lm.prefill(stores, cfg, {"tokens": tokens})
    lb, cb = lm.prefill(hbm, cfg, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lb),
                               rtol=1e-4, atol=1e-4)
    cf, cb = _grow(cf, plen, 2), _grow(cb, plen, 2)
    toks = jnp.argmax(lf, -1)[:, None]
    for _ in range(2):
        lf, cf = lm.decode(stores, cfg, cf, toks)
        lb, cb = lm.decode(hbm, cfg, cb, toks)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lb),
                                   rtol=1e-4, atol=1e-4)
        toks = jnp.argmax(lf, -1)[:, None]


def test_fused_serve_under_one_device_mesh():
    """The sharded serving path must degrade cleanly on a 1-device mesh: the
    unembed routes through shard_map + the fused kernel and the logits match
    the meshless fused path."""
    cfg, stores, _ = _deployments(1e-3)
    tokens = jnp.asarray([[3, 1, 4, 1, 5, 9, 2, 6]])
    ref, _ = lm.prefill(stores, cfg, {"tokens": tokens})
    mesh = make_host_mesh(model_axis=1)
    placed = serve_lib.place_on_mesh(stores, mesh)
    with shlib.use_mesh(mesh):
        got, caches = lm.prefill(placed, cfg, {"tokens": tokens})
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        caches = _grow(caches, tokens.shape[1], 1)
        toks = jnp.argmax(got, -1)[:, None]
        got2, _ = lm.decode(placed, cfg, caches, toks)
    assert np.isfinite(np.asarray(got2)).all()


def test_sharded_linear_one_device_mesh_both_dims():
    """cim_linear_store_sharded == cim_linear_store on a 1-device mesh for
    both shard layouts — 'j' (column groups) and 'k' (psum over the
    contraction) — static and per-read dynamic."""
    mesh = make_host_mesh(model_axis=1)
    key = jax.random.PRNGKey(3)
    thr = ber_to_threshold(0.003)
    seeds = cim.plane_seeds(key)
    sc = cr_ops.make_scalars(seeds, thr, thr)
    for protect in ("one4n", "none"):
        w = jax.random.normal(jax.random.PRNGKey(0), (256, 128)) * 0.1
        w, _ = align.align_matrix(w, align.AlignmentConfig(8, 2))
        store = cim.pack(w, cim.CIMConfig(protect=protect))
        x = jax.random.normal(jax.random.PRNGKey(4), (8, 256))
        ref_s = cr_ops.cim_linear_store(x, store)
        ref_d = cr_ops.cim_linear_store(x, store, scalars=sc)
        for dim in ("j", "k"):
            st = cim.shard_store(store, mesh, dim=dim)
            out, info = cr_ops.cim_linear_store_sharded(
                x, st, mesh=mesh, dim=dim, with_info=True)
            assert info["sharded"] and info["used_kernel"]
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref_s),
                                       rtol=1e-5, atol=1e-5)
            out_d = cr_ops.cim_linear_store_sharded(x, st, scalars=sc,
                                                    mesh=mesh, dim=dim)
            np.testing.assert_allclose(np.asarray(out_d), np.asarray(ref_d),
                                       rtol=1e-5, atol=1e-5)


def test_sharded_linear_falls_back_without_kernel_support():
    """per_weight stores cannot tile the fused kernel: the sharded entry
    point must fall back (GSPMD path) with the info signal saying so."""
    mesh = make_host_mesh(model_axis=1)
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 48)) * 0.1
    w16 = jnp.asarray(jnp.asarray(w, jnp.float16), jnp.float32)
    store = cim.pack(w16, cim.CIMConfig(protect="per_weight"))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
    out, info = cr_ops.cim_linear_store_sharded(x, store, mesh=mesh,
                                                with_info=True)
    assert not info["sharded"] and not info["used_kernel"]
    ref = cr_ops.cim_linear_store(x, store)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
