"""Fault-tolerance substrate: checkpoint/restore, auto-resume, elastic
coordinator, straggler watchdog, gradient compression, dynamic injection."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config
from repro.core.api import ReliabilityConfig
from repro.data.synthetic import MarkovLM
from repro.distributed import checkpoint as ckpt
from repro.distributed.compression import compress_decompress, quantize_int8
from repro.distributed.elastic import ElasticCoordinator, StragglerWatchdog
from repro.training import steps as steps_lib
from repro.training.loop import run_training


def _tiny_run(tmp_path, steps=6, every=3, rel=ReliabilityConfig(), **kw):
    cfg = get_config("olmo-1b").reduced()
    run = RunConfig(arch="olmo-1b", steps=steps, checkpoint_every=every,
                    checkpoint_dir=str(tmp_path), reliability=rel,
                    remat=False, **kw)
    data = MarkovLM(cfg.vocab_size, 32, 2, seed=0)
    return cfg, run, data


# ---------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_exact(tmp_path):
    state = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(5)},
             "n": None, "s": jnp.asarray(3)}
    ckpt.save(state, 7, str(tmp_path))
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored, step = ckpt.restore(state, str(tmp_path))
    assert step == 7
    assert (np.asarray(restored["a"]) == np.asarray(state["a"])).all()
    assert (np.asarray(restored["b"]["c"]) == 1).all()
    assert restored["n"] is None


def test_checkpoint_atomic_overwrite(tmp_path):
    state = {"a": jnp.zeros(3)}
    ckpt.save(state, 1, str(tmp_path))
    ckpt.save({"a": jnp.ones(3)}, 2, str(tmp_path))
    restored, step = ckpt.restore(state, str(tmp_path))
    assert step == 2 and (np.asarray(restored["a"]) == 1).all()


def test_async_checkpointer_and_gc(tmp_path):
    cp = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(1, 5):
        cp.save_async({"x": jnp.full(4, float(s))}, s)
    cp.wait()
    cp.close()
    steps_on_disk = sorted(d for d in os.listdir(tmp_path)
                           if d.startswith("step_"))
    assert len(steps_on_disk) == 2
    restored, step = ckpt.restore({"x": jnp.zeros(4)}, str(tmp_path))
    assert step == 4 and (np.asarray(restored["x"]) == 4).all()


def test_training_auto_resume(tmp_path):
    cfg, run, data = _tiny_run(tmp_path, steps=4, every=2)
    state1, hist1, info1 = run_training(cfg, run, iter(data))
    assert info1["resumed_from"] == 0
    run2 = RunConfig(**{**run.__dict__, "steps": 6})
    state2, hist2, info2 = run_training(cfg, run2, iter(data))
    assert info2["resumed_from"] == 4
    assert len(hist2) == 2
    assert int(state2.opt["step"]) == 6


def test_resume_preserves_frozen_exponents(tmp_path):
    rel = ReliabilityConfig(mode="align", n_group=8, index=2)
    cfg, run, data = _tiny_run(tmp_path, steps=2, every=2, rel=rel)
    state1, _, _ = run_training(cfg, run, iter(data))
    run2 = RunConfig(**{**run.__dict__, "steps": 4})
    state2, _, info = run_training(cfg, run2, iter(data))
    assert info["resumed_from"] == 2
    e1 = state1.exps["unembed"]
    e2 = state2.exps["unembed"]
    assert (np.asarray(e1) == np.asarray(e2)).all()


# ---------------------------------------------------------------- elastic

def test_elastic_failure_detection_and_reshape():
    t = [0.0]
    co = ElasticCoordinator([f"h{i}" for i in range(8)], model_axis=16,
                            heartbeat_timeout=10.0, clock=lambda: t[0])
    t[0] = 5.0
    for h in co.hosts:
        co.heartbeat(h)
    t[0] = 12.0
    assert co.check() == []
    # h3 and h5 stop heartbeating
    t[0] = 20.0
    for h in co.hosts:
        if h not in ("h3", "h5"):
            co.heartbeat(h)
    t[0] = 29.0   # h3/h5 last beat at t=5 (24s ago); others at t=20 (9s ago)
    failed = co.check()
    assert sorted(failed) == ["h3", "h5"]
    assert len(co.healthy_hosts) == 6
    # 6 hosts x 32 devices = 192 devices; model=16 -> usable dp=12 -> pow2: 8
    gen, dp = co.reconfigure(devices_per_host=32)
    assert gen == 1 and dp == 8


def test_straggler_watchdog():
    wd = StragglerWatchdog(factor=3.0)
    assert not wd.observe(1.0)
    for _ in range(5):
        assert not wd.observe(1.05)
    assert wd.observe(5.0)          # 5x the EWMA -> flagged
    assert wd.flagged == 1
    assert wd.ewma < 1.2            # straggler did not poison the EWMA


def test_straggler_flag_in_training(tmp_path):
    cfg, run, data = _tiny_run(tmp_path, steps=6, every=100,
                               straggler_factor=4.0)
    run = RunConfig(**{**run.__dict__, "checkpoint_dir": ""})
    _, _, info = run_training(cfg, run, iter(data),
                              sleep_injector=lambda s: 0.4 if s == 4 else 0.0)
    assert info["stragglers_flagged"] >= 1


# ---------------------------------------------------------------- compression

def test_int8_quantization_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3
    q, s = quantize_int8(x)
    err = jnp.abs(dequant := q.astype(jnp.float32) * s - x)
    assert float(jnp.max(err)) <= float(s) / 2 + 1e-6


def test_error_feedback_reduces_bias():
    """With EF, the *accumulated* compressed signal tracks the true sum."""
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (64, 64)) * 0.01}
    ef = {"w": jnp.zeros((64, 64))}
    total_true = jnp.zeros((64, 64))
    total_sent = jnp.zeros((64, 64))
    for i in range(20):
        gi = {"w": g["w"] * (1 + 0.1 * i)}
        sent, ef = compress_decompress(gi, ef)
        total_true += gi["w"]
        total_sent += sent["w"]
    resid = float(jnp.max(jnp.abs(total_true - total_sent - ef["w"])))
    assert resid < 1e-4   # sent + residual == true sum (EF invariant)


def test_training_with_compression_converges(tmp_path):
    cfg, run, data = _tiny_run(tmp_path, steps=8, every=100)
    run = RunConfig(**{**run.__dict__, "checkpoint_dir": "",
                       "grad_compression": True})
    _, hist, _ = run_training(cfg, run, iter(data))
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.1
    assert np.isfinite([h["loss"] for h in hist]).all()


# ---------------------------------------------------------------- dynamic faults

def test_dynamic_injection_protected_vs_not(tmp_path):
    """Fig. 7 mechanism at smoke scale: at a damaging BER, One4N keeps the
    loss finite/stable while the unprotected run degrades or explodes."""
    losses = {}
    for protect in ("one4n", "none"):
        rel = ReliabilityConfig(mode="cim", ber=2e-3, protect=protect,
                                inject="dynamic")
        cfg, run, data = _tiny_run(tmp_path, steps=8, rel=rel)
        run = RunConfig(**{**run.__dict__, "checkpoint_dir": ""})
        _, hist, _ = run_training(cfg, run, iter(data))
        losses[protect] = [h["loss"] for h in hist]
    bad = np.asarray(losses["none"])
    good = np.asarray(losses["one4n"])
    assert np.isfinite(good).all()
    assert (~np.isfinite(bad)).any() or bad[-1] > good[-1] + 0.5


def test_checkpointable_loader_resumes_exactly(tmp_path):
    """Data-pipeline state rides in the checkpoint: a restarted loader
    replays the exact next batch (no skips/repeats)."""
    from repro.data.synthetic import CheckpointableLoader, MarkovLM
    import numpy as np

    src = MarkovLM(64, 16, 2, seed=9)
    loader = CheckpointableLoader(src)
    consumed = [next(loader) for _ in range(5)]
    ckpt.save({"data": loader.state_dict()["cursor"]}, 5, str(tmp_path))

    restored, _ = ckpt.restore({"data": 0}, str(tmp_path))
    loader2 = CheckpointableLoader(src)
    loader2.load_state_dict({"cursor": int(restored["data"])})
    nxt = next(loader2)
    expected = src.batch(5)
    assert (np.asarray(nxt["tokens"]) == np.asarray(expected["tokens"])).all()
    # and it diverges from what a fresh (cursor=0) loader would give
    assert not (np.asarray(nxt["tokens"]) ==
                np.asarray(consumed[0]["tokens"])).all()
