import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # minimal installs: degrade to fixed-seed sampling
    HAVE_HYPOTHESIS = False

from repro.core import align, bitops, cim, fault
from repro.core.bitops import FP16


def _rand_w(key, k=64, j=32, scale=0.1):
    return jax.random.normal(key, (k, j)) * scale


# ---------------------------------------------------------------- alignment

@pytest.mark.parametrize("n,index", [(4, 1), (4, 2), (8, 2), (8, 3), (16, 2)])
def test_alignment_invariant_shared_exponent(n, index):
    w = _rand_w(jax.random.PRNGKey(0), k=4 * n, j=24)
    cfg = align.AlignmentConfig(n_group=n, index=index)
    w_al, e = align.align_matrix(w, cfg)
    _, ee, _ = bitops.split_fields(w_al, FP16)
    ee = np.asarray(ee).reshape(4, n, 24)
    assert (ee == ee[:, :1]).all(), "all weights in a block share one exponent"
    assert (ee[:, 0] == np.asarray(e)).all()


def _property_seeds(fn):
    """hypothesis-driven when available, else a fixed-seed parametrization."""
    if HAVE_HYPOTHESIS:
        return settings(max_examples=25, deadline=None)(
            given(st.integers(min_value=0, max_value=10 ** 6))(fn))
    return pytest.mark.parametrize("seed", [0, 1, 17, 4242, 999_983])(fn)


@_property_seeds
def test_alignment_within_range_property(seed):
    """|aligned| ∈ [LL, UL] of the block exponent (Fig. 5 invariant)."""
    key = jax.random.PRNGKey(seed)
    w = _rand_w(key, 32, 8, scale=float(jax.random.uniform(key, ()) * 2 + 1e-3))
    cfg = align.AlignmentConfig(n_group=8, index=2)
    w_al, e = align.align_matrix(w, cfg)
    ll, ul = bitops.exponent_range(e, FP16)
    mag = np.abs(np.asarray(w_al, np.float32)).reshape(4, 8, 8)
    assert (mag >= np.asarray(ll)[:, None] - 1e-12).all()
    assert (mag <= np.asarray(ul)[:, None] + 1e-12).all()


def test_alignment_monotone_within_sign_class():
    """Eq. 4 is a monotone min-max map: ordering of positives preserved."""
    w = jnp.asarray(np.linspace(0.011, 0.5, 8)[:, None], jnp.float32)
    cfg = align.AlignmentConfig(n_group=8, index=2)
    w_al, _ = align.align_matrix(w, cfg)
    v = np.asarray(w_al).ravel()
    assert (np.diff(v) >= 0).all()


def test_projection_idempotent_and_freezes_sign():
    key = jax.random.PRNGKey(1)
    w = _rand_w(key)
    cfg = align.AlignmentConfig(n_group=8, index=2)
    w_al, e = align.align_matrix(w, cfg)
    sign0 = jnp.sign(w_al)
    upd = w_al + jax.random.normal(jax.random.PRNGKey(2), w_al.shape) * 0.05
    p1 = align.project_to_block_exponent(upd, e, sign0, cfg)
    p2 = align.project_to_block_exponent(p1, e, sign0, cfg)
    assert np.allclose(np.asarray(p1), np.asarray(p2))
    assert (np.sign(np.asarray(p1)) == np.asarray(sign0)).all()
    _, ee, _ = bitops.split_fields(p1, FP16)
    assert (np.asarray(ee).reshape(8, 8, 32) == np.asarray(e)[:, None]).all()


def test_align_pytree_skips_vectors():
    params = {"w": _rand_w(jax.random.PRNGKey(0)), "scale": jnp.ones((16,))}
    aligned, exps = align.align_pytree(params, align.AlignmentConfig())
    assert exps["scale"] is None
    assert (np.asarray(aligned["scale"]) == 1).all()
    assert exps["w"] is not None


def test_ragged_last_block():
    """K not divisible by N: remaining weights form an extra block (fn. 2)."""
    w = _rand_w(jax.random.PRNGKey(3), k=19, j=8)
    w_al, e = align.align_matrix(w, align.AlignmentConfig(n_group=8, index=2))
    assert w_al.shape == (19, 8)
    assert e.shape == (3, 8)


# ---------------------------------------------------------------- fault

def test_fault_zero_ber_is_identity():
    w = _rand_w(jax.random.PRNGKey(0))
    out = fault.inject(jax.random.PRNGKey(1), w, 0.0, "full")
    assert (np.asarray(out) == np.asarray(w)).all()


@pytest.mark.parametrize("field", ["sign", "exponent", "mantissa", "full"])
def test_fault_flip_rate_statistics(field):
    """Empirical flip rate matches BER (binomial CI)."""
    n = 4096
    ber = 0.05
    w = jnp.full((n, 16), 1.5, jnp.float32)
    out = fault.inject(jax.random.PRNGKey(42), w, ber, field)
    xor = np.asarray(bitops.to_bits(out)) ^ np.asarray(bitops.to_bits(w))
    flipped = np.unpackbits(xor.view(np.uint8)).sum()
    n_bits = n * 16 * len(FP16.field_bit_positions(field))
    rate = flipped / n_bits
    assert abs(rate - ber) < 5 * np.sqrt(ber * (1 - ber) / n_bits)


@pytest.mark.parametrize("field", ["sign", "exponent", "mantissa"])
def test_fault_confined_to_field(field):
    # Power-of-two values (zero mantissa): exponent flips give ±inf, never NaN,
    # so the fp32 storage roundtrip is bit-exact and XOR isolates the field.
    # (With nonzero mantissas, exp->31 flips produce NaNs whose payload is
    # canonicalized by the fp32 cast — numerically faithful, bitwise lossy.)
    w = jnp.full((128, 64), 2.0, jnp.float32) * jnp.sign(
        jax.random.normal(jax.random.PRNGKey(0), (128, 64)))
    out = fault.inject(jax.random.PRNGKey(7), w, 0.2, field)
    xor = np.asarray(bitops.to_bits(out) ^ bitops.to_bits(w)).astype(np.uint32)
    allowed = np.zeros((), np.uint32)
    for p in FP16.field_bit_positions(field):
        allowed |= np.uint32(1 << p)
    assert (xor & ~allowed).max() == 0


def test_fault_pytree_skips_vectors():
    params = {"w": _rand_w(jax.random.PRNGKey(0)), "b": jnp.zeros((32,))}
    model = fault.FaultModel(ber=0.5, field="full")
    out = fault.inject_pytree(jax.random.PRNGKey(0), params, model)
    assert (np.asarray(out["b"]) == 0).all()
    assert not (np.asarray(out["w"]) == np.asarray(params["w"])).all()


# ---------------------------------------------------------------- CIM store

def test_cim_pack_read_exact_roundtrip():
    w = _rand_w(jax.random.PRNGKey(5), 64, 48)
    w_al, _ = align.align_matrix(w, align.AlignmentConfig())
    for protect in ("one4n", "none"):
        store = cim.pack(w_al, cim.CIMConfig(protect=protect))
        out, stats = cim.read(store)
        assert (np.asarray(out) == np.asarray(w_al, np.float32)).all()
        assert int(stats["uncorrectable"]) == 0


def test_cim_single_error_per_segment_fully_corrected():
    w = _rand_w(jax.random.PRNGKey(6), 32, 16)
    w_al, _ = align.align_matrix(w, align.AlignmentConfig())
    store = cim.pack(w_al, cim.CIMConfig(protect="one4n"))
    cw = store.codewords                     # packed uint32 [B, G, seg, W]
    cw = cw.at[..., 0].set(cw[..., 0] ^ jnp.uint32(1 << 3))  # 1 flip/segment
    store_f = cim.CIMStore(store.man, store.sign, store.exp, cw, store.shape, store.cfg)
    out, stats = cim.read(store_f)
    assert (np.asarray(out) == np.asarray(w_al, np.float32)).all()
    assert int(stats["corrected"]) == int(np.prod(cw.shape[:-1]))


def test_cim_protection_beats_unprotected():
    """Fig. 6 mechanism: at BER 1e-3 on exp/sign cells, One4N keeps weights
    near-exact while unprotected weights blow up."""
    w = _rand_w(jax.random.PRNGKey(8), 128, 64)
    w_al, _ = align.align_matrix(w, align.AlignmentConfig())
    key = jax.random.PRNGKey(0)
    errs = {}
    for protect in ("one4n", "none"):
        store = cim.pack(w_al, cim.CIMConfig(protect=protect))
        faulty = cim.inject(key, store, 1e-3, "exponent_sign")
        out, _ = cim.read(faulty)
        errs[protect] = float(jnp.max(jnp.abs(out - jnp.asarray(w_al, jnp.float32))))
    assert errs["one4n"] < 1.0
    assert errs["none"] > 100.0


def test_cim_mantissa_errors_bounded():
    """Mantissa flips perturb |w| by < one ulp span — the Fig. 2 robustness."""
    w = _rand_w(jax.random.PRNGKey(10), 64, 32)
    w_al, e = align.align_matrix(w, align.AlignmentConfig())
    store = cim.pack(w_al, cim.CIMConfig(protect="one4n"))
    faulty = cim.inject(jax.random.PRNGKey(11), store, 1e-2, "mantissa")
    out, _ = cim.read(faulty)
    _, ul = bitops.exponent_range(e, FP16)
    bound = float(jnp.max(ul))  # mantissa error < 2^(e-15) <= UL
    assert float(jnp.max(jnp.abs(out - jnp.asarray(w_al, jnp.float32)))) <= bound


def test_cim_deploy_pytree_and_stats():
    import pytest
    params = {"a": _rand_w(jax.random.PRNGKey(0), 32, 16),
              "norm": jnp.ones((16,))}
    with pytest.deprecated_call():
        stores, aligned = cim.deploy_pytree(params, cim.CIMConfig())
    assert isinstance(stores["a"], cim.CIMStore)
    assert not isinstance(stores["norm"], cim.CIMStore)
    with pytest.deprecated_call():
        faulty = cim.inject_pytree(jax.random.PRNGKey(1), stores, 1e-3)
    with pytest.deprecated_call():
        restored, stats = cim.read_pytree(faulty)
    assert restored["a"].shape == (32, 16)
    assert (np.asarray(restored["norm"]) == 1).all()
    assert "corrected" in stats


def test_cim_store_is_pytree():
    w = _rand_w(jax.random.PRNGKey(0), 16, 16)
    w_al, _ = align.align_matrix(w, align.AlignmentConfig())
    # protected: the ONLY exponent/sign copy lives in the codeword plane
    store = cim.pack(w_al, cim.CIMConfig())
    assert len(jax.tree_util.tree_leaves(store)) == 2      # man + codewords
    mapped = jax.tree_util.tree_map(lambda x: x, store)
    assert isinstance(mapped, cim.CIMStore)
    # unprotected: mantissa + packed sign + exponent planes
    raw = cim.pack(w_al, cim.CIMConfig(protect="none"))
    assert len(jax.tree_util.tree_leaves(raw)) == 3


def test_cim_per_weight_traditional_mode():
    """Table III 'traditional ECC for exponent & sign', functional: SECDED(6)
    per weight, exact roundtrip, single-flip correction, and EXACTLY 40x the
    One4N check bits (the paper's headline ratio)."""
    w = _rand_w(jax.random.PRNGKey(12), 64, 48)
    w16 = jnp.asarray(jnp.asarray(w, jnp.float16), jnp.float32)
    store = cim.pack(w16, cim.CIMConfig(protect="per_weight"))
    out, stats = cim.read(store)
    assert (np.asarray(out) == np.asarray(w16)).all()
    # flip one bit in every (uint16-packed) codeword -> fully corrected
    cw = store.codewords ^ jnp.uint16(1 << 4)
    out2, st2 = cim.read(cim.CIMStore(store.man, store.sign, store.exp, cw,
                                      store.shape, store.cfg))
    assert (np.asarray(out2) == np.asarray(w16)).all()
    assert int(st2["corrected"]) == 64 * 48
    # 40x check-bit ratio vs One4N (Table III), from logical stored bits
    w_al, _ = align.align_matrix(w, align.AlignmentConfig())
    s_pw = cim.pack(w_al, cim.CIMConfig(protect="per_weight"))
    s_o4 = cim.pack(w_al, cim.CIMConfig(protect="one4n"))
    pw_check = s_pw.codewords.size * (s_pw.cfg.pw_code.n - 6)
    n_blocks = int(np.prod(s_o4.codewords.shape[:2]))
    o4_check = n_blocks * s_o4.cfg.codec.redundant_bits_per_block
    assert pw_check / o4_check == 40.0
