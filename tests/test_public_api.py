"""Public API surface snapshot.

``repro.__all__`` is the framework's stable namespace. This test pins it to
an explicit snapshot so additions/removals are deliberate, reviewed events:
growing the API means updating BOTH ``src/repro/__init__.py`` and the
snapshot below in the same change.
"""
import inspect

import repro

PUBLIC_API_SNAPSHOT = (
    "__version__",
    # deployment (the one entry point onto the emulated macro)
    "CIMDeployment",
    "PolicyRule",
    "ReliabilityPolicy",
    "dispatch_linear",
    "dispatch_read_rows",
    # configuration
    "AlignmentConfig",
    "CIMConfig",
    "CIMStore",
    "FaultModel",
    "ReliabilityConfig",
    # fault-model zoo (error processes on the counter-PRNG contract)
    "FaultProcess",
    "parse_fault_model",
    # characterization
    "SweepEngine",
    "SweepPlan",
    "SweepResult",
    "characterize_fields",
    "characterize_policies",
    "characterize_protection",
    # co-design loop (resilience-aware fine-tuning + policy search)
    "AccuracySLO",
    "Finetuner",
    "PolicySearch",
    "SearchSpace",
    "TrainResult",
    "run_training",
    "search_policies",
    # kernel ops
    "ber_to_threshold",
    "cim_linear_store",
    "cim_linear_store_sharded",
    "fault_inject_bits",
    # expert-parallel MoE deployment (each expert its own macro)
    "ExpertDeployment",
    # slot-state protocol (the engine <-> architecture boundary)
    "SlotStateSpec",
    "extract_state_chunk",
    "init_slot_states",
    "inject_state_chunk",
    "slot_state_spec",
    # serving engine (continuous batching, per-request fault streams)
    "Engine",
    "LoadGen",
    "PrefixCache",
    "Request",
    # fleet serving (data-parallel replicas, SLO router, prefix reuse)
    "Fleet",
    # online ECC scrubbing (self-healing serving loop)
    "DriftAging",
    "ScrubController",
    "ScrubPolicy",
)


def test_public_api_matches_snapshot():
    got = sorted(repro.__all__)
    want = sorted(PUBLIC_API_SNAPSHOT)
    missing = [n for n in want if n not in got]
    extra = [n for n in got if n not in want]
    assert got == want, (
        f"public API drift: missing={missing} unexpected={extra} — if "
        f"intentional, update repro.__all__ AND the snapshot here together")


def test_public_api_names_resolve():
    for name in repro.__all__:
        assert hasattr(repro, name), f"repro.__all__ lists {name} but the " \
            f"attribute does not exist"


def test_public_api_entry_points_are_usable():
    # classes construct with defaults; functions are callable
    assert repro.ReliabilityPolicy().uniform
    assert repro.PolicyRule().protect == "one4n"
    assert repro.ReliabilityConfig().mode == "off"
    for name in ("characterize_fields", "characterize_policies",
                 "characterize_protection", "search_policies",
                 "run_training", "cim_linear_store",
                 "cim_linear_store_sharded", "dispatch_linear",
                 "dispatch_read_rows", "ber_to_threshold",
                 "fault_inject_bits"):
        assert callable(getattr(repro, name))
    assert inspect.isclass(repro.CIMDeployment)
    assert hasattr(repro.CIMDeployment, "deploy")
    for name in ("Finetuner", "PolicySearch", "SearchSpace", "AccuracySLO",
                 "TrainResult"):
        assert inspect.isclass(getattr(repro, name))
    assert hasattr(repro.PolicySearch, "search")
    assert hasattr(repro.Finetuner, "run")
    assert repro.parse_fault_model("burst:rate=0.5,length=4").kind == "burst"
    assert repro.FaultProcess.iid().kind == "iid"
    for name in ("DriftAging", "ScrubController", "ScrubPolicy"):
        assert inspect.isclass(getattr(repro, name))
    assert hasattr(repro.ScrubController, "on_step")
    assert repro.ScrubPolicy().threshold >= 1
    assert repro.__version__
