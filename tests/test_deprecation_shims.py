"""The legacy ``cim.*_pytree`` and ``lm`` KV-era entry points are shims.

Contract: each shim fires ``DeprecationWarning`` exactly once per call and
returns **bit-identical** results to its replacement (``cim.*_impl`` twins;
``lm.init_slot_states`` / ``extract_state_chunk`` / ``inject_state_chunk``
for the slot-state protocol renames). The shims only exist for old user
code — nothing inside the repo calls them.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.core import cim


@pytest.fixture(scope="module")
def tree():
    k = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(k, (64, 64)) * 0.1,
              "b": {"w": jax.random.normal(jax.random.fold_in(k, 1),
                                           (32, 64)) * 0.1},
              "scalar": jax.numpy.float32(1.0)}
    return params


def _plane_equal(a, b):
    for name, p in cim._plane_dict(a).items():
        q = cim._plane_dict(b)[name]
        assert (np.asarray(p) == np.asarray(q)).all(), name


def _tree_stores_equal(x, y):
    fx = jax.tree_util.tree_flatten(x, is_leaf=cim._is_store)[0]
    fy = jax.tree_util.tree_flatten(y, is_leaf=cim._is_store)[0]
    assert len(fx) == len(fy)
    for a, b in zip(fx, fy):
        assert cim._is_store(a) == cim._is_store(b)
        if cim._is_store(a):
            _plane_equal(a, b)
        else:
            assert (np.asarray(a) == np.asarray(b)).all()


@pytest.mark.parametrize("name", ["deploy_pytree", "inject_pytree",
                                  "read_pytree"])
def test_shim_warns(tree, name):
    cfg = cim.CIMConfig()
    stores, _ = cim.deploy_pytree_impl(tree, cfg)
    calls = {
        "deploy_pytree": lambda: cim.deploy_pytree(tree, cfg),
        "inject_pytree": lambda: cim.inject_pytree(
            jax.random.PRNGKey(1), stores, 1e-3),
        "read_pytree": lambda: cim.read_pytree(stores),
    }
    with pytest.warns(DeprecationWarning, match=name):
        calls[name]()


def test_impl_twins_do_not_warn(tree):
    cfg = cim.CIMConfig()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        stores, _ = cim.deploy_pytree_impl(tree, cfg)
        faulty = cim.inject_pytree_impl(jax.random.PRNGKey(1), stores, 1e-3)
        cim.read_pytree_impl(faulty)


def test_shims_bit_identical_to_impl(tree):
    cfg = cim.CIMConfig()
    key = jax.random.PRNGKey(2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        s_old, meta_old = cim.deploy_pytree(tree, cfg)
        s_new, meta_new = cim.deploy_pytree_impl(tree, cfg)
        _tree_stores_equal(s_old, s_new)
        assert jax.tree_util.tree_structure(meta_old) == \
            jax.tree_util.tree_structure(meta_new)

        f_old = cim.inject_pytree(key, s_old, 5e-3)
        f_new = cim.inject_pytree_impl(key, s_new, 5e-3)
        _tree_stores_equal(f_old, f_new)

        r_old, st_old = cim.read_pytree(f_old)
        r_new, st_new = cim.read_pytree_impl(f_new)
        for a, b in zip(jax.tree_util.tree_leaves(r_old),
                        jax.tree_util.tree_leaves(r_new)):
            a, b = np.asarray(a), np.asarray(b)
            assert ((a == b) | (np.isnan(a) & np.isnan(b))).all()
        assert int(st_old["corrected"]) == int(st_new["corrected"])
        assert int(st_old["uncorrectable"]) == int(st_new["uncorrectable"])


# --------------------------------------------------------------------------
# lm slot-state protocol renames (PR 10): init_caches / extract_kv_chunk /
# inject_kv_chunk forward to init_slot_states / extract_state_chunk /
# inject_state_chunk.
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lm_setup():
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("olmo-1b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(3), cfg)
    return cfg, params, lm


def _trees_bitwise_equal(x, y):
    fx, tx = jax.tree_util.tree_flatten(x)
    fy, ty = jax.tree_util.tree_flatten(y)
    assert tx == ty
    for a, b in zip(fx, fy):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype
        assert (a == b).all()


@pytest.mark.parametrize("name", ["init_caches", "extract_kv_chunk",
                                  "inject_kv_chunk"])
def test_lm_shim_warns(lm_setup, name):
    cfg, params, lm = lm_setup
    caches = lm.init_slot_states(cfg, 2, 16)
    chunk = lm.extract_state_chunk(cfg, caches, 0, 0, 8)
    calls = {
        "init_caches": lambda: lm.init_caches(cfg, 2, 16),
        "extract_kv_chunk": lambda: lm.extract_kv_chunk(
            cfg, caches, 0, 0, 8),
        "inject_kv_chunk": lambda: lm.inject_kv_chunk(
            cfg, caches, 1, 0, chunk),
    }
    with pytest.warns(DeprecationWarning, match=name):
        calls[name]()


def test_lm_new_names_do_not_warn(lm_setup):
    cfg, params, lm = lm_setup
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        caches = lm.init_slot_states(cfg, 2, 16)
        chunk = lm.extract_state_chunk(cfg, caches, 0, 0, 8)
        lm.inject_state_chunk(cfg, caches, 1, 0, chunk)


def test_lm_shims_bit_identical(lm_setup):
    cfg, params, lm = lm_setup
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        c_old = lm.init_caches(cfg, 2, 16)
        c_new = lm.init_slot_states(cfg, 2, 16)
        _trees_bitwise_equal(c_old, c_new)
        # prefill a real chunk so extract sees non-zero rows (per-slot pos
        # vector, as the engine sets up)
        c_new["pos"] = jax.numpy.zeros((2,), jax.numpy.int32)
        toks = np.arange(8, dtype=np.int32)
        _, c_new = lm.prefill_chunk(params, cfg, c_new, toks, 0, 0, length=8)
        ch_old = lm.extract_kv_chunk(cfg, c_new, 0, 0, 8)
        ch_new = lm.extract_state_chunk(cfg, c_new, 0, 0, 8)
        _trees_bitwise_equal(ch_old, ch_new)
        i_old = lm.inject_kv_chunk(cfg, c_new, 1, 0, ch_new)
        i_new = lm.inject_state_chunk(cfg, c_new, 1, 0, ch_new)
        _trees_bitwise_equal(i_old, i_new)
