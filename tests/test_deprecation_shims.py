"""The legacy ``cim.*_pytree`` entry points are deprecation shims.

Contract: each shim fires ``DeprecationWarning`` exactly once per call and
returns **bit-identical** results to its private ``*_impl`` twin (the twins
are what the deployment/sweep layers call; the shims only exist for old
user code).
"""
import warnings

import jax
import numpy as np
import pytest

from repro.core import cim


@pytest.fixture(scope="module")
def tree():
    k = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(k, (64, 64)) * 0.1,
              "b": {"w": jax.random.normal(jax.random.fold_in(k, 1),
                                           (32, 64)) * 0.1},
              "scalar": jax.numpy.float32(1.0)}
    return params


def _plane_equal(a, b):
    for name, p in cim._plane_dict(a).items():
        q = cim._plane_dict(b)[name]
        assert (np.asarray(p) == np.asarray(q)).all(), name


def _tree_stores_equal(x, y):
    fx = jax.tree_util.tree_flatten(x, is_leaf=cim._is_store)[0]
    fy = jax.tree_util.tree_flatten(y, is_leaf=cim._is_store)[0]
    assert len(fx) == len(fy)
    for a, b in zip(fx, fy):
        assert cim._is_store(a) == cim._is_store(b)
        if cim._is_store(a):
            _plane_equal(a, b)
        else:
            assert (np.asarray(a) == np.asarray(b)).all()


@pytest.mark.parametrize("name", ["deploy_pytree", "inject_pytree",
                                  "read_pytree"])
def test_shim_warns(tree, name):
    cfg = cim.CIMConfig()
    stores, _ = cim.deploy_pytree_impl(tree, cfg)
    calls = {
        "deploy_pytree": lambda: cim.deploy_pytree(tree, cfg),
        "inject_pytree": lambda: cim.inject_pytree(
            jax.random.PRNGKey(1), stores, 1e-3),
        "read_pytree": lambda: cim.read_pytree(stores),
    }
    with pytest.warns(DeprecationWarning, match=name):
        calls[name]()


def test_impl_twins_do_not_warn(tree):
    cfg = cim.CIMConfig()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        stores, _ = cim.deploy_pytree_impl(tree, cfg)
        faulty = cim.inject_pytree_impl(jax.random.PRNGKey(1), stores, 1e-3)
        cim.read_pytree_impl(faulty)


def test_shims_bit_identical_to_impl(tree):
    cfg = cim.CIMConfig()
    key = jax.random.PRNGKey(2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        s_old, meta_old = cim.deploy_pytree(tree, cfg)
        s_new, meta_new = cim.deploy_pytree_impl(tree, cfg)
        _tree_stores_equal(s_old, s_new)
        assert jax.tree_util.tree_structure(meta_old) == \
            jax.tree_util.tree_structure(meta_new)

        f_old = cim.inject_pytree(key, s_old, 5e-3)
        f_new = cim.inject_pytree_impl(key, s_new, 5e-3)
        _tree_stores_equal(f_old, f_new)

        r_old, st_old = cim.read_pytree(f_old)
        r_new, st_new = cim.read_pytree_impl(f_new)
        for a, b in zip(jax.tree_util.tree_leaves(r_old),
                        jax.tree_util.tree_leaves(r_new)):
            a, b = np.asarray(a), np.asarray(b)
            assert ((a == b) | (np.isnan(a) & np.isnan(b))).all()
        assert int(st_old["corrected"]) == int(st_new["corrected"])
        assert int(st_old["uncorrectable"]) == int(st_new["uncorrectable"])
