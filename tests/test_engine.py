"""Continuous-batching engine: batch-invariance contract + scheduler edges.

The acceptance contract of ``repro.launch.engine``:

* **batch invariance** — a request's decoded tokens, logits, and
  injected-fault streams (via per-request ECC accounting) are bit-identical
  whether it is served alone or continuously co-batched with other requests,
  for static and per-read dynamic injection, on the fused and hbm serve
  paths, on one device and (subprocess) under a forced-8-device "model"
  mesh. Seeds are keyed by (leaf, request, position) — never slot index or
  engine step — and decode math is row-independent across slots for every
  slot-state kind. The scenario matrix asserts this for all five kinds the
  slot-state protocol serves: attn (KV rows), local (rolling-window ring),
  rwkv / rec (recurrent folds with inactive-slot freezing), and drop-free
  moe (capacity never binds at these shapes — ``moe.drop_free``).
* **scheduler edges** — empty-queue idle steps are no-ops, evicted slots are
  reused lowest-index-first, prompts longer than the prefill chunk split
  raggedly without changing results, and a single-slot engine degenerates
  bit-identically to the lock-step ``lm.prefill``/``lm.decode`` serve path.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import engine as engine_lib
from repro.launch import serve as serve_lib
from repro.models import lm

CHUNK = 8
SLOTS = 4
MAX_LEN = 24


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("olmo-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    dkey = jax.random.fold_in(key, 1)
    return cfg, params, dkey


def _requests(n=4, seed=5, plens=(3, 14), gens=(3, 5)):
    load = engine_lib.LoadGen(n_requests=n, prompt_lens=plens, gen_lens=gens,
                              vocab_size=256, seed=seed)
    return load.requests()


def _serving_params(params, dkey, *, inject, serve_path, ber=1e-3):
    if serve_path == "hbm":
        out, _ = serve_lib.deploy(params, ber=ber, protect="one4n",
                                  n_group=8, index=2, key=dkey)
        return out
    return serve_lib.deploy_fused(params, ber=ber, protect="one4n",
                                  n_group=8, index=2, key=dkey,
                                  inject_mode=inject, field="full")


def _run(cfg, sparams, reqs, *, n_slots=SLOTS, chunk=CHUNK,
         max_len=MAX_LEN, **kw):
    eng = engine_lib.Engine(cfg, sparams, n_slots=n_slots, max_len=max_len,
                            chunk=chunk, collect_logits=True, **kw)
    results, agg = eng.run(reqs)
    assert sorted(results) == sorted(r.rid for r in reqs)
    return results, agg


@pytest.mark.parametrize("inject,serve_path", [
    ("static", "fused"), ("dynamic", "fused"), ("static", "hbm")])
def test_batch_invariance(setup, inject, serve_path):
    """Solo == co-batched, bitwise: tokens, every logit vector, and the
    per-request ECC stream accounting."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject=inject,
                              serve_path=serve_path)
    reqs = _requests()
    co, _ = _run(cfg, sparams, reqs)
    for rid in (0, 2):
        solo, _ = _run(cfg, sparams, [r for r in reqs if r.rid == rid])
        assert co[rid].tokens == solo[rid].tokens, (inject, serve_path, rid)
        assert np.array_equal(co[rid].logits, solo[rid].logits), \
            (inject, serve_path, rid)
        assert co[rid].ecc == solo[rid].ecc, (inject, serve_path, rid)


def test_invariance_across_slot_assignment(setup):
    """The slot a request lands on must not enter its fault streams: reverse
    the submission order (so every request gets a different slot) and demand
    identical tokens/logits per request."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="dynamic",
                              serve_path="fused")
    reqs = _requests()
    fwd, _ = _run(cfg, sparams, reqs)
    # same arrival time, reversed tiebreak order -> different slots
    rev = [engine_lib.Request(rid=r.rid, tokens=r.tokens, max_new=r.max_new,
                              arrival=float(len(reqs) - r.rid))
           for r in reqs]
    bwd, _ = _run(cfg, sparams, rev)
    moved = [r.rid for r in reqs if fwd[r.rid].slot != bwd[r.rid].slot]
    assert moved, "reversed order should shuffle slot assignment"
    for r in reqs:
        assert fwd[r.rid].tokens == bwd[r.rid].tokens
        assert np.array_equal(fwd[r.rid].logits, bwd[r.rid].logits)


def test_single_slot_degenerate_matches_serve_path(setup):
    """n_slots=1 engine == the existing lock-step prefill/decode serve path,
    bitwise, including the chunked prefill's first-token logits."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static",
                              serve_path="fused")
    req = _requests(n=1, seed=9, plens=(11, 11), gens=(5, 5))[0]
    res, _ = _run(cfg, sparams, [req], n_slots=1)

    tokens = jnp.asarray(req.tokens)[None]
    logits, caches = lm.prefill(sparams, cfg, {"tokens": tokens})
    plen = req.tokens.size
    caches = jax.tree_util.tree_map(
        lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 3)
                          + [(0, req.max_new), (0, 0), (0, 0)])
        if a.ndim >= 4 and a.shape[-3] == plen else a, caches)
    ref_tokens, ref_logits = [], []
    toks = jnp.argmax(logits, -1)[:, None]
    ref_tokens.append(int(toks[0, 0]))
    ref_logits.append(np.asarray(logits)[0])
    for _ in range(req.max_new - 1):
        logits, caches = lm.decode(sparams, cfg, caches, toks)
        toks = jnp.argmax(logits, -1)[:, None]
        ref_tokens.append(int(toks[0, 0]))
        ref_logits.append(np.asarray(logits)[0])
    assert res[req.rid].tokens == ref_tokens
    assert np.array_equal(res[req.rid].logits, np.stack(ref_logits))


def test_prompt_longer_than_chunk(setup):
    """A prompt spanning several ragged chunks decodes identically to a
    single-chunk prefill (static image: the read chain has no chunk-shape
    dependence)."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static",
                              serve_path="fused")
    req = _requests(n=1, seed=11, plens=(19, 19), gens=(4, 4))[0]
    fine, _ = _run(cfg, sparams, [req], chunk=4)       # 19 -> 4+4+4+4+3
    coarse, _ = _run(cfg, sparams, [req], chunk=32)    # one ragged chunk
    assert fine[req.rid].tokens == coarse[req.rid].tokens
    assert np.array_equal(fine[req.rid].logits, coarse[req.rid].logits)


def test_empty_queue_idle_step(setup):
    """Stepping an empty engine is a no-op: idle event, no position drift,
    and run([]) returns cleanly."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static",
                              serve_path="fused")
    eng = engine_lib.Engine(cfg, sparams, n_slots=2, max_len=MAX_LEN,
                            chunk=CHUNK)
    before = np.asarray(eng.caches["pos"])
    ev = eng.step()
    assert ev["idle"] and not ev["admitted"] and not ev["decoded"]
    assert np.array_equal(np.asarray(eng.caches["pos"]), before)
    assert eng.idle_steps == 1 and eng.steps == 0
    results, agg = eng.run([])
    assert results == {} and agg["n_requests"] == 0


def test_slot_eviction_reuse_ordering(setup):
    """With more requests than slots, a finished slot frees and the next
    queued request reuses the lowest free index; everything completes."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static",
                              serve_path="fused")
    reqs = [engine_lib.Request(rid=0, tokens=np.arange(4) % 256, max_new=2),
            engine_lib.Request(rid=1, tokens=np.arange(5) % 256, max_new=6),
            engine_lib.Request(rid=2, tokens=np.arange(6) % 256, max_new=3)]
    res, agg = _run(cfg, sparams, reqs, n_slots=2)
    assert res[0].slot == 0 and res[1].slot == 1
    # rid 0 (2 tokens) finishes before rid 1 (6 tokens): slot 0 frees first
    # and rid 2 must land there
    assert res[2].slot == 0
    assert [len(res[i].tokens) for i in range(3)] == [2, 6, 3]
    assert all(r.finish == "length" for r in res.values())
    assert agg["total_tokens"] == 11
    for r in res.values():
        # closed-loop runs gate admission with now=inf — that must never
        # leak into the latency record or the JSON artifact
        assert np.isfinite(r.queue_s) and r.queue_s >= 0
        assert r.finite is True
        json.dumps(r.to_json(), allow_nan=False)


def test_request_exceeding_max_len_rejected(setup):
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static",
                              serve_path="fused")
    eng = engine_lib.Engine(cfg, sparams, n_slots=1, max_len=16, chunk=CHUNK)
    big = engine_lib.Request(rid=0, tokens=np.zeros(12, np.int32), max_new=8)
    with pytest.raises(engine_lib.EngineError, match="exceeds"):
        eng.run([big])


def test_engine_accepts_all_slot_state_kinds():
    """The slot-state protocol serves every registered block kind — the old
    token-by-token rejection of recurrent / rolling-window architectures is
    gone. ``check_engine_kinds`` returns the per-block specs the engine
    schedules from, and each spec advertises the fields scheduling needs."""
    expect = {
        "olmo-1b": {"attn"},
        "rwkv6-1.6b": {"rwkv"},
        "recurrentgemma-9b": {"rec", "local"},
        "qwen3-moe-235b-a22b": {"moe"},
    }
    for name, kinds in expect.items():
        specs = lm.check_engine_kinds(get_config(name).reduced())
        assert {s.kind for s in specs} == kinds, name
    rwkv_spec, = set(lm.check_engine_kinds(get_config("rwkv6-1.6b").reduced()))
    assert rwkv_spec.advance == "scan" and rwkv_spec.cache_unit == "state"
    assert rwkv_spec.fold_state and not rwkv_spec.window_bound
    moe_spec, = set(lm.check_engine_kinds(
        get_config("qwen3-moe-235b-a22b").reduced()))
    assert moe_spec.capacity_coupled and moe_spec.cache_unit == "rows"


# --------------------------------------------------------------------------
# Scenario matrix: the batch-invariance contract for every slot-state kind.
# --------------------------------------------------------------------------

KINDS = ("attn", "local", "rwkv", "rec", "moe")


def _kind_cfg(kind):
    if kind == "attn":
        return get_config("olmo-1b").reduced()
    if kind == "local":
        # synthetic pure-local model: rolling-window ring with a window
        # smaller than max_len so eviction/wraparound is actually exercised
        return dataclasses.replace(get_config("olmo-1b").reduced(),
                                   block_pattern=("local",), local_window=16)
    if kind == "rwkv":
        return get_config("rwkv6-1.6b").reduced()
    if kind == "rec":
        return get_config("recurrentgemma-9b").reduced()
    return get_config("qwen3-moe-235b-a22b").reduced()


_KIND_CACHE = {}


def _kind_setup(kind):
    if kind not in _KIND_CACHE:
        cfg = _kind_cfg(kind)
        key = jax.random.PRNGKey(0)
        _KIND_CACHE[kind] = (cfg, lm.init_lm(key, cfg),
                             jax.random.fold_in(key, 1))
    return _KIND_CACHE[kind]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("inject", ["static", "dynamic"])
def test_scenario_matrix_batch_invariance(kind, inject):
    """For each architecture class the engine serves, a request's tokens,
    logits, and ECC stream accounting are bit-identical solo vs co-batched,
    under static and per-read dynamic injection. MoE runs drop-free at these
    shapes, so it carries the full guarantee with no capacity warning."""
    cfg, params, dkey = _kind_setup(kind)
    sparams = _serving_params(params, dkey, inject=inject, serve_path="fused")
    reqs = _requests(n=3)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # no capacity-coupling warning allowed
        eng = engine_lib.Engine(cfg, sparams, n_slots=SLOTS, max_len=MAX_LEN,
                                chunk=CHUNK, collect_logits=True)
    assert eng.capacity_coupled is False
    co, _ = eng.run(reqs)
    assert sorted(co) == sorted(r.rid for r in reqs)
    for rid in (0, 2):
        solo_eng = engine_lib.Engine(cfg, sparams, n_slots=SLOTS,
                                     max_len=MAX_LEN, chunk=CHUNK,
                                     collect_logits=True)
        solo, _ = solo_eng.run([r for r in reqs if r.rid == rid])
        assert co[rid].tokens == solo[rid].tokens, (kind, inject, rid)
        assert np.array_equal(co[rid].logits, solo[rid].logits), \
            (kind, inject, rid)
        assert co[rid].ecc == solo[rid].ecc, (kind, inject, rid)
        assert np.isfinite(co[rid].logits).all(), (kind, inject, rid)


def test_load_gen_open_loop_poisson():
    """Arrivals are monotone, lengths within range, and deterministic per
    seed (the CI soak artifact must be reproducible)."""
    load = engine_lib.LoadGen(n_requests=16, rate=100.0, prompt_lens=(4, 9),
                              gen_lens=(2, 5), vocab_size=64, seed=7)
    a, b = load.requests(), load.requests()
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[-1] > 0
    for ra, rb in zip(a, b):
        assert np.array_equal(ra.tokens, rb.tokens)
        assert (ra.arrival, ra.max_new) == (rb.arrival, rb.max_new)
        assert 4 <= ra.tokens.size <= 9 and 2 <= ra.max_new <= 5
        assert ra.tokens.max() < 64


_KIND_CFG_SNIPPET = {
    "attn": 'cfg = get_config("olmo-1b").reduced()',
    "local": ('import dataclasses\n'
              'cfg = dataclasses.replace(get_config("olmo-1b").reduced(), '
              'block_pattern=("local",), local_window=16)'),
    "rwkv": 'cfg = get_config("rwkv6-1.6b").reduced()',
    "rec": 'cfg = get_config("recurrentgemma-9b").reduced()',
    "moe": 'cfg = get_config("qwen3-moe-235b-a22b").reduced()',
}

_MESH_INVARIANCE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.distributed import sharding as shlib
    from repro.launch import engine as engine_lib
    from repro.launch import serve as serve_lib
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm

    {cfg_snippet}
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    dkey = jax.random.fold_in(key, 1)
    dep = serve_lib.make_deployment(params, ber=1e-3, protect="one4n",
                                   n_group=8, index=2, key=dkey,
                                   inject_mode="dynamic", field="full")
    mesh = make_host_mesh(model_axis=8)
    dep = dep.shard(mesh, axis="model", dim="j")
    sparams = serve_lib._serving_params(dep, ber=1e-3, key=dkey,
                                        inject_mode="dynamic", field="full")
    load = engine_lib.LoadGen(n_requests=3, prompt_lens=(3, 10),
                              gen_lens=(2, 3), vocab_size=256, seed=5)
    reqs = load.requests()
    with shlib.use_mesh(mesh):
        co, _ = engine_lib.Engine(cfg, sparams, n_slots=3, max_len=16,
                                  chunk=4, collect_logits=True).run(reqs)
        solo, _ = engine_lib.Engine(cfg, sparams, n_slots=3, max_len=16,
                                    chunk=4, collect_logits=True).run(
            [r for r in reqs if r.rid == 1])
    print(json.dumps({
        "tokens_equal": co[1].tokens == solo[1].tokens,
        "logits_equal": bool(np.array_equal(co[1].logits, solo[1].logits)),
        "ecc_equal": co[1].ecc == solo[1].ecc,
        "n_done": len(co),
        "finite": bool(np.isfinite(co[1].logits).all()),
    }))
""")


@pytest.mark.parametrize("kind", KINDS)
def test_batch_invariance_on_8_device_mesh(tmp_path, kind):
    """Dynamic-inject fused serving through the shard_map'd kernel on a
    forced-8-device "model" mesh: solo == co-batched, bitwise, for every
    slot-state kind."""
    path = tmp_path / f"mesh_engine_{kind}.py"
    path.write_text(_MESH_INVARIANCE_SCRIPT.replace(
        "{cfg_snippet}", _KIND_CFG_SNIPPET[kind]))
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, str(path)], capture_output=True,
                         text=True, env=env, cwd=os.getcwd(), timeout=560)
    assert out.returncode == 0, (kind, out.stderr[-3000:])
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got == {"tokens_equal": True, "logits_equal": True,
                   "ecc_equal": True, "n_done": 3, "finite": True}
