"""Mesh-sharded CIM store: real multi-device equivalence (subprocess with 8
forced host devices, same pattern as ``tests/test_distributed.py``).

Acceptance contracts of the mesh-native deployment:

* ``shard_store`` + ``inject_sharded`` is **bit-identical** to the
  single-device packed image for the same key, across >=2 mesh shapes and
  both shard layouts (per-shard counter-PRNG offsets put every local block's
  flip stream at its global store coordinates);
* the ``shard_map``'d fused decode+matmul (static and per-read dynamic)
  matches the single-device kernel, including the 'k' layout's psum over the
  contracted axis;
* end-to-end: the sharded fused serve path matches ``hbm`` logits within
  fp16 tolerance on a (2 data, 4 model) mesh;
* a Fig. 6 protection arm on a 2-D ("trial", "model") sweep mesh returns
  exactly the single-device engine's accuracies and ECC stats.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest


def _run(tmp_path, name, script):
    path = tmp_path / name
    path.write_text(script)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, str(path)], capture_output=True,
                         text=True, env=env, cwd=os.getcwd(), timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_INJECT_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import align, cim
    from repro.kernels.cim_read import ops as cr_ops
    from repro.kernels.fault_inject.ops import ber_to_threshold

    key = jax.random.PRNGKey(3)
    thr = ber_to_threshold(0.005)
    seeds = cim.plane_seeds(key)
    sc = cr_ops.make_scalars(seeds, thr, thr)
    checked = []
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 0.1
    w_al, _ = align.align_matrix(w, align.AlignmentConfig(8, 2))
    w16 = jnp.asarray(jnp.asarray(w, jnp.float16), jnp.float32)
    meshes = [jax.make_mesh((2,), ("model",)),
              jax.make_mesh((2, 4), ("data", "model"))]

    def plane_equal(a, b):
        for name, p in cim._plane_dict(a).items():
            q = cim._plane_dict(b)[name]
            assert (np.asarray(p) == np.asarray(q)).all(), name

    # (1) bit-identical sharded inject for every protect mode, 2 mesh shapes
    for protect in ("one4n", "none", "per_weight"):
        store = cim.pack(w16 if protect == "per_weight" else w_al,
                         cim.CIMConfig(protect=protect))
        ref = cim.inject(key, store, 0.005, "full")
        rr, sr = cim.read_reference(ref)
        for mesh in meshes:
            for dim in ("j", "k"):
                st = cim.shard_store(store, mesh, dim=dim)
                inj = jax.jit(lambda k, s, m=mesh, d=dim:
                              cim.inject_sharded(k, s, 0.005, "full",
                                                 mesh=m, dim=d))
                got = inj(key, st)
                plane_equal(ref, got)
                checked.append([protect, mesh.shape["model"], dim, "inject"])
        # planes are bit-equal on every mesh/dim, so one per-bit oracle
        # decode of a sharded image suffices per protect mode
        rg, sg = cim.read_reference(got)
        a, b = np.asarray(rr), np.asarray(rg)
        assert ((a == b) | (np.isnan(a) & np.isnan(b))).all()
        assert int(sr["uncorrectable"]) == int(sg["uncorrectable"])

    # (2) shard_map'd fused kernel: static + dynamic vs single device,
    #     'j' (column groups) and 'k' (psum over the contraction)
    store = cim.pack(w_al, cim.CIMConfig(protect="one4n"))
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 128))
    ref_s = np.asarray(cr_ops.cim_linear_store(x, store))
    ref_d = np.asarray(cr_ops.cim_linear_store(x, store, scalars=sc))
    for mesh in meshes:
        for dim in ("j", "k"):
            st = cim.shard_store(store, mesh, dim=dim)
            out, info = cr_ops.cim_linear_store_sharded(
                x, st, mesh=mesh, dim=dim, with_info=True)
            assert info["sharded"], (mesh.shape, dim)
            np.testing.assert_allclose(np.asarray(out), ref_s,
                                       rtol=1e-5, atol=1e-5)
            out_d = cr_ops.cim_linear_store_sharded(x, st, scalars=sc,
                                                    mesh=mesh, dim=dim)
            np.testing.assert_allclose(np.asarray(out_d), ref_d,
                                       rtol=1e-4, atol=1e-4)
            checked.append(["one4n", mesh.shape["model"], dim, "linear"])
    print(json.dumps({"checked": len(checked)}))
""")


@pytest.mark.slow
def test_sharded_inject_and_linear_bit_identical(tmp_path):
    result = _run(tmp_path, "sharded_equiv.py", _INJECT_EQUIV_SCRIPT)
    assert result["checked"] >= 14   # 3 protects x 2 meshes x 2 dims + linear


_TILE_STREAM_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import align, cim
    from repro.kernels.cim_read import ops as cr_ops
    from repro.kernels.fault_inject.ops import ber_to_threshold

    def bits(a):
        return np.asarray(jax.lax.bitcast_convert_type(
            jnp.asarray(a, jnp.float32), jnp.uint32))

    w = jax.random.normal(jax.random.PRNGKey(0), (256, 256)) * 0.1
    w_al, _ = align.align_matrix(w, align.AlignmentConfig(8, 2))
    store = cim.pack(w_al, cim.CIMConfig(protect="one4n"))
    key = jax.random.PRNGKey(11)
    seeds = cim.plane_seeds(key)
    thr = ber_to_threshold(0.003)
    sc = cr_ops.make_scalars(seeds, thr, thr)
    host = cim.inject_with_seeds(store, seeds, thr, thr)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 256))
    mesh = jax.make_mesh((8,), ("model",))
    checked = []
    # every autotuned tile combo, both shard layouts: the per-shard kernels
    # must draw flip streams at GLOBAL store coordinates (SCALAR_OFF_K/J
    # offsets), so the sharded dynamic read equals the sharded static read
    # of the host-injected image for the same key — bitwise
    for bm, bn, bk, hoist in cr_ops.autotuned_tile_shapes(store):
        for dim in ("j", "k"):
            st = cim.shard_store(store, mesh, dim=dim)
            st_host = cim.shard_store(host, mesh, dim=dim)
            dyn, info = cr_ops.cim_linear_store_sharded(
                x, st, scalars=sc, mesh=mesh, dim=dim, block_m=bm,
                block_n=bn, block_k=bk, hoist=hoist, with_info=True)
            assert info["sharded"], (dim, bm, bn, bk)
            static = cr_ops.cim_linear_store_sharded(
                x, st_host, mesh=mesh, dim=dim, block_m=bm, block_n=bn,
                block_k=bk, hoist=hoist)
            assert (bits(dyn) == bits(static)).all(), (dim, bm, bn, bk)
            checked.append([dim, bm, bn, bk, hoist])
    # cross-check against the single-device dynamic kernel (same key): the
    # 'j' layout splits pure column groups, so it stays bitwise; 'k' psums
    # partial products and is checked to fp32 tolerance
    ref_d = np.asarray(cr_ops.cim_linear_store(x, store, scalars=sc))
    for dim in ("j", "k"):
        st = cim.shard_store(store, mesh, dim=dim)
        out = np.asarray(cr_ops.cim_linear_store_sharded(
            x, st, scalars=sc, mesh=mesh, dim=dim))
        if dim == "j":
            assert (bits(out) == bits(ref_d)).all()
        else:
            np.testing.assert_allclose(out, ref_d, rtol=1e-5, atol=1e-5)
        checked.append([dim, "vs_1dev"])
    print(json.dumps({"checked": len(checked),
                      "n_tiles": len(cr_ops.autotuned_tile_shapes(store))}))
""")


@pytest.mark.slow
def test_sharded_dynamic_stream_identity_every_tile(tmp_path):
    """Satellite contract: on a forced-8-device "model" mesh, the shard_map'd
    kernel's per-read dynamic flip streams equal ``cim.inject_with_seeds``
    (static == dynamic for the same key) for EVERY autotuned tile shape and
    both shard layouts."""
    result = _run(tmp_path, "tile_stream.py", _TILE_STREAM_SCRIPT)
    assert result["n_tiles"] >= 2, result
    assert result["checked"] >= 2 * result["n_tiles"] + 2, result


_SERVE_EQUIV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.distributed import sharding as shlib
    from repro.launch import serve as serve_lib
    from repro.models import lm

    cfg = get_config("olmo-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    dkey = jax.random.fold_in(key, 1)
    stores = serve_lib.deploy_fused(params, ber=1e-3, protect="one4n",
                                    n_group=8, index=2, key=dkey,
                                    inject_mode="static", field="full")
    hbm, _ = serve_lib.deploy(params, ber=1e-3, protect="one4n", n_group=8,
                              index=2, key=dkey)
    tokens = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (4, 8)))
    lb, cb = lm.prefill(hbm, cfg, {"tokens": tokens})

    mesh = serve_lib.make_serve_mesh("2x4")
    shlib.set_mesh(mesh)
    placed = serve_lib.place_on_mesh(stores, mesh)
    unembed_shards = len(placed["unembed"].man.sharding.device_set)
    lf, cf = lm.prefill(placed, cfg, {"tokens": tokens})
    diff = float(np.abs(np.asarray(lf) - np.asarray(lb)).max())
    toks = jnp.argmax(lb, -1)[:, None]
    def grow(a):
        if a.ndim >= 4 and a.shape[-3] == 8:
            pad = [(0, 0)] * a.ndim; pad[-3] = (0, 2)
            return jnp.pad(a, pad)
        return a
    cf = jax.tree_util.tree_map(grow, cf)
    cb = jax.tree_util.tree_map(grow, cb)
    lf2, _ = lm.decode(placed, cfg, cf, toks)
    lb2, _ = lm.decode(hbm, cfg, cb, toks)
    diff2 = float(np.abs(np.asarray(lf2) - np.asarray(lb2)).max())
    print(json.dumps({"prefill_diff": diff, "decode_diff": diff2,
                      "unembed_shards": unembed_shards}))
""")


@pytest.mark.slow
def test_sharded_fused_serve_matches_hbm_logits(tmp_path):
    """Acceptance: the fused sharded serve path matches hbm logits within
    fp16 tolerance on a (2 data, 4 model) mesh, and the unembed store's
    planes are really distributed across devices."""
    result = _run(tmp_path, "sharded_serve.py", _SERVE_EQUIV_SCRIPT)
    assert result["prefill_diff"] < 1e-3, result
    assert result["decode_diff"] < 1e-3, result
    assert result["unembed_shards"] == 8, result


_SWEEP_COMPOSE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import sweep as sweep_lib
    from repro.launch.mesh import make_sweep_mesh

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {"w1": jax.random.normal(k1, (16, 64)) * 0.3,
              "w2": jax.random.normal(k2, (64, 16)) * 0.3}
    xe = jax.random.normal(jax.random.PRNGKey(5), (256, 16))
    ye = jnp.argmax(xe @ jax.random.normal(jax.random.PRNGKey(6), (16, 16)), -1)

    def eval_fn(p):
        h = jax.nn.relu(xe @ p["w1"])
        return jnp.mean(jnp.argmax(h @ p["w2"], -1) == ye)

    plan = sweep_lib.SweepPlan(bers=(1e-3, 1e-2), n_trials=8,
                               protects=("none", "one4n"))
    ref = sweep_lib.SweepEngine(plan, mesh=None).run_protection(
        jax.random.PRNGKey(9), params, eval_fn)
    mesh = make_sweep_mesh(model_axis=2)          # (4 trial, 2 model)
    eng = sweep_lib.SweepEngine(plan, mesh=mesh)
    got = eng.run_protection(jax.random.PRNGKey(9), params, eval_fn)
    same = all(a.accuracies == b.accuracies
               and (a.corrected, a.uncorrectable)
               == (b.corrected, b.uncorrectable)
               for a, b in zip(ref, got))
    compiles = max(eng.compiles().values())
    print(json.dumps({"cells": len(got), "identical": same,
                      "trial": mesh.shape["trial"],
                      "model": mesh.shape["model"],
                      "compiles_per_arm": compiles}))
""")


@pytest.mark.slow
def test_sweep_composes_trial_and_model_sharding(tmp_path):
    """A Fig. 6 arm on a ("trial", "model") mesh spans the whole mesh and
    returns exactly the single-device engine's numbers, still compiling once
    per arm."""
    result = _run(tmp_path, "sweep_compose.py", _SWEEP_COMPOSE_SCRIPT)
    assert result["identical"], result
    assert result["cells"] == 4
    assert (result["trial"], result["model"]) == (4, 2)
    assert result["compiles_per_arm"] == 1
