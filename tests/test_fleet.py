"""Fleet serving: replica invariance, prefix reuse, drain/re-admit, routing.

The acceptance contract of ``repro.launch.fleet`` (+ the engine's prefix
cache and fleet hooks):

* **replica invariance** — a request's tokens, logits, fault streams and
  ECC counts are bit-identical whether it is served solo on one engine,
  routed across N replicas, admitted off the prefix trie, or drained
  mid-flight and re-served elsewhere. Verified for static and per-read
  dynamic injection on one device, and (subprocess) as a 2x(1x4) fleet over
  8 forced host devices.
* **one shared image** — every replica restores the same deployed planes
  from one spool; compared bitwise leaf by leaf.
* **router** — SLO scoring balances a homogeneous closed burst, drains
  requeue in arrival order, recovery re-admits, and a fully-drained fleet
  with arrived work raises instead of hanging.
* **elastic edges** — ``propose_data_axis`` returns 0 (not a crash) for 0
  survivors or model_axis > surviving devices, and non-power-of-two device
  counts round down.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import cim as cim_lib
from repro.core import deployment as dep_lib
from repro.distributed.elastic import ElasticCoordinator
from repro.launch import engine as engine_lib
from repro.launch import fleet as fleet_lib
from repro.launch import serve as serve_lib
from repro.models import lm

CHUNK = 8
MAX_LEN = 40


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("olmo-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    dkey = jax.random.fold_in(key, 1)
    return cfg, params, dkey


def _serving_params(params, dkey, *, inject="dynamic", ber=1e-3):
    return serve_lib.deploy_fused(params, ber=ber, protect="one4n",
                                  n_group=8, index=2, key=dkey,
                                  inject_mode=inject, field="full")


def _load(n=6, seed=7, prefix_len=16, gens=(3, 5)):
    return engine_lib.LoadGen(n_requests=n, prompt_lens=(3, 10),
                              gen_lens=gens, vocab_size=256, seed=seed,
                              prefix_len=prefix_len)


# ------------------------------------------------------------ salts


def test_prefix_salt_deterministic_and_content_keyed():
    toks = np.arange(12, dtype=np.int32)
    a = dep_lib.prefix_salt(toks)
    assert a == dep_lib.prefix_salt(list(range(12)))       # dtype-independent
    assert a != dep_lib.prefix_salt(toks[:11])             # length-sensitive
    bumped = toks.copy()
    bumped[0] += 1
    assert a != dep_lib.prefix_salt(bumped)                # content-sensitive
    assert 0 <= a <= 0xFFFFFFFF


def test_prefix_salt_does_not_alias_request_salts():
    # the two salt families must never collide on small ids/prefixes: a
    # prefill stream aliasing a decode stream would correlate their faults
    reqs = {int(dep_lib.request_salt(rid)) for rid in range(64)}
    prefs = {dep_lib.prefix_salt(np.arange(n) % 7) for n in range(1, 65)}
    assert not reqs & prefs


# ------------------------------------------------------------ elastic edges


def test_propose_data_axis_zero_survivors():
    co = ElasticCoordinator(["h0", "h1"], model_axis=2)
    for h in ("h0", "h1"):
        co.mark_failed(h)
    assert co.healthy_hosts == []
    assert co.propose_data_axis(4) == 0                    # not a crash
    gen, dp = co.reconfigure(4)
    assert dp == 0 and gen == 1


def test_propose_data_axis_model_axis_exceeds_survivors():
    co = ElasticCoordinator(["h0", "h1"], model_axis=8)
    assert co.propose_data_axis(4) == 1                    # 8 devs / 8 = 1
    co.mark_failed("h1")
    assert co.propose_data_axis(4) == 0                    # 4 devs < 8


def test_propose_data_axis_non_power_of_two():
    co = ElasticCoordinator([f"h{i}" for i in range(3)], model_axis=2)
    assert co.propose_data_axis(2) == 2                    # 6//2=3 -> dp 2
    assert co.propose_data_axis(5) == 4                    # 15//2=7 -> dp 4
    assert co.propose_data_axis(1) == 1                    # 3//2=1 -> dp 1


def test_heartbeat_readmits_failed_host():
    co = ElasticCoordinator(["h0", "h1"], model_axis=1)
    assert co.mark_failed("h0") is True
    assert co.mark_failed("h0") is False                   # already failed
    assert co.healthy_hosts == ["h1"]
    co.heartbeat("h0")                                     # back from the dead
    assert co.healthy_hosts == ["h0", "h1"]
    assert co.drain_recovered() == ["h0"]
    assert co.drain_recovered() == []                      # drained once
    co.heartbeat("nope")                                   # unknown: ignored


def test_timeout_check_marks_failed_once():
    t = [0.0]
    co = ElasticCoordinator(["h0", "h1"], model_axis=1,
                            heartbeat_timeout=10.0, clock=lambda: t[0])
    t[0] = 5.0
    co.heartbeat("h1")
    t[0] = 11.0
    assert co.check() == ["h0"]
    assert co.check() == []                                # newly-failed only


# ------------------------------------------------------------ prefix cache


def test_prefix_cache_hash_consing_and_trie_paths():
    pc = engine_lib.PrefixCache()
    a = np.arange(8, dtype=np.int32)
    b = a + 1
    n1 = pc.insert(None, a, state="kv_a", salt=1)
    assert pc.insert(None, a, state="other", salt=1) is n1    # hash-consed
    assert pc.inserts == 1
    n2 = pc.insert(n1, b, state="kv_b", salt=2)
    assert pc.lookup(None, a) is n1
    assert pc.lookup(n1, b) is n2
    assert pc.lookup(None, b) is None                      # wrong parent
    assert pc.lookup(n2, a) is None
    assert len(pc) == 2 and pc.hits == 2 and pc.misses == 2


def test_prefix_cache_lru_evicts_leaves_only():
    pc = engine_lib.PrefixCache(max_chunks=2)
    root = pc.insert(None, [1], state=0, salt=0)
    pc.insert(root, [2], state=0, salt=0)                     # child of root
    pc.lookup(None, [1])                # root is now the RECENT one
    pc.insert(None, [3], state=0, salt=0)  # over capacity -> evict one leaf
    assert pc.evictions == 1
    # the child was the oldest leaf; root survives even though it is older
    # than its child was (evicting it would orphan reachable descendants)
    assert pc.lookup(None, [1]) is not None
    assert pc.lookup(root, [2]) is None
    assert pc.lookup(None, [3]) is not None


def test_prefix_cache_invalidate():
    pc = engine_lib.PrefixCache()
    n = pc.insert(None, [1, 2], state=0, salt=0)
    pc.insert(n, [3, 4], state=0, salt=0)
    pc.invalidate()
    assert len(pc) == 0 and pc.invalidations == 1
    assert pc.lookup(None, [1, 2]) is None


# ------------------------------------------------------------ engine reuse


@pytest.mark.parametrize("inject", ["static", "dynamic"])
def test_prefix_reuse_bitwise(setup, inject):
    """Trie-warm admission == cold prefill, bitwise: tokens, every logit
    vector, and the replayed per-request ECC stream accounting."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject=inject)
    reqs = _load().requests()

    def run(pc):
        eng = engine_lib.Engine(cfg, sparams, n_slots=3, max_len=MAX_LEN,
                                chunk=CHUNK, collect_logits=True,
                                prefix_cache=pc)
        return eng.run(reqs)[0], eng

    cold, _ = run(None)
    warm, eng = run(True)
    hits = 0
    for rid in cold:
        assert cold[rid].tokens == warm[rid].tokens, rid
        assert np.array_equal(cold[rid].logits, warm[rid].logits), rid
        assert cold[rid].ecc == warm[rid].ecc, rid
        hits += warm[rid].prefix_tokens > 0
    assert hits > 0, "16-token shared prefix produced no trie hits"
    st = eng.prefix_cache.stats()
    assert st["hits"] > 0 and st["chunks"] > 0


def test_prefix_reuse_within_one_run(setup):
    """Later requests of one run hit the chunks the first request inserted;
    the first request itself admits fully cold."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static")
    eng = engine_lib.Engine(cfg, sparams, n_slots=2, max_len=MAX_LEN,
                            chunk=CHUNK, prefix_cache=True)
    res, agg = eng.run(_load().requests())
    first = min(res)
    assert res[first].prefix_tokens == 0
    assert agg["prefix_hits"] >= 1
    assert agg["prefix_tokens"] == sum(r.prefix_tokens for r in res.values())


def test_refresh_params_invalidates_trie(setup):
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static")
    eng = engine_lib.Engine(cfg, sparams, n_slots=2, max_len=MAX_LEN,
                            chunk=CHUNK, prefix_cache=True)
    eng.run(_load(n=3).requests())
    assert len(eng.prefix_cache) > 0
    eng.refresh_params(sparams)
    assert len(eng.prefix_cache) == 0
    assert eng.prefix_cache.invalidations == 1


def test_refresh_params_refuses_busy_engine(setup):
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static")
    eng = engine_lib.Engine(cfg, sparams, n_slots=2, max_len=MAX_LEN,
                            chunk=CHUNK)
    eng.submit(engine_lib.Request(rid=0, tokens=[1, 2, 3], max_new=2))
    with pytest.raises(engine_lib.EngineError, match="busy"):
        eng.refresh_params(sparams)


def test_result_json_carries_fleet_fields(setup):
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static")
    eng = engine_lib.Engine(cfg, sparams, n_slots=2, max_len=MAX_LEN,
                            chunk=CHUNK, prefix_cache=True, replica="r9")
    res, _ = eng.run(_load(n=3).requests())
    rows = [r.to_json() for r in res.values()]
    assert all(row["replica"] == "r9" for row in rows)
    assert all(row["salt"] == int(dep_lib.request_salt(row["rid"]))
               for row in rows)
    assert any(row["prefix_hit"] for row in rows)
    assert all(row["prefix_hit"] == (row["prefix_tokens"] > 0)
               for row in rows)


# ------------------------------------------------------------ fleet


def test_fleet_routed_equals_solo_bitwise(setup):
    """Dynamic injection, 2 replicas off one spooled image: routed results
    == a solo engine serving the same load off the ORIGINAL params."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="dynamic")
    reqs = _load().requests()
    solo, _ = engine_lib.Engine(cfg, sparams, n_slots=3, max_len=MAX_LEN,
                                chunk=CHUNK, collect_logits=True).run(reqs)
    fl = fleet_lib.Fleet.from_serving_params(
        cfg, sparams, n_replicas=2, n_slots=3, max_len=MAX_LEN, chunk=CHUNK,
        collect_logits=True)
    routed, agg = fl.run(reqs)
    assert sorted(routed) == sorted(r.rid for r in reqs)
    for rid in solo:
        assert solo[rid].tokens == routed[rid].tokens, rid
        assert np.array_equal(solo[rid].logits, routed[rid].logits), rid
        assert solo[rid].ecc == routed[rid].ecc, rid
    # the router actually fanned out
    assert len({r.replica for r in routed.values()}) == 2
    assert agg["n_replicas"] == 2 and agg["drains"] == 0


def test_fleet_replicas_share_one_image(setup):
    """Every replica's restored params match the source bitwise, leaf by
    leaf — packed planes, ECC metadata, dynamic seed table, everything."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="dynamic")
    fl = fleet_lib.Fleet.from_serving_params(
        cfg, sparams, n_replicas=2, n_slots=2, max_len=MAX_LEN, chunk=CHUNK)
    src = jax.tree_util.tree_leaves(sparams)
    for rep in fl.replicas.values():
        got = jax.tree_util.tree_leaves(rep.engine.params)
        assert len(got) == len(src)
        for a, b in zip(src, got):
            assert np.asarray(a).dtype == np.asarray(b).dtype
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_fleet_balances_closed_burst(setup):
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static")
    load = _load(n=8, prefix_len=0, gens=(4, 4))
    fl = fleet_lib.Fleet.from_serving_params(
        cfg, sparams, n_replicas=2, prefix_cache=False, n_slots=2,
        max_len=MAX_LEN, chunk=CHUNK)
    _, agg = fl.run(load.requests())
    by_rep = agg["requests_by_replica"]
    assert sum(by_rep.values()) == 8
    # depth-based scoring must not starve a replica of a homogeneous burst
    assert min(by_rep.values()) >= 2, by_rep


def test_fleet_drain_requeue_bitwise(setup):
    """Force-fail a replica mid-run: its in-flight + queued requests re-route
    and the final results still match the uninterrupted solo run bitwise."""
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="dynamic")
    reqs = _load().requests()
    solo, _ = engine_lib.Engine(cfg, sparams, n_slots=3, max_len=MAX_LEN,
                                chunk=CHUNK, collect_logits=True).run(reqs)
    fl = fleet_lib.Fleet.from_serving_params(
        cfg, sparams, n_replicas=2, n_slots=2, max_len=MAX_LEN, chunk=CHUNK,
        collect_logits=True)
    import time
    fl._t0 = time.perf_counter()
    for rep in fl.replicas.values():
        rep.engine.start(fl._t0)
    for r in sorted(reqs, key=lambda r: (r.arrival, r.rid)):
        fl._queue.append((r, 0.0))
    fl.tick()
    fl.tick()
    fl.fail("replica0")
    assert fl.drains == 1 and fl.requeued >= 1
    fl.tick()
    fl.recover("replica0")
    while fl._queue or any(r.engine.busy for r in fl.replicas.values()):
        fl.tick()
    assert sorted(fl.results) == sorted(r.rid for r in reqs)
    for rid in solo:
        assert solo[rid].tokens == fl.results[rid].tokens, rid
        assert np.array_equal(solo[rid].logits, fl.results[rid].logits), rid
        assert solo[rid].ecc == fl.results[rid].ecc, rid
    # recovery re-admitted replica0 (it may or may not have won work since)
    assert "replica0" in fl._admitting


def test_fleet_all_drained_raises(setup):
    cfg, params, dkey = setup
    sparams = _serving_params(params, dkey, inject="static")
    fl = fleet_lib.Fleet.from_serving_params(
        cfg, sparams, n_replicas=2, n_slots=2, max_len=MAX_LEN, chunk=CHUNK)
    fl.fail("replica0")
    fl.fail("replica1")
    with pytest.raises(fleet_lib.FleetError, match="no admitting"):
        fl.run(_load(n=2).requests())


def test_fleet_meshes_require_enough_devices():
    with pytest.raises(AssertionError, match="devices"):
        fleet_lib.make_fleet_meshes("1x8", 2)    # 16 devices on a 1-dev host


# ------------------------------------------------------------ load gen


def test_loadgen_fleet_fanout_determinism():
    a = _load(seed=3).requests()
    b = _load(seed=3).requests()
    for ra, rb in zip(a, b):
        assert ra.rid == rb.rid and ra.max_new == rb.max_new
        assert ra.arrival == rb.arrival
        assert np.array_equal(ra.tokens, rb.tokens)


def test_loadgen_shared_prefix_semantics():
    load = _load(n=4, seed=9, prefix_len=12)
    reqs = load.requests()
    first = reqs[0].tokens[:12]
    assert all(np.array_equal(r.tokens[:12], first) for r in reqs)
    assert load.max_len() >= max(r.tokens.size + r.max_new for r in reqs)
    # prefix_len=0 reproduces the historical schedule exactly
    base = engine_lib.LoadGen(n_requests=4, prompt_lens=(3, 10),
                              gen_lens=(3, 5), vocab_size=256, seed=9)
    again = engine_lib.LoadGen(n_requests=4, prompt_lens=(3, 10),
                               gen_lens=(3, 5), vocab_size=256, seed=9,
                               prefix_len=0)
    for ra, rb in zip(base.requests(), again.requests()):
        assert np.array_equal(ra.tokens, rb.tokens)


# ------------------------------------------------------------ 8-device fleet


_FLEET_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.launch import engine as engine_lib
    from repro.launch import fleet as fleet_lib
    from repro.launch import serve as serve_lib
    from repro.models import lm

    cfg = get_config("olmo-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    dkey = jax.random.fold_in(key, 1)
    sparams = serve_lib.deploy_fused(params, ber=1e-3, protect="one4n",
                                     n_group=8, index=2, key=dkey,
                                     inject_mode="dynamic", field="full")
    load = engine_lib.LoadGen(n_requests=4, prompt_lens=(3, 10),
                              gen_lens=(2, 3), vocab_size=256, seed=5,
                              prefix_len=8)
    reqs = load.requests()
    meshes = fleet_lib.make_fleet_meshes("1x4", 2)
    assert [sorted(d.id for d in m.devices.flat) for m in meshes] == \\
        [[0, 1, 2, 3], [4, 5, 6, 7]]                    # disjoint blocks
    fl = fleet_lib.Fleet.from_serving_params(
        cfg, sparams, n_replicas=2, meshes=meshes, n_slots=2, max_len=20,
        chunk=4, collect_logits=True)
    routed, agg = fl.run(reqs)
    rid = 1
    pf = fleet_lib.Fleet.from_serving_params(
        cfg, sparams, n_replicas=1, meshes=meshes[:1],
        spool_dir=fl.spool_dir, n_slots=2, max_len=20, chunk=4,
        collect_logits=True)
    probe, _ = pf.run([r for r in reqs if r.rid == rid])
    print(json.dumps({
        "n_done": len(routed),
        "replicas": sorted({r.replica for r in routed.values()}),
        "tokens_equal": routed[rid].tokens == probe[rid].tokens,
        "logits_equal": bool(np.array_equal(routed[rid].logits,
                                            probe[rid].logits)),
        "ecc_equal": routed[rid].ecc == probe[rid].ecc,
        "prefix_hits": int(agg["prefix_hits"]),
    }))
""")


def test_fleet_invariance_on_8_device_split(tmp_path):
    """2 replicas x (1x4) disjoint device blocks, dynamic injection: the
    routed run matches a single-replica probe off the same spool bitwise."""
    path = tmp_path / "mesh_fleet.py"
    path.write_text(_FLEET_MESH_SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, str(path)], capture_output=True,
                         text=True, env=env, cwd=os.getcwd(), timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got["n_done"] == 4
    assert got["tokens_equal"] and got["logits_equal"] and got["ecc_equal"]
    assert got["replicas"] == ["replica0", "replica1"]
    assert got["prefix_hits"] >= 1
