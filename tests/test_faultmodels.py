"""Fault-model zoo (``repro.core.faultmodels``): process grammar, stream
identity, and cross-path/cross-device reproducibility.

Acceptance contracts:

* the default ``iid`` process is **bit-for-bit** the legacy counter-PRNG
  stream — static inject across all three protect modes, the dynamic
  per-read path, and the fused kernel scalars;
* every non-trivial process draws a flip set that is a **subset** of the
  iid flips at the same (key, BER) — model thresholds only ever scale down;
* drift is monotone in the tick (larger tick ⇒ superset flips) and
  ``tick=0`` is exactly iid;
* burst / drift masks are identical on 1 device vs a forced-8-device mesh,
  both shard layouts (subprocess; same pattern as test_sharded_store.py);
* the sweep engine's fault-model axis tags results and keeps the default
  ``("iid",)`` plan's streams unchanged.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import align, cim
from repro.core import faultmodels as fm
from repro.kernels.cim_read import ops as cr_ops
from repro.kernels.fault_inject.ops import ber_to_threshold


def _plane_equal(a, b):
    for name, p in cim._plane_dict(a).items():
        q = cim._plane_dict(b)[name]
        assert (np.asarray(p) == np.asarray(q)).all(), name


def _stores(w_shape=(64, 64), seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), w_shape) * 0.1
    w_al, _ = align.align_matrix(w, align.AlignmentConfig(8, 2))
    w16 = jnp.asarray(jnp.asarray(w, jnp.float16), jnp.float32)
    out = {}
    for protect in ("one4n", "none", "per_weight"):
        src = w16 if protect == "per_weight" else w_al
        out[protect] = cim.pack(src, cim.CIMConfig(protect=protect))
    return out


def _flip_words(clean, faulty):
    """Total differing words across planes (the incident flip mass)."""
    n = 0
    for name, p in cim._plane_dict(clean).items():
        q = cim._plane_dict(faulty)[name]
        n += int((np.asarray(p) != np.asarray(q)).sum())
    return n


def _flip_subset(clean, a, b):
    """Every bit flipped in ``a`` is also flipped in ``b`` (vs clean)."""
    for name, p in cim._plane_dict(clean).items():
        base = np.asarray(p)
        fa = base ^ np.asarray(cim._plane_dict(a)[name])
        fb = base ^ np.asarray(cim._plane_dict(b)[name])
        assert (fa & ~fb).sum() == 0, name


# ---------------------------------------------------------------- grammar


def test_grammar_parses_and_validates():
    p = fm.parse_fault_model("burst:rate=0.3,length=8,axis=col")
    assert (p.kind, p.rate, p.length, p.axis) == ("burst", 0.3, 8, "col")
    assert fm.parse_fault_model("") is None
    assert fm.parse_fault_model(None) is None
    assert fm.parse_fault_model(p) is p
    assert fm.parse_fault_model("drift").kind == "drift"
    assert fm.parse_fault_model("correlated:strength=0.9").strength == 0.9
    with pytest.raises(ValueError):
        fm.parse_fault_model("gamma:rate=0.1")
    with pytest.raises(ValueError):
        fm.parse_fault_model("burst:bogus=1")
    with pytest.raises(ValueError):
        fm.FaultProcess(kind="burst", axis="diag")


def test_process_is_static_pytree():
    p = fm.FaultProcess.burst(rate=0.5, length=4)
    leaves, treedef = jax.tree_util.tree_flatten(p)
    assert leaves == []          # leafless: rides through jit as structure
    assert jax.tree_util.tree_unflatten(treedef, leaves) == p
    hash(p)                      # usable as a static_argnames value


# ---------------------------------------------------- iid stream identity


def test_iid_bitwise_equals_legacy_static_inject():
    key = jax.random.PRNGKey(11)
    for protect, store in _stores().items():
        legacy = cim.inject(key, store, 0.01, "full")
        for model in (None, fm.FaultProcess.iid(),
                      fm.parse_fault_model("iid")):
            _plane_equal(legacy, cim.inject(key, store, 0.01, "full",
                                            model=model))
        # a drift process at tick=0 is exactly the base BER
        _plane_equal(legacy, cim.inject(key, store, 0.01, "full",
                                        model=fm.FaultProcess.drift()))


def test_iid_bitwise_equals_legacy_dynamic_and_kernel():
    key = jax.random.PRNGKey(12)
    store = _stores()["one4n"]
    seeds = cim.plane_seeds(key)
    thr = ber_to_threshold(0.005)
    legacy = cim.inject_with_seeds(store, seeds, thr, thr)
    _plane_equal(legacy, cim.inject_with_seeds(store, seeds, thr, thr,
                                               model=fm.FaultProcess.iid()))
    # fused kernel: iid scalars produce bit-identical outputs to legacy
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    sc0 = cr_ops.make_scalars(seeds, thr, thr)
    sc1 = cr_ops.make_scalars(seeds, thr, thr, model=fm.FaultProcess.iid())
    y0 = np.asarray(cr_ops.cim_linear_store(x, store, scalars=sc0))
    y1 = np.asarray(cr_ops.cim_linear_store(x, store, scalars=sc1,
                                            model=fm.FaultProcess.iid()))
    assert (y0 == y1).all()


# ------------------------------------------------------- model semantics


@pytest.mark.parametrize("spec", [
    "burst:rate=0.5,length=4,axis=row",
    "burst:rate=0.5,length=4,axis=col",
    "burst:rate=0.5,length=8,axis=bank",
    "correlated:strength=0.8,period=4",
])
def test_model_flips_subset_of_iid(spec):
    key = jax.random.PRNGKey(21)
    model = fm.parse_fault_model(spec)
    for protect, store in _stores().items():
        iid = cim.inject(key, store, 0.02, "full")
        got = cim.inject(key, store, 0.02, "full", model=model)
        _flip_subset(store, got, iid)
        assert _flip_words(store, got) < _flip_words(store, iid), \
            (protect, spec)   # the process actually thins the stream


def test_burst_concentrates_flips():
    # burst flips cluster into hit units: fewer distinct mantissa rows carry
    # flips than under iid at matched incident rate
    key = jax.random.PRNGKey(22)
    store = _stores((128, 64))["one4n"]
    iid = cim.inject(key, store, 0.02, "full")
    got = cim.inject(key, store, 0.02, "full",
                     model=fm.FaultProcess.burst(rate=0.3, length=4))
    def rows_hit(faulty):
        d = np.asarray(store.man) != np.asarray(faulty.man)
        return int(d.any(1).sum())
    assert 0 < rows_hit(got) < rows_hit(iid)


def test_drift_monotone_and_tick0_identity():
    key = jax.random.PRNGKey(23)
    store = _stores()["one4n"]
    model = fm.FaultProcess.drift(drift_rate=0.5)
    iid = cim.inject(key, store, 0.005, "full")
    t0 = cim.inject(key, store, 0.005, "full", model=model)
    _plane_equal(iid, t0)        # tick=0: no elapsed time, exactly iid
    prev, prev_n = store, 0
    import dataclasses
    for tick in (1, 4, 16):
        cur = cim.inject(key, store, 0.005, "full",
                         model=dataclasses.replace(model, tick=tick))
        _flip_subset(store, prev, cur)       # superset as time advances
        n = _flip_words(store, cur)
        assert n >= prev_n
        prev, prev_n = cur, n
    assert prev_n > _flip_words(store, iid)  # drift actually grew the BER
    # threshold curve saturates instead of wrapping
    thr = np.uint32(fm.drift_threshold(ber_to_threshold(0.005), 0.5, 1000))
    assert thr == np.uint32(0xFFFFFFFF)


def test_deployment_rule_fault_model():
    from repro.core import deployment as dep_lib
    with pytest.raises(ValueError):
        dep_lib.PolicyRule(fault_model="nope:x=1")
    rule = dep_lib.PolicyRule(fault_model="burst:rate=0.4,length=4")
    assert rule.fault_process.kind == "burst"
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.1}
    pol = dep_lib.ReliabilityPolicy(rules=(), default=rule)
    dep = dep_lib.CIMDeployment.deploy(params, pol)
    store = dep.store_leaves()[0][2]
    key = jax.random.PRNGKey(5)
    # rule-level process drives inject; an explicit model= overrides it
    via_rule = dep.inject(key, 0.02)
    k0 = jax.random.split(key, 1)[0]
    ref = cim.inject(k0, store, 0.02, "full", model=rule.fault_process)
    _plane_equal(ref, via_rule.store_leaves()[0][2])
    via_override = dep.inject(key, 0.02, model="iid")
    _plane_equal(cim.inject(k0, store, 0.02, "full"),
                 via_override.store_leaves()[0][2])


def test_sweep_fault_model_axis():
    from repro.core import sweep as sweep_lib
    from repro.core.resilience import characterize_protection
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 32)) * 0.1}

    def eval_fn(p):
        return -jnp.mean(jnp.abs(p["w"]))

    key = jax.random.PRNGKey(9)
    base = characterize_protection(key, params, eval_fn, bers=[1e-3],
                                   n_trials=2, protects=("one4n",))
    multi = characterize_protection(
        key, params, eval_fn, bers=[1e-3], n_trials=2, protects=("one4n",),
        fault_models=("iid", "burst:rate=0.5,length=4"))
    assert [r.fault_model for r in base] == ["iid"]
    assert sorted({r.fault_model for r in multi}) == \
        ["burst:rate=0.5,length=4", "iid"]
    # the iid arm of the widened plan draws the same streams as the default
    iid_arm = [r for r in multi if r.fault_model == "iid"]
    assert [r.accuracies for r in iid_arm] == [r.accuracies for r in base]
    with pytest.raises(ValueError):
        sweep_lib.SweepPlan(bers=(1e-3,), fault_models=("bogus:x=1",))


# ----------------------------------------- sharded mask identity (slow)


def _run(tmp_path, name, script):
    path = tmp_path / name
    path.write_text(script)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, str(path)], capture_output=True,
                         text=True, env=env, cwd=os.getcwd(), timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_SHARDED_MODEL_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import align, cim
    from repro.core import faultmodels as fm

    key = jax.random.PRNGKey(31)
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 0.1
    w_al, _ = align.align_matrix(w, align.AlignmentConfig(8, 2))
    store = cim.pack(w_al, cim.CIMConfig(protect="one4n"))
    meshes = [jax.make_mesh((2,), ("model",)),
              jax.make_mesh((8,), ("model",)),
              jax.make_mesh((2, 4), ("data", "model"))]
    models = [fm.FaultProcess.burst(rate=0.4, length=4, axis="row"),
              fm.FaultProcess.burst(rate=0.4, length=8, axis="col"),
              dataclasses.replace(fm.FaultProcess.drift(drift_rate=0.3),
                                  tick=5),
              fm.FaultProcess.correlated(strength=0.7, period=4)]

    def plane_equal(a, b):
        for name, p in cim._plane_dict(a).items():
            q = cim._plane_dict(b)[name]
            assert (np.asarray(p) == np.asarray(q)).all(), name

    checked = 0
    for model in models:
        ref = cim.inject(key, store, 0.01, "full", model=model)
        assert any((np.asarray(p) != np.asarray(q)).any()
                   for p, q in zip(cim._plane_dict(store).values(),
                                   cim._plane_dict(ref).values()))
        for mesh in meshes:
            for dim in ("j", "k"):
                st = cim.shard_store(store, mesh, dim=dim)
                got = jax.jit(lambda k, s, m=mesh, d=dim, mo=model:
                              cim.inject_sharded(k, s, 0.01, "full",
                                                 mesh=m, dim=d, model=mo)
                              )(key, st)
                plane_equal(ref, got)
                checked += 1
    print(json.dumps({"checked": checked}))
""")


@pytest.mark.slow
def test_model_masks_identical_across_mesh_shapes(tmp_path):
    result = _run(tmp_path, "sharded_models.py", _SHARDED_MODEL_SCRIPT)
    assert result["checked"] == 4 * 3 * 2   # models x meshes x shard dims
