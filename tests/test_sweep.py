"""Vectorized sweep engine: equivalence with the loop harness + kernel route.

The contract under test (repro/core/sweep.py):

* the XLA backend reproduces the loop-based ``characterize_*_loop`` results
  trial-for-trial (identical PRNG stream -> identical corrupted weights);
* the trial-batched Pallas fault-inject route is bit-exact with its
  counter-PRNG oracle in interpret mode, stays confined to the target field,
  and matches the empirical flip rate of ``repro.core.fault.inject``;
* each arm compiles exactly once for a whole (BER x trial) plane.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops, cim, fault, resilience
from repro.core import sweep as sweep_lib
from repro.core.bitops import FP16
from repro.kernels.fault_inject import ops as fi_ops
from repro.kernels.fault_inject import ref as fi_ref

BERS = (1e-4, 1e-3, 1e-2)


def _params():
    return {"w1": jax.random.normal(jax.random.PRNGKey(1), (16, 24)) * 0.1,
            "w2": jax.random.normal(jax.random.PRNGKey(2), (24, 8)) * 0.1,
            "b": jnp.zeros((8,))}


def _smooth_eval():
    """NaN-tolerant smooth eval (tanh saturates corrupted activations)."""
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 16))

    def eval_fn(p):
        h = jnp.tanh(x @ p["w1"])
        return jnp.mean(jnp.tanh(h @ p["w2"] + p["b"]))
    return eval_fn


# -------------------------------------------------- loop/batched equivalence

def test_field_sweep_matches_loop():
    params, eval_fn = _params(), _smooth_eval()
    kw = dict(bers=BERS, fields=("exponent", "mantissa"), n_trials=4)
    loop = resilience.characterize_fields_loop(
        jax.random.PRNGKey(9), params, eval_fn, **kw)
    vec = resilience.characterize_fields(
        jax.random.PRNGKey(9), params, eval_fn, **kw)
    assert len(loop) == len(vec) == 6
    for a, b in zip(loop, vec):
        assert (a.ber, a.field, a.protect) == (b.ber, b.field, b.protect)
        np.testing.assert_allclose(a.accuracies, b.accuracies,
                                   atol=1e-6, equal_nan=True)


def test_protection_sweep_matches_loop():
    params, eval_fn = _params(), _smooth_eval()
    kw = dict(bers=BERS, n_trials=3, protects=("none", "one4n"))
    loop = resilience.characterize_protection_loop(
        jax.random.PRNGKey(5), params, eval_fn, **kw)
    vec = resilience.characterize_protection(
        jax.random.PRNGKey(5), params, eval_fn, **kw)
    for a, b in zip(loop, vec):
        assert (a.ber, a.protect) == (b.ber, b.protect)
        np.testing.assert_allclose(a.accuracies, b.accuracies,
                                   atol=1e-6, equal_nan=True)
        # ECC decode stats are integer counts -> must agree exactly
        assert a.corrected == pytest.approx(b.corrected)
        assert a.uncorrectable == pytest.approx(b.uncorrectable)


def test_engine_carries_key_across_arms():
    """Arms consume the key sequentially (loop-compat): re-running arm 2 alone
    with a fresh key must NOT reproduce its in-sequence accuracies."""
    params, eval_fn = _params(), _smooth_eval()
    both = resilience.characterize_fields(
        jax.random.PRNGKey(9), params, eval_fn, BERS,
        fields=("exponent", "mantissa"), n_trials=4)
    alone = resilience.characterize_fields(
        jax.random.PRNGKey(9), params, eval_fn, BERS,
        fields=("mantissa",), n_trials=4)
    mant_in_seq = [r for r in both if r.field == "mantissa"]
    assert any(not np.allclose(a.accuracies, b.accuracies)
               for a, b in zip(mant_in_seq, alone))


# -------------------------------------------------------- Pallas route

def test_batched_kernel_bit_exact_vs_oracle():
    bits = (jax.random.bits(jax.random.PRNGKey(0), (96, 48), jnp.uint32)
            & 0xFFFF).astype(jnp.uint16)
    seeds = jnp.asarray([3, 17, 123456], jnp.uint32)
    thr = jnp.uint32(int(round(0.02 * 2 ** 32)))
    pos = tuple(int(p) for p in FP16.field_bit_positions("exponent"))
    out = fi_ops.fault_inject_bits_batched(bits, seeds, thr, positions=pos,
                                           interpret=True)
    oracle = fi_ref.fault_inject_batched_ref(bits, seeds, thr, positions=pos)
    assert (np.asarray(out) == np.asarray(oracle)).all()
    # trial t of the batched call == static kernel at seed=seeds[t]
    single = fi_ref.fault_inject_ref(bits, seed=17, ber=0.02, positions=pos)
    assert (np.asarray(out[1]) == np.asarray(single)).all()


def test_counter_streams_independent_across_elements_32bit():
    """Bit p of element e must not reuse bit p-16 of element e+1's stream:
    the counter stride is 32 so fp32 'full' injection stays i.i.d."""
    bits = jnp.zeros((4, 64), jnp.uint32)
    thr = jnp.uint32(int(0.5 * 2 ** 32))
    out = fi_ref.fault_inject_batched_ref(bits, jnp.asarray([9], jnp.uint32),
                                          thr, positions=tuple(range(32)))
    mask = np.asarray(out[0]).reshape(-1)
    hi = (mask >> 16) & 0xFFFF
    lo = mask & 0xFFFF
    assert not (hi[:-1] == lo[1:]).all()


@pytest.mark.parametrize("field", ["sign", "exponent", "mantissa"])
def test_batched_inject_confined_to_field(field):
    params = {"w": jnp.full((64, 32), 2.0, jnp.float32)}
    seeds = jnp.arange(4, dtype=jnp.uint32)
    thr = fi_ops.ber_to_threshold(0.2)
    out = sweep_lib.inject_pytree_batched(params, seeds, thr, field,
                                          interpret=True)
    assert out["w"].shape == (4, 64, 32)
    xor = np.asarray(bitops.to_bits(out["w"]) ^
                     bitops.to_bits(params["w"])[None]).astype(np.uint32)
    allowed = np.zeros((), np.uint32)
    for p in FP16.field_bit_positions(field):
        allowed |= np.uint32(1 << p)
    assert (xor & ~allowed).max() == 0
    # distinct trials see distinct fault patterns
    assert not (xor[0] == xor[1]).all()


def test_batched_inject_flip_rate_matches_fault_model():
    """Counter-PRNG route hits the same Bernoulli(ber) rate as core.fault."""
    ber, n, t = 0.05, 2048, 4
    params = {"w": jnp.full((n, 16), 1.5, jnp.float32)}
    out = sweep_lib.inject_pytree_batched(
        params, jnp.arange(t, dtype=jnp.uint32),
        fi_ops.ber_to_threshold(ber), "full", interpret=True)
    xor = np.asarray(bitops.to_bits(out["w"]) ^ bitops.to_bits(params["w"])[None])
    rate = np.unpackbits(xor.view(np.uint8)).sum() / (t * n * 16 * 16)
    assert abs(rate - ber) < 5 * np.sqrt(ber * (1 - ber) / (t * n * 16 * 16))


def test_pallas_backend_protection_sweep_runs():
    """Full inject -> ECC-decode -> eval plane on the kernel route, with
    plausible ECC behavior (protected arm corrects rows at high BER)."""
    params, eval_fn = _params(), _smooth_eval()
    plan = sweep_lib.SweepPlan(bers=BERS, n_trials=3, backend="pallas",
                               interpret=True)
    res = sweep_lib.SweepEngine(plan).run_protection(
        jax.random.PRNGKey(12), params, eval_fn)
    assert len(res) == len(BERS) * 2
    one4n_hi = [r for r in res if r.protect == "one4n" and r.ber == 1e-2][0]
    assert one4n_hi.corrected > 0
    none_arm = [r for r in res if r.protect == "none"]
    assert all(r.corrected == 0 for r in none_arm)


# ------------------------------------------------------------ engine contract

def test_one_compile_per_arm():
    params, eval_fn = _params(), _smooth_eval()
    plan = sweep_lib.SweepPlan(bers=BERS, n_trials=4,
                               fields=("exponent", "mantissa"))
    engine = sweep_lib.SweepEngine(plan)
    engine.run_fields(jax.random.PRNGKey(0), params, eval_fn)
    compiles = engine.compiles()
    assert len(compiles) == 2
    assert all(c == 1 for c in compiles.values())
    # a second sweep on the same engine reuses the compiled executors
    engine.run_fields(jax.random.PRNGKey(1), params, eval_fn)
    assert all(c == 1 for c in engine.compiles().values())


def test_sharded_trials_layout():
    """The trial axis is placed on the ('trial',) mesh (no-op on 1 device,
    split placement on many) and the sweep still runs end to end."""
    params, eval_fn = _params(), _smooth_eval()
    plan = sweep_lib.SweepPlan(bers=BERS, n_trials=len(jax.devices()) * 2,
                               fields=("mantissa",), shard_trials=True)
    engine = sweep_lib.SweepEngine(plan)
    assert engine.mesh is not None
    assert engine.mesh.axis_names == ("trial",)
    res = engine.run_fields(jax.random.PRNGKey(0), params, eval_fn)
    assert len(res) == len(BERS)
    assert all(len(r.accuracies) == plan.n_trials for r in res)


def test_sweep_result_stable_shape():
    """SweepResult keeps the loop-era surface (benchmarks depend on it)."""
    r = sweep_lib.SweepResult(1e-3, "exponent", "raw", [0.5, 0.7])
    assert r.mean == pytest.approx(0.6)
    assert r.std == pytest.approx(0.1)
    assert resilience.SweepResult is sweep_lib.SweepResult


def test_plan_validation():
    with pytest.raises(ValueError):
        sweep_lib.SweepPlan(bers=(1e-3,), backend="cuda")
    # sequences normalize to tuples (hashable, and list-built plans compare
    # equal to tuple-built ones in the wrapper grid check)
    p = sweep_lib.SweepPlan(bers=[1e-3], fields=["exponent"], protects=["none"])
    assert p.fields == ("exponent",) and p.protects == ("none",)


def test_counter_space_guard():
    """Leaves beyond 2^27 elements would wrap the uint32 counter (correlated
    faults) — the kernel route refuses them instead."""
    import jax as _jax
    from repro.kernels.fault_inject import ops as _ops
    big = _jax.ShapeDtypeStruct((2 ** 14, 2 ** 14), jnp.uint16)
    with pytest.raises(ValueError, match="counter space"):
        _jax.eval_shape(
            lambda b: _ops.fault_inject_bits_batched(
                b, jnp.zeros((2,), jnp.uint32), jnp.uint32(1),
                positions=(0,), interpret=True), big)


def test_wrapper_rejects_conflicting_engine_grid():
    """Explicit grid arguments must not be silently ignored when a prebuilt
    engine describes a different grid."""
    params, eval_fn = _params(), _smooth_eval()
    engine = sweep_lib.SweepEngine(sweep_lib.SweepPlan(
        bers=BERS, n_trials=4, fields=("exponent",)))
    with pytest.raises(ValueError, match="engine.plan.bers"):
        resilience.characterize_fields(
            jax.random.PRNGKey(0), params, eval_fn, (1e-5,),
            fields=("exponent",), n_trials=4, engine=engine)
    # matching grid passes through
    res = resilience.characterize_fields(
        jax.random.PRNGKey(0), params, eval_fn, BERS,
        fields=("exponent",), n_trials=4, engine=engine)
    assert len(res) == len(BERS)
