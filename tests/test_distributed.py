"""Distribution substrate: sharding rules, sanitizer, and real multi-device
execution (subprocess with 8 forced host devices so the main test process
keeps its single-device view)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shlib


def test_param_spec_rules_no_mesh():
    # without a mesh every logical axis maps to None
    assert shlib.param_spec("layers/blk0/attn/wq", 2) == P(None, None)


def test_param_spec_rules_with_mesh_names():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    shlib.set_mesh(FakeMesh())
    try:
        assert shlib.param_spec("groups/blk0/attn/wq", 3) == P(None, "data", "model")
        assert shlib.param_spec("groups/blk0/attn/wo", 3) == P(None, "model", "data")
        assert shlib.param_spec("embed", 2) == P("model", "data")
        assert shlib.param_spec("unembed", 2) == P("data", "model")
        assert shlib.param_spec("groups/blk0/moe/moe_win", 4) == \
            P(None, "model", "data", None)
        assert shlib.param_spec("groups/blk0/norm1/scale", 2) == P(None, None)
        assert shlib.param_spec("groups/blk0/tmix/w_r", 3) == P(None, "data", "model")
        assert shlib.batch_axes() == ("pod", "data")
    finally:
        shlib.set_mesh(None)


def test_sanitize_spec_drops_nondivisible():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    m = FakeMesh()
    assert shlib.sanitize_spec(m, P("data", "model"), (32, 64)) == P("data", "model")
    assert shlib.sanitize_spec(m, P("data", "model"), (1, 8)) == P(None, None)
    assert shlib.sanitize_spec(m, P(("data", "model"), None), (256, 4)) == \
        P(("data", "model"), None)
    assert shlib.sanitize_spec(m, P(("data", "model"), None), (128, 4)) == P(None, None)


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from repro.configs import RunConfig, get_config
    from repro.core.api import ReliabilityConfig
    from repro.data.synthetic import batches_for, MarkovLM
    from repro.distributed import sharding as shlib
    from repro.launch import specs
    from repro.launch.mesh import make_host_mesh
    from repro.training import steps

    assert len(jax.devices()) == 8
    mesh = make_host_mesh(model_axis=4)          # (2 data, 4 model)
    cfg = get_config("olmo-1b").reduced()
    run = RunConfig(arch="olmo-1b", steps=4, remat=False,
                    reliability=ReliabilityConfig(mode="align"))
    shlib.set_mesh(mesh)
    with mesh:
        state = steps.init_train_state(jax.random.PRNGKey(0), cfg, run)
        st_sh = specs.state_shardings(mesh, jax.eval_shape(lambda: state))
        state = jax.device_put(state, st_sh)
        step = jax.jit(steps.make_train_step(cfg, run),
                       in_shardings=(st_sh, None), out_shardings=(st_sh, None),
                       donate_argnums=(0,))
        data = MarkovLM(cfg.vocab_size, 64, 8, seed=0)
        losses = []
        for i in range(3):
            state, metrics = step(state, data.batch(i))
            losses.append(float(metrics["loss"]))
        wq = state.params["groups"]["blk0"]["attn"]["wq"]
        n_shards = len(wq.sharding.device_set)
        print(json.dumps({"losses": losses, "wq_shards": n_shards}))
""")


@pytest.mark.slow
def test_multidevice_training_step(tmp_path):
    script = tmp_path / "multidev.py"
    script.write_text(_MULTIDEV_SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, env=env, cwd=os.getcwd(), timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(l == l and l < 1e4 for l in result["losses"])  # finite
    assert result["losses"][-1] <= result["losses"][0]
    assert result["wq_shards"] == 8


_RESHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    import jax.numpy as jnp
    from repro.configs import RunConfig, get_config
    from repro.data.synthetic import MarkovLM
    from repro.distributed import checkpoint as ckpt
    from repro.distributed import sharding as shlib
    from repro.launch import specs
    from repro.training import steps

    ckdir = sys.argv[1]
    cfg = get_config("olmo-1b").reduced()
    run = RunConfig(arch="olmo-1b", steps=2, remat=False)
    # Phase 1: train on a (4, 2) mesh, checkpoint.
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    shlib.set_mesh(mesh_a)
    with mesh_a:
        state = steps.init_train_state(jax.random.PRNGKey(0), cfg, run)
        sh_a = specs.state_shardings(mesh_a, jax.eval_shape(lambda: state))
        state = jax.device_put(state, sh_a)
        step = jax.jit(steps.make_train_step(cfg, run))
        data = MarkovLM(cfg.vocab_size, 32, 4, seed=0)
        state, m1 = step(state, data.batch(0))
        ckpt.save(state, 1, ckdir)

    # Phase 2: "two hosts failed" -> shrink to a (2, 2) mesh, restore, resume.
    mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                           devices=jax.devices()[:4])
    shlib.set_mesh(mesh_b)
    with mesh_b:
        abstract = jax.eval_shape(
            lambda: steps.init_train_state(jax.random.PRNGKey(0), cfg, run))
        sh_b = specs.state_shardings(mesh_b, abstract)
        restored, step_no = ckpt.restore(abstract, ckdir, shardings=sh_b)
        step_b = jax.jit(steps.make_train_step(cfg, run))
        state2, m2 = step_b(restored, data.batch(1))
        print(json.dumps({"resumed_step": step_no,
                          "loss": float(m2["loss"]),
                          "devices": len(jax.tree_util.tree_leaves(
                              state2.params)[0].sharding.device_set)}))
""")


@pytest.mark.slow
def test_elastic_reshard_restore(tmp_path):
    script = tmp_path / "reshard.py"
    script.write_text(_RESHARD_SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, str(script), str(tmp_path / "ck")],
                         capture_output=True, text=True, env=env,
                         cwd=os.getcwd(), timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["resumed_step"] == 1
    assert result["loss"] < 1e4
    assert result["devices"] == 4


_A2A_MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.distributed import sharding as shlib
    from repro.models import moe as moe_lib

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shlib.set_mesh(mesh)
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    cfg = dataclasses.replace(cfg, d_model=64, n_experts=8, top_k=2,
                              d_ff_expert=32, capacity_factor=8.0)
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
    with mesh:
        p_sh = {"router": NamedSharding(mesh, P(None, None)),
                "moe_win": NamedSharding(mesh, P("model", None, None)),
                "moe_wgate": NamedSharding(mesh, P("model", None, None)),
                "moe_wout": NamedSharding(mesh, P("model", None, None))}
        params = jax.device_put(params, p_sh)
        x = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
        outs = {}
        for mode in ("sort", "a2a"):
            c = dataclasses.replace(cfg, moe_dispatch=mode)
            out, aux = jax.jit(lambda p, xx, c=c: moe_lib.apply_moe(p, c, xx))(params, x)
            outs[mode] = (np.asarray(out), float(aux))
    diff = float(np.abs(outs["sort"][0] - outs["a2a"][0]).max())
    print(json.dumps({"max_diff": diff,
                      "aux_sort": outs["sort"][1], "aux_a2a": outs["a2a"][1]}))
""")


@pytest.mark.slow
def test_a2a_moe_matches_dense_dispatch(tmp_path):
    """shard_map all-to-all EP dispatch == GSPMD dense dispatch (no drops at
    high capacity factor), on a real 2x4 device mesh."""
    script = tmp_path / "a2a_moe.py"
    script.write_text(_A2A_MOE_SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, env=env, cwd=os.getcwd(), timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["max_diff"] < 1e-4, result
    # aux: a2a computes per-device load-balance statistics (Switch-style
    # local aux) vs the dense dispatch's global statistics — close, not equal
    assert abs(result["aux_sort"] - result["aux_a2a"]) < 0.3 * result["aux_sort"]
