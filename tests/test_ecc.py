import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # minimal installs: degrade to fixed-example sampling
    HAVE_HYPOTHESIS = False

from repro.core.ecc import MAX_SEGMENT_DATA_BITS, One4NRowCodec, SecdedCode, \
    secded_redundant_bits


@pytest.mark.parametrize("d", [6, 10, 32, 72, 84, 96, 104, 160])
def test_clean_roundtrip(d):
    rng = np.random.default_rng(d)
    code = SecdedCode(d)
    data = jnp.asarray(rng.integers(0, 2, (8, d)), jnp.uint8)
    out, status = code.decode(code.encode(data))
    assert (np.asarray(out) == np.asarray(data)).all()
    assert (np.asarray(status) == 0).all()


def _single_flip_case(seed, d, pos_frac):
    """SECDED property: every single-bit flip (data, parity or overall bit)
    is corrected — the paper's case (ii)."""
    rng = np.random.default_rng(seed)
    code = SecdedCode(d)
    data = jnp.asarray(rng.integers(0, 2, (1, d)), jnp.uint8)
    cw = code.encode(data)
    pos = min(int(pos_frac * code.n), code.n - 1)
    cw = cw.at[0, pos].set(1 - cw[0, pos])
    out, status = code.decode(cw)
    assert (np.asarray(out) == np.asarray(data)).all()
    assert int(status[0]) == 1


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=10 ** 9),
           st.sampled_from([6, 96, 104]),
           st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=60, deadline=None)
    def test_any_single_flip_corrected(seed, d, pos_frac):
        _single_flip_case(seed, d, pos_frac)
else:
    @pytest.mark.parametrize("seed", [0, 1, 99, 10 ** 9])
    @pytest.mark.parametrize("d", [6, 96, 104])
    @pytest.mark.parametrize("pos_frac", [0.0, 0.37, 0.99])
    def test_any_single_flip_corrected(seed, d, pos_frac):
        _single_flip_case(seed, d, pos_frac)


def _double_flip_case(seed, f1, f2):
    """Every 2-bit flip is flagged uncorrectable — the paper's case (iii)."""
    rng = np.random.default_rng(seed)
    code = SecdedCode(104)
    data = jnp.asarray(rng.integers(0, 2, (1, 104)), jnp.uint8)
    cw = code.encode(data)
    p1 = min(int(f1 * code.n), code.n - 1)
    p2 = min(int(f2 * code.n), code.n - 1)
    if p1 == p2:
        return
    for p in (p1, p2):
        cw = cw.at[0, p].set(1 - cw[0, p])
    _, status = code.decode(cw)
    assert int(status[0]) == 2


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=10 ** 9),
           st.floats(min_value=0.0, max_value=1.0),
           st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=60, deadline=None)
    def test_any_double_flip_detected(seed, f1, f2):
        _double_flip_case(seed, f1, f2)
else:
    @pytest.mark.parametrize("seed", [0, 7, 10 ** 9])
    @pytest.mark.parametrize("f1,f2", [(0.0, 0.99), (0.1, 0.5), (0.42, 0.43)])
    def test_any_double_flip_detected(seed, f1, f2):
        _double_flip_case(seed, f1, f2)


def test_paper_redundancy_counts():
    """Every redundant-bit count quoted in the paper (§III-A2, §III-B1, Tab III)."""
    assert secded_redundant_bits(6) == 5      # naive per-weight sign+exp
    assert secded_redundant_bits(10) == 5     # per-weight mantissa
    assert secded_redundant_bits(96) == 8     # unified 16-weight row
    assert secded_redundant_bits(104) == 8    # One4N N=8 half-payload
    assert secded_redundant_bits(160) == 9    # row of 16 mantissas


def test_one4n_paper_layout_n8():
    codec = One4NRowCodec(n_group=8)
    assert codec.payload_bits == 5 * 16 + 8 * 16 == 208     # Eq. 3
    assert codec.n_segments == 2                            # "two rows"
    assert codec.segment_bits == 104
    assert codec.redundant_bits_per_block == 16             # 8 + 8
    # 256x256 array: 256 rows / 8 = 32 blocks -> 512 redundant bits (Table III)
    assert 32 * codec.redundant_bits_per_block == 512


@pytest.mark.parametrize("n", [4, 8, 16])
def test_one4n_roundtrip_and_correction(n):
    rng = np.random.default_rng(n)
    codec = One4NRowCodec(n_group=n)
    exp_row = jnp.asarray(rng.integers(0, 32, (3, 2, 16)), jnp.uint8)
    signs = jnp.asarray(rng.integers(0, 2, (3, 2, n, 16)), jnp.uint8)
    cw = codec.encode(exp_row, signs)
    assert cw.shape[-2:] == (codec.n_segments, codec.code.n)
    e2, s2, status = codec.decode(cw)
    assert (np.asarray(e2) == np.asarray(exp_row)).all()
    assert (np.asarray(s2) == np.asarray(signs)).all()
    assert (np.asarray(status) == 0).all()
    # flip one bit in every segment -> still decodes exactly
    cw = cw.at[..., 11].set(1 - cw[..., 11])
    e3, s3, status = codec.decode(cw)
    assert (np.asarray(e3) == np.asarray(exp_row)).all()
    assert (np.asarray(s3) == np.asarray(signs)).all()
    assert (np.asarray(status) == 1).all()


def test_syndrome_semantics_r7():
    """Fig. 4 ③: R[7] (overall parity) distinguishes 1-flip from 2-flip."""
    code = SecdedCode(104)
    data = jnp.zeros((1, 104), jnp.uint8)
    cw = code.encode(data)
    _, st1 = code.decode(cw.at[0, 5].set(1))
    _, st2 = code.decode(cw.at[0, 5].set(1).at[0, 9].set(1))
    assert int(st1[0]) == 1 and int(st2[0]) == 2
