"""End-to-end behaviour of the paper's system: train -> align -> CIM deploy ->
inject -> ECC -> evaluate, plus serving-path integration (BFP kernel) and the
closed-form residual-BER model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config
from repro.core import align, cim, ecc
from repro.core.api import ReliabilityConfig
from repro.data.synthetic import MarkovLM
from repro.models import lm
from repro.models.losses import lm_loss
from repro.training.loop import run_training


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("olmo-1b").reduced()
    data = MarkovLM(cfg.vocab_size, 48, 8, seed=3)
    rel = ReliabilityConfig(mode="align", n_group=8, index=2)
    run = RunConfig(arch="olmo-1b", steps=60, checkpoint_dir="", remat=False,
                    learning_rate=1e-3, reliability=rel)
    state, hist, _ = run_training(cfg, run, iter(data))
    batch = data.batch(777)

    def eval_fn(params):
        logits, _, _ = lm.forward(params, cfg, batch, remat=False)
        return float(lm_loss(logits, batch["labels"])[1]["accuracy"])

    return cfg, state, eval_fn, hist


def test_aligned_training_learns(trained):
    _, _, _, hist = trained
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1


def test_trained_params_stay_aligned(trained):
    cfg, state, _, _ = trained
    w = state.params["unembed"]
    from repro.core import bitops
    _, e, _ = bitops.split_fields(w)
    e = np.asarray(e).reshape(-1, 8, w.shape[1])
    assert (e == e[:, :1]).all(), "frozen-exponent training kept blocks aligned"


def test_e2e_protection_pipeline(trained):
    """The paper's headline at smoke scale: at a damaging BER, One4N keeps
    accuracy; unprotected deployment loses it."""
    cfg, state, eval_fn, _ = trained
    clean = eval_fn(state.params)
    key = jax.random.PRNGKey(5)
    accs = {}
    for protect in ("one4n", "none"):
        from repro import CIMDeployment, PolicyRule, ReliabilityPolicy
        policy = ReliabilityPolicy(default=PolicyRule(
            protect=protect, n_group=8, index=2))
        dep = CIMDeployment.deploy(state.params, policy)
        vals = []
        for t in range(3):
            restored, _ = dep.inject(jax.random.fold_in(key, t), 1e-4).read()
            vals.append(eval_fn(restored))
        accs[protect] = float(np.mean(vals))
    assert accs["one4n"] >= clean - 0.08
    assert accs["one4n"] > accs["none"]


def test_serve_with_bfp_kernel_matches_dense(trained):
    """cim_linear (Pallas bfp_matmul) == dense matmul on aligned weights."""
    from repro.kernels.bfp_matmul import ops as bfp_ops
    from repro.kernels.bfp_matmul import ref as bfp_ref
    cfg, state, _, _ = trained
    w = jnp.asarray(state.params["unembed"], jnp.float32)   # aligned by training
    k = w.shape[0] - (w.shape[0] % 8)
    w = w[:k]
    man, exp = bfp_ref.pack_bfp(w, 8)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, k))
    out = bfp_ops.cim_linear(x, man, exp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=2e-4, atol=2e-4)


def test_residual_ber_model_matches_montecarlo():
    """Closed-form post-SECDED residual rate vs bit-accurate simulation."""
    rng = np.random.default_rng(0)
    code = ecc.SecdedCode(104)
    p = 5e-3
    n_words, n = 4000, code.n
    data = jnp.asarray(rng.integers(0, 2, (n_words, 104)), jnp.uint8)
    cw = code.encode(data)
    flips = jnp.asarray(rng.random((n_words, n)) < p, jnp.uint8)
    out, _ = code.decode(cw ^ flips)
    err_rate = float(jnp.mean(out != data))
    pred = ecc.residual_ber_after_secded(p, n)
    assert err_rate == pytest.approx(pred, rel=0.5)


def test_int8_kv_cache_close_to_bf16():
    cfg = get_config("granite-3-8b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    from repro.training import steps as steps_lib
    toks = jnp.arange(2, dtype=jnp.int32)[:, None] % cfg.vocab_size
    outs = {}
    for mode in ("compute", "int8"):
        c = dataclasses.replace(cfg, kv_cache_dtype=mode)
        caches = lm.init_slot_states(c, 2, 16, prefilled=0)
        serve = jax.jit(steps_lib.make_serve_step(c))
        logits = None
        for i in range(4):
            logits, caches = serve(params, caches, toks)
        outs[mode] = np.asarray(jax.nn.softmax(logits))
    assert np.abs(outs["compute"] - outs["int8"]).max() < 0.05
