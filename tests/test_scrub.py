"""Online ECC scrubbing (``repro.launch.scrub``) + the engine's scrub hooks.

Acceptance contracts:

* a drift-aging soak with scrubbing enabled accumulates **strictly fewer**
  cumulative uncorrectable ECC events than the identical soak (same key,
  same wear stream) with scrubbing off, while every request still completes
  with finite logits and scrub events are logged with accounting;
* ``RequestResult.to_json`` carries the per-request ``ecc_window`` time
  series (one row per decode chunk: reads/corrected/uncorrectable);
* a scrub resets the scrubbed store's cumulative ``store_ecc`` counters and
  drops the prefix cache (the PR-6 invalidation contract: a hot-swapped
  image must not serve stale KV);
* ``Fleet.aggregate`` rolls replica scrub summaries up.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import cim
from repro.core import faultmodels as fm
from repro.launch import engine as engine_lib
from repro.launch import scrub as scrub_lib
from repro.launch import serve as serve_lib
from repro.models import lm

CHUNK = 8
MAX_LEN = 24


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("olmo-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    dkey = jax.random.fold_in(key, 1)
    dep = serve_lib.make_deployment(params, ber=0.0, protect="one4n",
                                    n_group=8, index=2, key=dkey,
                                    inject_mode="static", field="full")
    return cfg, dep


def _requests(n=4, seed=5):
    load = engine_lib.LoadGen(n_requests=n, prompt_lens=(3, 12),
                              gen_lens=(4, 6), vocab_size=256, seed=seed)
    return load.requests()


def _soak(cfg, dep, *, scrub: bool, n=4):
    """One drift-aging engine run -> (engine, results, aggregate)."""
    aging = scrub_lib.DriftAging(key=jax.random.PRNGKey(77), ber=1e-3,
                                 model=fm.FaultProcess.drift(drift_rate=0.2))
    policy = scrub_lib.ScrubPolicy(threshold=4, interval=1)
    ctl = scrub_lib.ScrubController(dep, policy if scrub else
                                    scrub_lib.ScrubPolicy(threshold=10**9),
                                    aging=aging, serving_kw={})
    # the unscrubbed arm is ALLOWED to rot into non-finite logits — that
    # divergence is the vulnerability scrubbing averts, so don't raise on it
    eng = engine_lib.Engine(cfg, dep.serving_params(), n_slots=2,
                            max_len=MAX_LEN, chunk=CHUNK,
                            collect_logits=True, check_finite=False)
    results, agg = eng.run(_requests(n), on_step=ctl)
    assert sorted(results) == list(range(n))
    return eng, results, agg


def test_scrub_on_beats_scrub_off(setup):
    cfg, dep = setup
    _, res_off, agg_off = _soak(cfg, dep, scrub=False)
    _, res_on, agg_on = _soak(cfg, dep, scrub=True)

    # same wear stream either way; scrubbing strictly reduces cumulative
    # uncorrectable events (the self-healing acceptance bound)
    assert agg_off["scrub"]["events"] == 0
    assert agg_on["scrub"]["events"] > 0
    assert agg_on["scrub"]["rows_reencoded"] > 0
    assert agg_on["ecc"]["uncorrectable"] < agg_off["ecc"]["uncorrectable"]
    assert agg_off["ecc"]["uncorrectable"] > 0   # the soak actually wears

    # every request completes; the self-healing arm keeps its logits finite
    for res in (res_on, res_off):
        for r in res.values():
            assert len(r.tokens) >= 1
    for r in res_on.values():
        assert all(np.isfinite(np.asarray(lg)).all() for lg in r.logits)

    # per-scrub accounting is populated
    for ev in agg_on["scrub"], :
        assert ev["wall_s"] > 0
        assert ev["corrected_cleared"] + ev["uncorrectable_cleared"] > 0


def test_ecc_window_in_request_json(setup):
    cfg, dep = setup
    _, results, _ = _soak(cfg, dep, scrub=True, n=2)
    for r in results.values():
        j = r.to_json()
        assert j["ecc_window"], "per-request ECC time series missing"
        for row in j["ecc_window"]:
            assert set(row) == {"pos", "reads", "corrected", "uncorrectable"}
            assert all(isinstance(v, int) for v in row.values())
        assert sum(w["reads"] for w in j["ecc_window"]) == j["ecc"]["reads"]
        assert sum(w["corrected"] for w in j["ecc_window"]) == \
            j["ecc"]["corrected"]
        assert isinstance(j["scrubs"], int)


def test_record_scrub_resets_store_counters(setup):
    cfg, dep = setup
    eng = engine_lib.Engine(cfg, dep.serving_params(), n_slots=2,
                            max_len=MAX_LEN, chunk=CHUNK,
                            prefix_cache=True)
    eng.run(_requests(2))
    assert any(v["reads"] > 0 for v in eng.store_ecc.values())
    path = next(iter(eng.store_ecc))
    eng.store_ecc[path]["corrected"] = 7
    eng.record_scrub({"paths": [path], "rows": 1, "corrected_cleared": 7,
                      "uncorrectable_cleared": 0, "wall_s": 0.0})
    assert eng.store_ecc[path] == {"reads": 0, "corrected": 0,
                                   "uncorrectable": 0}
    assert eng.aggregate()["scrub"]["events"] == 1

    # refresh_params(force=True) mid-flight is allowed and drops the prefix
    # cache — the hot-swap invalidation contract
    eng.prefix_cache.insert(None, [1, 2, 3, 4],
                            jax.tree_util.tree_map(lambda x: x,
                                                   eng.caches), 0)
    assert len(eng.prefix_cache) > 0
    eng.refresh_params(dep.serving_params(), force=True)
    assert len(eng.prefix_cache) == 0


def test_scrub_controller_reencodes_exactly(setup):
    """Scrubbing a damaged image restores bit-exact clean planes when every
    row is still correctable (single-bit hits only heal perfectly)."""
    _, dep = setup
    clean = {p: s for p, _, s in dep.store_leaves()}
    damaged = dep.inject(jax.random.PRNGKey(3), 5e-4, field="exponent_sign")
    pre = {p: cim.store_stats(s) for p, _, s in damaged.store_leaves()}
    assert any(int(st["corrected"]) > 0 for st in pre.values())
    ctl = scrub_lib.ScrubController(damaged)
    ev = ctl.scrub(list(clean))
    assert set(ev["paths"]) == set(clean)
    for p, _, s in ctl.dep.store_leaves():
        if int(pre[p]["uncorrectable"]) == 0:
            for name, plane in cim._plane_dict(clean[p]).items():
                got = cim._plane_dict(s)[name]
                assert (np.asarray(plane) == np.asarray(got)).all(), (p, name)


def test_policy_and_aging_validation():
    with pytest.raises(ValueError):
        scrub_lib.ScrubPolicy(threshold=0)
    with pytest.raises(ValueError):
        scrub_lib.ScrubPolicy(interval=0)
    with pytest.raises(ValueError):
        scrub_lib.DriftAging(key=jax.random.PRNGKey(0), ber=1e-3, every=0)
    pol = scrub_lib.ScrubPolicy(threshold=3)
    assert pol.due({"a": {"corrected": 2, "uncorrectable": 1},
                    "b": {"corrected": 0, "uncorrectable": 0}}) == ["a"]


def test_fleet_aggregate_scrub_rollup(setup):
    cfg, dep = setup
    from repro.launch import fleet as fleet_lib
    fl = fleet_lib.Fleet.from_serving_params(
        cfg, dep.serving_params(), n_replicas=1, n_slots=2,
        max_len=MAX_LEN, chunk=CHUNK)
    fl.run(_requests(2))
    agg = fl.aggregate()
    assert set(agg["scrub"]) == {"events", "rows_reencoded",
                                 "corrected_cleared",
                                 "uncorrectable_cleared", "wall_s"}
    assert agg["scrub"]["events"] == 0
