"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train step + one decode step on CPU; asserts shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, RunConfig, get_config, list_archs
from repro.data.synthetic import batches_for
from repro.models import lm
from repro.training import steps

ARCHS = list_archs()


def _small_batch(cfg, b=2, s=32):
    return batches_for(cfg, SHAPES["train_4k"], batch_override=b, seq_override=s)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _small_batch(cfg)
    logits, aux, _ = lm.forward(params, cfg, batch, remat=False)
    b = batch["labels"].shape[0]
    s = batch["labels"].shape[1]
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_config(arch).reduced()
    run = RunConfig(arch=arch, steps=4, remat=False)
    state = steps.init_train_state(jax.random.PRNGKey(0), cfg, run)
    train_step = jax.jit(steps.make_train_step(cfg, run))
    batch = _small_batch(cfg)
    state, metrics = train_step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(state.params)[0]
    assert l0.dtype == jnp.float32


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    b, cache_len = 2, 32
    caches = lm.init_slot_states(cfg, b, cache_len, prefilled=cache_len - 1)
    toks = jnp.zeros((b, 1), jnp.int32)
    serve = jax.jit(steps.make_serve_step(cfg))
    logits, new_caches = serve(params, caches, toks)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(new_caches["pos"]) == cache_len


@pytest.mark.parametrize("arch", ["olmo-1b", "rwkv6-1.6b", "recurrentgemma-9b"])
def test_prefill_then_decode_consistency(arch):
    """Prefill(S) then decode(S+1) must match forward over S+1 tokens."""
    cfg = get_config(arch).reduced()
    params = lm.init_lm(jax.random.PRNGKey(1), cfg)
    b, s = 2, 32
    batch = _small_batch(cfg, b=b, s=s + 1)
    logits_all, _, _ = lm.forward(params, cfg, batch, remat=False)

    if cfg.modality == "text":
        pre_batch = {"tokens": batch["tokens"][:, :s]}
        last_tok = batch["tokens"][:, s:s + 1]
    else:
        pytest.skip("stub modalities covered elsewhere")
    logits_pre, caches = lm.prefill(params, cfg, pre_batch)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_all[:, s - 1]),
                               rtol=2e-4, atol=2e-4)

    # grow attention caches to hold one more token
    def grow(c):
        def pad(a):
            if a.ndim >= 2 and a.shape[-3:-2] == (s,):  # kv caches [..., S, K, hd]
                pad_width = [(0, 0)] * a.ndim
                pad_width[-3] = (0, 8)
                return jnp.pad(a, pad_width)
            return a
        return jax.tree_util.tree_map(pad, c)

    caches = grow(caches)
    logits_dec, _ = lm.decode(params, cfg, caches, last_tok)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_all[:, s]),
                               rtol=2e-4, atol=2e-4)


def test_moe_aux_loss_nonzero():
    cfg = get_config("dbrx-132b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _small_batch(cfg)
    _, aux, _ = lm.forward(params, cfg, batch, remat=False)
    assert float(aux) > 0.0


def test_vlm_loss_masks_vision_positions():
    cfg = get_config("internvl2-76b").reduced()
    batch = _small_batch(cfg, b=2, s=32)
    assert batch["labels"].shape == (2, 32)
    assert (np.asarray(batch["labels"][:, :cfg.n_prefix_embeds]) == -100).all()


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (l, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (l, d, h, kv, ff, v), arch
    assert get_config("qwen3-moe-235b-a22b").n_experts == 128
    assert get_config("qwen3-moe-235b-a22b").top_k == 8
    assert get_config("dbrx-132b").n_experts == 16
    assert get_config("dbrx-132b").top_k == 4
    assert get_config("recurrentgemma-9b").block_pattern == ("rec", "rec", "local")


def test_attn_impl_variants_equivalent_on_host():
    """cp vs tp attention and fsdp vs tp MLP are sharding-layout changes:
    same math up to einsum reassociation (grouped vs merged-head contraction
    order), so allclose — the bit-exact check is the MoE dispatch one."""
    import dataclasses
    cfg = get_config("granite-3-8b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _small_batch(cfg)
    outs = []
    for attn, mlp in (("cp", "fsdp"), ("tp", "tp")):
        c = dataclasses.replace(cfg, attn_impl=attn, mlp_impl=mlp)
        logits, _, _ = lm.forward(params, c, batch, remat=False)
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-5, atol=2e-5)
