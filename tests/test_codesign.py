"""Co-design loop tests: the policy-native training API, the exponent-
compression regularizer, resilience-aware fine-tuning, automatic policy
search — and the counter-PRNG contract that training fault streams are
bit-identical on 1 device and a forced-8-device ("data","model") mesh.
"""
import json
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config
from repro.core.api import ReliabilityConfig
from repro.core.deployment import PolicyRule, ReliabilityPolicy
from repro.data.synthetic import MarkovLM
from repro.training.codesign import (AccuracySLO, Finetuner, PolicySearch,
                                     SearchSpace)
from repro.training.loop import TrainResult, make_fault_schedule, run_training


def _leaves_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _params():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    f16 = lambda k, s: jnp.asarray(
        jnp.asarray(jax.random.normal(k, s) * 0.1, jnp.float16), jnp.float32)
    return {"embed": f16(ks[0], (64, 32)), "unembed": f16(ks[1], (32, 64)),
            "mlp": {"w1": f16(ks[2], (32, 32))}, "norm": jnp.ones((32,))}


# ------------------------------------------------------ policy-native API

def test_policy_native_run_matches_legacy_reliability_streams():
    """RunConfig(policy=uniform) compiles into the legacy schedule
    bit-compatibly: identical per-leaf fault streams for the same key."""
    new = RunConfig(policy=ReliabilityPolicy(), ber=1e-3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = RunConfig(reliability=ReliabilityConfig(
            mode="cim", ber=1e-3, protect="one4n", inject="dynamic"))
    c_new, c_old = make_fault_schedule(new), make_fault_schedule(old)
    params = _params()
    for step in (0, 1, 7):
        key = jax.random.fold_in(jax.random.PRNGKey(17), step)
        assert _leaves_equal(c_new(params, key), c_old(params, key))


def test_runconfig_rejects_policy_and_reliability_together():
    with pytest.raises(ValueError, match="not both"):
        RunConfig(policy=ReliabilityPolicy(),
                  reliability=ReliabilityConfig(mode="cim", ber=1e-3))
    with pytest.raises(TypeError, match="ReliabilityPolicy"):
        RunConfig(policy=ReliabilityConfig(mode="cim"))


def test_legacy_reliability_path_warns_and_unpacks():
    cfg = get_config("olmo-1b").reduced()
    data = MarkovLM(cfg.vocab_size, 8, 2, seed=0)
    run = RunConfig(arch="olmo-1b", steps=1, checkpoint_dir="", remat=False,
                    reliability=ReliabilityConfig(mode="cim", ber=1e-3,
                                                  protect="one4n",
                                                  inject="dynamic"))
    with pytest.warns(DeprecationWarning, match="deprecated"):
        res = run_training(cfg, run, iter(data))
    # tuple-unpacking compat shim
    state, history, info = res
    assert state is res.state and history is res.history
    assert len(history) == 1 and "resumed_from" in info


def test_train_result_deployment_and_ecc_stats():
    cfg = get_config("olmo-1b").reduced()
    data = MarkovLM(cfg.vocab_size, 8, 2, seed=0)
    policy = ReliabilityPolicy(
        rules=(PolicyRule("embed", protect="one4n"),
               PolicyRule("unembed", protect="none")))
    run = RunConfig(arch="olmo-1b", steps=2, checkpoint_dir="", remat=False,
                    policy=policy, ber=1e-3)
    res = run_training(cfg, run, iter(data))
    assert isinstance(res, TrainResult)
    assert np.isfinite(res.final_loss)
    dep = res.deployment
    assert dep is not None and dep.policy is policy
    stats = res.ecc_stats
    assert stats["stored_bits"] > 0 and stats["raw_bits"] > 0
    # shared block exponents store fewer cells than raw fp16, so overhead
    # vs raw is typically negative; it is a ratio in (-1, 1)
    assert -1.0 < stats["overhead"] < 1.0
    # off-mode runs have no deployment
    off = run_training(cfg, RunConfig(arch="olmo-1b", steps=1,
                                      checkpoint_dir="", remat=False),
                       iter(data))
    assert off.deployment is None and off.ecc_stats == {}


# ------------------------------------------------------------- regularizer

def test_exponent_spread_penalty_orders_spread():
    from repro.models.losses import exponent_spread_penalty
    key = jax.random.PRNGKey(0)
    tight = jax.random.uniform(key, (64, 64), minval=0.5, maxval=1.0)
    spread = tight * jnp.exp2(
        jax.random.randint(jax.random.fold_in(key, 1), (64, 64), -6, 7)
        .astype(jnp.float32))
    p_tight = float(exponent_spread_penalty(tight, n_group=8, margin=1.0))
    p_spread = float(exponent_spread_penalty(spread, n_group=8, margin=1.0))
    assert p_tight < 1e-6          # within one octave -> inside the margin
    assert p_spread > 1.0          # many octaves of in-block spread
    g = jax.grad(lambda w: exponent_spread_penalty(w, 8, 1.0))(spread)
    assert np.isfinite(np.asarray(g)).all() and float(jnp.abs(g).sum()) > 0


def test_exponent_compression_penalty_follows_policy():
    from repro.models.losses import exponent_compression_penalty
    params = _params()
    spread = jax.tree_util.tree_map(
        lambda w: w * jnp.exp2(jnp.arange(w.size, dtype=jnp.float32)
                               .reshape(w.shape) % 13 - 6), params)
    on = exponent_compression_penalty(spread, ReliabilityPolicy())
    off = exponent_compression_penalty(
        spread, ReliabilityPolicy(default=PolicyRule(deploy=False)))
    assert float(on) > 0.1
    assert float(off) == 0.0


# --------------------------------------------------------------- Finetuner

def test_finetuner_smoke_trains_through_deployment():
    cfg = get_config("olmo-1b").reduced()
    data = MarkovLM(cfg.vocab_size, 8, 2, seed=0)
    ft = Finetuner(cfg, ReliabilityPolicy(), ber=1e-3, reshape_steps=2,
                   aligned_steps=2, exp_reg_coef=5e-2, seed=0, mesh=None)
    res = ft.run(iter(data))
    losses = [h["loss"] for h in res.info["reshape"]["history"]] + \
        [h["loss"] for h in res.history]
    assert len(losses) == 4 and np.isfinite(losses).all()
    # stage 1 carries the regularizer metric; stage 2 deploys
    assert "exp_penalty" in res.info["reshape"]["history"][0]
    assert res.deployment is not None
    assert res.ecc_stats["stored_bits"] > 0
    # reshape_steps=0 skips stage 1
    res2 = Finetuner(cfg, ReliabilityPolicy(), reshape_steps=0,
                     aligned_steps=1, mesh=None).run(iter(data))
    assert res2.info["reshape"]["history"] == []


# ------------------------------------------------------------ PolicySearch

def _search_fixture():
    """Two 64x64 leaves; only "a" matters to the eval. Exponent/sign-only
    injection at 3e-3 (calibrated): One4N holds ~0.993 accuracy, unprotected
    ~0.979 — a 0.986 floor separates them by ~3 sigma either side."""
    key = jax.random.PRNGKey(0)
    ka, kb, ks = jax.random.split(key, 3)
    mag = jax.random.uniform(ka, (64, 64), minval=0.5, maxval=1.0)
    sign = jnp.where(jax.random.bernoulli(ks, 0.5, (64, 64)), 1.0, -1.0)
    a0 = jnp.asarray(jnp.asarray(mag * sign, jnp.float16), jnp.float32)
    params = {"a": a0, "b": jax.random.normal(kb, (64, 64))}

    def eval_fn(p):
        return jnp.mean((jnp.abs(p["a"] - a0) < 0.6 * jnp.abs(a0) + 1e-3)
                        .astype(jnp.float32))

    return params, eval_fn


def test_policy_search_finds_cheapest_protection():
    params, eval_fn = _search_fixture()
    space = SearchSpace(groups=(("a", "a"), ("b", "b")),
                        protects=("none", "one4n"),
                        fields=("exponent_sign",))
    slo = AccuracySLO(ber=3e-3, max_drop=0.014)
    search = PolicySearch(params, eval_fn, slo, space, n_trials=6,
                          key=jax.random.PRNGKey(11))
    res = search.search()
    assert res.slo_met and res.accuracy >= res.floor
    # only "a" needs protection; "b" stays at the cheap end
    assert res.assignment["a"]["protect"] == "one4n"
    assert res.assignment["b"]["protect"] == "none"
    # strictly cheaper than uniform One4N, costed on the same pytree
    uniform_bits = PolicySearch(params, eval_fn, slo, key=jax.random.PRNGKey(1)
                                )._result(ReliabilityPolicy(
                                    default=PolicyRule(
                                        field="exponent_sign")),
                                    "uniform", 1.0, 1.0, 0.0, 0).stored_bits
    assert res.stored_bits < uniform_bits
    assert res.evals >= 2 and len(res.trace) >= 2


def test_policy_search_select_picks_cheapest_meeting_slo():
    params, eval_fn = _search_fixture()
    slo = AccuracySLO(ber=3e-3, max_drop=0.014)
    search = PolicySearch(params, eval_fn, slo, n_trials=6,
                          key=jax.random.PRNGKey(5))
    a_only = ReliabilityPolicy(rules=(
        PolicyRule("a", protect="one4n", field="exponent_sign"),
        PolicyRule("b", protect="none", field="exponent_sign")))
    uniform = ReliabilityPolicy(default=PolicyRule(field="exponent_sign"))
    res = search.select({"uniform": uniform, "a_only": a_only})
    assert res.slo_met and res.name == "a_only"
    # impossible floor -> most accurate arm, flagged unmet
    strict = PolicySearch(params, eval_fn,
                          AccuracySLO(ber=3e-3, min_accuracy=2.0,
                                      max_drop=0.0),
                          n_trials=2, key=jax.random.PRNGKey(6))
    res2 = strict.select({"uniform": uniform, "a_only": a_only})
    assert not res2.slo_met


def test_search_space_validates():
    with pytest.raises(ValueError, match="at least one"):
        SearchSpace(groups=())
    with pytest.raises(ValueError, match="duplicate"):
        SearchSpace(groups=(("g", "a"), ("g", "b")))
    with pytest.raises(ValueError, match="protects"):
        SearchSpace(groups=(("g", "*"),), protects=("bogus",))
    space = SearchSpace(groups=(("g", "*"),), protects=("none", "one4n"),
                        n_groups=(8, 16))
    assert len(space.candidates()) == 4


def test_search_policies_wrapper():
    from repro.core.resilience import search_policies
    params, eval_fn = _search_fixture()
    res = search_policies(params, eval_fn, ber=3e-3,
                          groups=(("a", "a"), ("b", "b")), max_drop=0.014,
                          n_trials=6, key=jax.random.PRNGKey(11),
                          protects=("none", "one4n"),
                          fields=("exponent_sign",))
    assert res.slo_met and res.assignment["a"]["protect"] == "one4n"


# ------------------------------------------------- forced-8-device identity

def _run(tmp_path, name, script, extra_env=None):
    path = tmp_path / name
    path.write_text(script)
    env = dict(os.environ, PYTHONPATH="src", **(extra_env or {}))
    out = subprocess.run([sys.executable, str(path)], capture_output=True,
                         text=True, env=env, cwd=os.getcwd(), timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


_TRAIN_STREAM_SCRIPT = textwrap.dedent("""
    import os
    if os.environ.get("CODESIGN_FORCE8") == "1":
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import hashlib
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import RunConfig, get_config
    from repro.core.deployment import PolicyRule, ReliabilityPolicy, path_str
    from repro.data.synthetic import MarkovLM
    from repro.training import steps as steps_lib
    from repro.training.loop import make_fault_schedule, run_training

    cfg = get_config("olmo-1b").reduced()
    policy = ReliabilityPolicy(
        rules=(PolicyRule("embed", protect="one4n"),
               PolicyRule("unembed", protect="none", field="mantissa",
                          ber_scale=0.5)),
        default=PolicyRule(deploy=False))
    run = RunConfig(arch="olmo-1b", steps=3, checkpoint_dir="", remat=False,
                    learning_rate=1e-3, warmup_steps=0, policy=policy,
                    ber=1e-3)
    state0 = steps_lib.init_train_state(jax.random.PRNGKey(run.seed), cfg,
                                        run)
    corrupt = make_fault_schedule(run)
    hashes = {}
    for step in range(3):
        k = jax.random.fold_in(jax.random.PRNGKey(run.seed + 17), step)
        faulty = corrupt(state0.params, k)
        for path, leaf in jax.tree_util.tree_flatten_with_path(faulty)[0]:
            hashes[f"{step}:{path_str(path)}"] = hashlib.sha256(
                np.asarray(jax.device_get(leaf)).tobytes()).hexdigest()

    mesh = None
    if os.environ.get("CODESIGN_FORCE8") == "1":
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model_axis=2)
    data = MarkovLM(cfg.vocab_size, 16, 8, seed=0)
    res = run_training(cfg, run, iter(data), state=state0, mesh=mesh)
    print(json.dumps({
        "devices": jax.device_count(),
        "mesh": None if mesh is None else
            {k: int(v) for k, v in mesh.shape.items()},
        "hashes": hashes,
        "losses": [h["loss"] for h in res.history]}))
""")


def test_training_streams_bit_identical_on_8_device_mesh(tmp_path):
    """Same (key, policy) -> per-leaf training fault streams hash equal on 1
    device and a forced-8-device (4, 2) ("data","model") mesh, and the loss
    curves of the data-sharded run match the single-device run."""
    ref = _run(tmp_path, "stream_1dev.py", _TRAIN_STREAM_SCRIPT)
    got = _run(tmp_path, "stream_8dev.py", _TRAIN_STREAM_SCRIPT,
               extra_env={"CODESIGN_FORCE8": "1"})
    assert ref["devices"] == 1 and got["devices"] == 8
    assert got["mesh"] == {"data": 4, "model": 2}
    assert ref["hashes"] == got["hashes"]   # bitwise stream identity
    np.testing.assert_allclose(ref["losses"], got["losses"],
                               rtol=5e-4, atol=5e-4)
