"""Equivalence suite for the word-packed CIM store and decode-on-read path.

Contracts under test:

* packed SECDED / One4N encode+decode are bit-exact with the per-bit oracle
  codecs across codec geometries, including check-bit flips, overall-parity
  flips and uncorrectable (>=2 flip) rows;
* ``pack -> inject -> read`` on the packed store equals the per-bit reference
  decode (``cim.read_reference``) bit-for-bit — weights AND corrected /
  uncorrectable stats — across (n_group, row_weights, protect, field);
* the fused ``cim_read`` kernel (static and per-read dynamic) equals
  decode-then-matmul, and its in-kernel dynamic flip streams equal
  ``cim.inject`` with the same key;
* packed planes store >= 4x fewer bytes than the per-bit representation, and
  the ``stored_bits`` accounting counts protected sign bits exactly once.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import align, bitpack, cim
from repro.core.ecc import One4NRowCodec, SecdedCode, residual_ber_after_secded
from repro.kernels.cim_read import ops as cr_ops
from repro.kernels.cim_read.ref import cim_read_ref
from repro.kernels.fault_inject.ops import ber_to_threshold


def _store(k, j, protect, n=8, rw=16, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, j)) * 0.1
    if protect == "per_weight":
        w16 = jnp.asarray(jnp.asarray(w, jnp.float16), jnp.float32)
        return cim.pack(w16, cim.CIMConfig(n_group=n, row_weights=rw,
                                           protect=protect)), w16
    w_al, _ = align.align_matrix(w, align.AlignmentConfig(n_group=n, index=2))
    return cim.pack(w_al, cim.CIMConfig(n_group=n, row_weights=rw,
                                        protect=protect)), w_al


def _assert_same(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert ((a == b) | (np.isnan(a) & np.isnan(b))).all()


# ---------------------------------------------------------------- ecc packed

@pytest.mark.parametrize("d", [6, 10, 96, 104, 160])
def test_secded_packed_matches_perbit(d):
    """Packed encode/decode == per-bit oracle under 0..3 random flips."""
    rng = np.random.default_rng(d)
    code = SecdedCode(d)
    data = jnp.asarray(rng.integers(0, 2, (32, d)), jnp.uint8)
    cw_bits = code.encode(data)
    cw_packed = code.encode_packed(bitpack.pack_bits_words(data, d))
    assert (np.asarray(bitpack.unpack_words(cw_packed, code.n))
            == np.asarray(cw_bits)).all()
    flips = np.zeros((32, code.n), np.uint8)
    for row in range(32):
        nf = rng.integers(0, 4)
        flips[row, rng.choice(code.n, size=nf, replace=False)] = 1
    d1, s1 = code.decode(cw_bits ^ jnp.asarray(flips))
    d2, s2 = code.decode_packed(
        cw_packed ^ bitpack.pack_bits_words(jnp.asarray(flips), code.n))
    assert (np.asarray(s1) == np.asarray(s2)).all()
    assert (np.asarray(d1) == np.asarray(bitpack.unpack_words(d2, d))).all()


@pytest.mark.parametrize("n,rw", [(8, 16), (4, 16), (16, 16), (8, 8), (8, 24)])
def test_one4n_packed_matches_perbit(n, rw):
    """Row-codec packed path == per-bit across geometries, with a data-bit
    flip in segment 0 and an overall-parity flip in the last segment."""
    rng = np.random.default_rng(n * 100 + rw)
    codec = One4NRowCodec(n_group=n, row_weights=rw, sign_bits_per_row=rw)
    exp_row = jnp.asarray(rng.integers(0, 32, (3, 2, rw)), jnp.uint8)
    signs = jnp.asarray(rng.integers(0, 2, (3, 2, n, rw)), jnp.uint8)
    cw_bits = codec.encode(exp_row, signs)
    cw_packed = codec.encode_packed(exp_row, codec.pack_signs(signs))
    assert (np.asarray(bitpack.unpack_words(cw_packed, codec.code.n))
            == np.asarray(cw_bits)).all()
    flip = np.zeros(cw_bits.shape, np.uint8)
    flip[..., 0, 5] = 1
    flip[..., codec.n_segments - 1, codec.code.n - 1] = 1
    e1, s1, st1 = codec.decode(cw_bits ^ jnp.asarray(flip))
    e2, sw2, st2 = codec.decode_packed(
        cw_packed ^ bitpack.pack_bits_words(jnp.asarray(flip), codec.code.n))
    assert (np.asarray(st1) == np.asarray(st2)).all()
    assert (np.asarray(e1) == np.asarray(e2)).all()
    assert (np.asarray(s1) == np.asarray(codec.unpack_signs(sw2))).all()


# ------------------------------------------------- store-level equivalence

@pytest.mark.parametrize("n,rw", [(8, 16), (4, 16), (16, 16), (8, 8)])
@pytest.mark.parametrize("protect", ["one4n", "none", "per_weight"])
def test_pack_read_roundtrip_geometries(protect, n, rw):
    store, w_ref = _store(4 * n, 3 * rw, protect, n=n, rw=rw)
    out, stats = cim.read(store)
    assert (np.asarray(out) == np.asarray(w_ref, np.float32)).all()
    assert int(stats["uncorrectable"]) == 0


@pytest.mark.parametrize("field", ["full", "mantissa", "exponent_sign"])
@pytest.mark.parametrize("protect", ["one4n", "none", "per_weight"])
def test_packed_inject_read_matches_perbit_oracle(protect, field):
    """The headline contract: packed pack->inject->read is bit-exact against
    the per-bit reference decode, including ECC stats, at BERs high enough to
    produce corrected AND uncorrectable rows (check-bit flips included —
    every codeword bit is a target cell)."""
    store, _ = _store(64, 48, protect)
    saw_corrected = saw_uncorrectable = False
    for i, ber in enumerate((1e-3, 1e-2, 0.05)):
        faulty = cim.inject(jax.random.PRNGKey(i), store, ber, field)
        a, sa = cim.read(faulty)
        b, sb = cim.read_reference(faulty)
        _assert_same(a, b)
        assert int(sa["corrected"]) == int(sb["corrected"])
        assert int(sa["uncorrectable"]) == int(sb["uncorrectable"])
        saw_corrected |= int(sa["corrected"]) > 0
        saw_uncorrectable |= int(sa["uncorrectable"]) > 0
    if protect != "none" and field != "mantissa":
        assert saw_corrected and saw_uncorrectable


@pytest.mark.parametrize("n,rw", [(8, 16), (4, 16), (8, 8)])
def test_packed_inject_read_matches_oracle_geometries(n, rw):
    store, _ = _store(4 * n, 3 * rw, "one4n", n=n, rw=rw, seed=3)
    faulty = cim.inject(jax.random.PRNGKey(1), store, 0.02, "full")
    a, sa = cim.read(faulty)
    b, sb = cim.read_reference(faulty)
    _assert_same(a, b)
    assert int(sa["corrected"]) == int(sb["corrected"])
    assert int(sa["uncorrectable"]) == int(sb["uncorrectable"])


def test_inject_rate_and_confinement_on_packed_planes():
    """Flip rate on codeword words matches Bernoulli(ber) over STORED bits
    only (padding lanes never flip), and mantissa-field injection leaves the
    codeword plane untouched."""
    store, _ = _store(256, 256, "one4n", seed=5)
    ber = 0.02
    faulty = cim.inject(jax.random.PRNGKey(2), store, ber, "exponent_sign")
    xor = np.asarray(faulty.codewords) ^ np.asarray(store.codewords)
    masks = store.cfg.codec.code.code_word_masks
    assert (xor & ~masks).max() == 0, "padding lanes must never flip"
    n_bits = int(np.prod(store.codewords.shape[:-1])) * store.cfg.codec.code.n
    rate = np.unpackbits(xor.view(np.uint8)).sum() / n_bits
    assert abs(rate - ber) < 5 * np.sqrt(ber * (1 - ber) / n_bits)
    assert (np.asarray(faulty.man) == np.asarray(store.man)).all()
    man_only = cim.inject(jax.random.PRNGKey(2), store, ber, "mantissa")
    assert (np.asarray(man_only.codewords) == np.asarray(store.codewords)).all()
    mxor = np.asarray(man_only.man) ^ np.asarray(store.man)
    assert (mxor & ~np.uint16(0x3FF)).max() == 0


def test_stored_bits_counts_protected_signs_once():
    """Regression (satellite): with protect='one4n' sign bits live inside the
    codewords ONLY — the overhead accounting must not add a sign plane."""
    store, _ = _store(64, 48, "one4n")
    b, g = 8, 3
    codec = store.cfg.codec
    assert store.stored_bits == 64 * 48 * 10 + b * g * codec.n_segments * codec.code.n
    raw, _ = _store(64, 48, "none")
    assert raw.stored_bits == 64 * 48 * 10 + 64 * 48 + b * 48 * 5
    # One4N overhead over unprotected = check bits only (paper Table III)
    assert store.stored_bits - (64 * 48 * 10 + 64 * 48 + b * 48 * 5) \
        == b * g * codec.redundant_bits_per_block


def test_packed_codeword_plane_bytes_at_least_4x_smaller():
    """Acceptance: >= 4x fewer bytes than one uint8 per codeword bit."""
    store, _ = _store(256, 256, "one4n")
    packed = store.codewords.size * store.codewords.dtype.itemsize
    perbit = int(np.prod(store.codewords.shape[:-1])) * store.cfg.codec.code.n
    assert perbit >= 4 * packed
    pw, _ = _store(64, 48, "per_weight")
    assert pw.cfg.pw_code.n >= 4 * pw.codewords.dtype.itemsize


def test_read_rows_matches_full_read():
    """Embedding-path row gather == rows of the full decode, static and
    dynamic (same counter streams as inject with the same key)."""
    idx = jnp.asarray([[0, 7, 13], [63, 32, 1]])
    key = jax.random.PRNGKey(11)
    thr = ber_to_threshold(0.01)
    for protect in ("one4n", "none", "per_weight"):
        store, _ = _store(64, 48, protect)
        rows = cim.read_rows(store, idx)
        full, _ = cim.read(store)
        _assert_same(rows, np.asarray(full)[np.asarray(idx)])
        rows_d = cim.read_rows(store, idx, seeds=cim.plane_seeds(key),
                               thr_man=thr, thr_meta=thr)
        full_d, _ = cim.read(cim.inject(key, store, 0.01, "full"))
        _assert_same(rows_d, np.asarray(full_d)[np.asarray(idx)])


def test_residual_ber_default_derives_from_codec():
    assert residual_ber_after_secded(1e-3) == \
        residual_ber_after_secded(1e-3, One4NRowCodec().code.n)
    custom = One4NRowCodec(n_group=4)
    assert residual_ber_after_secded(1e-3, codec=custom) == \
        residual_ber_after_secded(1e-3, custom.code.n)
    assert custom.code.n != One4NRowCodec().code.n


# ------------------------------------------------- fused decode-on-read

@pytest.mark.parametrize("shape", [(512, 128), (128, 256), (96, 48), (40, 24)])
@pytest.mark.parametrize("protect", ["one4n", "none"])
def test_fused_kernel_static_matches_reference(protect, shape):
    k, j = shape
    store, _ = _store(k, j, protect, seed=k + j)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, k))
    out, info = cr_ops.cim_linear_store(x, store, with_info=True)
    assert info["used_kernel"], "padding must keep the kernel path live"
    ref, _ = cim_read_ref(x, store)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_kernel_dynamic_matches_injected_reference():
    """In-kernel per-read flips == inject_with_seeds -> decode -> matmul."""
    seeds = cim.plane_seeds(jax.random.PRNGKey(3))
    thr = ber_to_threshold(0.003)
    sc = cr_ops.make_scalars(seeds, thr, thr)
    for protect in ("one4n", "none"):
        store, _ = _store(512, 128, protect, seed=9)
        x = jax.random.normal(jax.random.PRNGKey(4), (8, 512))
        out = cr_ops.cim_linear_store(x, store, scalars=sc)
        ref, _ = cim_read_ref(x, store, seeds=seeds, thr_man=thr, thr_meta=thr)
        # corrupted exponents make |w| huge; tolerate fp32 summation-order
        # noise relative to the row scale (weights themselves are checked
        # bit-exact via test_fused_dynamic_equals_static_injected_same_key)
        scale = float(np.abs(np.asarray(ref)).max())
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4 + 1e-6 * scale)


def test_fused_dynamic_equals_static_injected_same_key():
    """The serving contract: inject(key) into the image then serve statically
    == serve dynamically with plane_seeds(key) — identical PRNG streams."""
    key = jax.random.PRNGKey(7)
    thr = ber_to_threshold(0.003)
    store, _ = _store(512, 128, "one4n", seed=2)
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 512))
    a = cr_ops.cim_linear_store(x, cim.inject(key, store, 0.003, "full"))
    b = cr_ops.cim_linear_store(
        x, store, scalars=cr_ops.make_scalars(cim.plane_seeds(key), thr, thr))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_fused_per_weight_falls_back_with_signal():
    store, _ = _store(64, 48, "per_weight")
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64))
    out, info = cr_ops.cim_linear_store(x, store, with_info=True)
    assert not info["used_kernel"]
    ref, _ = cim_read_ref(x, store)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_lm_serves_from_packed_store():
    """End-to-end fused serving: CIMStore embed/unembed leaves drive prefill
    and decode, matching the decoded-weights (HBM) baseline exactly when the
    image is clean."""
    from repro.configs import get_config
    from repro.launch.serve import deploy_fused
    from repro.models import lm
    cfg = get_config("olmo-1b").reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    stores = deploy_fused(params, ber=0.0, protect="one4n", n_group=8,
                          index=2, key=key, inject_mode="static", field="full")
    # baseline: decode the stores back to fp16 weights, serve those
    decoded, _ = cim.read_pytree_impl(stores)
    tokens = jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]])
    lf, cf = lm.prefill(stores, cfg, {"tokens": tokens})
    lb, cb = lm.prefill(decoded, cfg, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lb),
                               rtol=2e-5, atol=2e-5)
    tok = jnp.argmax(lf, -1)[:, None]
    lf2, _ = lm.decode(stores, cfg, cf, tok)
    lb2, _ = lm.decode(decoded, cfg, cb, tok)
    np.testing.assert_allclose(np.asarray(lf2), np.asarray(lb2),
                               rtol=2e-5, atol=2e-5)
