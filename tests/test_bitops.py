import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # minimal installs: degrade to fixed-example sampling
    HAVE_HYPOTHESIS = False

from repro.core import bitops
from repro.core.bitops import BF16, FP16, FP32


def _examples(*fallback_cases, argnames):
    """hypothesis strategies when available, else fixed parametrized cases."""
    def deco(strategies):
        def wrap(fn):
            if HAVE_HYPOTHESIS:
                return settings(max_examples=100, deadline=None)(
                    given(*strategies())(fn))
            return pytest.mark.parametrize(argnames, list(fallback_cases))(fn)
        return wrap
    return deco


@pytest.mark.parametrize("fmt", [FP16, BF16, FP32])
def test_roundtrip_bits(fmt):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(256), fmt.float_dtype)
    y = bitops.from_bits(bitops.to_bits(x, fmt), fmt)
    assert (np.asarray(x) == np.asarray(y)).all()


@pytest.mark.parametrize("fmt", [FP16, BF16, FP32])
def test_split_combine_identity(fmt):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(512) * 10, fmt.float_dtype)
    s, e, m = bitops.split_fields(x, fmt)
    y = bitops.combine_fields(s, e, m, fmt)
    assert (np.asarray(x) == np.asarray(y)).all()


@_examples(6.2e-5, 0.125, 1.0, 1.5, 3.14159, 1024.7, 59999.0, argnames="v")(
    lambda: (st.floats(min_value=6e-5, max_value=60000.0, allow_nan=False),))
def test_fp16_field_semantics(v):
    """value == (-1)^s * 2^(e-15) * (1 + m/2^10) for normal fp16 numbers."""
    x = np.float16(v)
    if not np.isfinite(x) or x == 0:
        return
    s, e, m = (int(np.asarray(t)[0]) for t in bitops.split_fields(jnp.asarray([x]), FP16))
    if e == 0:
        return  # subnormal
    recon = (-1.0) ** s * 2.0 ** (e - 15) * (1 + m / 1024.0)
    assert np.isclose(recon, float(x), rtol=1e-6)


def test_field_positions():
    assert list(FP16.field_bit_positions("sign")) == [15]
    assert list(FP16.field_bit_positions("exponent")) == [10, 11, 12, 13, 14]
    assert len(FP16.field_bit_positions("mantissa")) == 10
    assert len(FP16.field_bit_positions("full")) == 16
    assert list(FP16.field_bit_positions("exponent_sign")) == list(range(10, 16))


def test_exponent_range_matches_fig5():
    ll, ul = bitops.exponent_range(jnp.asarray([15]), FP16)  # e=0
    assert float(ll[0]) == 1.0
    assert float(ul[0]) == 2.0 - 2.0 ** -10


@_examples((0, 1), (1, 1), (0b1011, 4), (0xBEEF, 16), (0x7FFF, 15),
           argnames="word,nbits")(
    lambda: (st.integers(min_value=0, max_value=2**16 - 1),
             st.integers(min_value=1, max_value=16)))
def test_pack_unpack_bits(word, nbits):
    word = word & ((1 << nbits) - 1)
    bits = bitops.unpack_bits(jnp.asarray([word]), nbits)
    assert bits.shape == (1, nbits)
    back = int(np.asarray(bitops.pack_bits(bits))[0])
    assert back == word


def test_quantize_fp8_monotone_and_exact_on_grid():
    x = jnp.asarray([0.0, 0.5, 1.0, 1.5, -2.0, 448.0])
    y = bitops.quantize_to_format(x, bitops.FP8_E4M3)
    assert np.allclose(np.asarray(y), np.asarray(x))  # all on e4m3 grid
    z = bitops.quantize_to_format(jnp.asarray([1.06]), bitops.FP8_E4M3)
    assert float(z[0]) in (1.0, 1.125)


@pytest.mark.parametrize("fmt", [bitops.FP8_E4M3, bitops.FP8_E5M2])
def test_fp8_pack_unpack_roundtrip(fmt):
    """Beyond-paper FP8 support: grid values survive pack->unpack exactly."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(512) * 4, jnp.float32)
    xq = bitops.quantize_to_format(x, fmt)
    back = bitops.from_bits(bitops.to_bits(xq, fmt), fmt)
    assert (np.asarray(back) == np.asarray(xq)).all()


def test_fp8_injection_field_confined():
    from repro.core import fault
    w = jnp.full((64, 32), 1.0, jnp.float32)
    out = fault.inject(jax.random.PRNGKey(1), w, 0.2, "mantissa", bitops.FP8_E4M3)
    # mantissa flips at exp=0 keep |w| within [1, 2)
    a = np.abs(np.asarray(out))
    assert (a >= 1.0).all() and (a < 2.0).all()
