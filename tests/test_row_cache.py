"""Decoded-row cache on CIM stores (fused static serving fast path).

Acceptance contract:

* a warmed cache serves ``dispatch_linear`` / ``dispatch_read_rows`` through
  the ``"cached"`` route, **bit-identical** to the fused kernel on the packed
  planes (autotuned grids are single-K-tile, i.e. a plain matmul);
* per-read dynamic injection (``scalars``/``seeds``) always bypasses the
  cache — per-request streams are keyed per read, never against a
  materialized image;
* ``CIMDeployment.inject`` invalidates: every store it rebuilds is
  cache-less, and re-warming decodes the NEW fault image. Derived
  deployments never bleed a stale cache back into their base;
* warming obeys ``PolicyRule.row_cache`` (embed tables opt out — sparse
  row-gather serving is the packed image's whole point) and the
  ``serving_params(row_cache=False)`` override; dynamic serving never warms;
* the serving engine returns bitwise-identical tokens/logits with and
  without the cache, solo and co-batched.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import align, cim
from repro.core import deployment as dep_lib
from repro.kernels.cim_read import ops as cr_ops
from repro.kernels.fault_inject.ops import ber_to_threshold
from repro.launch import engine as engine_lib
from repro.launch import serve as serve_lib
from repro.models import lm


def _bits(a):
    return np.asarray(jax.lax.bitcast_convert_type(
        jnp.asarray(a, jnp.float32), jnp.uint32))


def _dep(k=256, j=128, ber=1e-3, seed=0, **rule_kw):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, j)) * 0.1
    policy = dep_lib.ReliabilityPolicy(default=dep_lib.PolicyRule(**rule_kw))
    dep = policy.deploy({"w": w})
    if ber:
        dep = dep.inject(jax.random.PRNGKey(3), ber)
    return dep


def test_cache_hit_route_bitwise_identical_to_kernel():
    dep = _dep()
    store_c = dep.serving_params()["w"]
    assert store_c.cache is not None
    store_u = cim.drop_row_cache(store_c)
    assert store_u.cache is None
    assert (_bits(store_c.cache) == _bits(cim.read(store_u)[0])).all()
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 256))
    out_c, info_c = dep_lib.dispatch_linear(x, store_c, with_info=True)
    assert info_c["route"] == "cached" and not info_c["used_kernel"]
    out_u, info_u = dep_lib.dispatch_linear(x, store_u, with_info=True)
    assert info_u["used_kernel"]
    assert (_bits(out_c) == _bits(out_u)).all()


def test_read_rows_cache_hit_bitwise():
    dep = _dep()
    store_c = dep.serving_params()["w"]
    idx = jnp.asarray([0, 5, 255, 17, 5])
    rows_c = dep_lib.dispatch_read_rows(store_c, idx)
    rows_u = dep_lib.dispatch_read_rows(cim.drop_row_cache(store_c), idx)
    assert (_bits(rows_c) == _bits(rows_u)).all()


def test_dynamic_injection_bypasses_cache():
    dep = _dep(ber=0)
    store_c = dep.serving_params()["w"]
    seeds = cim.plane_seeds(jax.random.PRNGKey(9))
    thr = ber_to_threshold(0.01)
    sc = cr_ops.make_scalars(seeds, thr, thr)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 256))
    dyn_c, info = dep_lib.dispatch_linear(x, store_c, scalars=sc,
                                          with_info=True)
    assert info.get("route") != "cached" and info["used_kernel"]
    dyn_u = dep_lib.dispatch_linear(x, cim.drop_row_cache(store_c),
                                    scalars=sc)
    assert (_bits(dyn_c) == _bits(dyn_u)).all()
    static = dep_lib.dispatch_linear(x, store_c)
    assert (np.asarray(dyn_c) != np.asarray(static)).any(), \
        "dynamic faults must actually land"
    idx = jnp.asarray([3, 200, 3])
    rows_d = dep_lib.dispatch_read_rows(store_c, idx, seeds=seeds,
                                        thr_man=thr, thr_meta=thr)
    rows_u = dep_lib.dispatch_read_rows(cim.drop_row_cache(store_c), idx,
                                        seeds=seeds, thr_man=thr,
                                        thr_meta=thr)
    assert (_bits(rows_d) == _bits(rows_u)).all()


def test_inject_invalidates_and_rewarm_tracks_new_image():
    dep = _dep(ber=0)
    sp1 = dep.serving_params()
    c1 = sp1["w"].cache
    dep2 = dep.inject(jax.random.PRNGKey(5), 0.01)
    for _, _, s in dep2.store_leaves():
        assert s.cache is None, "inject must rebuild stores cache-less"
    sp2 = dep2.serving_params()
    c2 = sp2["w"].cache
    assert (_bits(c2) ==
            _bits(cim.read(cim.drop_row_cache(sp2["w"]))[0])).all()
    assert (np.asarray(c1) != np.asarray(c2)).any(), \
        "re-warmed cache must reflect the injected faults"
    # no bleed into the base deployment: its clean cache still decodes clean
    (_, _, base_store), = dep.store_leaves()
    assert (_bits(c1) == _bits(cim.read(base_store)[0])).all()


def test_policy_row_cache_opt_out_and_overrides():
    policy = dep_lib.ReliabilityPolicy(
        rules=(dep_lib.PolicyRule(pattern="embed", row_cache=False),),
        default=dep_lib.PolicyRule())
    w1 = jax.random.normal(jax.random.PRNGKey(0), (128, 64)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(1), (128, 64)) * 0.1
    dep = policy.deploy({"embed": w1, "unembed": w2})
    sp = dep.serving_params()
    assert sp["embed"].cache is None, "row_cache=False rule must not warm"
    assert sp["unembed"].cache is not None
    sp_off = dep.serving_params(row_cache=False)
    assert sp_off["embed"].cache is None and sp_off["unembed"].cache is None
    sp_dyn = dep.serving_params(dynamic_key=jax.random.PRNGKey(2), ber=1e-3)
    assert sp_dyn["embed"].cache is None and sp_dyn["unembed"].cache is None


def test_serving_policy_embed_packed_unembed_cached():
    """The launch-level fused policy: the embed table stays packed (row
    gathers decode on read), the unembed projection carries the cache."""
    cfg = get_config("olmo-1b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    stores = serve_lib.deploy_fused(params, ber=1e-4, protect="one4n",
                                    n_group=8, index=2,
                                    key=jax.random.PRNGKey(1),
                                    inject_mode="static", field="full")
    assert stores["embed"].cache is None
    assert stores["unembed"].cache is not None


def test_shard_and_derived_copies_no_stale_cache():
    dep = _dep()
    sp = dep.serving_params()
    mesh = jax.make_mesh((1,), ("model",))
    dep_sh = dep.shard(mesh)
    for _, _, s in dep_sh.store_leaves():
        assert s.cache is None, "shard() must not inherit a serving cache"
    # a warmed store survives explicit placement with a cache sharding
    placed = dep_lib.place_stores({"w": sp["w"]}, mesh)
    assert placed["w"].cache is not None
    assert (_bits(placed["w"].cache) == _bits(sp["w"].cache)).all()
    # cache is excluded from the SRAM image accounting
    assert sp["w"].stored_bytes == dep_sh.store_leaves()[0][2].stored_bytes


def test_engine_cached_vs_uncached_bitwise():
    """Solo and co-batched engine runs return bit-identical tokens, logits
    and ECC accounting whether the unembed cache is warmed or dropped."""
    cfg = get_config("olmo-1b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    cached = serve_lib.deploy_fused(params, ber=1e-3, protect="one4n",
                                    n_group=8, index=2,
                                    key=jax.random.fold_in(
                                        jax.random.PRNGKey(0), 1),
                                    inject_mode="static", field="full")
    uncached = jax.tree_util.tree_map(
        lambda s: cim.drop_row_cache(s) if cim._is_store(s) else s,
        cached, is_leaf=cim._is_store)
    assert any(s.cache is not None for s in jax.tree_util.tree_leaves(
        cached, is_leaf=cim._is_store) if cim._is_store(s))
    load = engine_lib.LoadGen(n_requests=3, prompt_lens=(3, 12),
                              gen_lens=(3, 5), vocab_size=256, seed=5)
    reqs = load.requests()

    def run(sparams, rs, n_slots=3):
        eng = engine_lib.Engine(cfg, sparams, n_slots=n_slots, max_len=24,
                                chunk=8, collect_logits=True)
        results, _ = eng.run(rs)
        return results

    co_c = run(cached, reqs)
    co_u = run(uncached, reqs)
    solo_c = run(cached, [reqs[0]], n_slots=1)
    solo_u = run(uncached, [reqs[0]], n_slots=1)
    for rid in (r.rid for r in reqs):
        assert co_c[rid].tokens == co_u[rid].tokens
        assert np.array_equal(co_c[rid].logits, co_u[rid].logits)
        assert co_c[rid].ecc == co_u[rid].ecc
    rid0 = reqs[0].rid
    assert solo_c[rid0].tokens == solo_u[rid0].tokens \
        == co_c[rid0].tokens
    assert np.array_equal(solo_c[rid0].logits, co_c[rid0].logits)
    assert np.array_equal(solo_u[rid0].logits, co_u[rid0].logits)
