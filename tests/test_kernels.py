"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import align, bitops, cim
from repro.kernels.bfp_matmul import ops as bfp_ops
from repro.kernels.bfp_matmul import ref as bfp_ref
from repro.kernels.bfp_matmul.kernel import bfp_matmul_pallas
from repro.kernels.cim_read import ops as cr_ops
from repro.kernels.cim_read.ref import cim_read_ref
from repro.kernels.fault_inject import ops as fi_ops
from repro.kernels.fault_inject import ref as fi_ref
from repro.kernels.fault_inject.kernel import fault_inject_pallas
from repro.kernels.fault_inject.ops import ber_to_threshold


def _packed(key, k, n, n_group=8, scale=0.05):
    w = jax.random.normal(key, (k, n)) * scale
    w_al, _ = align.align_matrix(w, align.AlignmentConfig(n_group=n_group, index=2))
    return bfp_ref.pack_bfp(w_al, n_group), w_al


# ---------------------------------------------------------------- bfp matmul

@pytest.mark.parametrize("m,k,n", [(128, 512, 128), (256, 1024, 256),
                                   (128, 2048, 384), (8, 512, 128)])
def test_bfp_matmul_shapes(m, k, n):
    (man, exp), w_al = _packed(jax.random.PRNGKey(m + k + n), k, n)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    out = bfp_ops.bfp_matmul(x, man, exp, block_m=min(128, m))
    ref = bfp_ref.bfp_matmul_ref(x, man, exp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # dequant path is bit-exact vs the aligned fp16 weights
    direct = x @ jnp.asarray(w_al, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_bfp_matmul_dtypes(xdtype):
    (man, exp), _ = _packed(jax.random.PRNGKey(0), 512, 128)
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 512)).astype(xdtype)
    out = bfp_ops.bfp_matmul(x, man, exp)
    ref = bfp_ref.bfp_matmul_ref(x, man, exp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_group", [4, 8, 16])
def test_bfp_matmul_group_sizes(n_group):
    (man, exp), _ = _packed(jax.random.PRNGKey(2), 512, 128, n_group=n_group)
    x = jax.random.normal(jax.random.PRNGKey(3), (128, 512))
    out = bfp_ops.bfp_matmul(x, man, exp, n_group=n_group)
    ref = bfp_ref.bfp_matmul_ref(x, man, exp, n_group)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bn,bk", [(128, 128, 512), (128, 256, 256),
                                      (64, 128, 1024)])
def test_bfp_matmul_block_shapes(bm, bn, bk):
    (man, exp), _ = _packed(jax.random.PRNGKey(4), 1024, 256)
    x = jax.random.normal(jax.random.PRNGKey(5), (128, 1024))
    out = bfp_matmul_pallas(x, man, exp, n_group=8, block_m=bm, block_n=bn,
                            block_k=bk, interpret=True)
    ref = bfp_ref.bfp_matmul_ref(x, man, exp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_pack_bfp_roundtrip_exact():
    (man, exp), w_al = _packed(jax.random.PRNGKey(6), 256, 64)
    deq = bfp_ref.dequant_ref(man, exp)
    assert (np.asarray(deq) == np.asarray(w_al, np.float32)).all()


def test_cim_linear_wrapper():
    (man, exp), w_al = _packed(jax.random.PRNGKey(7), 512, 128)
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 32, 512))
    out = bfp_ops.cim_linear(x, man, exp)
    ref = x.reshape(-1, 512) @ jnp.asarray(w_al, jnp.float32)
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 128), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(5, 72, 40), (3, 512, 130), (130, 520, 128)])
def test_cim_linear_pads_instead_of_falling_back(m, k, n):
    """Ragged M/K/N must be tile-padded, not silently dequantized — the
    used_kernel signal proves the Pallas path ran."""
    (man, exp), w_al = _packed(jax.random.PRNGKey(m + k), k, n)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    out, info = bfp_ops.cim_linear(x, man, exp, with_info=True)
    assert info["used_kernel"]
    ref = x @ jnp.asarray(w_al, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    out2, info2 = bfp_ops.cim_linear(x, man, exp, use_kernel=False,
                                     with_info=True)
    assert not info2["used_kernel"]
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- fault inject

@pytest.mark.parametrize("shape", [(256, 256), (512, 384), (128, 1024)])
@pytest.mark.parametrize("positions", [(15,), (10, 11, 12, 13, 14),
                                       tuple(range(16))])
def test_fault_inject_matches_ref(shape, positions):
    bits = jax.random.randint(jax.random.PRNGKey(0), shape, 0, 2 ** 16).astype(jnp.uint16)
    out = fi_ops.fault_inject_bits(bits, seed=3, ber=0.02, positions=positions)
    ref = fi_ref.fault_inject_ref(bits, seed=3, ber=0.02, positions=positions)
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_fault_inject_tiling_independent():
    bits = jax.random.randint(jax.random.PRNGKey(1), (512, 512), 0, 2 ** 16).astype(jnp.uint16)
    a = fault_inject_pallas(bits, seed=9, ber=0.01, positions=(10, 15),
                            block_r=512, block_c=512, interpret=True)
    b = fault_inject_pallas(bits, seed=9, ber=0.01, positions=(10, 15),
                            block_r=128, block_c=256, interpret=True)
    assert (np.asarray(a) == np.asarray(b)).all()


def test_fault_inject_rate_and_confinement():
    bits = jnp.zeros((1024, 512), jnp.uint16)
    positions = (10, 11, 12, 13, 14)
    out = fi_ops.fault_inject_bits(bits, seed=11, ber=0.05, positions=positions)
    xor = np.asarray(out)
    allowed = sum(1 << p for p in positions)
    assert (xor & ~np.uint16(allowed)).max() == 0
    flips = np.unpackbits(xor.view(np.uint8)).sum()
    n_bits = bits.size * len(positions)
    assert abs(flips / n_bits - 0.05) < 5 * np.sqrt(0.05 * 0.95 / n_bits)


# ------------------------------------------------- cim_read fused decode-read
#
# Bit-identity contract of the fused decode-on-read matmul: for EVERY grid the
# autotuner can pick (plus legacy fixed tiles), the kernel's output equals the
# packed decode path `cim.read` — itself locked to the per-bit
# `cim.read_reference` oracle — bitwise. One-hot activations make the matmul
# itself exact (each output element is one weight accumulated with zeros), so
# the probe checks decoded WEIGHT BITS through the kernel, not a tolerance.


def _cim_store(k, j, protect="one4n", n_group=8, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, j)) * 0.1
    w_al, _ = align.align_matrix(w, align.AlignmentConfig(n_group=n_group,
                                                          index=2))
    return cim.pack(w_al, cim.CIMConfig(n_group=n_group, protect=protect))


def _tile_matrix(store):
    """Every autotuned combo for this store plus legacy fixed tiles."""
    tiles = list(cr_ops.autotuned_tile_shapes(store))
    for fixed in ((64, 128, 128, False), (128, 256, 256, True)):
        if fixed not in tiles:
            tiles.append(fixed)
    return tiles


def _bits(a):
    return np.asarray(jax.lax.bitcast_convert_type(
        jnp.asarray(a, jnp.float32), jnp.uint32))


@pytest.mark.parametrize("protect", ["one4n", "none"])
def test_cim_read_parity_matrix(protect):
    """Kernel output is bit-identical to ``cim.read`` (locked to the per-bit
    ``read_reference`` oracle) for every autotuned + legacy tile shape."""
    store = _cim_store(512, 256, protect=protect)
    w_ref, _ = cim.read(store)
    w_oracle, _ = cim.read_reference(store)
    assert (_bits(w_ref) == _bits(w_oracle)).all()
    probe = jnp.eye(512, dtype=jnp.float32)          # one weight per output
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 512))
    want, _ = cim_read_ref(x, store)
    k_pad = store.man.shape[0]
    for bm, bn, bk, hoist in _tile_matrix(store):
        out, info = cr_ops.cim_linear_store(
            probe, store, block_m=bm, block_n=bn, block_k=bk, hoist=hoist,
            with_info=True)
        assert info["used_kernel"], (bm, bn, bk)
        assert (_bits(out) == _bits(w_ref)).all(), (bm, bn, bk, hoist)
        dense = cr_ops.cim_linear_store(x, store, block_m=bm, block_n=bn,
                                        block_k=bk, hoist=hoist)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        if bk >= k_pad:
            # single-K-tile grids keep a plain matmul's accumulation order
            assert (_bits(dense) == _bits(x @ w_ref)).all(), (bm, bn, bk)


@pytest.mark.parametrize("m,k,j", [(5, 72, 48), (3, 264, 130), (130, 520, 112)])
@pytest.mark.parametrize("protect", ["one4n", "none"])
def test_cim_read_ragged_shapes(m, k, j, protect):
    """Ragged M/K/J is tile-padded on the kernel path (used_kernel proves it),
    bit-identical to the packed decode; autotuned grids are single-K-tile so
    the dense product is exactly ``x @ read(store)``."""
    store = _cim_store(k, j, protect=protect, seed=m + k)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    out, info = cr_ops.cim_linear_store(x, store, with_info=True)
    assert info["used_kernel"]
    w_ref, _ = cim.read(store)
    # the kernel contracts over the TILE-padded K (zero x against zero
    # decoded rows); XLA's blocked dot reduction depends on the contraction
    # length, so the bitwise oracle is the matmul on the padded operands
    _, _, bk, _ = cr_ops.resolve_tiles(store, m)
    k_pad = store.man.shape[0]
    k_t = -(-k_pad // bk) * bk
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, k_t - k)))
    wp = jnp.pad(w_ref, ((0, k_t - k), (0, 0)))
    assert (_bits(out) == _bits(xp @ wp)).all()
    want, _ = cim_read_ref(x, store)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_group", [4, 8, 16])
@pytest.mark.parametrize("protect", ["one4n", "none"])
def test_cim_read_n_group_matrix(n_group, protect):
    store = _cim_store(128, 64, protect=protect, n_group=n_group,
                       seed=n_group)
    w_ref, _ = cim.read(store)
    w_oracle, _ = cim.read_reference(store)
    assert (_bits(w_ref) == _bits(w_oracle)).all()
    probe = jnp.eye(128, dtype=jnp.float32)
    out, info = cr_ops.cim_linear_store(probe, store, with_info=True)
    assert info["used_kernel"]
    assert (_bits(out) == _bits(w_ref)).all()


def test_cim_read_hoist_bitwise_invariant():
    """The decode-hoisted grid (VMEM strip decoded once at i==0, reused on
    every M-revisit) returns the same bits as re-decoding per revisit AND as
    the plain matmul on the decoded matrix."""
    store = _cim_store(512, 256)
    x = jax.random.normal(jax.random.PRNGKey(2), (256, 512))
    hoisted = cr_ops.cim_linear_store(x, store, block_m=64, hoist=True)
    rescan = cr_ops.cim_linear_store(x, store, block_m=64, hoist=False)
    assert (_bits(hoisted) == _bits(rescan)).all()
    w_ref, _ = cim.read(store)
    assert (_bits(hoisted) == _bits(x @ w_ref)).all()


@pytest.mark.parametrize("protect", ["one4n", "none"])
def test_cim_read_dynamic_stream_identity(protect):
    """Per-read dynamic injection draws flip streams bit-identical to the
    host ``cim.inject_with_seeds`` for the same key: dynamic kernel output ==
    static kernel output on the pre-injected image, for every autotuned +
    legacy tile shape (same grid -> same accumulation order -> bitwise)."""
    store = _cim_store(256, 128, protect=protect)
    key = jax.random.PRNGKey(7)
    seeds = cim.plane_seeds(key)
    thr = ber_to_threshold(0.003)
    host = cim.inject_with_seeds(store, seeds, thr, thr)
    w_host, _ = cim.read(host)
    scalars = cr_ops.make_scalars(seeds, thr, thr)
    x = jax.random.normal(jax.random.PRNGKey(8), (16, 256))
    k_pad = store.man.shape[0]
    for bm, bn, bk, hoist in _tile_matrix(store):
        dyn = cr_ops.cim_linear_store(x, store, scalars=scalars, block_m=bm,
                                      block_n=bn, block_k=bk, hoist=hoist)
        static = cr_ops.cim_linear_store(x, host, block_m=bm, block_n=bn,
                                         block_k=bk, hoist=hoist)
        assert (_bits(dyn) == _bits(static)).all(), (bm, bn, bk, hoist)
        if bk >= k_pad:
            assert (_bits(dyn) == _bits(x.astype(jnp.float32)
                                        @ w_host)).all(), (bm, bn, bk)


def test_fault_inject_fp16_field_semantics():
    w = jnp.full((256, 256), 1.0, jnp.float32)
    out = fi_ops.fault_inject_fp16(w, seed=5, ber=0.01, field="exponent")
    xor = np.asarray(bitops.to_bits(out) ^ bitops.to_bits(w)).astype(np.uint32)
    assert (xor & ~np.uint32(0x7C00)).max() == 0
    assert xor.sum() > 0
